"""Stable public API surface.

Everything a downstream user needs rides this one module::

    from repro import api

    opt = api.Kfac(api.KfacConfig(...), taps)
    params, state = api.run_kfac_training(
        loss_fn, opt, params, batches, n_tokens=...,
        dist=api.DistSpec(mesh=mesh, curvature_axis="curv"),
        obs=api.ObsSpec(writer=api.TelemetryWriter("events.jsonl")),
        ckpt=api.CkptSpec(dir="ckpt"))

    bank = api.TenantBank(opt)              # stacked multi-tenant states
    svc = api.TenantService(lm, opt, params, n_tenants=8)

Internal module paths (``repro.core.*``, ``repro.train.*``, …) remain
importable but are NOT covered by the deprecation policy; symbols listed
in ``__all__`` here are.  Legacy loose kwargs on the training entry
points keep working for one deprecation cycle (a ``DeprecationWarning``
points at the spec replacement) — see docs/api.md for the mapping.
"""
from __future__ import annotations

# optimizer core
from repro.core.kfac import Kfac, KfacConfig, KfacState, TapInfo
from repro.core.policy import PolicyConfig
from repro.core.schedule import Scheduler, StepWork, group_by_work
from repro.core.tenant import TenantBank, tree_stack, tree_unstack

# typed option specs (PR 10 API consolidation)
from repro.specs import CkptSpec, DistSpec, ObsSpec, ResilienceSpec

# training entry points
from repro.train.loop import (kfac_grads, make_scheduled_kfac_step,
                              run_kfac_training)
from repro.launch.steps import build_train_step, default_kfac_config

# serving
from repro.serve.engine import Engine, Request
from repro.serve.service import FinetuneRequest, TenantService

# observability
from repro.obs import TelemetryWriter

__all__ = [
    # optimizer
    "Kfac", "KfacConfig", "KfacState", "PolicyConfig", "TapInfo",
    "Scheduler", "StepWork", "group_by_work",
    # multi-tenant
    "TenantBank", "tree_stack", "tree_unstack",
    "TenantService", "FinetuneRequest",
    # specs
    "DistSpec", "ObsSpec", "CkptSpec", "ResilienceSpec",
    # training
    "run_kfac_training", "make_scheduled_kfac_step", "kfac_grads",
    "build_train_step", "default_kfac_config",
    # serving
    "Engine", "Request",
    # observability
    "TelemetryWriter",
]

"""Deterministic, shardable synthetic data pipelines (offline container —
no external datasets).

* ``TokenStream``  — seeded LM token batches with learnable structure
  (a fixed random bigram teacher, so CE can actually drop below uniform).
* ``ImageStream``  — CIFAR-like labeled images from a fixed random teacher
  network (linearly separable enough for accuracy curves — the paper's
  VGG16_bn experiment runs on these).

Both are stateless functions of (seed, step) so any worker can regenerate
any batch after a restart — the data side of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    structure: float = 0.7      # prob of following the bigram teacher

    def _teacher(self) -> Array:
        key = jax.random.PRNGKey(self.seed)
        return jax.random.randint(key, (self.vocab,), 0, self.vocab)

    def batch_at(self, step: int) -> Dict[str, Array]:
        """Batch for a given step — deterministic, restart-safe."""
        nxt = self._teacher()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        k1, k2, k3 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (self.batch, 1), 0, self.vocab)
        noise = jax.random.randint(k2, (self.batch, self.seq_len), 0,
                                   self.vocab)
        follow = jax.random.bernoulli(k3, self.structure,
                                      (self.batch, self.seq_len))

        def step_fn(tok, inp):
            nz, fl = inp
            new = jnp.where(fl, nxt[tok], nz)
            return new, new

        _, toks = jax.lax.scan(step_fn, first[:, 0],
                               (noise.T, follow.T))
        tokens = jnp.concatenate([first, toks.T], axis=1)[:, : self.seq_len]
        return {"tokens": tokens, "targets": tokens}

    def __iter__(self) -> Iterator[Dict[str, Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class ImageStream:
    """(B, 32, 32, 3) images, 10 classes, from a random linear teacher."""
    batch: int
    seed: int = 0
    n_classes: int = 10
    margin: float = 2.0

    def batch_at(self, step: int) -> Tuple[Array, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 17), step)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (self.batch, 32, 32, 3))
        wkey = jax.random.PRNGKey(self.seed + 29)
        W = jax.random.normal(wkey, (32 * 32 * 3, self.n_classes))
        logits = x.reshape(self.batch, -1) @ W / np.sqrt(32 * 32 * 3)
        y = jnp.argmax(logits + jax.random.normal(
            k2, logits.shape) / self.margin, axis=-1)
        return x, y

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

"""Multi-tenant continuous fine-tuning service.

One :class:`~repro.core.tenant.TenantBank` holds N per-tenant adapter
param sets + optimizer states as stacked pytrees; the service admits a
mixed stream of **fine-tune** requests (a small training batch against
one tenant's adapter) and **inference** requests (decode under one
tenant's adapter), and batches both *across tenants* per tick:

* Fine-tune: tenants with a pending batch are grouped by their
  scheduler-derived :class:`~repro.core.schedule.StepWork` mask
  (:func:`repro.core.schedule.group_by_work` — each tenant keeps its own
  schedule position, so a freshly admitted tenant fires its warmup heavy
  step while veterans ride their staggered cadence) and each group runs
  as ONE stacked ``TenantBank.update`` with an ``active`` lane mask: the
  launch-group count per tick is O(#distinct masks × #shape classes),
  independent of the number of tenants.
* Inference: requests ride the engine's per-slot decode lanes with
  ``lane_params_fn`` gathering each slot's **tenant params** out of the
  stacked tree — different tenants' decodes share one batched launch.

Checkpoints stream through the schema-v6 manifest: the stacked
{params, opt} tree plus a first-class ``tenants`` table mapping each
tenant id to its bank slot and local step, so a restore re-seats every
tenant at its own schedule position (``TenantService.restore``).

Telemetry: ``serve_request`` events (with a ``tenant`` field) for both
request kinds, ``tenant_update`` events per fine-tune step, and
``latency_report()`` p50/p99 over each stream — the numbers the
synthetic load generator (serve/load.py) publishes.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kfac as kfac_lib
from repro.core import schedule
from repro.core import tenant as tenant_lib
from repro.models import layers
from repro.models.lm import LM
from repro.serve import engine as engine_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import loop as loop_lib


@dataclasses.dataclass
class FinetuneRequest:
    """One fine-tune step's worth of data for one tenant.  ``batch`` must
    match the service's fixed fine-tune batch shapes (jit stability)."""
    uid: int
    tenant: int
    batch: Dict[str, np.ndarray]
    loss: float = float("nan")
    step: int = -1                      # tenant-local step it executed as
    t_submit: float = 0.0
    t_done: float = 0.0


class TenantService:
    """N tenants, one stacked bank, mixed fine-tune/inference traffic.

    ``submit`` takes either an :class:`repro.serve.engine.Request` (its
    ``tenant`` field names the adapter to decode under) or a
    :class:`FinetuneRequest`; ``tick()`` advances both streams one step;
    ``run_until_drained()`` loops until all queues empty."""

    def __init__(self, lm: LM, opt: kfac_lib.Kfac, base_params,
                 n_tenants: int, ft_batch: int = 2, ft_seq: int = 16,
                 batch_slots: int = 4, max_len: int = 64,
                 eos_id: Optional[int] = None, seed: int = 0,
                 writer=None, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 0, ckpt_keep: int = 3):
        self.lm = lm
        self.opt = opt
        self.n = n_tenants
        self.ft_shape = (ft_batch, ft_seq)
        self.n_tokens = ft_batch * ft_seq
        self.writer = writer
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self.bank = tenant_lib.TenantBank(opt)
        # every tenant starts from the shared base adapter; their stacks
        # diverge as fine-tune traffic lands
        self.params = tenant_lib.tree_stack([base_params] * n_tenants)
        self.state = self.bank.init(self.params)
        self.steps: List[int] = [0] * n_tenants   # per-tenant local step
        self.sched = opt.scheduler()
        self._key = jax.random.PRNGKey(seed)
        self._ft_queue: "queue.Queue[FinetuneRequest]" = queue.Queue()
        self.completed_ft: Dict[int, FinetuneRequest] = {}
        self.ticks = 0
        self.engine = engine_lib.Engine(
            lm, None, batch_slots=batch_slots, max_len=max_len,
            eos_id=eos_id, seed=seed + 1, writer=writer,
            lane_params_fn=self._lane_params)
        self._tick_fn = jax.jit(self._train_tick,
                                static_argnames=("work",))

    # -- jitted fine-tune tick ---------------------------------------------

    def _train_tick(self, params, state, batch, rngs, active, work):
        def grads_one(p, b):
            probes = layers.make_probes(self.opt.taps, jnp.float32)
            return loop_lib.kfac_grads(self.lm.loss_fn, p, probes, b)

        loss, acts, gp, gprobe = jax.vmap(grads_one)(params, batch)
        updates, state = self.bank.update(
            gp, state, params, acts=acts, probe_grads=gprobe,
            n_tokens=self.n_tokens, rngs=rngs, work=work, active=active)
        params = self.bank.apply_updates(params, updates, active=active)
        return params, state, loss

    # -- inference lane params ---------------------------------------------

    def _lane_params(self, slots):
        idx = np.zeros((len(slots),), np.int32)
        for i, req in enumerate(slots):
            if req is not None and req.tenant is not None:
                idx[i] = int(req.tenant)
        gather = jnp.asarray(idx)
        return jax.tree_util.tree_map(lambda x: x[gather], self.params)

    # -- admission ----------------------------------------------------------

    def submit(self, req):
        if isinstance(req, FinetuneRequest):
            if not 0 <= req.tenant < self.n:
                raise ValueError(f"unknown tenant {req.tenant} "
                                 f"(bank holds {self.n})")
            req.t_submit = time.time()
            self._ft_queue.put(req)
        else:
            if req.tenant is None:
                req.tenant = 0
            if not 0 <= req.tenant < self.n:
                raise ValueError(f"unknown tenant {req.tenant} "
                                 f"(bank holds {self.n})")
            self.engine.submit(req)

    def _admit_finetunes(self) -> Dict[int, FinetuneRequest]:
        """Pop at most one pending fine-tune per tenant for this tick
        (a tenant's later batches stay queued, FIFO — its optimizer
        state must advance one step at a time)."""
        picked: Dict[int, FinetuneRequest] = {}
        requeue = []
        while not self._ft_queue.empty():
            req = self._ft_queue.get()
            if req.tenant in picked:
                requeue.append(req)
            else:
                picked[req.tenant] = req
        for req in requeue:
            self._ft_queue.put(req)
        return picked

    # -- the tick ------------------------------------------------------------

    def tick(self):
        """One service tick: all pending fine-tunes (grouped by work
        mask, one stacked launch per distinct mask) + one decode step."""
        picked = self._admit_finetunes()
        if picked:
            tenants = sorted(picked)
            groups = schedule.group_by_work(
                self.sched, [self.steps[t] for t in tenants])
            batch = self._stack_batches(picked)
            self._key, sub = jax.random.split(self._key)
            rngs = jax.random.split(sub, self.n)
            for work, idx in sorted(groups.items(),
                                    key=lambda kv: kv[1]):
                group = [tenants[i] for i in idx]
                active = np.zeros((self.n,), bool)
                active[group] = True
                self.params, self.state, loss = self._tick_fn(
                    self.params, self.state, batch, rngs,
                    jnp.asarray(active), work)
                loss = np.asarray(loss)
                for t in group:
                    req = picked[t]
                    req.loss = float(loss[t])
                    req.step = self.steps[t]
                    req.t_done = time.time()
                    self.steps[t] += 1
                    self.completed_ft[req.uid] = req
                    if self.writer is not None:
                        self.writer.emit(
                            "tenant_update", tenant=t, step=req.step,
                            loss=req.loss, phase=work.label)
                        self.writer.emit(
                            "serve_request", uid=req.uid,
                            wait_s=req.t_done - req.t_submit,
                            total_s=req.t_done - req.t_submit,
                            n_new=0, tenant=t, kind="finetune")
        if (not self.engine._queue.empty()
                or any(s is not None for s in self.engine._slots)):
            self.engine.step()
        self.ticks += 1
        if (self.ckpt_dir is not None and self.ckpt_every > 0
                and self.ticks % self.ckpt_every == 0):
            self.save_checkpoint()

    def _stack_batches(self, picked: Dict[int, FinetuneRequest]):
        """(N, B_ft, T_ft) stacked batch — lanes without a request get
        zeros (they are masked inactive; vmap is dense)."""
        B, T = self.ft_shape
        out = {"tokens": np.zeros((self.n, B, T), np.int32),
               "targets": np.zeros((self.n, B, T), np.int32)}
        for t, req in picked.items():
            for k in out:
                arr = np.asarray(req.batch[k])
                if arr.shape != (B, T):
                    raise ValueError(
                        f"tenant {t} batch {k!r} has shape {arr.shape}; "
                        f"the service's fine-tune cell is {(B, T)}")
                out[k][t] = arr
        return {k: jnp.asarray(v) for k, v in out.items()}

    # -- draining / reporting ------------------------------------------------

    def pending(self) -> bool:
        return (not self._ft_queue.empty()
                or not self.engine._queue.empty()
                or any(s is not None for s in self.engine._slots))

    def run_until_drained(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks

    def latency_report(self) -> Dict[str, Any]:
        """p50/p99 per stream + per-tenant request counts."""
        def pcts(xs):
            xs = sorted(xs)
            if not xs:
                return {"requests": 0}
            pct = lambda q: xs[min(len(xs) - 1,
                                   int(round(q * (len(xs) - 1))))]
            return {"requests": len(xs), "p50_s": pct(0.5),
                    "p99_s": pct(0.99)}

        per_tenant: Dict[int, int] = {}
        for r in self.completed_ft.values():
            per_tenant[r.tenant] = per_tenant.get(r.tenant, 0) + 1
        for r in self.engine.completed.values():
            t = r.tenant or 0
            per_tenant[t] = per_tenant.get(t, 0) + 1
        return {
            "infer": self.engine.latency_report(),
            "finetune": pcts([r.t_done - r.t_submit
                              for r in self.completed_ft.values()]),
            "tenants": {str(t): c for t, c in sorted(per_tenant.items())},
            "steps": list(self.steps),
        }

    # -- checkpoint streaming ------------------------------------------------

    def tenant_table(self) -> List[dict]:
        return [{"tenant": t, "slot": t, "step": int(self.steps[t])}
                for t in range(self.n)]

    def save_checkpoint(self) -> Optional[str]:
        if self.ckpt_dir is None:
            return None
        path = ckpt_lib.save(self.ckpt_dir, self.ticks,
                             {"params": self.params, "opt": self.state},
                             tenants=self.tenant_table())
        ckpt_lib.prune(self.ckpt_dir, keep=self.ckpt_keep)
        if self.writer is not None:
            self.writer.emit("ckpt_save", step=self.ticks, path=path)
        return path

    def restore(self, directory: Optional[str] = None):
        """Re-seat the bank from the newest healthy snapshot: stacked
        params/state plus each tenant's local step out of the manifest's
        v6 ``tenants`` table (absent in pre-v6 manifests → steps reset)."""
        directory = directory or self.ckpt_dir
        tree, manifest = ckpt_lib.restore_latest_healthy(
            directory, {"params": self.params, "opt": self.state})
        self.params, self.state = tree["params"], tree["opt"]
        table = manifest.get("tenants") or []
        for row in table:
            self.steps[int(row["slot"])] = int(row["step"])
        return manifest

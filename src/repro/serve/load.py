"""Synthetic mixed-traffic load generator for the multi-tenant service.

Drives a :class:`~repro.serve.service.TenantService` over a reduced LM
with N tenants and a mixed fine-tune/inference request stream submitted
in waves, then publishes the latency report:

    PYTHONPATH=src python -m repro.serve.load \\
        --tenants 4 --waves 3 --infer-per-wave 4 --ft-per-wave 4 \\
        --telemetry-dir telem-serve

Outputs (CI's serve-tier job consumes both):
  * ``<telemetry-dir>/events.jsonl``  — schema-validated ``serve_request``
    / ``tenant_update`` / ``ckpt_save`` events
    (``repro.obs.summary --validate`` gates them)
  * ``<telemetry-dir>/latency.json``  — p50/p99 per stream + per-tenant
    request counts (uploaded as the latency artifact)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.launch.steps import default_kfac_config
from repro.core import kfac as kfac_lib
from repro.models.lm import LM
from repro.obs import TelemetryWriter
from repro.serve.engine import Request
from repro.serve.service import FinetuneRequest, TenantService


def build_service(tenants: int = 4, variant: str = "bkfac",
                  arch_name: str = "gemma3_4b", seed: int = 0,
                  writer=None, ckpt_dir=None, ckpt_every: int = 0,
                  ft_batch: int = 2, ft_seq: int = 16,
                  batch_slots: int = 4, max_len: int = 48):
    arch = get_arch(arch_name).reduced()
    lm = LM(arch, remat=False)
    params = lm.init(jax.random.PRNGKey(seed))
    # Fine-tune cadence: the pretrain defaults refresh decompositions
    # every T_updt=25 steps, which leaves the warm-start spectrum empty
    # (near-zero eigenvalues -> the global-norm clip zeroes the first
    # T_updt updates entirely).  A fine-tune tenant takes few, precious
    # steps, so refresh every step and keep heavy passes frequent.
    cfg = dataclasses.replace(
        default_kfac_config(arch, variant),
        T_updt=1, T_brand=1, T_inv=2, T_rsvd=2, T_corct=4)
    opt = kfac_lib.Kfac(cfg, lm.taps)
    svc = TenantService(lm, opt, params, tenants, ft_batch=ft_batch,
                        ft_seq=ft_seq, batch_slots=batch_slots,
                        max_len=max_len, seed=seed, writer=writer,
                        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
    return svc, arch


def run_load(svc: TenantService, vocab: int, waves: int = 3,
             infer_per_wave: int = 4, ft_per_wave: int = 4,
             ticks_between: int = 4, seed: int = 0,
             max_ticks: int = 2000) -> int:
    """Submit ``waves`` rounds of mixed traffic (tenants round-robin),
    ticking between rounds so requests overlap in flight — staggered
    admission is exactly what the per-slot/per-tenant paths must get
    right.  Returns total ticks run."""
    rng = np.random.default_rng(seed)
    B, T = svc.ft_shape
    uid = 0
    total = 0
    for w in range(waves):
        for i in range(infer_per_wave):
            t = (w * infer_per_wave + i) % svc.n
            prompt = rng.integers(1, vocab, size=rng.integers(2, 6)).tolist()
            svc.submit(Request(uid=uid, prompt=prompt, max_new=4,
                               tenant=t))
            uid += 1
        for i in range(ft_per_wave):
            t = (w * ft_per_wave + i) % svc.n
            batch = {
                "tokens": rng.integers(0, vocab, size=(B, T),
                                       dtype=np.int64).astype(np.int32),
                "targets": rng.integers(0, vocab, size=(B, T),
                                        dtype=np.int64).astype(np.int32),
            }
            svc.submit(FinetuneRequest(uid=uid, tenant=t, batch=batch))
            uid += 1
        for _ in range(ticks_between):
            svc.tick()
            total += 1
    total += svc.run_until_drained(max_ticks=max_ticks - total)
    return total


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--variant", default="bkfac")
    ap.add_argument("--arch", default="gemma3_4b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--infer-per-wave", type=int, default=4)
    ap.add_argument("--ft-per-wave", type=int, default=4)
    ap.add_argument("--ticks-between", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-dir", default="telem-serve")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="stream a v6 tenant-table checkpoint every N "
                         "ticks into <telemetry-dir>/ckpt (0 = off)")
    args = ap.parse_args(argv)

    os.makedirs(args.telemetry_dir, exist_ok=True)
    events = os.path.join(args.telemetry_dir, "events.jsonl")
    ckpt_dir = (os.path.join(args.telemetry_dir, "ckpt")
                if args.ckpt_every > 0 else None)
    with TelemetryWriter(events, console=False) as writer:
        writer.emit("run_start", config={
            "mode": "serve-load", "tenants": args.tenants,
            "variant": args.variant, "arch": args.arch,
            "waves": args.waves})
        svc, arch = build_service(
            args.tenants, variant=args.variant, arch_name=args.arch,
            seed=args.seed, writer=writer, ckpt_dir=ckpt_dir,
            ckpt_every=args.ckpt_every)
        ticks = run_load(svc, arch.vocab, waves=args.waves,
                         infer_per_wave=args.infer_per_wave,
                         ft_per_wave=args.ft_per_wave,
                         ticks_between=args.ticks_between,
                         seed=args.seed)
        report = svc.latency_report()
        report["ticks"] = ticks
        n_done = (report["infer"].get("requests", 0)
                  + report["finetune"].get("requests", 0))
        writer.emit("log", msg=f"serve load done: {n_done} requests over "
                               f"{args.tenants} tenants in {ticks} ticks")
    out = os.path.join(args.telemetry_dir, "latency.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))
    expect = args.waves * (args.infer_per_wave + args.ft_per_wave)
    assert n_done == expect, f"served {n_done}/{expect} requests"
    return report


if __name__ == "__main__":
    main()

"""Batched serving engine: continuous-batching decode loop over a KV-cache.

Small-model demo quality (the 32k/500k serving paths are exercised by the
dry-run): requests join a fixed-slot batch; prompts are fed token-by-token
through ``decode_step`` (prefill == forced decode), then sampled greedily /
by temperature until EOS or max_len; finished slots are refilled from the
queue.  Slot state (per-slot position, done flags) lives host-side; the
jitted step is shape-stable.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, lm: LM, params, batch_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 seed: int = 0, writer=None):
        self.lm = lm
        self.params = params
        self.B = batch_slots
        self.S = max_len
        self.eos = eos_id
        self.writer = writer      # repro.obs TelemetryWriter (optional)
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._slots: List[Optional[Request]] = [None] * batch_slots
        self._fed: List[int] = [0] * batch_slots      # prompt tokens fed
        self._pos: List[int] = [0] * batch_slots
        self._t_start: List[float] = [0.0] * batch_slots
        self._cache = lm.init_cache(batch_slots, max_len)
        self._key = jax.random.PRNGKey(seed)
        self._step = jax.jit(lm.decode_step)
        self.completed: Dict[int, Request] = {}

    def submit(self, req: Request):
        req.t_submit = time.time()
        self._queue.put(req)

    def _fill_slots(self):
        for i in range(self.B):
            if self._slots[i] is None and not self._queue.empty():
                self._slots[i] = self._queue.get()
                self._fed[i] = 0
                self._pos[i] = 0
                self._t_start[i] = time.time()

    def step(self):
        """One engine tick: one decode_step for the whole batch."""
        self._fill_slots()
        tokens = np.zeros((self.B, 1), np.int32)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            if self._fed[i] < len(req.prompt):
                tokens[i, 0] = req.prompt[self._fed[i]]
            elif req.out_tokens:
                tokens[i, 0] = req.out_tokens[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        # NOTE: slots share a position counter per slot; the cache is
        # per-slot so we step each active slot at its own position by
        # batching the most common position (demo simplification: all
        # slots advance together; empty slots decode garbage harmlessly)
        t = max(self._pos) if any(s is not None for s in self._slots) else 0
        logits, self._cache = self._step(self.params, self._cache,
                                         jnp.asarray(tokens),
                                         jnp.asarray(t, jnp.int32))
        logits = np.asarray(logits[:, 0], np.float32)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            self._pos[i] = t + 1
            if self._fed[i] < len(req.prompt):
                self._fed[i] += 1
                continue                      # still prefill — no sampling
            if req.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                tok = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i] / req.temperature)))
            else:
                tok = int(np.argmax(logits[i]))
            req.out_tokens.append(tok)
            done = (len(req.out_tokens) >= req.max_new or
                    (self.eos is not None and tok == self.eos) or
                    self._pos[i] >= self.S - 1)
            if done:
                req.t_done = time.time()
                self.completed[req.uid] = req
                self._slots[i] = None
                if self.writer is not None:
                    self.writer.emit(
                        "serve_request", uid=req.uid,
                        wait_s=self._t_start[i] - req.t_submit,
                        total_s=req.t_done - req.t_submit,
                        n_new=len(req.out_tokens))

    def latency_report(self) -> Dict[str, float]:
        """Request-latency percentiles over everything completed so far
        (same numbers ``repro.obs.summary`` derives from the
        ``serve_request`` events)."""
        tot = sorted(r.t_done - r.t_submit
                     for r in self.completed.values())
        if not tot:
            return {"requests": 0}
        pct = lambda q: tot[min(len(tot) - 1,
                                int(round(q * (len(tot) - 1))))]
        return {"requests": len(tot), "p50_s": pct(0.5),
                "p99_s": pct(0.99)}

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (not self._queue.empty() or
               any(s is not None for s in self._slots)):
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                break
        return ticks

"""Batched serving engine: continuous-batching decode loop over a KV-cache.

Requests join a fixed-slot batch; prompts are fed token-by-token through
``decode_step`` (prefill == forced decode), then sampled greedily / by
temperature until EOS or max_len; finished slots are refilled from the
queue.  Slot state (per-slot position, done flags) lives host-side; the
jitted step is shape-stable.

Each slot is an independent **lane**: the KV-cache carries a leading lane
axis (one B=1 cache per slot, stacked), and one jitted
``vmap(decode_step)`` advances every lane at its OWN position per tick.
That is what makes continuous batching correct — a request admitted into
a drained slot starts at position 0 while its neighbors keep decoding at
theirs, and produces exactly the tokens it would have produced alone
(tests/test_serve.py).  It is also what the multi-tenant service builds
on: with ``lane_params_fn`` set, the decoder maps params over the lane
axis too, so each slot can decode under a *different tenant's* weights in
the same batched launch (serve/service.py).

Empty lanes decode a dummy token at position 0; their cache writes are
overwritten position-by-position by the next admitted prompt before ever
being attended (decode at position t attends only 0..t, all re-fed).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


def init_lane_cache(lm: LM, lanes: int, max_len: int):
    """A stacked per-lane KV-cache: ``lanes`` independent B=1 caches on a
    NEW leading axis.  (``lm.init_cache(lanes, S)`` puts the batch dim
    *inside* each leaf — (repeats, B, ...) — which is the layout the
    shared-position decode wants, not the per-lane one.)"""
    return jax.vmap(lambda _: lm.init_cache(1, max_len))(jnp.arange(lanes))


def make_lane_decoder(lm: LM, batched_params: bool = False):
    """jit(vmap(decode_step)) over the lane axis: (params, lane_caches,
    tokens (L,), positions (L,)) → (logits (L, V), lane_caches) — every
    lane advances at its own position.  ``batched_params`` additionally
    maps params over the lane axis (per-tenant weights per slot)."""

    def lane(params, cache, tok, t):
        logits, cache = lm.decode_step(params, cache,
                                       tok[None, None], t)
        return logits[0, 0], cache

    return jax.jit(jax.vmap(
        lane, in_axes=(0 if batched_params else None, 0, 0, 0)))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    tenant: Optional[int] = None       # bank slot (multi-tenant service)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, lm: LM, params, batch_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 seed: int = 0, writer=None,
                 lane_params_fn: Optional[Callable] = None):
        self.lm = lm
        self.params = params
        self.B = batch_slots
        self.S = max_len
        self.eos = eos_id
        self.writer = writer      # repro.obs TelemetryWriter (optional)
        # lane_params_fn(slots) -> params stacked over the lane axis —
        # the multi-tenant hook: each slot decodes under the weights of
        # the tenant its request names.
        self._lane_params_fn = lane_params_fn
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._slots: List[Optional[Request]] = [None] * batch_slots
        self._fed: List[int] = [0] * batch_slots      # prompt tokens fed
        self._pos: List[int] = [0] * batch_slots
        self._t_start: List[float] = [0.0] * batch_slots
        self._cache = init_lane_cache(lm, batch_slots, max_len)
        self._key = jax.random.PRNGKey(seed)
        self._step = make_lane_decoder(
            lm, batched_params=lane_params_fn is not None)
        self.completed: Dict[int, Request] = {}

    def submit(self, req: Request):
        req.t_submit = time.time()
        self._queue.put(req)

    def _fill_slots(self):
        for i in range(self.B):
            if self._slots[i] is None and not self._queue.empty():
                self._slots[i] = self._queue.get()
                self._fed[i] = 0
                self._pos[i] = 0
                self._t_start[i] = time.time()

    def step(self):
        """One engine tick: one lane-vmapped decode_step for the batch."""
        self._fill_slots()
        tokens = np.zeros((self.B,), np.int32)
        ts = np.zeros((self.B,), np.int32)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            ts[i] = self._pos[i]
            if self._fed[i] < len(req.prompt):
                tokens[i] = req.prompt[self._fed[i]]
            elif req.out_tokens:
                tokens[i] = req.out_tokens[-1]
            else:
                tokens[i] = req.prompt[-1]
        params = (self._lane_params_fn(self._slots)
                  if self._lane_params_fn is not None else self.params)
        logits, self._cache = self._step(params, self._cache,
                                         jnp.asarray(tokens),
                                         jnp.asarray(ts))
        logits = np.asarray(logits, np.float32)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            self._pos[i] += 1
            if self._fed[i] < len(req.prompt):
                self._fed[i] += 1
                continue                      # still prefill — no sampling
            if req.temperature > 0:
                self._key, sub = jax.random.split(self._key)
                tok = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i] / req.temperature)))
            else:
                tok = int(np.argmax(logits[i]))
            req.out_tokens.append(tok)
            done = (len(req.out_tokens) >= req.max_new or
                    (self.eos is not None and tok == self.eos) or
                    self._pos[i] >= self.S - 1)
            if done:
                req.t_done = time.time()
                self.completed[req.uid] = req
                self._slots[i] = None
                if self.writer is not None:
                    extra = {} if req.tenant is None \
                        else {"tenant": int(req.tenant)}
                    self.writer.emit(
                        "serve_request", uid=req.uid,
                        wait_s=self._t_start[i] - req.t_submit,
                        total_s=req.t_done - req.t_submit,
                        n_new=len(req.out_tokens), **extra)

    def latency_report(self) -> Dict[str, float]:
        """Request-latency percentiles over everything completed so far
        (same numbers ``repro.obs.summary`` derives from the
        ``serve_request`` events)."""
        tot = sorted(r.t_done - r.t_submit
                     for r in self.completed.values())
        if not tot:
            return {"requests": 0}
        pct = lambda q: tot[min(len(tot) - 1,
                                int(round(q * (len(tot) - 1))))]
        return {"requests": len(tot), "p50_s": pct(0.5),
                "p99_s": pct(0.99)}

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (not self._queue.empty() or
               any(s is not None for s in self._slots)):
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                break
        return ticks

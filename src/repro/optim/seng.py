"""SENG baseline (Yang et al., 2021 — "Sketchy Empirical Natural Gradient")
— the paper's state-of-the-art comparison point (§6, benchmark 0).

Layer-wise *empirical* Fisher with per-example gradient factors.  For a
matmul layer, the per-example gradient is the rank-1 outer product
dW_i = a_i g_iᵀ, so the empirical Fisher solve reduces — via Woodbury — to
an n×n gram-matrix solve built from two small grams (no P×P matrix ever):

    (λI + (1/n) Σ vec(dW_i)vec(dW_i)ᵀ)⁻¹ vec(Ḡ)
      = (1/λ) [ Ḡ − (1/n) A diag(c) Gᵀ ],
    c  = (λ n I + K)⁻¹ t,
    K  = (AᵀA) ⊙ (GᵀG),        t_i = a_iᵀ Ḡ g_i,

with A (d_in, n), G (d_out, n) the tapped activation / probe-grad factors.
The "sketchy" part: n is a subsample of examples (the official impl's
``fim_col_sample_size``), and the factors are refreshed only every
``T_fim`` steps (``curvature_update_freq``) — between refreshes the cached
factors precondition fresh gradients.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.optim import adamw as _adamw
from repro.optim import base as optbase

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SengConfig:
    lr: optbase.Schedule = optbase.constant(0.05)
    damping: float = 2.0
    momentum: float = 0.9
    weight_decay: float = 1e-2
    T_fim: int = 200                 # curvature_update_freq
    fallback_lr: optbase.Schedule = optbase.constant(1e-3)

    def flags(self, step: int) -> Dict[str, bool]:
        return dict(do_fim=step % self.T_fim == 0)


class SengState(NamedTuple):
    step: Array
    factors: Dict[str, Any]          # name -> (A, G) cached factors
    momentum: Any
    fallback: Any


def _precondition(A, G, J, lam):
    """Woodbury empirical-NG solve; J = mean grad (d_in, d_out)."""
    n = A.shape[-1]
    K = (A.T @ A) * (G.T @ G)                     # (n, n)
    t = jnp.einsum("in,io,on->n", A, J, G)        # a_iᵀ J g_i
    c = jnp.linalg.solve(lam * n * jnp.eye(n, dtype=J.dtype) + K, t)
    correction = jnp.einsum("in,n,on->io", A, c, G)
    return (J - correction) / lam


class Seng:
    """Per-layer sketchy empirical NG over the same tap protocol as Kfac."""

    def __init__(self, cfg: SengConfig, taps: Dict[str, kfac_lib.TapInfo]):
        self.cfg = cfg
        self.taps = dict(taps)
        self._fallback = _adamw.adamw(cfg.fallback_lr)

    def init(self, params) -> SengState:
        factors = {}
        for name, t in self.taps.items():
            factors[name] = (
                jnp.zeros(t.stack + (t.d_in, t.n_stat), jnp.float32),
                jnp.zeros(t.stack + (t.d_out, t.n_stat), jnp.float32))
        mom = {n: jnp.zeros((t.d_in, t.d_out), jnp.float32)
               if not t.stack else
               jnp.zeros(t.stack + (t.d_in, t.d_out), jnp.float32)
               for n, t in self.taps.items()}
        return SengState(step=jnp.zeros((), jnp.int32), factors=factors,
                         momentum=mom, fallback=self._fallback.init(params))

    def update(self, grads, state: SengState, params, *, acts, probe_grads,
               n_tokens, rng=None, do_fim: bool = False):
        cfg = self.cfg
        lr = cfg.lr(state.step)
        factors = dict(state.factors)
        if do_fim:
            for name in self.taps:
                A = jnp.swapaxes(acts[name], -1, -2).astype(jnp.float32)
                G = (jnp.swapaxes(probe_grads[name], -1, -2)
                     .astype(jnp.float32) * jnp.asarray(n_tokens, jnp.float32))
                factors[name] = (A, G)

        updates = grads
        new_mom = dict(state.momentum)
        for name, t in self.taps.items():
            W = kfac_lib.get_path(params, t.param_path)
            J = kfac_lib.get_path(grads, t.param_path).astype(jnp.float32)
            A, G = factors[name]
            fn = _precondition
            for _ in t.stack:
                fn = jax.vmap(fn, in_axes=(0, 0, 0, None))
            S = fn(A, G, J, jnp.asarray(cfg.damping, jnp.float32))
            S = S + cfg.weight_decay * W.astype(jnp.float32)
            m = cfg.momentum * new_mom[name] + S
            new_mom[name] = m
            updates = kfac_lib.set_path(updates, t.param_path, m)

        tapped_paths = {t.param_path for t in self.taps.values()}
        fb_updates, fb_state = self._fallback.update(grads, state.fallback,
                                                     params)

        def finalize(path_keys, seng_u, fb_u):
            path = "/".join(str(k.key) for k in path_keys)
            if path in tapped_paths:
                return -lr * seng_u.astype(jnp.float32)
            return fb_u

        updates = jax.tree_util.tree_map_with_path(finalize, updates,
                                                   fb_updates)
        return updates, SengState(step=state.step + 1, factors=factors,
                                  momentum=new_mom, fallback=fb_state)

"""Optimizer interface + shared transforms (schedules, clipping, wd).

All optimizers follow the (init, update) functional convention:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, **aux)
    params = apply_updates(params, updates)

``updates`` are *additive deltas* (already scaled by -lr).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Schedule = Callable[[Array], Array]   # step -> value


def constant(v: float) -> Schedule:
    return lambda step: jnp.asarray(v, jnp.float32)


def piecewise(boundaries, values) -> Schedule:
    """Paper-style staircase schedules (α_k and φ_λ,k of §6)."""
    bs = jnp.asarray(boundaries, jnp.float32)
    vs = jnp.asarray(values, jnp.float32)

    def sched(step):
        idx = jnp.sum(jnp.asarray(step, jnp.float32) >= bs)
        return vs[idx]
    return sched


def paper_lr_schedule(steps_per_epoch: int) -> Schedule:
    """α_k = 0.3 − 0.1·[e≥2] − 0.1·[e≥3] − 0.07·[e≥13] − 0.02·[e≥18]
                − 0.007·[e≥27] − 0.002·[e≥40]   (paper §6)."""
    e = steps_per_epoch
    vals = [0.3, 0.2, 0.1, 0.03, 0.01, 0.003, 0.001]
    return piecewise([2 * e, 3 * e, 13 * e, 18 * e, 27 * e, 40 * e], vals)


def paper_damping_schedule(steps_per_epoch: int) -> Schedule:
    """φ_λ,k = 0.1 − 0.05·[e≥25] − 0.04·[e≥35]   (paper §6)."""
    e = steps_per_epoch
    return piecewise([25 * e, 35 * e], [0.1, 0.05, 0.01])


def global_norm(tree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: Array):
    """Scale the whole update tree so its global l2 norm ≤ max_norm
    (the paper's "clip parameter" applied to the preconditioned step)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda x: (x * scale).astype(x.dtype), tree)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                      ).astype(p.dtype), params, updates)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params, **aux) -> (upd, st)

"""SGD with momentum + decoupled weight decay (baseline substrate)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import base


class SgdState(NamedTuple):
    step: jax.Array
    momentum: object


def sgd(lr: base.Schedule, momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> base.Optimizer:
    def init(params):
        mom = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return SgdState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params, **_):
        a = lr(state.step)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g
            d = g + momentum * m_new if nesterov else m_new
            return -a * d, m_new

        flat = jax.tree_util.tree_map(upd, grads, state.momentum, params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda t: isinstance(t, tuple))
        mom = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
        return updates, SgdState(step=state.step + 1, momentum=mom)

    return base.Optimizer(init=init, update=update)

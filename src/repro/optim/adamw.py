"""AdamW — the fallback optimizer for non-tapped parameters (embeddings,
norms, biases) inside the K-FAC hybrid, and a standalone baseline."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import base


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def adamw(lr: base.Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> base.Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree_util.tree_map(zeros, params),
                          nu=jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params, **_):
        step = state.step + 1
        a = lr(state.step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / c1
            vhat = v_new / c2
            d = mhat / (jnp.sqrt(vhat) + eps) + \
                weight_decay * p.astype(jnp.float32)
            return -a * d, m_new, v_new

        istuple = lambda t: isinstance(t, tuple)
        flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
        pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], flat,
                                                is_leaf=istuple)
        return pick(0), AdamWState(step=step, mu=pick(1), nu=pick(2))

    return base.Optimizer(init=init, update=update)

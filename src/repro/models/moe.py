"""Mixture-of-Experts layer (deepseek-v3: 256 routed top-8 + 1 shared;
llama4-scout: 16 routed top-1).

Dispatch is sort-based (dropping, capacity-factor bounded): expanded
(token, expert) assignments are sorted by expert, positions within each
expert computed from segment offsets, tokens beyond capacity dropped.  This
avoids the O(N·E) one-hot dispatch tensors of the GShard formulation — the
only large intermediates are the (E, C, d) expert buffers, which shard as
(experts → model axis, capacity → data axes).

K-FAC taps: each expert matmul is tapped with an (E,)-stacked tap; expert
activations come from the first n_stat rows of each expert's buffer (the
paper's B-update applies per expert — forming per-expert dense factors for
256 experts would be impossible, the low-rank Brand states are not).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array


class MoeDims(NamedTuple):
    d_model: int
    d_ff: int             # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0     # shared-expert count (d_ff each)
    capacity_factor: float = 1.25
    router_softcap: float = 0.0


def route(x: Array, w_router: Array, dims: MoeDims
          ) -> Tuple[Array, Array, Array]:
    """Router: returns (weights (N,k), expert_idx (N,k), aux_loss)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (N, E)
    w, idx = jax.lax.top_k(probs, dims.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    E = dims.n_experts
    me = jnp.mean(probs, axis=0)                             # (E,)
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    fe = jnp.mean(onehot, axis=0)
    aux = E * jnp.sum(me * fe)
    return w.astype(jnp.float32), idx, aux


def dispatch(x: Array, idx: Array, dims: MoeDims, capacity: int):
    """Scatter tokens into per-expert buffers.

    x: (N, d); idx: (N, k). Returns (buffers (E, C, d), scatter_info)."""
    N, d = x.shape
    k = idx.shape[1]
    E, C = dims.n_experts, capacity
    flat_e = idx.reshape(-1)                                 # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    pos_in_e = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    buf_idx = jnp.where(keep, sorted_e * C + pos_in_e, E * C)
    token_of = order // k                                    # (N*k,)
    buffers = jnp.zeros((E * C + 1, d), x.dtype).at[buf_idx].set(
        x[token_of])
    buffers = buffers[: E * C].reshape(E, C, d)
    return buffers, (order, token_of, buf_idx, keep)


def combine(expert_out: Array, weights: Array, scatter_info, N: int
            ) -> Array:
    """Gather expert outputs back to token order with router weights."""
    order, token_of, buf_idx, keep = scatter_info
    E, C, d = expert_out.shape
    flat = jnp.concatenate(
        [expert_out.reshape(E * C, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    gathered = flat[buf_idx]                                 # (N*k, d)
    w_sorted = weights.reshape(-1)[order] * keep
    contrib = gathered.astype(jnp.float32) * w_sorted[:, None]
    y = jnp.zeros((N, d), jnp.float32).at[token_of].add(contrib)
    return y


def expert_ffn(buffers: Array, p: Dict, probes, acts, tag: str,
               n_stat: int) -> Array:
    """Vmapped gated-SiLU FFN over experts, with (E,)-stacked taps.

    buffers: (E, C, d). Params p: wi (E, d, 2*d_ff), wo (E, d_ff, d)."""
    E, C, d = buffers.shape

    def one(buf, wi, wo, probe_i, probe_o):
        h, act_i = layers.tapped_matmul(wi, buf, probe_i, n_stat)
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        y, act_o = layers.tapped_matmul(wo, h, probe_o, n_stat)
        return y, act_i, act_o

    pi = probes.get(f"{tag}/moe_wi")
    po = probes.get(f"{tag}/moe_wo")
    pi = pi if pi is not None else jnp.zeros((E, n_stat, p["wi"].shape[-1]),
                                             buffers.dtype)
    po = po if po is not None else jnp.zeros((E, n_stat, p["wo"].shape[-1]),
                                             buffers.dtype)
    y, act_i, act_o = jax.vmap(one)(buffers, p["wi"], p["wo"], pi, po)
    acts[f"{tag}/moe_wi"] = act_i
    acts[f"{tag}/moe_wo"] = act_o
    return y


def moe_block(x: Array, p: Dict, dims: MoeDims, probes, acts, tag: str,
              n_stat: int) -> Tuple[Array, Array]:
    """Full MoE FFN. x: (B, T, d) → (y, aux_loss)."""
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    w, idx, aux = route(xf, p["router"], dims)
    capacity = int(N * dims.top_k / dims.n_experts *
                   dims.capacity_factor + 1)
    capacity = max(8, min(capacity, N))
    buffers, info = dispatch(xf, idx, dims, capacity)
    expert_out = expert_ffn(buffers, p, probes, acts, tag, n_stat)
    y = combine(expert_out, w, info, N)
    if dims.n_shared > 0:
        h, act_i = layers.tapped_matmul(p["shared_wi"], xf,
                                        probes.get(f"{tag}/shared_wi"),
                                        n_stat)
        acts[f"{tag}/shared_wi"] = act_i
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
        sy, act_o = layers.tapped_matmul(p["shared_wo"], h,
                                         probes.get(f"{tag}/shared_wo"),
                                         n_stat)
        acts[f"{tag}/shared_wo"] = act_o
        y = y + sy.astype(jnp.float32)
    return y.reshape(B, T, d).astype(x.dtype), aux


def init_moe_params(key: Array, dims: MoeDims, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    E, d, f = dims.n_experts, dims.d_model, dims.d_ff
    p = {
        "router": layers.dense_init(ks[0], d, E, dtype=jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, 2 * f)) /
               jnp.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(ks[2], (E, f, d)) /
               jnp.sqrt(f)).astype(dtype),
    }
    if dims.n_shared > 0:
        fs = f * dims.n_shared
        p["shared_wi"] = layers.dense_init(ks[3], d, 2 * fs, dtype=dtype)
        p["shared_wo"] = layers.dense_init(ks[4], fs, d, dtype=dtype)
    return p

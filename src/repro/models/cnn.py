"""Conv nets with K-FAC taps — the paper's VGG16_bn experiment substrate.

Convolutions are expressed as im2col patches × a tapped matmul, which IS
the K-FAC conv approximation (Grosse & Martens 2016: A-factor over patch
vectors, n_M = B·H'·W' spatial samples).  Because n_M ≫ d for conv layers,
the policy engine automatically assigns them RSVD updates while wide FC
layers get B-updates — the paper's §3.5 mixture, reproduced structurally.

``make_vgg`` builds the paper's *modified* VGG16_bn: 2×1 pooling (instead
of 2×2) so FC0 widens 32× — 16384-in × 2048-out — putting the FC inverse
on the critical path exactly as in §6.  A ``depth`` knob scales the conv
stack for CPU benchmarking.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.kfac import TapInfo
from repro.models import layers

Array = jax.Array


def im2col(x: Array, k: int, stride: int = 1, pad: str = "SAME") -> Array:
    """(B, H, W, C) → (B, H', W', k*k*C) patch extraction."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches


def conv_tap(name, params, x, probes, acts, n_stat, k=3, stride=1):
    """Tapped conv layer: im2col + matmul + bias."""
    p = im2col(x, k, stride)
    B, H, W, D = p.shape
    flat = p.reshape(B * H * W, D)
    y, act = layers.tapped_matmul(params[name]["w"], flat,
                                  probes.get(name), n_stat)
    acts[name] = act
    y = y + params[name]["b"]
    return y.reshape(B, H, W, -1)


def batch_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


@dataclasses.dataclass(frozen=True)
class VggConfig:
    # channel plan per stage (paper VGG16: 64,128,256,512,512; scaled down
    # by `width` for CPU benches), convs per stage = 2
    stages: Tuple[int, ...] = (16, 32, 64)
    n_classes: int = 10
    fc_hidden: int = 512
    n_stat: int = 256
    pool: Tuple[int, int] = (2, 1)   # the paper's 2×1 pooling trick
    img: int = 32


def make_vgg(cfg: VggConfig):
    """Returns (init_fn, loss_fn, taps)."""
    conv_specs: List[Tuple[str, int, int]] = []   # (name, d_in_patch, c_out)
    c_in = 3
    for s, c in enumerate(cfg.stages):
        for j in range(2):
            conv_specs.append((f"conv{s}_{j}", 9 * c_in, c))
            c_in = c
    # spatial after pooling (2,1) per stage: H /= 2 each stage, W stays
    h = cfg.img // (2 ** len(cfg.stages))
    w = cfg.img
    flat_dim = h * w * cfg.stages[-1]

    taps: Dict[str, TapInfo] = {}
    for name, d_in, c_out in conv_specs:
        taps[name] = TapInfo(param_path=f"{name}/w", d_in=d_in, d_out=c_out,
                             n_stat=cfg.n_stat)
    taps["fc0"] = TapInfo(param_path="fc0/w", d_in=flat_dim,
                          d_out=cfg.fc_hidden, n_stat=cfg.n_stat)
    taps["fc1"] = TapInfo(param_path="fc1/w", d_in=cfg.fc_hidden,
                          d_out=cfg.n_classes, n_stat=cfg.n_stat)

    def init(key):
        params = {}
        ks = jax.random.split(key, len(conv_specs) + 2)
        for i, (name, d_in, c_out) in enumerate(conv_specs):
            params[name] = {
                "w": layers.dense_init(ks[i], d_in, c_out),
                "b": jnp.zeros((c_out,)),
                "bn_s": jnp.ones((c_out,)), "bn_b": jnp.zeros((c_out,))}
        params["fc0"] = {"w": layers.dense_init(ks[-2], flat_dim,
                                                cfg.fc_hidden),
                         "b": jnp.zeros((cfg.fc_hidden,))}
        params["fc1"] = {"w": layers.dense_init(ks[-1], cfg.fc_hidden,
                                                cfg.n_classes),
                         "b": jnp.zeros((cfg.n_classes,))}
        return params

    def forward(params, probes, x):
        acts: Dict[str, Array] = {}
        h = x
        i = 0
        for s, c in enumerate(cfg.stages):
            for j in range(2):
                name = f"conv{s}_{j}"
                h = conv_tap(name, params, h, probes, acts, cfg.n_stat)
                h = batch_norm(h, params[name]["bn_s"], params[name]["bn_b"])
                h = jax.nn.relu(h)
                i += 1
            # paper's modified pooling: 2×1 keeps width
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max,
                (1, cfg.pool[0], cfg.pool[1], 1),
                (1, cfg.pool[0], cfg.pool[1], 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        h, act = layers.tapped_matmul(params["fc0"]["w"], h,
                                      probes.get("fc0"), cfg.n_stat)
        acts["fc0"] = act
        h = jax.nn.relu(h + params["fc0"]["b"])
        logits, act = layers.tapped_matmul(params["fc1"]["w"], h,
                                           probes.get("fc1"), cfg.n_stat)
        acts["fc1"] = act
        logits = logits + params["fc1"]["b"]
        return logits, acts

    def loss_fn(params, probes, batch):
        x, y = batch
        logits, acts = forward(params, probes, x)
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ll, y[:, None], axis=1))
        return loss, acts

    def accuracy(params, batch):
        x, y = batch
        logits, _ = forward(params, {}, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return init, loss_fn, accuracy, taps

"""Building-block layers with K-FAC taps.

A *tap* instruments a matmul ``y = x @ W`` for K-FAC statistics capture:
the first ``n_stat`` tokens of the input are emitted as the forward-factor
square root, and a zeros-valued *probe* is added to the same token slice of
the output so that ∂L/∂probe is the backward-factor square root (the
functional replacement for torch hooks — see core/kfac.py docstring).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def tapped_matmul(W: Array, x: Array, probe: Optional[Array], n_stat: int
                  ) -> Tuple[Array, Array]:
    """y = x @ W with K-FAC instrumentation.

    Returns (y, act); act (n_stat, d_in) is the tapped input slice and the
    probe (n_stat, d_out) is added to the matching output slice so
    ∂L/∂probe = ∂L/∂y there.

    Sharding note: for sequence inputs (B, T, d) the stats tokens are the
    *first ceil(n_stat/B) tokens of every sequence* — a slice on the
    unsharded T dim, so the tap is local on a batch-sharded mesh.  A flat
    ``[:n_stat]`` slice would land entirely on data-shard 0 and force XLA
    to replicate the whole activation (measured: +28 GB/device temp on the
    danube train cell).
    """
    y = jnp.einsum("...i,io->...o", x, W.astype(x.dtype))
    d_in = x.shape[-1]
    d_out = y.shape[-1]
    if x.ndim == 3:
        B, T = x.shape[0], x.shape[1]
        n_per = min(T, max(1, -(-n_stat // B)))
        rows = B * n_per
        act = x[:, :n_per, :].reshape(rows, d_in)
        if rows >= n_stat:
            act = act[:n_stat]
        else:
            act = jnp.pad(act, ((0, n_stat - rows), (0, 0)))
        if probe is not None:
            pr = probe.astype(y.dtype)
            if rows > n_stat:
                pr = jnp.pad(pr, ((0, rows - n_stat), (0, 0)))
            elif rows < n_stat:
                pr = pr[:rows]
            y = y.at[:, :n_per, :].add(pr.reshape(B, n_per, d_out))
        return y, act
    # flat path (MLP / conv-im2col / expert buffers)
    xf = x.reshape(-1, d_in)
    n = min(n_stat, xf.shape[0])
    act = xf[:n]
    if n < n_stat:   # pad so tap shapes are static across shapes
        act = jnp.pad(act, ((0, n_stat - n), (0, 0)))
    if probe is not None:
        yf = y.reshape(-1, d_out)
        yf = yf.at[:n].add(probe[:n].astype(y.dtype))
        y = yf.reshape(y.shape)
    return y, act


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5
               ) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def dense_init(key: Array, d_in: int, d_out: int, scale: float | None = None,
               dtype=jnp.float32) -> Array:
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def softcap(x: Array, cap: float) -> Array:
    """Gemma2-style logit soft-capping."""
    return cap * jnp.tanh(x / cap)


def rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Rotary embeddings. x: (..., T, H, hd), positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def make_probes(taps: Dict, dtype=jnp.float32):
    """Zeros probe pytree matching a tap dict {name: TapInfo}."""
    return {name: jnp.zeros(t.stack + (t.n_stat, t.d_out), dtype)
            for name, t in taps.items()}

"""Activation-sharding policy threaded through the models.

Maps logical activation roles onto mesh axes; on CPU smoke tests the policy
is inert (no constraints).  The residual stream is sequence-sharded over the
model axis between blocks (Megatron-style sequence parallelism) — without
it, scan-saved residuals for the backward pass of 70B+ configs exceed HBM
(43 GB/device at train_4k for qwen2-72b; /16 with SP → 2.7 GB).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardPolicy:
    dp: Tuple[str, ...] = ()          # data axes ("pod","data") or ("data",)
    tp: Optional[str] = None          # model axis
    seq_shard_residual: bool = True   # sequence parallelism on residuals
    shard_kv_seq: bool = False        # long-context: shard cache seq over dp
    axis_sizes: Tuple[Tuple[str, int], ...] = ()   # mesh axis → size
    #: decode-cache layout: "seq" (baseline; S on model axis — dynamic
    #: cache writes become collective-permutes of the cache shard) or
    #: "heads" (hillclimb: KV heads replicated up to the model-axis size,
    #: head-sharded cache, writes are local)
    kv_cache_layout: str = "seq"
    #: ring/window caches at or below this many slots use the "batch"
    #: layout regardless (replication is cheap, writes become local)
    kv_small_seq_threshold: int = 0

    @property
    def active(self) -> bool:
        return bool(self.dp) or self.tp is not None

    def _c(self, x: Array, spec: P) -> Array:
        if not self.active:
            return x
        if self.axis_sizes:
            # drop axes that don't divide the dim (odd vocab/head counts)
            sizes = dict(self.axis_sizes)

            def ax(entry):
                if entry is None:
                    return 1
                names = entry if isinstance(entry, tuple) else (entry,)
                n = 1
                for a in names:
                    n *= sizes.get(a, 1)
                return n
            fitted = []
            for i, entry in enumerate(tuple(spec)):
                if i < x.ndim and entry is not None and \
                        x.shape[i] % ax(entry) == 0:
                    fitted.append(entry)
                else:
                    fitted.append(None)
            spec = P(*fitted)
        return jax.lax.with_sharding_constraint(x, spec)

    # --- activation roles --------------------------------------------------
    def residual(self, h: Array) -> Array:
        """(B, T, d) between blocks."""
        seq = self.tp if self.seq_shard_residual else None
        return self._c(h, P(self.dp or None, seq, None))

    def full_seq(self, h: Array) -> Array:
        """(B, T, d) inside blocks (sequence gathered)."""
        return self._c(h, P(self.dp or None, None, None))

    def heads(self, x: Array) -> Array:
        """(B, T, H, hd) — heads on the model axis."""
        return self._c(x, P(self.dp or None, None, self.tp, None))

    def ffn_hidden(self, x: Array) -> Array:
        """(B, T, f) — hidden on the model axis."""
        return self._c(x, P(self.dp or None, None, self.tp))

    def moe_buffers(self, x: Array) -> Array:
        """(E, C, d) — experts on model, capacity on data."""
        return self._c(x, P(self.tp, self.dp or None, None))

    def logits(self, x: Array) -> Array:
        """(B, T, V) — vocab on the model axis."""
        return self._c(x, P(self.dp or None, None, self.tp))

    def kv_cache(self, x: Array) -> Array:
        """Cache with layout (…, B, S, *inner): batch on the data axes and
        *sequence* on the model axis (flash-decoding style: XLA psums the
        partial softmax stats across cache shards).  Sequence-sharding is
        chosen over head-sharding because kv_heads (1–16) rarely divide the
        model axis while S always does.  Long-context decode (B=1) shards
        the sequence over every axis instead."""
        n_inner = x.ndim - 3        # dims after (B, S): 2 for KV, 1 for MLA
        S = x.shape[x.ndim - n_inner - 1]
        if self.shard_kv_seq:
            axes = tuple(self.dp) + ((self.tp,) if self.tp else ())
            spec = (None, axes or None) + (None,) * n_inner
        elif S <= self.kv_small_seq_threshold:
            spec = (self.dp or None,) + (None,) * (n_inner + 1)
        elif self.kv_cache_layout == "heads" and n_inner == 2:
            spec = (self.dp or None, None, self.tp, None)
        elif self.kv_cache_layout == "hd" and n_inner == 2:
            # head_dim-sharded: writes local; attention pays one tiny
            # scores-psum (contraction dim sharded)
            spec = (self.dp or None, None, None, self.tp)
        else:
            spec = (self.dp or None, self.tp) + (None,) * n_inner
        return self._c(x, P(*((None,) * (x.ndim - len(spec)) + spec)))

    def state(self, x: Array) -> Array:
        """Recurrent state (B, ...): batch over dp only."""
        spec = P(self.dp or None, *([None] * (x.ndim - 1)))
        return self._c(x, spec)


NO_SHARD = ShardPolicy()

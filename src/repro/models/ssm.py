"""State-space / linear-recurrence mixers: Mamba-2 SSD and RG-LRU.

Mamba-2 (SSD, arXiv:2405.21060): chunked state-space-duality algorithm —
intra-chunk quadratic term + inter-chunk recurrent state passing.  The
chunked form is the TPU-native adaptation: each chunk's work is dense
MXU-friendly einsums, the sequential part is an O(T/chunk) scan over small
(H, hd, N) states.  Sub-quadratic in T; decode is O(1) per token.

RG-LRU (RecurrentGemma / Griffin, arXiv:2402.19427): gated diagonal linear
recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t), implemented with
an associative scan over T for training and a one-step update for decode.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# depthwise causal conv1d (both mixers use a short temporal conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x: Array, w: Array) -> Array:
    """x: (B, T, C), w: (K, C) depthwise. Causal (pads left)."""
    K = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (K - 1 - i, i), (0, 0)))[:, : x.shape[1]]
            for i in range(K)]
    # y_t = Σ_i w[K-1-i] * x_{t-(K-1-i)} ; build explicitly (K is tiny)
    y = sum(p * w[i][None, None, :] for i, p in enumerate(pads))
    return y


def causal_conv1d_step(x_t: Array, buf: Array, w: Array
                       ) -> Tuple[Array, Array]:
    """Decode step. x_t: (B, C); buf: (B, K-1, C) past inputs."""
    K = w.shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

class SsdDims(NamedTuple):
    d_model: int
    d_inner: int          # = expand * d_model (expand = 2)
    n_heads: int          # = d_inner // head_dim
    head_dim: int = 64
    d_state: int = 128
    n_groups: int = 1
    conv_k: int = 4
    chunk: int = 256


def ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int) -> Array:
    """Chunked SSD scan.

    xh: (B, T, H, P) inputs; dt: (B, T, H) positive step sizes;
    A: (H,) negative decay rates; Bm, Cm: (B, T, G, N) input/output maps
    (G groups broadcast over H). Returns (B, T, H, P).
    """
    Bsz, T, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = T // chunk
    rep = H // G
    # per-step log decay
    dA = dt * A[None, None, :]                          # (B,T,H) ≤ 0
    xh = xh.reshape(Bsz, nc, chunk, H, P)
    dt_c = dt.reshape(Bsz, nc, chunk, H)
    dA_c = dA.reshape(Bsz, nc, chunk, H)
    B_c = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)
    C_c = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)

    cum = jnp.cumsum(dA_c, axis=2)                      # (B,nc,c,H)
    seg_end = cum[:, :, -1]                             # (B,nc,H) total decay

    # ---- intra-chunk (quadratic within the chunk, causal) ----
    # L[s, t] = exp(cum_s − cum_t) for s ≥ t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,s,t,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcshn,bcthn->bcsth", C_c, B_c)         # (B,nc,s,t,H)
    y_intra = jnp.einsum("bcsth,bcsth,bcth,bcthp->bcshp",
                         CB, Lmat, dt_c, xh)

    # ---- chunk states + inter-chunk recurrence ----
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)    # (B,nc,c,H)
    states = jnp.einsum("bcthn,bcth,bcth,bcthp->bchnp",
                        B_c, dt_c, decay_to_end, xh)        # (B,nc,H,N,P)

    def chunk_step(carry, inp):
        st_prev = carry                                     # (B,H,N,P)
        st_c, g = inp                                       # g: (B,H)
        st = st_prev * jnp.exp(g)[..., None, None] + st_c
        return st, st_prev

    init = jnp.zeros_like(states[:, 0])
    _, prev_states = jax.lax.scan(
        chunk_step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_end, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcthn,bcth,bchnp->bcthp",
                         C_c, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y


def ssd_decode_step(x_t: Array, dt_t: Array, A: Array, B_t: Array,
                    C_t: Array, state: Array) -> Tuple[Array, Array]:
    """One-token SSD update.  x_t: (B,H,P), dt_t: (B,H), B_t/C_t: (B,G,N),
    state: (B,H,N,P) → (y_t, new_state)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)                       # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(dt_t * A[None, :])                      # (B,H)
    upd = jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt_t, x_t)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    return y, state


def ssd_reference(xh, dt, A, Bm, Cm):
    """O(T²) dense SSD oracle (tests only): y_s = Σ_{t≤s} C_s·exp(ΣdA)·B_t dt_t x_t."""
    Bsz, T, H, P = xh.shape
    G = Bm.shape[2]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    dA = dt * A[None, None, :]
    cum = jnp.cumsum(dA, axis=1)                            # (B,T,H)
    diff = cum[:, :, None, :] - cum[:, None, :, :]          # (B,s,t,H)
    L = jnp.where(jnp.tril(jnp.ones((T, T), bool))[None, :, :, None],
                  jnp.exp(diff), 0.0)
    CB = jnp.einsum("bshn,bthn->bsth", Ch, Bh)
    return jnp.einsum("bsth,bsth,bth,bthp->bshp", CB, L, dt, xh)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

_C_RGLRU = 8.0


def rglru(x: Array, gate_x: Array, gate_a: Array, lam: Array) -> Array:
    """RG-LRU over a sequence.  x, gates: (B, T, D); lam: (D,) raw Λ.
    a_t = exp(−c·softplus(Λ)·σ(gate_a)); h_t = a_t h_{t-1} + √(1−a_t²)·(σ(gate_x)⊙x)."""
    log_a = -_C_RGLRU * jax.nn.softplus(lam)[None, None, :] * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * x.astype(jnp.float32)
    inp = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, inp), axis=1)
    return h.astype(x.dtype)


def rglru_step(x_t, gate_x, gate_a, lam, h_prev):
    """One-token RG-LRU.  x_t, gates: (B, D); h_prev: (B, D)."""
    log_a = -_C_RGLRU * jax.nn.softplus(lam)[None, :] * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * \
        x_t.astype(jnp.float32)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    return h.astype(x_t.dtype), h

"""Attention flavors for the assigned architectures.

All variants are memory-efficient (blockwise online-softmax over KV blocks —
the TPU-native adaptation of flash attention in pure JAX; a Pallas kernel is
a hillclimb option, see EXPERIMENTS.md §Perf) and support:

  * GQA / MQA / MHA        (n_kv_heads ≤ n_heads)
  * causal + sliding-window (local) masking, logit softcap (gemma2/3)
  * MLA (deepseek-v3): latent-compressed KV with decoupled RoPE dims;
    decode uses the *absorbed* formulation (attention in latent space)
  * decode with a KV cache (one new token), including sequence-sharded
    caches for the 500k cells.

Shapes: q (B, Tq, H, hd); k, v (B, Tk, Hk, hd). Masks are computed from
absolute positions so chunked prefill / offset decode are consistent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Array = jax.Array
NEG_INF = -1e30


def _scores_mask(q_pos: Array, k_pos: Array, causal: bool, window: int):
    """(Tq, Tk) boolean validity mask from absolute positions."""
    valid = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        valid &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        valid &= q_pos[:, None] - k_pos[None, :] < window
    return valid


def _sdp_block(q, k, v, valid, softcap: float):
    """One (q-block × kv-block) online-softmax partial.

    q: (B, Tq, Hk, G, hd), k/v: (B, Tk, Hk, hd), valid: (Tq, Tk).
    Returns (scores_max, exp_scores@v, exp_sum) for combination.
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgh,bskh->bqkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = layers.softcap(s, softcap)
    s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                               # (B,Tq,Hk,G)
    # guard fully-masked rows
    m_safe = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[None, :, None, None, :], p, 0.0)
    o = jnp.einsum("bqkgs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    l = jnp.sum(p, axis=-1)
    return m_safe, o, l


def _combine(m1, o1, l1, m2, o2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, o1 * a1[..., None] + o2 * a2[..., None], l1 * a1 + l2 * a2


def blockwise_attention(q: Array, k: Array, v: Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0, q_block: int = 1024,
                        kv_block: int = 1024, q_offset=0,
                        k_offset=0) -> Array:
    """Memory-efficient attention; O(q_block·kv_block) live scores.

    GQA grouping handled internally; Tq % q_block == Tk % kv_block == 0
    is arranged by the callers (all assigned shapes are powers of two).
    """
    B, Tq, H, hd = q.shape
    _, Tk, Hk, _ = k.shape
    hd_v = v.shape[-1]          # MLA: value head dim may differ from q/k
    G = H // Hk
    q = q.reshape(B, Tq, Hk, G, hd)
    q_block = min(q_block, Tq)
    kv_block = min(kv_block, Tk)
    nq, nk = Tq // q_block, Tk // kv_block
    q_blocks = q.reshape(B, nq, q_block, Hk, G, hd)
    k_blocks = k.reshape(B, nk, kv_block, Hk, hd)
    v_blocks = v.reshape(B, nk, kv_block, Hk, hd_v)
    q_pos = jnp.arange(Tq) + q_offset
    k_pos = jnp.arange(Tk) + k_offset

    def per_q_block(i):
        qb = q_blocks[:, i]
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_block, q_block)

        def kv_step(carry, j):
            m, o, l = carry
            kb = k_blocks[:, j]
            vb = v_blocks[:, j]
            kp = jax.lax.dynamic_slice_in_dim(k_pos, j * kv_block, kv_block)
            valid = _scores_mask(qp, kp, causal, window)
            m2, o2, l2 = _sdp_block(qb, kb, vb, valid, softcap)
            return _combine(m, o, l, m2, o2, l2), None

        init = (jnp.full((B, q_block, Hk, G), NEG_INF, jnp.float32),
                jnp.zeros((B, q_block, Hk, G, hd_v), jnp.float32),
                jnp.zeros((B, q_block, Hk, G), jnp.float32))
        (m, o, l), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return o / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(per_q_block, jnp.arange(nq))       # (nq,B,qb,Hk,G,hdv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Tq, H, hd_v)
    return out.astype(v.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, *,
                     window: int = 0, softcap: float = 0.0,
                     t: Optional[Array] = None) -> Array:
    """One-token attention over a cache.  q: (B, 1, H, hd);
    k/v_cache: (B, S, Hk, hd); t = current absolute position (for masking
    unwritten cache slots and the sliding window)."""
    B, S, Hk, hd = k_cache.shape
    H = q.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    if softcap > 0:
        s = layers.softcap(s, softcap)
    pos = jnp.arange(S)
    valid = jnp.ones((S,), bool) if t is None else pos <= t
    if window > 0 and t is not None:
        valid &= pos > t - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# MLA (deepseek-v3) — latent-compressed attention
# ---------------------------------------------------------------------------

class MlaDims(NamedTuple):
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


def mla_train_attention(x, p, dims: MlaDims, probes, acts, tag, n_stat,
                        positions):
    """Training-path MLA: materialize per-head K/V from the latent.

    Params p: wq_a (d, q_lora), wq_b (q_lora, H*(nope+rope)),
    wkv_a (d, kv_lora + rope), wkv_b (kv_lora, H*(nope+v)), wo (H*v, d).
    """
    B, T, d = x.shape
    H, dn, dr, dv = dims.n_heads, dims.qk_nope, dims.qk_rope, dims.v_head

    def mm(name, W, inp):
        y, act = layers.tapped_matmul(W, inp, probes.get(f"{tag}/{name}"),
                                      n_stat)
        acts[f"{tag}/{name}"] = act
        return y

    q = mm("wq_b", p["wq_b"], mm("wq_a", p["wq_a"], x))
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = mm("wkv_a", p["wkv_a"], x)                       # (B,T,kv_lora+dr)
    c_kv, k_rope = kv[..., :dims.kv_lora], kv[..., dims.kv_lora:]
    kvu = mm("wkv_b", p["wkv_b"], c_kv).reshape(B, T, H, dn + dv)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    q_rope = layers.rope(q_rope, positions)
    k_rope = layers.rope(k_rope[..., None, :], positions)  # (B,T,1,dr)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, T, H, dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = blockwise_attention(qf, k, v, causal=True)
    o = o.reshape(B, T, H * dv)
    return mm("wo", p["wo"], o)


def mla_decode_attention(x_t, p, dims: MlaDims, cache, t):
    """Absorbed-MLA decode: attention runs in the kv_lora latent space, so
    the cache stores only (c_kv, k_rope) — the paper('s arch)'s memory win.

    cache: dict(c_kv (B,S,kv_lora), k_rope (B,S,dr)). x_t: (B,1,d).
    """
    B = x_t.shape[0]
    H, dn, dr, dv = dims.n_heads, dims.qk_nope, dims.qk_rope, dims.v_head
    L = dims.kv_lora
    q = (x_t @ p["wq_a"]) @ p["wq_b"]
    q = q.reshape(B, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = x_t @ p["wkv_a"]                                 # (B,1,L+dr)
    c_new, kr_new = kv[..., :L], kv[..., L:]
    pos_t = jnp.full((B, 1), t)
    q_rope = layers.rope(q_rope[:, None, :, :], pos_t)[:, 0]
    kr_new = layers.rope(kr_new[:, :, None, :], pos_t)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"],
                                               c_new.astype(cache["c_kv"].dtype),
                                               t, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"],
                                                 kr_new.astype(
                                                     cache["k_rope"].dtype),
                                                 t, axis=1)
    # absorb W_uk into q: wkv_b reshaped (L, H, dn+dv)
    wkv_b = p["wkv_b"].reshape(L, H, dn + dv)
    w_uk = wkv_b[..., :dn]                                # (L,H,dn)
    w_uv = wkv_b[..., dn:]                                # (L,H,dv)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope, w_uk.astype(q_nope.dtype),
                       preferred_element_type=jnp.float32)  # (B,H,L)
    s = (jnp.einsum("bhl,bsl->bhs", q_lat.astype(c_kv.dtype), c_kv,
                    preferred_element_type=jnp.float32) +
         jnp.einsum("bhr,bsr->bhs", q_rope.astype(k_rope.dtype), k_rope,
                    preferred_element_type=jnp.float32))
    s = s / jnp.sqrt(dn + dr)
    S = c_kv.shape[1]
    valid = jnp.arange(S) <= t
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", pattn.astype(c_kv.dtype), c_kv,
                       preferred_element_type=jnp.float32)
    o = jnp.einsum("bhl,lhv->bhv", o_lat.astype(w_uv.dtype), w_uv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H * dv).astype(x_t.dtype)
    return o @ p["wo"], dict(c_kv=c_kv, k_rope=k_rope)

"""The generic LM covering all 10 assigned architectures.

Assembly: embed → [segments: scan over (pattern × repeats)] → final norm →
tapped LM head (→ optional MTP head).  Enc-dec archs (whisper) run an
encoder stack first and feed it as cross-attention memory.  VLM/audio
frontends are stubs: precomputed embeddings enter as a sequence prefix /
encoder input per the assignment.

Train path: ``loss_fn(params, probes, batch) -> (loss, acts)`` — the K-FAC
tap contract (core/kfac.py).  Serve path: ``decode_step`` (one token, KV /
state caches) and ``forward`` (prefill-shaped logits).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, Segment
from repro.core.kfac import TapInfo
from repro.models import blocks, layers
from repro.models.sharding_policy import ShardPolicy, NO_SHARD

Array = jax.Array

#: local tap name → block param sub-path ("mix"/"ffn" namespaced)
_TAP_PARAM = {
    "attn_q": "mix/wq", "attn_kv": "mix/wkv", "attn_o": "mix/wo",
    "x_attn_q": "mix/x_wq", "x_attn_kv": "mix/x_wkv",
    "x_attn_o": "mix/x_wo",
    "ffn_wi": "ffn/wi", "ffn_wo": "ffn/wo_f",
    "moe_wi": "ffn/wi", "moe_wo": "ffn/wo",
    "shared_wi": "ffn/shared_wi", "shared_wo": "ffn/shared_wo",
    "wq_a": "mix/wq_a", "wq_b": "mix/wq_b", "wkv_a": "mix/wkv_a",
    "wkv_b": "mix/wkv_b", "wo": "mix/wo",
    "ssm_in": "mix/in_proj", "ssm_out": "mix/out_proj",
    "lru_in": "mix/wi", "lru_gates": "mix/wg", "lru_out": "mix/wo",
}


def _ce_loss(logits: Array, targets: Array, mask: Optional[Array] = None
             ) -> Array:
    """Token-mean cross-entropy, f32 accumulation without materializing an
    f32 logits copy (vocab can be 262k).

    The target log-prob is extracted with a fused iota==target contraction
    instead of take_along_axis: a vocab-sharded gather would force XLA to
    all-gather the full logits (GBs/device); the masked sum reduces locally
    per vocab shard and psums a scalar."""
    m = jnp.max(logits, axis=-1, keepdims=True).astype(jnp.float32)
    lse = m[..., 0] + jnp.log(
        jnp.sum(jnp.exp(logits - m.astype(logits.dtype)),
                axis=-1, dtype=jnp.float32))
    vocab_iota = jnp.arange(logits.shape[-1], dtype=targets.dtype)
    onehot = vocab_iota == targets[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0).astype(jnp.float32), axis=-1)
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


class LM:
    def __init__(self, arch: ArchConfig, sp: ShardPolicy = NO_SHARD,
                 remat: bool = True, unroll: bool = False):
        self.arch = arch
        self.sp = sp
        self.remat = remat
        self.unroll = unroll     # python-loop layers (scan-FLOP probes)
        self.dtype = (jnp.bfloat16 if arch.dtype == "bfloat16"
                      else jnp.float32)
        self._enc_segments: Tuple[Segment, ...] = ()
        if arch.is_encdec:
            enc_spec = LayerSpec(mixer="gqa", ffn="dense",
                                 causal=arch.enc_causal)
            self._enc_segments = (Segment((enc_spec,), arch.n_enc_layers),)
        self.taps = self._build_taps()

    # ------------------------------------------------------------------ taps
    def _seg_taps(self, segments, base: str) -> Dict[str, TapInfo]:
        arch = self.arch
        out = {}
        cross = arch.is_encdec and base == "segments"
        for s, seg in enumerate(segments):
            for i, spec in enumerate(seg.pattern):
                for local, (d_in, d_out, extra) in blocks.block_taps(
                        arch, spec, cross=cross).items():
                    name = f"{base}/seg{s}/p{i}/{local}"
                    pkey = _TAP_PARAM[local]
                    out[name] = TapInfo(
                        param_path=f"{base}/{s}/p{i}/{pkey}",
                        d_in=d_in, d_out=d_out,
                        stack=(seg.repeats,) + tuple(extra),
                        n_stat=arch.n_stat)
        return out

    def _build_taps(self) -> Dict[str, TapInfo]:
        arch = self.arch
        taps = self._seg_taps(arch.segments, "segments")
        if self._enc_segments:
            taps.update(self._seg_taps(self._enc_segments, "enc"))
        taps["head"] = TapInfo(param_path="head/w", d_in=arch.d_model,
                               d_out=arch.vocab, n_stat=arch.n_stat)
        if arch.mtp:
            taps["mtp_proj"] = TapInfo(param_path="mtp/w",
                                       d_in=arch.d_model,
                                       d_out=arch.d_model,
                                       n_stat=arch.n_stat)
        return taps

    # ------------------------------------------------------------------ init
    def _init_segments(self, key, segments, cross: bool):
        arch = self.arch
        out = {}
        for s, seg in enumerate(segments):
            ks = jax.random.split(jax.random.fold_in(key, s),
                                  seg.repeats * len(seg.pattern))
            seg_params = {}
            for i, spec in enumerate(seg.pattern):
                kk = ks[i::len(seg.pattern)]
                seg_params[f"p{i}"] = jax.vmap(
                    lambda k: blocks.init_block(k, arch, spec, cross=cross,
                                                dtype=jnp.float32))(
                    jnp.stack(kk))
            out[str(s)] = seg_params
        return out

    def init(self, key) -> Dict:
        arch = self.arch
        k_emb, k_seg, k_enc, k_head, k_mtp = jax.random.split(key, 5)
        params = {
            "embed": (jax.random.normal(k_emb, (arch.vocab, arch.d_model))
                      * 0.01).astype(jnp.float32),
            "segments": self._init_segments(
                k_seg, arch.segments, cross=arch.is_encdec),
            "final_ln": jnp.zeros((arch.d_model,), jnp.float32),
            "head": {"w": layers.dense_init(k_head, arch.d_model, arch.vocab,
                                            scale=0.01)},
        }
        if self._enc_segments:
            params["enc"] = self._init_segments(k_enc, self._enc_segments,
                                                cross=False)
            params["enc_ln"] = jnp.zeros((arch.d_model,), jnp.float32)
        if arch.mtp:
            params["mtp"] = {"w": layers.dense_init(k_mtp, arch.d_model,
                                                    arch.d_model)}
        return params

    # --------------------------------------------------------------- forward
    def _run_segments(self, segments, seg_params, base, h, probes, positions,
                      memory=None, train=True):
        arch, sp = self.arch, self.sp
        aux = jnp.zeros((), jnp.float32)
        acts: Dict[str, Array] = {}
        cross = memory is not None
        for s, seg in enumerate(segments):
            pattern = seg.pattern
            names = [n for n in self.taps
                     if n.startswith(f"{base}/seg{s}/")]
            probes_seg = {n: probes[n] for n in names if n in probes}

            def body(carry, xs):
                hh, aux_c = carry
                p_stack, probe_sl = xs
                acts_l: Dict[str, Array] = {}
                for i, spec in enumerate(pattern):
                    tc = blocks.TapCtx(probe_sl, arch.n_stat,
                                       prefix=f"{base}/seg{s}/p{i}/")
                    hh, aux_i = blocks.apply_block(
                        arch, spec, p_stack[f"p{i}"], hh, tc, positions, sp,
                        memory=memory if cross else None)
                    aux_c = aux_c + aux_i
                    acts_l.update(tc.acts)
                return (hh, aux_c), acts_l

            fn = jax.checkpoint(body) if (train and self.remat) else body
            if self.unroll:
                carry = (h, aux)
                acts_list = []
                for r in range(seg.repeats):
                    xs_r = jax.tree_util.tree_map(
                        lambda x: x[r], (seg_params[str(s)], probes_seg))
                    carry, acts_r = fn(carry, xs_r)
                    acts_list.append(acts_r)
                h, aux = carry
                acts_s = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *acts_list)
            else:
                (h, aux), acts_s = jax.lax.scan(
                    fn, (h, aux), (seg_params[str(s)], probes_seg))
            acts.update(acts_s)
        return h, aux, acts

    def _embed(self, params, tokens):
        h = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        return h * jnp.asarray(jnp.sqrt(self.arch.d_model), self.dtype)

    def forward(self, params, batch, probes=None, train=True):
        """Full-sequence forward. Returns (logits, aux, acts)."""
        arch, sp = self.arch, self.sp
        probes = probes or {}
        acts: Dict[str, Array] = {}
        memory = None
        if arch.is_encdec:
            mem = batch["frames"].astype(self.dtype)     # (B, Te, d) stub
            pos_e = jnp.broadcast_to(jnp.arange(mem.shape[1]),
                                     mem.shape[:2])
            memory, _, acts_e = self._run_segments(
                self._enc_segments, params["enc"], "enc", mem, probes,
                pos_e, train=train)
            memory = layers.rms_norm(memory, params["enc_ln"])
            acts.update(acts_e)
        tokens = batch["tokens"]
        h = self._embed(params, tokens)
        if arch.frontend == "vision":
            h = jnp.concatenate([batch["embeds"].astype(self.dtype), h],
                                axis=1)
        B, T = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        h = sp.residual(h)
        h, aux, acts_m = self._run_segments(
            arch.segments, params["segments"], "segments", h, probes,
            positions, memory=memory, train=train)
        acts.update(acts_m)
        h = layers.rms_norm(h, params["final_ln"])
        tc = blocks.TapCtx(probes, arch.n_stat, prefix="")
        logits = tc.mm("head", params["head"]["w"], h)
        acts.update(tc.acts)
        if arch.logit_softcap > 0:
            logits = layers.softcap(logits, arch.logit_softcap)
        logits = sp.logits(logits)
        if arch.mtp and train:
            tcm = blocks.TapCtx(probes, arch.n_stat, prefix="")
            h_mtp = tcm.mm("mtp_proj", params["mtp"]["w"], h)
            acts.update(tcm.acts)
            logits_mtp = jnp.einsum("...i,io->...o", h_mtp,
                                    params["head"]["w"].astype(h_mtp.dtype))
            logits_mtp = sp.logits(logits_mtp)
            return logits, aux, acts, logits_mtp
        return logits, aux, acts, None

    def loss_fn(self, params, probes, batch):
        arch = self.arch
        logits, aux, acts, logits_mtp = self.forward(params, batch, probes,
                                                     train=True)
        targets = batch["targets"]
        mask = None
        if arch.frontend == "vision":       # loss only on the token span
            logits = logits[:, arch.n_prefix:]
        loss = _ce_loss(logits[:, :-1], targets[:, 1:])
        if logits_mtp is not None:          # MTP: predict t+2 (depth-1)
            if arch.frontend == "vision":
                logits_mtp = logits_mtp[:, arch.n_prefix:]
            loss = loss + 0.3 * _ce_loss(logits_mtp[:, :-2], targets[:, 2:])
        loss = loss + arch.aux_loss_coef * aux
        return loss, acts

    # ----------------------------------------------------------------- serve
    def init_cache(self, B: int, S: int, cross_len: int = 0,
                   window_caches: bool = False, kv_rep: int = 1):
        arch = self.arch
        cache = {}
        for s, seg in enumerate(arch.segments):
            seg_cache = {}
            for i, spec in enumerate(seg.pattern):
                def one(_):
                    return blocks.block_cache_init(
                        arch, spec, B, S, self.dtype, cross_len=cross_len,
                        window_caches=window_caches, kv_rep=kv_rep)
                seg_cache[f"p{i}"] = jax.vmap(one)(jnp.arange(seg.repeats))
            cache[str(s)] = seg_cache
        return cache

    def decode_step(self, params, cache, token, t):
        """One decode step. token: (B, 1) int32; t: scalar position.
        Returns (logits (B, 1, V), new_cache)."""
        arch, sp = self.arch, self.sp
        h_t = self._embed(params, token)
        new_cache = {}
        for s, seg in enumerate(arch.segments):
            pattern = seg.pattern

            def body(hh, xs):
                p_stack, cache_sl = xs
                ncs = {}
                for i, spec in enumerate(pattern):
                    hh, nc = blocks.decode_block(
                        arch, spec, p_stack[f"p{i}"], hh,
                        cache_sl[f"p{i}"], t, sp)
                    ncs[f"p{i}"] = nc
                return hh, ncs

            if self.unroll:
                ncs_list = []
                for r in range(seg.repeats):
                    xs_r = jax.tree_util.tree_map(
                        lambda x: x[r],
                        (params["segments"][str(s)], cache[str(s)]))
                    h_t, ncs_r = body(h_t, xs_r)
                    ncs_list.append(ncs_r)
                ncs = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                             *ncs_list)
            else:
                h_t, ncs = jax.lax.scan(
                    body, h_t, (params["segments"][str(s)], cache[str(s)]))
            new_cache[str(s)] = ncs
        h_t = layers.rms_norm(h_t, params["final_ln"])
        logits = h_t @ params["head"]["w"].astype(h_t.dtype)
        if arch.logit_softcap > 0:
            logits = layers.softcap(logits, arch.logit_softcap)
        return logits, new_cache

"""Per-LayerSpec transformer blocks: init, train apply, decode apply,
cache init, and K-FAC tap enumeration.

A *block* = (norm → mixer → residual) [→ norm → FFN → residual].  Mixers:
GQA attention (global / sliding-window / non-causal / cross), MLA, Mamba-2
SSD, RG-LRU.  FFNs: gated-SiLU dense, MoE, or none.  All matmuls are
K-FAC-tapped; tap names are local to the block ("attn_q", "ffn_wi", …) and
prefixed by the caller ("seg0/p1/attn_q").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.core.kfac import TapInfo
from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.sharding_policy import ShardPolicy, NO_SHARD

Array = jax.Array


def tap_dims(d_in: int, d_out: int, extra: tuple = ()):
    """(d_in, d_out, extra_stack) for one tapped matmul family."""
    return (d_in, d_out, extra)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

class TapCtx:
    """Carries probes in / activations out through a block application."""

    def __init__(self, probes: Dict, n_stat: int, prefix: str = ""):
        self.probes = probes or {}
        self.acts: Dict[str, Array] = {}
        self.n_stat = n_stat
        self.prefix = prefix

    def mm(self, name: str, W: Array, x: Array) -> Array:
        full = f"{self.prefix}{name}"
        y, act = layers.tapped_matmul(W, x, self.probes.get(full),
                                      self.n_stat)
        self.acts[full] = act
        return y


def _mixer_dims(arch: ArchConfig):
    H, Hk, hd = arch.n_heads, arch.n_kv_heads, arch.hd
    return H, Hk, hd


# ---------------------------------------------------------------------------
# GQA attention sub-block
# ---------------------------------------------------------------------------

def init_gqa(key, arch: ArchConfig, cross: bool = False, dtype=jnp.float32):
    H, Hk, hd = _mixer_dims(arch)
    d = arch.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, H * hd, dtype=dtype),
        "wkv": layers.dense_init(ks[1], d, 2 * Hk * hd, dtype=dtype),
        "wo": layers.dense_init(ks[2], H * hd, d, dtype=dtype),
        "ln": jnp.zeros((d,), jnp.float32),
    }
    if arch.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bkv"] = jnp.zeros((2 * Hk * hd,), jnp.float32)
    if cross:
        p["x_wq"] = layers.dense_init(ks[3], d, H * hd, dtype=dtype)
        p["x_wkv"] = layers.dense_init(jax.random.fold_in(key, 9), d,
                                       2 * Hk * hd, dtype=dtype)
        p["x_wo"] = layers.dense_init(jax.random.fold_in(key, 10), H * hd, d,
                                      dtype=dtype)
        p["x_ln"] = jnp.zeros((d,), jnp.float32)
    return p


def gqa_taps(arch: ArchConfig, cross: bool = False) -> Dict[str, dict]:
    H, Hk, hd = _mixer_dims(arch)
    d = arch.d_model
    t = {"attn_q": tap_dims(d, H * hd), "attn_kv": tap_dims(d, 2 * Hk * hd),
         "attn_o": tap_dims(H * hd, d)}
    if cross:
        t.update({"x_attn_q": tap_dims(d, H * hd),
                  "x_attn_kv": tap_dims(d, 2 * Hk * hd),
                  "x_attn_o": tap_dims(H * hd, d)})
    return t


def _qkv(p, arch, tc: TapCtx, x, positions):
    H, Hk, hd = _mixer_dims(arch)
    B, T, _ = x.shape
    q = tc.mm("attn_q", p["wq"], x)
    kv = tc.mm("attn_kv", p["wkv"], x)
    if arch.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        kv = kv + p["bkv"].astype(kv.dtype)
    q = q.reshape(B, T, H, hd)
    k, v = jnp.split(kv.reshape(B, T, 2 * Hk, hd), 2, axis=2)
    if positions is not None:
        q = layers.rope(q, positions, arch.rope_theta)
        k = layers.rope(k, positions, arch.rope_theta)
    return q, k, v


def apply_gqa(spec: LayerSpec, arch: ArchConfig, p, h, tc: TapCtx,
              positions, sp: ShardPolicy, memory: Optional[Array] = None):
    """Self-attention (+ optional cross-attention when memory given)."""
    B, T, d = h.shape
    H, Hk, hd = _mixer_dims(arch)
    x = layers.rms_norm(h, p["ln"])
    x = sp.full_seq(x)
    q, k, v = _qkv(p, arch, tc, x, positions)
    q, k, v = sp.heads(q), sp.heads(k), sp.heads(v)
    o = attn_lib.blockwise_attention(
        q, k, v, causal=spec.causal, window=spec.window,
        softcap=arch.attn_softcap, q_block=512, kv_block=512)
    o = tc.mm("attn_o", p["wo"], o.reshape(B, T, H * hd))
    h = sp.residual(h + o.astype(h.dtype))
    if memory is not None:
        x = layers.rms_norm(h, p["x_ln"])
        q = tc.mm("x_attn_q", p["x_wq"], x).reshape(B, T, H, hd)
        Tm = memory.shape[1]
        kvm = tc.mm("x_attn_kv", p["x_wkv"], memory)
        km, vm = jnp.split(kvm.reshape(B, Tm, 2 * Hk, hd), 2, axis=2)
        o = attn_lib.blockwise_attention(q, km, vm, causal=False,
                                         q_block=512, kv_block=512)
        o = tc.mm("x_attn_o", p["x_wo"], o.reshape(B, T, H * hd))
        h = sp.residual(h + o.astype(h.dtype))
    return h


def gqa_cache_init(arch: ArchConfig, B: int, S: int, dtype,
                   cross_len: int = 0, spec: Optional[LayerSpec] = None,
                   window_caches: bool = False, kv_rep: int = 1):
    """KV cache.  Hillclimb options (EXPERIMENTS.md §Perf):
    * window_caches — sliding-window layers keep only a `window`-slot ring
      buffer instead of the full sequence;
    * kv_rep — replicate KV heads ×kv_rep so the head dim matches the
      model-axis size ("heads" cache layout: local writes, no permutes)."""
    H, Hk, hd = _mixer_dims(arch)
    Hc = Hk * kv_rep
    S_eff = S
    if window_caches and spec is not None and spec.window > 0:
        S_eff = min(S, spec.window)
    c = {"k": jnp.zeros((B, S_eff, Hc, hd), dtype),
         "v": jnp.zeros((B, S_eff, Hc, hd), dtype)}
    if cross_len:
        c["xk"] = jnp.zeros((B, cross_len, Hc, hd), dtype)
        c["xv"] = jnp.zeros((B, cross_len, Hc, hd), dtype)
    return c


def decode_gqa(spec: LayerSpec, arch: ArchConfig, p, h_t, cache, t,
               sp: ShardPolicy):
    """One-token step. h_t: (B, 1, d)."""
    B = h_t.shape[0]
    H, Hk, hd = _mixer_dims(arch)
    x = layers.rms_norm(h_t, p["ln"])
    pos = jnp.broadcast_to(t, (B, 1))
    q = (x @ p["wq"].astype(x.dtype))
    kv = (x @ p["wkv"].astype(x.dtype))
    if arch.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        kv = kv + p["bkv"].astype(kv.dtype)
    q = layers.rope(q.reshape(B, 1, H, hd), pos, arch.rope_theta)
    k_new, v_new = jnp.split(kv.reshape(B, 1, 2 * Hk, hd), 2, axis=2)
    k_new = layers.rope(k_new, pos, arch.rope_theta)
    S_cache, Hc = cache["k"].shape[1], cache["k"].shape[2]
    if Hc != Hk:        # "heads" layout: KV heads replicated to Hc
        rep = Hc // Hk
        k_new = jnp.repeat(k_new, rep, axis=2)
        v_new = jnp.repeat(v_new, rep, axis=2)
    # ring-buffer write: for full caches t < S_cache so this is identity
    write_t = t % S_cache
    k = sp.kv_cache(jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), write_t, axis=1))
    v = sp.kv_cache(jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), write_t, axis=1))
    if spec.window > 0 and S_cache <= spec.window:
        # ring buffer: every written slot is within the window by
        # construction; mask only unwritten slots (t < S_cache)
        o = attn_lib.decode_attention(q, k, v,
                                      softcap=arch.attn_softcap,
                                      t=jnp.minimum(t, S_cache - 1))
    else:
        o = attn_lib.decode_attention(q, k, v, window=spec.window,
                                      softcap=arch.attn_softcap, t=t)
    o = (o.reshape(B, 1, H * hd) @ p["wo"].astype(h_t.dtype))
    h_t = h_t + o.astype(h_t.dtype)
    new_cache = dict(cache, k=k, v=v)
    if "xk" in cache:  # cross-attention over a precomputed memory cache
        x = layers.rms_norm(h_t, p["x_ln"])
        q = (x @ p["x_wq"].astype(x.dtype)).reshape(B, 1, H, hd)
        o = attn_lib.decode_attention(q, cache["xk"], cache["xv"], t=None)
        o = o.reshape(B, 1, H * hd) @ p["x_wo"].astype(h_t.dtype)
        h_t = h_t + o.astype(h_t.dtype)
    return h_t, new_cache


# ---------------------------------------------------------------------------
# MLA sub-block (deepseek)
# ---------------------------------------------------------------------------

def init_mla(key, arch: ArchConfig, dtype=jnp.float32):
    d = arch.d_model
    dims = attn_lib.MlaDims(arch.n_heads, arch.mla_q_lora, arch.mla_kv_lora,
                            arch.mla_qk_nope, arch.mla_qk_rope,
                            arch.mla_v_head)
    H = dims.n_heads
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wq_a": layers.dense_init(ks[0], d, dims.q_lora, dtype=dtype),
        "wq_b": layers.dense_init(ks[1], dims.q_lora,
                                  H * (dims.qk_nope + dims.qk_rope),
                                  dtype=dtype),
        "wkv_a": layers.dense_init(ks[2], d, dims.kv_lora + dims.qk_rope,
                                   dtype=dtype),
        "wkv_b": layers.dense_init(ks[3], dims.kv_lora,
                                   H * (dims.qk_nope + dims.v_head),
                                   dtype=dtype),
        "wo": layers.dense_init(ks[4], H * dims.v_head, d, dtype=dtype),
    }


def mla_taps(arch: ArchConfig) -> Dict[str, tuple]:
    d = arch.d_model
    H = arch.n_heads
    dn, dr, dv = arch.mla_qk_nope, arch.mla_qk_rope, arch.mla_v_head
    ql, kl = arch.mla_q_lora, arch.mla_kv_lora
    return {"wq_a": tap_dims(d, ql), "wq_b": tap_dims(ql, H * (dn + dr)),
            "wkv_a": tap_dims(d, kl + dr),
            "wkv_b": tap_dims(kl, H * (dn + dv)),
            "wo": tap_dims(H * dv, d)}


def apply_mla(spec, arch: ArchConfig, p, h, tc: TapCtx, positions,
              sp: ShardPolicy):
    dims = attn_lib.MlaDims(arch.n_heads, arch.mla_q_lora, arch.mla_kv_lora,
                            arch.mla_qk_nope, arch.mla_qk_rope,
                            arch.mla_v_head)
    x = sp.full_seq(layers.rms_norm(h, p["ln"]))
    probes = {"mla/" + k[len(tc.prefix):]: v for k, v in tc.probes.items()
              if k.startswith(tc.prefix)}
    acts: Dict[str, Array] = {}
    o = attn_lib.mla_train_attention(x, p, dims, probes, acts, "mla",
                                     tc.n_stat, positions)
    # re-prefix the acts recorded by the mla helper
    for k, v in acts.items():
        tc.acts[f"{tc.prefix}{k.split('/', 1)[1]}"] = v
    return sp.residual(h + o.astype(h.dtype))


def mla_cache_init(arch: ArchConfig, B: int, S: int, dtype):
    return {"c_kv": jnp.zeros((B, S, arch.mla_kv_lora), dtype),
            "k_rope": jnp.zeros((B, S, arch.mla_qk_rope), dtype)}


def decode_mla(spec, arch: ArchConfig, p, h_t, cache, t, sp: ShardPolicy):
    dims = attn_lib.MlaDims(arch.n_heads, arch.mla_q_lora, arch.mla_kv_lora,
                            arch.mla_qk_nope, arch.mla_qk_rope,
                            arch.mla_v_head)
    x = layers.rms_norm(h_t, p["ln"])
    o, new_cache = attn_lib.mla_decode_attention(x, p, dims, cache, t)
    return h_t + o.astype(h_t.dtype), new_cache


# ---------------------------------------------------------------------------
# Mamba-2 SSD sub-block
# ---------------------------------------------------------------------------

def _ssd_dims(arch: ArchConfig):
    d_inner = arch.ssm_expand * arch.d_model
    H = d_inner // arch.ssm_head_dim
    G, N = arch.ssm_groups, arch.ssm_state
    conv_dim = d_inner + 2 * G * N
    in_dim = 2 * d_inner + 2 * G * N + H
    return d_inner, H, G, N, conv_dim, in_dim


def init_ssm(key, arch: ArchConfig, dtype=jnp.float32):
    d = arch.d_model
    d_inner, H, G, N, conv_dim, in_dim = _ssd_dims(arch)
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "in_proj": layers.dense_init(ks[0], d, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (arch.conv_k, conv_dim))
                   * 0.1).astype(jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "out_norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": layers.dense_init(ks[2], d_inner, d, dtype=dtype),
    }


def ssm_taps(arch: ArchConfig) -> Dict[str, tuple]:
    d = arch.d_model
    d_inner, H, G, N, conv_dim, in_dim = _ssd_dims(arch)
    return {"ssm_in": tap_dims(d, in_dim), "ssm_out": tap_dims(d_inner, d)}


def _ssd_split(arch, xz):
    d_inner, H, G, N, conv_dim, _ = _ssd_dims(arch)
    z = xz[..., :d_inner]
    xBC = xz[..., d_inner: d_inner + conv_dim]
    dt = xz[..., d_inner + conv_dim:]
    return z, xBC, dt


def apply_ssm(spec, arch: ArchConfig, p, h, tc: TapCtx, positions,
              sp: ShardPolicy):
    B, T, d = h.shape
    d_inner, H, G, N, conv_dim, _ = _ssd_dims(arch)
    P_dim = arch.ssm_head_dim
    x = sp.full_seq(layers.rms_norm(h, p["ln"]))
    xz = tc.mm("ssm_in", p["in_proj"], x)
    z, xBC, dt = _ssd_split(arch, xz)
    xBC = jax.nn.silu(ssm_lib.causal_conv1d(xBC, p["conv_w"]))
    xs = xBC[..., :d_inner].reshape(B, T, H, P_dim)
    Bm = xBC[..., d_inner: d_inner + G * N].reshape(B, T, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y = ssm_lib.ssd_chunked(xs.astype(jnp.float32), dt, A,
                            Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                            chunk=min(arch.ssm_chunk, T))
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_inner)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                        p["out_norm"]).astype(h.dtype)
    o = tc.mm("ssm_out", p["out_proj"], y)
    return sp.residual(h + o.astype(h.dtype))


def ssm_cache_init(arch: ArchConfig, B: int, S: int, dtype):
    d_inner, H, G, N, conv_dim, _ = _ssd_dims(arch)
    return {"conv": jnp.zeros((B, arch.conv_k - 1, conv_dim), dtype),
            "state": jnp.zeros((B, H, N, arch.ssm_head_dim), jnp.float32)}


def decode_ssm(spec, arch: ArchConfig, p, h_t, cache, t, sp: ShardPolicy):
    B = h_t.shape[0]
    d_inner, H, G, N, conv_dim, _ = _ssd_dims(arch)
    P_dim = arch.ssm_head_dim
    x = layers.rms_norm(h_t, p["ln"])
    xz = (x @ p["in_proj"].astype(x.dtype))[:, 0]
    z, xBC, dt = _ssd_split(arch, xz)
    xBC, conv_buf = ssm_lib.causal_conv1d_step(
        xBC.astype(cache["conv"].dtype), cache["conv"], p["conv_w"])
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :d_inner].reshape(B, H, P_dim).astype(jnp.float32)
    Bm = xBC[..., d_inner: d_inner + G * N].reshape(B, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssm_lib.ssd_decode_step(xs, dt, A, Bm.astype(jnp.float32),
                                       Cm.astype(jnp.float32),
                                       cache["state"])
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(B, d_inner)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                        p["out_norm"]).astype(h_t.dtype)
    o = y[:, None, :] @ p["out_proj"].astype(h_t.dtype)
    return h_t + o.astype(h_t.dtype), {"conv": conv_buf, "state": state}


# ---------------------------------------------------------------------------
# RG-LRU sub-block (recurrentgemma)
# ---------------------------------------------------------------------------

def init_rglru(key, arch: ArchConfig, dtype=jnp.float32):
    d, D = arch.d_model, arch.lru_width
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wi": layers.dense_init(ks[0], d, 2 * D, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (arch.conv_k, D))
                   * 0.1).astype(jnp.float32),
        "wg": layers.dense_init(ks[2], D, 2 * D, dtype=dtype),
        "lam": jnp.full((D,), 0.5, jnp.float32),
        "wo": layers.dense_init(jax.random.fold_in(key, 7), D, d,
                                dtype=dtype),
    }


def rglru_taps(arch: ArchConfig) -> Dict[str, tuple]:
    d, D = arch.d_model, arch.lru_width
    return {"lru_in": tap_dims(d, 2 * D), "lru_gates": tap_dims(D, 2 * D),
            "lru_out": tap_dims(D, d)}


def apply_rglru(spec, arch: ArchConfig, p, h, tc: TapCtx, positions,
                sp: ShardPolicy):
    D = arch.lru_width
    x0 = sp.full_seq(layers.rms_norm(h, p["ln"]))
    xy = tc.mm("lru_in", p["wi"], x0)
    x, y = xy[..., :D], xy[..., D:]
    x = ssm_lib.causal_conv1d(x, p["conv_w"])
    gates = tc.mm("lru_gates", p["wg"], x)
    gx, ga = gates[..., :D], gates[..., D:]
    hseq = ssm_lib.rglru(x, gx, ga, p["lam"])
    out = tc.mm("lru_out", p["wo"], hseq * jax.nn.gelu(y))
    return sp.residual(h + out.astype(h.dtype))


def rglru_cache_init(arch: ArchConfig, B: int, S: int, dtype):
    D = arch.lru_width
    return {"conv": jnp.zeros((B, arch.conv_k - 1, D), dtype),
            "h": jnp.zeros((B, D), jnp.float32)}


def decode_rglru(spec, arch: ArchConfig, p, h_t, cache, t, sp: ShardPolicy):
    D = arch.lru_width
    x0 = layers.rms_norm(h_t, p["ln"])
    xy = (x0 @ p["wi"].astype(x0.dtype))[:, 0]
    x, y = xy[..., :D], xy[..., D:]
    x, conv_buf = ssm_lib.causal_conv1d_step(x.astype(cache["conv"].dtype),
                                             cache["conv"], p["conv_w"])
    gates = x @ p["wg"].astype(x.dtype)
    gx, ga = gates[..., :D], gates[..., D:]
    hn, hstate = ssm_lib.rglru_step(x, gx, ga, p["lam"], cache["h"])
    out = (hn * jax.nn.gelu(y))[:, None, :] @ p["wo"].astype(h_t.dtype)
    return h_t + out.astype(h_t.dtype), {"conv": conv_buf, "h": hstate}


# ---------------------------------------------------------------------------
# FFN sub-blocks
# ---------------------------------------------------------------------------

def init_ffn(key, arch: ArchConfig, spec: LayerSpec, dtype=jnp.float32):
    d = arch.d_model
    if spec.ffn == "dense":
        f = arch.d_ff
        ks = jax.random.split(key, 2)
        return {"ln2": jnp.zeros((d,), jnp.float32),
                "wi": layers.dense_init(ks[0], d, 2 * f, dtype=dtype),
                "wo_f": layers.dense_init(ks[1], f, d, dtype=dtype)}
    if spec.ffn == "moe":
        dims = _moe_dims(arch)
        p = moe_lib.init_moe_params(key, dims, dtype)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        return p
    return {}


def _moe_dims(arch: ArchConfig) -> moe_lib.MoeDims:
    return moe_lib.MoeDims(d_model=arch.d_model, d_ff=arch.d_ff_expert,
                           n_experts=arch.n_experts, top_k=arch.top_k,
                           n_shared=arch.n_shared_experts)


def ffn_taps(arch: ArchConfig, spec: LayerSpec) -> Dict[str, tuple]:
    d = arch.d_model
    if spec.ffn == "dense":
        return {"ffn_wi": tap_dims(d, 2 * arch.d_ff),
                "ffn_wo": tap_dims(arch.d_ff, d)}
    if spec.ffn == "moe":
        f = arch.d_ff_expert
        t = {"moe_wi": tap_dims(d, 2 * f, (arch.n_experts,)),
             "moe_wo": tap_dims(f, d, (arch.n_experts,))}
        if arch.n_shared_experts:
            fs = f * arch.n_shared_experts
            t["shared_wi"] = tap_dims(d, 2 * fs)
            t["shared_wo"] = tap_dims(fs, d)
        return t
    return {}


def apply_ffn(spec: LayerSpec, arch: ArchConfig, p, h, tc: TapCtx,
              sp: ShardPolicy) -> Tuple[Array, Array]:
    """Returns (h, aux_loss)."""
    zero = jnp.zeros((), jnp.float32)
    if spec.ffn == "none":
        return h, zero
    x = sp.full_seq(layers.rms_norm(h, p["ln2"]))
    if spec.ffn == "dense":
        u = tc.mm("ffn_wi", p["wi"], x)
        gate, up = jnp.split(u, 2, axis=-1)
        gate, up = sp.ffn_hidden(gate), sp.ffn_hidden(up)
        y = tc.mm("ffn_wo", p["wo_f"], jax.nn.silu(gate) * up)
        return sp.residual(h + y.astype(h.dtype)), zero
    # MoE
    dims = _moe_dims(arch)
    probes = {"moe/" + k[len(tc.prefix):]: v for k, v in tc.probes.items()
              if k.startswith(tc.prefix)}
    acts: Dict[str, Array] = {}
    y, aux = moe_lib.moe_block(x, p, dims, probes, acts, "moe",
                               tc.n_stat)
    for k, v in acts.items():
        tc.acts[f"{tc.prefix}{k.split('/', 1)[1]}"] = v
    return sp.residual(h + y.astype(h.dtype)), aux


# ---------------------------------------------------------------------------
# whole-block dispatch
# ---------------------------------------------------------------------------

_MIXERS = {
    "gqa": (init_gqa, apply_gqa, decode_gqa, gqa_cache_init, gqa_taps),
    "mla": (init_mla, apply_mla, decode_mla, mla_cache_init, mla_taps),
    "ssm": (init_ssm, apply_ssm, decode_ssm, ssm_cache_init, ssm_taps),
    "rglru": (init_rglru, apply_rglru, decode_rglru, rglru_cache_init,
              rglru_taps),
}


def init_block(key, arch: ArchConfig, spec: LayerSpec, cross=False,
               dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    init_fn = _MIXERS[spec.mixer][0]
    mix = (init_fn(k1, arch, cross=cross, dtype=dtype)
           if spec.mixer == "gqa" else init_fn(k1, arch, dtype=dtype))
    return {"mix": mix, "ffn": init_ffn(k2, arch, spec, dtype=dtype)}


def block_taps(arch: ArchConfig, spec: LayerSpec, cross=False
               ) -> Dict[str, tuple]:
    taps_fn = _MIXERS[spec.mixer][4]
    t = dict(taps_fn(arch, cross=cross) if spec.mixer == "gqa"
             else taps_fn(arch))
    t.update(ffn_taps(arch, spec))
    return t


def apply_block(arch: ArchConfig, spec: LayerSpec, p, h, tc: TapCtx,
                positions, sp: ShardPolicy, memory=None):
    apply_fn = _MIXERS[spec.mixer][1]
    if spec.mixer == "gqa":
        h = apply_fn(spec, arch, p["mix"], h, tc, positions, sp,
                     memory=memory)
    else:
        h = apply_fn(spec, arch, p["mix"], h, tc, positions, sp)
    return apply_ffn(spec, arch, p["ffn"], h, tc, sp)


def block_cache_init(arch: ArchConfig, spec: LayerSpec, B, S, dtype,
                     cross_len=0, window_caches=False, kv_rep=1):
    fn = _MIXERS[spec.mixer][3]
    if spec.mixer == "gqa":
        return fn(arch, B, S, dtype, cross_len=cross_len, spec=spec,
                  window_caches=window_caches, kv_rep=kv_rep)
    return fn(arch, B, S, dtype)


def decode_block(arch: ArchConfig, spec: LayerSpec, p, h_t, cache, t,
                 sp: ShardPolicy):
    h_t, new_cache = _MIXERS[spec.mixer][2](spec, arch, p["mix"], h_t,
                                            cache, t, sp)
    p = p["ffn"]
    if spec.ffn == "dense":
        x = layers.rms_norm(h_t, p["ln2"])
        u = x @ p["wi"].astype(x.dtype)
        gate, up = jnp.split(u, 2, axis=-1)
        y = (jax.nn.silu(gate) * up) @ p["wo_f"].astype(x.dtype)
        h_t = h_t + y.astype(h_t.dtype)
    elif spec.ffn == "moe":
        dims = _moe_dims(arch)
        B = h_t.shape[0]
        x = layers.rms_norm(h_t, p["ln2"]).reshape(B, -1)
        w, idx, _ = moe_lib.route(x, p["router"], dims)
        # decode: tiny token count — dense "all experts" dispatch is cheapest
        cap = max(8, min(B * dims.top_k, B))
        buffers, info = moe_lib.dispatch(x, idx, dims, cap)
        def one(buf, wi, wo):
            u = buf @ wi.astype(buf.dtype)
            g, up2 = jnp.split(u, 2, axis=-1)
            return (jax.nn.silu(g) * up2) @ wo.astype(buf.dtype)
        out = jax.vmap(one)(buffers, p["wi"], p["wo"])
        y = moe_lib.combine(out, w, info, B)
        if dims.n_shared:
            u = x @ p["shared_wi"].astype(x.dtype)
            g, up2 = jnp.split(u, 2, axis=-1)
            y = y + ((jax.nn.silu(g) * up2)
                     @ p["shared_wo"].astype(x.dtype)).astype(jnp.float32)
        h_t = h_t + y.reshape(h_t.shape).astype(h_t.dtype)
    return h_t, new_cache

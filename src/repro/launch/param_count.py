"""Analytic parameter / FLOP counting from an ArchConfig — used by the
smoke tests (scale sanity) and the roofline (MODEL_FLOPS = 6·N·D terms,
with N_active for MoE)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, LayerSpec


def _block_params(arch: ArchConfig, spec: LayerSpec, active_only=False
                  ) -> int:
    d = arch.d_model
    H, Hk, hd = arch.n_heads, arch.n_kv_heads, arch.hd
    n = 0
    if spec.mixer == "gqa":
        n += d * H * hd + d * 2 * Hk * hd + H * hd * d
        if arch.qkv_bias:
            n += H * hd + 2 * Hk * hd
    elif spec.mixer == "mla":
        dn, dr, dv = arch.mla_qk_nope, arch.mla_qk_rope, arch.mla_v_head
        ql, kl = arch.mla_q_lora, arch.mla_kv_lora
        n += d * ql + ql * H * (dn + dr) + d * (kl + dr) + \
            kl * H * (dn + dv) + H * dv * d
    elif spec.mixer == "ssm":
        d_inner = arch.ssm_expand * d
        Hs = d_inner // arch.ssm_head_dim
        G, N = arch.ssm_groups, arch.ssm_state
        in_dim = 2 * d_inner + 2 * G * N + Hs
        n += d * in_dim + d_inner * d + arch.conv_k * (d_inner + 2 * G * N)
    elif spec.mixer == "rglru":
        D = arch.lru_width
        n += d * 2 * D + D * 2 * D + D * d + arch.conv_k * D
    if spec.ffn == "dense":
        n += d * 2 * arch.d_ff + arch.d_ff * d
    elif spec.ffn == "moe":
        f = arch.d_ff_expert
        per_expert = d * 2 * f + f * d
        n_routed = arch.top_k if active_only else arch.n_experts
        n += n_routed * per_expert + d * arch.n_experts  # + router
        if arch.n_shared_experts:
            fs = f * arch.n_shared_experts
            n += d * 2 * fs + fs * d
    return n


def count_params(arch: ArchConfig, active_only: bool = False) -> int:
    n = arch.vocab * arch.d_model            # embed
    n += arch.d_model * arch.vocab           # head
    for seg in arch.segments:
        for spec in seg.pattern:
            n += seg.repeats * _block_params(arch, spec, active_only)
    if arch.is_encdec:
        enc = LayerSpec(mixer="gqa", ffn="dense", causal=arch.enc_causal)
        n += arch.n_enc_layers * _block_params(arch, enc)
        # decoder cross-attention
        H, Hk, hd = arch.n_heads, arch.n_kv_heads, arch.hd
        d = arch.d_model
        n += arch.n_layers * (d * H * hd + d * 2 * Hk * hd + H * hd * d)
    if arch.mtp:
        n += arch.d_model * arch.d_model
    return n


def model_flops_per_token(arch: ArchConfig, train: bool = True) -> float:
    """MODEL_FLOPS/token = 6·N_active (train) or 2·N_active (inference)."""
    n_active = count_params(arch, active_only=True)
    return (6.0 if train else 2.0) * n_active

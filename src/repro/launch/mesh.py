"""Production mesh construction (assignment spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain 512 placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic fallback shapes, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """All non-model axes (batch/token sharding)."""
    return tuple(a for a in mesh.axis_names if a != "model")

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture × input-shape) cell, lower + compile the relevant
step on the production mesh (16×16 single-pod and 2×16×16 multi-pod),
record memory_analysis / cost_analysis / collective bytes, and — single-pod
only — lower *unrolled probe models* (1 and 2 pattern-repeats per segment)
to recover the scan-body costs that XLA's cost analysis counts only once
(while-loop bodies are visited once; measured in this repo: a 10-step scan
reports 1/10 the flops of its unrolled equivalent).  Corrected totals:

    X_corrected = X_full + Σ_seg (reps_seg − 1) · (X_probe2_seg − X_probe1)

Usage:
    python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json (cached).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import base as cfg_base
from repro.configs.base import ARCH_NAMES, SHAPES, cell_applicable, get_arch
from repro.launch import hlo_analysis, steps
from repro.launch.mesh import make_production_mesh
from repro.launch.param_count import count_params, model_flops_per_token

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# v5e hardware model (assignment constants)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def _scale_segments(arch, reps_map):
    """arch with segment repeats overridden: reps_map[i] (enc = 'enc')."""
    segs = tuple(
        dataclasses.replace(seg, repeats=reps_map.get(i, 1))
        for i, seg in enumerate(arch.segments))
    enc = reps_map.get("enc", 1) if arch.is_encdec else arch.n_enc_layers
    return dataclasses.replace(arch, segments=segs,
                               n_enc_layers=enc if arch.is_encdec else
                               arch.n_enc_layers)


def _segment_ids(arch):
    ids = list(range(len(arch.segments)))
    if arch.is_encdec:
        ids.append("enc")
    return ids


def _lower_cell(arch, shape_name, mesh, unroll=False, opt=""):
    cell = SHAPES[shape_name]
    with mesh:
        return _lower_cell_inner(arch, cell, mesh, unroll, opt)


def _lower_cell_inner(arch, cell, mesh, unroll, opt=""):
    if cell.kind == "train":
        built = steps.build_train_step(arch, mesh, cell=cell, unroll=unroll,
                                       plan="fsdp" if opt == "fsdp" else
                                       "tp")
        fn = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings,
                     donate_argnums=(0, 1))
        args = (built.abstract_params, built.abstract_opt,
                built.batch_specs, jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
        lowered = fn.lower(*args)
    elif cell.kind == "prefill":
        built = steps.build_prefill_step(arch, mesh, cell=cell,
                                         unroll=unroll)
        fn = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings)
        lowered = fn.lower(built.abstract_params, *built.arg_specs)
    else:
        kv = dict(cache_layout="heads", window_caches=True) \
            if opt == "kvopt" else {}
        built = steps.build_decode_step(arch, mesh, cell=cell,
                                        unroll=unroll, **kv)
        fn = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings,
                     donate_argnums=(1,))
        lowered = fn.lower(built.abstract_params, *built.arg_specs)
    return lowered


def _analyse(lowered, n_devices):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll, by_kind = hlo_analysis.collective_bytes(text)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "dot_flops": float(hlo_analysis.dot_flops(text)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll),
        "collectives": by_kind,
        "n_devices": n_devices,
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    return out


def _probe_costs(arch, shape_name, mesh, opt=""):
    """Per-segment scan-body costs via unrolled 1- vs 2-repeat lowerings."""
    base_arch = _scale_segments(arch, {})
    base = _analyse(_lower_cell(base_arch, shape_name, mesh, unroll=True,
                                opt=opt), mesh.size)
    seg_costs = {}
    for sid in _segment_ids(arch):
        arch2 = _scale_segments(arch, {sid: 2})
        two = _analyse(_lower_cell(arch2, shape_name, mesh, unroll=True,
                                   opt=opt), mesh.size)
        seg_costs[str(sid)] = {
            k: max(two[k] - base[k], 0.0)
            for k in ("flops", "dot_flops", "bytes", "collective_bytes")}
    return base, seg_costs


def _corrected(full: Dict, base: Dict, seg_costs: Dict, arch) -> Dict:
    out = {}
    reps = {str(i): seg.repeats for i, seg in enumerate(arch.segments)}
    if arch.is_encdec:
        reps["enc"] = arch.n_enc_layers
    for key in ("flops", "dot_flops", "bytes", "collective_bytes"):
        extra = sum((reps[sid] - 1) * seg_costs[sid][key]
                    for sid in seg_costs)
        out[key + "_corrected"] = full[key] + extra
    return out


def roofline_terms(rec: Dict, n_devices: int) -> Dict:
    """Three roofline terms (seconds).  cost_analysis runs on the SPMD-
    partitioned module, so flops/bytes are PER-DEVICE (verified in-repo:
    a (1024³) matmul on 64 devices reports 2·1024³/64) — no further
    division by chip count.

    compute term — parsed dot FLOPs (MXU work; cost_analysis 'flops' is
      polluted by CPU-backend bf16→f32 legalization converts);
    memory term — per-device buffer-traffic estimate: argument + output +
      temp sizes from memory_analysis (each buffer read/written ≈ once per
      step under fusion; 'bytes accessed' double-counts legalization
      copies);
    collective term — parsed per-device collective volume (HLO text),
      scan-corrected like the FLOPs."""
    f = rec.get("dot_flops_corrected", rec.get("dot_flops", rec["flops"]))
    b = (rec.get("argument_size_in_bytes", 0) +
         rec.get("output_size_in_bytes", 0) +
         rec.get("temp_size_in_bytes", 0)) or rec["bytes"]
    c = rec.get("collective_bytes_corrected", rec["collective_bytes"])
    t_comp = f / PEAK_FLOPS
    t_mem = b / HBM_BW
    t_coll = c / ICI_BW
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "bottleneck": dom[1],
            "roofline_fraction": (max(t_comp, 1e-30) /
                                  max(t_comp, t_mem, t_coll))}


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             probes: bool = True, force: bool = False, opt: str = "") -> Dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{opt}" if opt else ""
    out_path = os.path.join(
        RESULTS_DIR, f"{arch_name}__{shape_name}__{mesh_tag}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    arch = get_arch(arch_name)
    ok, reason = cell_applicable(arch, shape_name)
    rec: Dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                 "opt": opt, "time": time.time()}
    if not ok:
        rec.update(status="skipped", reason=reason)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        lowered = _lower_cell(arch, shape_name, mesh, opt=opt)
        rec["lower_s"] = time.time() - t0
        t0 = time.time()
        rec.update(_analyse(lowered, mesh.size))
        rec["compile_s"] = time.time() - t0
        rec["status"] = "ok"
        if probes and not multi_pod:
            t0 = time.time()
            base, seg_costs = _probe_costs(arch, shape_name, mesh, opt=opt)
            rec["probe_base"] = {k: base[k] for k in
                                 ("flops", "dot_flops", "bytes",
                                  "collective_bytes")}
            rec["probe_segments"] = seg_costs
            rec.update(_corrected(rec, base, seg_costs, arch))
            rec["probe_s"] = time.time() - t0
            rec["roofline"] = roofline_terms(rec, mesh.size)
            # analytic model flops (6·N_active·D) for the waste ratio
            cellk = SHAPES[shape_name].kind
            n_tok = (steps.n_tokens_of(arch, SHAPES[shape_name])
                     if cellk == "train" else
                     SHAPES[shape_name].global_batch *
                     (SHAPES[shape_name].seq_len if cellk == "prefill"
                      else 1))
            mf = model_flops_per_token(arch, train=(cellk == "train"))
            rec["model_flops"] = mf * n_tok
            fc = rec.get("dot_flops_corrected", rec["dot_flops"])
            rec["useful_flops_ratio"] = rec["model_flops"] / max(
                fc * rec["n_devices"], 1.0)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="", choices=("", "kvopt", "fsdp"))
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for a, s in cells:
        for mp in meshes:
            t0 = time.time()
            try:
                rec = run_cell(a, s, mp, probes=not args.no_probes,
                               force=args.force, opt=args.opt)
                status = rec.get("status")
                extra = (f" bottleneck={rec['roofline']['bottleneck']}"
                         if "roofline" in rec else "")
                print(f"[dryrun] {a} {s} multi_pod={mp}: {status} "
                      f"({time.time()-t0:.0f}s){extra}", flush=True)
                if status == "ok":
                    print(f"  dot_flops={rec['dot_flops']:.3e} "
                          f"corrected={rec.get('dot_flops_corrected', 0):.3e} "
                          f"coll={rec['collective_bytes']:.3e} "
                          f"temp_bytes={rec.get('temp_size_in_bytes', 0):,}",
                          flush=True)
            except Exception as e:
                print(f"[dryrun] {a} {s} multi_pod={mp}: FAILED {e}",
                      flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()

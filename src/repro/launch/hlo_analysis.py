"""HLO post-processing for the roofline: collective-bytes accounting.

``collective_bytes`` parses the optimized HLO text of a compiled module and
sums the data volume moved by every collective op.  Method (documented per
assignment):

  * all-gather / all-to-all / collective-permute / all-reduce: the output
    tensor size is the per-device moved volume (ring all-reduce moves ~2×
    the tensor — we report the tensor size; the 2× is folded into the
    link-bandwidth model notes);
  * reduce-scatter: output is the shard — scaled by the replica-group size
    to recover the operand volume;
  * ops inside `while` bodies appear once in the text — the dry-run scales
    them by the scan trip count via the probe-lowering diffs
    (launch/dryrun.py), exactly like the FLOP correction.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,1024,512]{2,1,0} all-gather(bf16[2,64,512] %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:f|bf|s|u|pred|c)\d*)\[([\d,]*)\][^ ]*\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(r"%([\w.-]+)\s*=\s*((?:f|bf|s|u|pred|c)\d*)"
                     r"\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"%[\w.-]+\s*=\s*(?:f|bf|s|u|c)\d*\[([\d,]*)\][^ ]*\s+dot\("
    r"[^%]*%([\w.-]+)[^%]*%([\w.-]+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def dot_flops(hlo_text: str) -> float:
    """Matmul FLOPs from the partitioned HLO: Σ over dot ops of
    2 · |output| · Π(lhs contracting dims).

    Rationale: XLA:CPU legalizes bf16 through f32 converts, so
    ``cost_analysis()['flops']`` is polluted by elementwise legalization
    traffic that a TPU would never execute; MXU work (dots) is the
    meaningful compute-roofline numerator and parses exactly.
    """
    shapes: Dict[str, Tuple[int, ...]] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        d = _DEF_RE.search(line)
        if d:
            name, _, dims = d.groups()
            shapes[name] = tuple(int(x) for x in dims.split(",") if x)
        m = _DOT_RE.search(line)
        if not m:
            continue
        out_dims, lhs_name, _ = m.groups()
        out_n = 1
        for x in out_dims.split(","):
            if x:
                out_n *= int(x)
        lc = _LHS_C_RE.search(line)
        contract = 1
        lhs_shape = shapes.get(lhs_name, ())
        if lc and lhs_shape:
            for idx in lc.group(1).split(","):
                if idx and int(idx) < len(lhs_shape):
                    contract *= lhs_shape[int(idx)]
        total += 2.0 * out_n * contract
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """→ (total_bytes, per-kind breakdown). 'start' ops only (async pairs
    would double-count); sync ops have no suffix and count once."""
    total = 0
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k + "_count": 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        if kind == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                group_size = len([x for x in g.group(1).split(",") if x])
                nbytes *= max(group_size, 1)
        total += nbytes
        by_kind[kind] += nbytes
        counts[kind + "_count"] += 1
    by_kind.update(counts)
    return total, by_kind

"""Production trainer entry point.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3_4b \
        --steps 100 [--variant bkfac] [--mesh 16x16|2x16x16|none] \
        [--ckpt-dir /path] [--compress] [--reduced]

On real hardware the mesh comes from the actual devices; ``--reduced``
trains the CPU-scale config of the same family (CI / this container).
Composes: model zoo + K-FAC optimizer + deterministic data + async
checkpointing + straggler detector + (optional) gradient compression.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_NAMES, SHAPES, get_arch
from repro.core import kfac as kfac_lib
from repro.core import policy as policy_lib
from repro.data.synthetic import TokenStream
from repro.distributed import compress as compress_lib
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, make_mesh
from repro.models import layers
from repro.models.lm import LM
from repro.obs import events as obs_events
from repro.obs import trace as obs_trace
from repro.optim import base as optbase
from repro.train import checkpoint as ckpt
from repro.train import health as health_lib
from repro.train import loop as loop_lib
from repro.train import straggler as strag_lib
from repro import specs as specs_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_4b", choices=ARCH_NAMES)
    ap.add_argument("--variant", default="bkfac",
                    choices=list(policy_lib.VARIANTS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="none",
                    help="none | 16x16 | 2x16x16 | AxB (custom)")
    ap.add_argument("--mesh-axes", default="",
                    help="comma-separated axis names for a custom --mesh "
                         "AxB, e.g. 'data,curv' for the 2D "
                         "data × curvature mesh (default: data,model)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config of the same family")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress", action="store_true",
                    help="PowerSGD-style DP gradient compression (error "
                         "feedback + warm-started power iteration)")
    ap.add_argument("--curvature-compress", type=int, default=0,
                    help="rank-q compression of the curvature engine's "
                         "(U, λ) cross-axis gathers (0 = raw gathers); "
                         "lossy — trades a little factor accuracy for "
                         "O(d·q) instead of O(d·r) bytes on the wire")
    ap.add_argument("--stagger", dest="stagger", action="store_true",
                    default=True,
                    help="phase heavy factor work across the T_inv window "
                         "(constant per-step cost instead of a spike)")
    ap.add_argument("--no-stagger", dest="stagger", action="store_false")
    ap.add_argument("--stagger-splits", type=int, default=4,
                    help="max entry-aligned chunks per factor bucket")
    ap.add_argument("--async-heavy", dest="async_heavy",
                    action="store_true",
                    help="two-phase launch/land heavy pipeline: heavy "
                         "overwrites compute against a snapshot and swap "
                         "in --heavy-lag steps later (overlapped with "
                         "training on a spare device when replicated)")
    ap.add_argument("--heavy-lag", type=int, default=2,
                    help="steps between a heavy launch (snapshot) and "
                         "its landing (swap-in); 0 = same-step (exactly "
                         "the synchronous numerics)")
    ap.add_argument("--curvature", default="auto",
                    choices=("auto", "none"),
                    help="auto: shard factor work across the mesh's first "
                         "data axis (distributed curvature engine)")
    ap.add_argument("--health", action="store_true",
                    help="enable the in-graph health guards + staged "
                         "remediation ladder (skip / damping escalation "
                         "/ forced refresh / checkpoint rollback — the "
                         "last needs --ckpt-dir).  Bit-inert on healthy "
                         "runs (train/health.py)")
    ap.add_argument("--telemetry-dir", default="",
                    help="write the structured JSONL event log to "
                         "<dir>/events.jsonl (repro.obs; feed it to "
                         "`python -m repro.obs.summary`)")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="in-graph curvature-metric flush cadence in "
                         "steps (needs --telemetry-dir; 0 disables)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of a short step "
                         "window into this directory")
    ap.add_argument("--profile-steps", type=int, default=3,
                    help="steps in the --profile-dir trace window")
    args = ap.parse_args()

    jsonl = (os.path.join(args.telemetry_dir, "events.jsonl")
             if args.telemetry_dir else None)
    if jsonl is not None:
        os.makedirs(args.telemetry_dir, exist_ok=True)
    writer = obs_events.TelemetryWriter(path=jsonl, console=True)
    writer.emit("run_start", config={
        "arch": args.arch, "variant": args.variant, "steps": args.steps,
        "batch": args.batch, "seq": args.seq, "mesh": args.mesh,
        "reduced": args.reduced, "stagger": args.stagger,
        "async_heavy": args.async_heavy, "heavy_lag": args.heavy_lag,
        "metrics_every": args.metrics_every})

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    mesh = None
    if args.mesh == "16x16":
        mesh = make_production_mesh()
    elif args.mesh == "2x16x16":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh not in ("none", ""):
        dims = tuple(int(x) for x in args.mesh.split("x"))
        if args.mesh_axes:
            names = tuple(a.strip() for a in args.mesh_axes.split(","))
            if len(names) != len(dims):
                raise SystemExit(f"--mesh-axes {names} does not match "
                                 f"--mesh {args.mesh}")
        else:
            names = ("data", "model")[: len(dims)]
        mesh = make_mesh(dims, names)

    sp = steps_lib.shard_policy_for(mesh)
    lm = LM(arch, sp, remat=not args.reduced)
    kcfg = steps_lib.default_kfac_config(arch, args.variant)
    if args.reduced:
        kcfg = kfac_lib.KfacConfig(
            policy=policy_lib.PolicyConfig(variant=args.variant, r=32,
                                           max_dense_dim=1024),
            lr=optbase.constant(0.02), damping_phi=optbase.constant(0.1),
            weight_decay=1e-4, clip=0.5, T_updt=2, T_inv=10, T_brand=2,
            T_rsvd=10, T_corct=10, fallback_lr=optbase.constant(3e-3))
    kcfg = dataclasses.replace(kcfg, stagger=args.stagger,
                               stagger_splits=args.stagger_splits,
                               async_heavy=args.async_heavy,
                               heavy_lag=args.heavy_lag if args.async_heavy
                               else 0)
    opt = kfac_lib.Kfac(kcfg, lm.taps)
    curv_axis = None
    row_axis = None
    if args.curvature == "auto" and mesh is not None:
        dp = [a for a in mesh.axis_names if a != "model"]
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if "curv" in sizes and sizes["curv"] > 1:
            # 2D data × curvature mesh: factor *slots* shard over the
            # dedicated curv axis; the dense M *rows* (and the heavy
            # FLOPs on them) shard over the remaining data axis.
            curv_axis = "curv"
            rows = [a for a in dp if a != "curv" and sizes[a] > 1]
            row_axis = rows[0] if rows else None
        elif dp and sizes[dp[0]] > 1:
            curv_axis = dp[0]
    eng = specs_lib.DistSpec(
        mesh=mesh, curvature_axis=curv_axis, row_axis=row_axis,
        curvature_compress=args.curvature_compress or None).attach(opt)
    if eng is not None:
        rep, dev = eng.job_counts()
        writer.log(f"curvature sharded on '{curv_axis}': "
                   f"{rep} factor slots replicated -> {dev}/device "
                   f"({eng.describe()})")
        m_rep, m_dev = eng.m_bytes()
        cb = eng.collective_bytes()
        writer.log(f"dense-M memory: {m_rep / 1e6:.2f} MB replicated -> "
                   f"{m_dev / 1e6:.2f} MB/device; (U, lambda) gather "
                   f"bytes/round: {cb['uncompressed'] / 1e6:.3f} MB raw, "
                   f"{cb['on_wire'] / 1e6:.3f} MB on wire")
    sched = opt.scheduler()
    if args.stagger or args.async_heavy:
        writer.emit("sched",
                    detail=f"heavy-work scheduler: {sched.describe()}")
    runner = (loop_lib.AsyncInverseRunner.for_opt(opt, writer=writer)
              if args.async_heavy else None)
    if runner is not None:
        writer.log(f"async heavy pipeline: lag={kcfg.heavy_lag} offload="
                   f"{'spare device' if runner.device else 'in-thread'}")

    n_tokens = args.batch * args.seq
    stream = TokenStream(vocab=arch.vocab, batch=args.batch,
                         seq_len=args.seq, seed=0)
    params = lm.init(jax.random.PRNGKey(0))
    state = loop_lib.TrainState(params=params, opt=opt.init(params),
                                rng=jax.random.PRNGKey(1))
    if mesh is not None:
        p_sh = shd.params_sharding(params, mesh)
        o_sh = shd.kfac_state_sharding(state.opt, mesh,
                                       curvature_axis=curv_axis,
                                       row_axis=row_axis)
        state = loop_lib.TrainState(
            params=jax.device_put(params, p_sh),
            opt=jax.device_put(state.opt, o_sh), rng=state.rng)

    # DP gradient compression rides as a grad_transform inside the jitted
    # step; its CompressState (error feedback + warm-start Q) is a
    # separate carry, deliberately *outside* TrainState so the checkpoint
    # schema is untouched (a restore simply cold-starts the compressor).
    grad_transform = None
    cstate = None
    if args.compress:
        ccfg = compress_lib.CompressConfig(rank=8)
        cstate = compress_lib.init_state(params, ccfg)
        grad_transform = lambda gp, cs: compress_lib.compress_tree(
            gp, cs, ccfg)
        if args.health:
            writer.log("--compress ignored with --health: the resilient "
                       "step has no gradient-transform hook")
            grad_transform = cstate = None

    meter = None
    if jsonl is not None:
        meter = specs_lib.ObsSpec(
            writer=writer,
            metrics_every=args.metrics_every).make_meter(opt)
    policy = None
    if args.health:
        policy = health_lib.RemediationPolicy(writer=writer)
        step_fn = jax.jit(health_lib.make_resilient_kfac_step(
            lm.loss_fn, opt, n_tokens, meter=meter),
            static_argnames=("work",))
        writer.log("health guards on: staged remediation ladder armed"
                   + ("" if args.ckpt_dir
                      else " (no --ckpt-dir: rollback stage disabled)"))
    else:
        step_fn = jax.jit(loop_lib.make_scheduled_kfac_step(
            lm.loss_fn, opt, n_tokens, meter=meter,
            grad_transform=grad_transform),
            static_argnames=("work",))

    checkpointer = (ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
                    if args.ckpt_dir else None)
    start = ckpt.latest_step(args.ckpt_dir) if args.ckpt_dir else None
    if start is not None:
        state, _ = ckpt.restore(args.ckpt_dir, state)
        writer.emit("ckpt_restore", step=start, path=args.ckpt_dir)
    k0 = 0 if start is None else start + 1

    mesh_txt = ("×".join(f"{a}={s}" for a, s in
                              zip(mesh.axis_names, mesh.devices.shape))
                if mesh is not None else "")
    det = strag_lib.StragglerDetector(writer=writer, mesh_desc=mesh_txt)
    profiler = obs_trace.StepProfiler(args.profile_dir or None,
                                      first=k0 + 1,
                                      steps=args.profile_steps)
    t_start = time.time()
    losses = []
    # the model's internal with_sharding_constraint calls need the mesh
    # context when PartitionSpecs are in play
    ctx = mesh if mesh is not None else contextlib.nullcontext()
    with ctx:
        run_steps(args, sched, det, stream, step_fn, state,
                  checkpointer, k0, t_start, losses, runner=runner,
                  writer=writer, meter=meter, profiler=profiler,
                  policy=policy, opt=opt, cstate=cstate)
    profiler.close()
    if runner is not None:
        runner.close()
    if checkpointer is not None:
        checkpointer.close()
    writer.emit("run_end", steps=len(losses), loss_first=losses[0],
                loss_last=float(np.mean(losses[-3:])),
                s_per_step=(time.time() - t_start) / max(len(losses), 1))
    writer.close()


def run_steps(args, sched, det, stream, step_fn, state, checkpointer,
              k0, t_start, losses, runner=None, writer=None, meter=None,
              profiler=None, policy=None, opt=None, cstate=None):
    mbuf = meter.init() if meter is not None else None
    last_k = k0
    k_off = 0          # rollback re-anchor: schedule runs at k_off + k
    for k in range(k0, args.steps):
        last_k = k
        t0 = time.time()
        kk = k_off + k
        work = sched.work(kk)
        if policy is not None and policy.take_refresh():
            # remediation stage 2: abandon the (possibly poisoned)
            # pipeline, re-establish the inverse rep from the live M
            work = opt.remedial_work()
            state = state._replace(opt=opt.clear_inflight(state.opt))
            if runner is not None:
                runner.drop_pending(reason="dropped")
        actions = det.observe_step(k, {"host0": time.time() - t0 + 1e-6})
        work = strag_lib.apply_to_work(actions.get("host0",
                                                   strag_lib.Action.NONE),
                                       work)
        batch = stream.batch_at(k)
        landing = (runner.landing(work, step=kk)
                   if runner is not None else None)
        if profiler is not None:
            profiler.tick(k)
        report = None
        if policy is not None:
            scale = jnp.float32(policy.damping_scale)
            if meter is None:
                state, loss, report = step_fn(state, batch, work,
                                              landing, None, scale)
            else:
                state, loss, report, mbuf = step_fn(state, batch, work,
                                                    landing, mbuf, scale)
        elif cstate is not None:
            # compressed-DP step: the CompressState carry trails the
            # outputs (after mbuf when a meter is on)
            if meter is None:
                state, loss, cstate = step_fn(state, batch, work, landing,
                                              None, cstate)
            else:
                state, loss, mbuf, cstate = step_fn(state, batch, work,
                                                    landing, mbuf, cstate)
        elif meter is None:
            state, loss = step_fn(state, batch, work, landing)
        else:
            state, loss, mbuf = step_fn(state, batch, work, landing, mbuf)
        if runner is not None:
            runner.launch(state.opt, work, step=kk)
        losses.append(float(loss))
        faulty = False
        if policy is not None:
            rep = {n: float(v) for n, v in
                   jax.device_get(report).items()}
            faulty = policy.observe(kk, losses[-1], rep)
            if policy.take_rollback() and args.ckpt_dir:
                # remediation stage 3: restore the newest snapshot that
                # verifies and re-anchor the staggered cadence on it
                if runner is not None:
                    runner.drop_pending(reason="dropped")
                if checkpointer is not None:
                    checkpointer.wait()
                state, man = ckpt.restore_latest_healthy(args.ckpt_dir,
                                                         state)
                k_off = int(jax.device_get(state.opt.phase)) - (k + 1)
                policy.notify_rollback(kk, man["step"], args.ckpt_dir)
                if writer is not None:
                    writer.emit("ckpt_restore", step=int(man["step"]),
                                path=args.ckpt_dir)
                faulty = False
        if (checkpointer is not None and not faulty
                and k % args.ckpt_every == 0):
            checkpointer.submit(k, state)
            if writer is not None:
                writer.emit("ckpt_save", step=k, path=args.ckpt_dir)
        if writer is not None:
            writer.emit("step", step=kk, loss=float(loss),
                        dt_s=time.time() - t0, phase=work.label)
    if meter is not None:
        meter.drain(mbuf, last_k)
    return state


if __name__ == "__main__":
    main()

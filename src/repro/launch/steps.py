"""Step-function builders for training / prefill / decode, with mesh
shardings — shared by the dry-run, the trainer, and the serving engine.

Everything here works on abstract values (jax.eval_shape) so the dry-run
never allocates the 671B parameter trees it lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import specs as specs_lib
from repro.configs.base import ArchConfig, ShapeCell, SHAPES
from repro.core import kfac as kfac_lib
from repro.core import policy as policy_lib
from repro.distributed import sharding as shd
from repro.models import layers
from repro.models.lm import LM
from repro.models.sharding_policy import ShardPolicy, NO_SHARD
from repro.optim import base as optbase
from repro.train import loop as loop_lib


def shard_policy_for(mesh: Optional[Mesh], shard_kv_seq: bool = False,
                     seq_shard_residual: bool = True) -> ShardPolicy:
    if mesh is None:
        return NO_SHARD
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tp = "model" if "model" in mesh.axis_names else None
    sizes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    return ShardPolicy(dp=dp, tp=tp, seq_shard_residual=seq_shard_residual,
                       shard_kv_seq=shard_kv_seq, axis_sizes=sizes)


def default_kfac_config(arch: ArchConfig, variant: str = "bkfac",
                        use_kernels: bool = False) -> kfac_lib.KfacConfig:
    pol = policy_lib.PolicyConfig(variant=variant, r=256,
                                  max_dense_dim=8192)
    return kfac_lib.KfacConfig(
        policy=pol,
        lr=optbase.constant(0.3),
        damping_phi=optbase.constant(0.1),
        weight_decay=7e-4, clip=0.07,
        use_kernels=use_kernels,
        T_updt=25, T_inv=250, T_brand=25, T_rsvd=250, T_corct=500,
        fallback_lr=optbase.constant(1e-3))


@dataclasses.dataclass
class BuiltTrain:
    lm: LM
    opt: kfac_lib.Kfac
    step_fn: Any                 # (params, opt_state, batch, rng) -> ...
    abstract_params: Any
    abstract_opt: Any
    in_shardings: Any
    out_shardings: Any
    batch_specs: Dict[str, jax.ShapeDtypeStruct]


def train_batch_specs(arch: ArchConfig, cell: ShapeCell
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    B, T = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if arch.is_encdec:
        Td = max(T // arch.dec_ratio, 8)
        return {"frames": jax.ShapeDtypeStruct((B, T, arch.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((B, Td), i32),
                "targets": jax.ShapeDtypeStruct((B, Td), i32)}
    if arch.frontend == "vision":
        Tt = T - arch.n_prefix
        return {"embeds": jax.ShapeDtypeStruct(
                    (B, arch.n_prefix, arch.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((B, Tt), i32),
                "targets": jax.ShapeDtypeStruct((B, Tt), i32)}
    return {"tokens": jax.ShapeDtypeStruct((B, T), i32),
            "targets": jax.ShapeDtypeStruct((B, T), i32)}


def n_tokens_of(arch: ArchConfig, cell: ShapeCell) -> int:
    specs = train_batch_specs(arch, cell)
    return int(specs["tokens"].shape[0] * specs["tokens"].shape[1])


def build_train_step(arch: ArchConfig, mesh: Optional[Mesh] = None,
                     variant: str = "bkfac", unroll: bool = False,
                     cell: Optional[ShapeCell] = None,
                     flags: Optional[Dict[str, bool]] = None,
                     work=None, curvature_axis: Optional[str] = None,
                     remat: bool = True, plan: str = "tp",
                     async_heavy: bool = False,
                     heavy_lag: int = 0,
                     dist: Optional[specs_lib.DistSpec] = None
                     ) -> BuiltTrain:
    """``work`` (a schedule.StepWork) supersedes ``flags`` when given —
    the dry-run lowers the exact staggered step variant the scheduler
    would dispatch.  ``dist`` (a :class:`repro.specs.DistSpec`) is the
    spec-level spelling of the ``mesh``/``curvature_axis`` pair: its mesh
    shards the model (plan-dependent) and its curvature_axis shards the
    bucketed factor work via the distributed curvature engine
    (row_axis/curvature_compress ride along).  The loose pair keeps
    working but may not be mixed with ``dist``.  ``async_heavy``/
    ``heavy_lag`` enable the double-buffered heavy pipeline (the dry-run
    then lowers launch/land step variants and the optimizer state
    carries the in-flight buffers)."""
    if dist is not None:
        if mesh is not None or curvature_axis is not None:
            raise ValueError("build_train_step: pass dist= OR the loose "
                             "mesh=/curvature_axis= pair, not both")
        mesh, curvature_axis = dist.mesh, dist.curvature_axis
    else:
        dist = specs_lib.DistSpec(mesh=mesh, curvature_axis=curvature_axis)
    cell = cell or SHAPES["train_4k"]
    flags = flags or dict(do_stats=True, do_light=True, do_heavy=False)
    if plan == "fsdp" and mesh is not None:
        sizes = tuple(zip(mesh.axis_names, mesh.devices.shape))
        sp = ShardPolicy(dp=tuple(mesh.axis_names), tp=None,
                         seq_shard_residual=False, axis_sizes=sizes)
    else:
        sp = shard_policy_for(mesh)
    lm = LM(arch, sp, remat=remat, unroll=unroll)
    kcfg = default_kfac_config(arch, variant)
    if async_heavy:
        kcfg = dataclasses.replace(kcfg, async_heavy=True,
                                   heavy_lag=heavy_lag)
    opt = kfac_lib.Kfac(kcfg, lm.taps)
    dist.attach(opt)
    n_tokens = n_tokens_of(arch, cell)
    step_work = work if work is not None else opt.uniform_work(**flags)

    def train_step(params, opt_state, batch, rng):
        probes = layers.make_probes(opt.taps, jnp.float32)
        loss, acts, gp, gprobe = loop_lib.kfac_grads(
            lm.loss_fn, params, probes, batch)
        updates, opt_state = opt.update(
            gp, opt_state, params, acts=acts, probe_grads=gprobe,
            n_tokens=n_tokens, rng=rng, work=step_work)
        params = optbase.apply_updates(params, updates)
        return params, opt_state, loss

    key = jax.random.PRNGKey(0)
    abstract_params = jax.eval_shape(lm.init, key)
    abstract_opt = jax.eval_shape(opt.init, abstract_params)
    batch_specs = train_batch_specs(arch, cell)
    in_sh = out_sh = None
    if mesh is not None:
        if plan == "fsdp":
            p_sh = shd.params_sharding_fsdp(abstract_params, mesh)
            o_sh = shd.params_sharding_fsdp(abstract_opt, mesh)
            dp_all = tuple(mesh.axis_names)
            b_sh = jax.tree_util.tree_map(
                lambda leaf: NamedSharding(
                    mesh, P(*((dp_all,) + (None,) * (leaf.ndim - 1)))),
                batch_specs)
        else:
            p_sh = shd.params_sharding(abstract_params, mesh)
            o_sh = shd.kfac_state_sharding(abstract_opt, mesh,
                                           curvature_axis=curvature_axis)
            b_sh = shd.batch_sharding(batch_specs, mesh)
        r_sh = NamedSharding(mesh, P())
        in_sh = (p_sh, o_sh, b_sh, r_sh)
        out_sh = (p_sh, o_sh, NamedSharding(mesh, P()))
    return BuiltTrain(lm=lm, opt=opt, step_fn=train_step,
                      abstract_params=abstract_params,
                      abstract_opt=abstract_opt,
                      in_shardings=in_sh, out_shardings=out_sh,
                      batch_specs=batch_specs)


@dataclasses.dataclass
class BuiltServe:
    lm: LM
    step_fn: Any
    abstract_params: Any
    arg_specs: Tuple
    in_shardings: Any
    out_shardings: Any


def build_prefill_step(arch: ArchConfig, mesh: Optional[Mesh] = None,
                       cell: Optional[ShapeCell] = None,
                       unroll: bool = False) -> BuiltServe:
    cell = cell or SHAPES["prefill_32k"]
    sp = shard_policy_for(mesh)
    lm = LM(arch, sp, remat=False, unroll=unroll)
    batch_specs = train_batch_specs(arch, cell)
    batch_specs.pop("targets")

    def prefill(params, batch):
        logits, _, _, _ = lm.forward(params, batch, train=False)
        return logits

    abstract_params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    in_sh = out_sh = None
    if mesh is not None:
        p_sh = shd.params_sharding(abstract_params, mesh)
        b_sh = shd.batch_sharding(batch_specs, mesh)
        dp = tuple(a for a in mesh.axis_names if a != "model")
        in_sh = (p_sh, b_sh)
        logits_shape = (cell.global_batch, 1, arch.vocab)
        out_sh = NamedSharding(mesh, shd.fit_spec(P(dp, None, "model"),
                                                  logits_shape, mesh))
    return BuiltServe(lm=lm, step_fn=prefill,
                      abstract_params=abstract_params,
                      arg_specs=(batch_specs,), in_shardings=in_sh,
                      out_shardings=out_sh)


def kv_rep_for(arch: ArchConfig, mesh: Optional[Mesh]) -> int:
    """Smallest KV-head replication r with (Hk·r) divisible by the model
    axis and r dividing the GQA group (so H/(Hk·r) stays integral)."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    Hk, G = arch.n_kv_heads, arch.n_heads // arch.n_kv_heads
    for r in range(1, G + 1):
        if G % r == 0 and (Hk * r) % tp == 0:
            return r
    return 1


def build_decode_step(arch: ArchConfig, mesh: Optional[Mesh] = None,
                      cell: Optional[ShapeCell] = None,
                      unroll: bool = False,
                      cache_layout: str = "seq",
                      window_caches: bool = False) -> BuiltServe:
    cell = cell or SHAPES["decode_32k"]
    B, S = cell.global_batch, cell.seq_len
    shard_seq = cell.name == "long_500k"
    kv_rep = 1
    if cache_layout == "heads" and not shard_seq:
        kv_rep = kv_rep_for(arch, mesh)
        if kv_rep == 1 and mesh is not None:
            tp = dict(zip(mesh.axis_names,
                          mesh.devices.shape)).get("model", 1)
            if arch.n_kv_heads % tp != 0:
                # heads unrealizable → shard head_dim (always 128/256)
                cache_layout = "hd" if arch.hd % tp == 0 else "seq"
    small_thr = 0   # batch layout for small rings: REFUTED (see §Perf)
    sp = shard_policy_for(mesh, shard_kv_seq=shard_seq)
    if sp.active:
        sp = ShardPolicy(**{**sp.__dict__, "kv_cache_layout": cache_layout,
                            "kv_small_seq_threshold": small_thr})
    lm = LM(arch, sp, remat=False, unroll=unroll)
    cross_len = S if arch.is_encdec else 0
    S_self = max(S // arch.dec_ratio, 64) if arch.is_encdec else S

    def decode(params, cache, token, t):
        return lm.decode_step(params, cache, token, t)

    abstract_params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    abstract_cache = jax.eval_shape(
        lambda: lm.init_cache(B, S_self, cross_len=cross_len,
                              window_caches=window_caches, kv_rep=kv_rep))
    token_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_spec = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = out_sh = None
    if mesh is not None:
        p_sh = shd.params_sharding(abstract_params, mesh)
        c_sh = shd.cache_sharding(abstract_cache, mesh,
                                  shard_seq=shard_seq,
                                  layout=cache_layout,
                                  small_seq_threshold=small_thr)
        dp = tuple(a for a in mesh.axis_names if a != "model")
        tok_sh = NamedSharding(mesh, P() if shard_seq else P(dp, None))
        in_sh = (p_sh, c_sh, tok_sh, NamedSharding(mesh, P()))
        out_logits = P() if shard_seq else P(dp, None, None)
        out_sh = (NamedSharding(mesh, out_logits), c_sh)
    return BuiltServe(lm=lm, step_fn=decode,
                      abstract_params=abstract_params,
                      arg_specs=(abstract_cache, token_spec, t_spec),
                      in_shardings=in_sh, out_shardings=out_sh)

"""Distributed curvature engine: shard the bucketed K-factor pipeline
across one or two mesh axes.

The paper's preconditioning cost is linear in layer size, but a replicated
optimizer still makes *every* device run *every* layer's curvature work —
stats absorbs, Brand panels/CholeskyQR2, and the heavy EVD/RSVD/correction
overwrites are recomputed N-fold on an N-device mesh.  KAISA and the
distributed K-FAC line (PAPERS.md) fix this by assigning each factor to
one device and broadcasting the small inverse representation; this module
is that idea applied to the *bucketed* pipeline of ``core/buckets.py``:

  * each factor bucket's flat batch axis is partitioned across the mesh's
    **curvature axis** with a round-robin slot → device assignment
    (``buckets.shard_perm``): slot ``s`` lives on device ``s % N``, so
    every device owns an equal ``⌈B/N⌉`` share of every bucket;
  * inside ``jax.experimental.shard_map`` each device runs the SAME
    per-bucket program as the replicated path
    (``kfactor.bucket_factor_step``) on its local shard — stats, Brand,
    and the scheduled heavy ranges all cost 1/N of the replicated work;
  * the updated low-rank reps (U, λ) are **all-gathered** — they are
    O(d·r) per factor, far cheaper to communicate than to recompute —
    while the dense EA factor M (O(d²)) is *never all-gathered across
    the curvature axis*: only the slot's owning device ever reads it, so
    its out_spec keeps it sharded there.

2D mesh (``row_axis``) — the scale-out generalization
-----------------------------------------------------
With a second mesh axis (canonically ``data`` × ``curv``), the engine
additionally shards each bucket's stacked dense M **by rows** over the
``row_axis``: a (B, d, d) bucket M lives as (B/N_curv, d/N_rows, d) per
device — per-device K-factor memory drops from O(d²) to O(d²/N) across
the whole mesh, not just 1/N_curv.  The pieces:

  * **stats** stay exact on row blocks: every element of X Xᵀ is an
    independent full-length dot product, so the EA absorb of a row block
    equals the row block of the EA absorb (``kfactor.ea_update_m_rows``
    — no reduction is ever split);
  * **heavy ops** (EVD / RSVD / Alg-6 correction / Newton–Schulz) need
    the full M of the firing slots, so the engine gathers *only those
    slots'* rows transiently (``all_gather`` over ``row_axis``), splits
    the firing slot range across the row members — heavy FLOPs shard
    over BOTH axes — and re-gathers the refreshed (U, λ) chunks.  The
    live M is untouched by every heavy op, so the row-sharded M never
    needs re-scattering;
  * **(U, λ) gathers** can be routed through the PowerSGD projection of
    ``distributed/compress.py`` (``compress_rank=q``): each device
    ships a rank-q (P, Q) pair instead of its (d × width) U block —
    O(d·q) instead of O(d·r) on the wire.  The projection is memoryless
    (recomputed from the exact local U each round, deterministic seeded
    basis, so the error does not accumulate across steps — the stream-EF
    machinery of ``compress_tree`` is for gradient *increments*) but
    lossy, so it is opt-in and excluded from the strict parity contract;
    λ/aux (O(width)) always ride uncompressed.  Every mesh member —
    owner included — uses the *decompressed* U, keeping the logically
    replicated out-spec consistent.

The async double-buffered pipeline composes: row-block stats run first,
and a step whose local shard launches or lands gathers the live and
in-flight M rows transiently around the unchanged
``bucket_factor_step_async`` program (heavy work in the async path
shards across the curvature axis only — the landing math is unchanged).

Work masks from ``core/schedule.py`` compose with sharding: a heavy range
aligned to ``align = N_curv · N_rows`` (the Scheduler's ``align``
contract, consumed by ``Kfac.scheduler``) maps to the same static local
row range on every curvature member AND splits evenly across row
members, so staggering and sharding multiply.

Numerics are exactly those of the replicated bucketed path (same per-slot
programs, same per-slot PRNG keys, row-block-deterministic reductions):
``tests/test_distributed_curvature.py`` and ``tests/test_mesh2d.py``
assert allclose parity (replicated ≡ 1D ≡ 2D) on an 8-device host mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import buckets, kfactor, schedule
from repro.core.kfactor import KFactorState
from repro.distributed import compress as compress_lib
from repro.obs import trace as obs_trace

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static layout of one bucket's batch axis on the curvature axis."""
    total: int                   # true bucket batch
    n: int                       # devices on the curvature axis
    padded: int                  # total padded up to a multiple of n
    perm: Tuple[int, ...]        # device-major round-robin gather indices
    unperm: Tuple[int, ...]      # slot → device-major position

    @classmethod
    def build(cls, total: int, n: int) -> "ShardPlan":
        return cls(total=total, n=n,
                   padded=buckets.padded_total(total, n),
                   perm=tuple(buckets.shard_perm(total, n)),
                   unperm=tuple(buckets.shard_unperm(total, n)))

    @property
    def per_device(self) -> int:
        return self.padded // self.n

    def shard(self, tree):
        """(total, …) leaves → (padded, …) in device-major round-robin
        order (one static take; pad rows wrap onto real slots)."""
        idx = jnp.asarray(self.perm)
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, idx, axis=0), tree)

    def unshard(self, tree):
        """Inverse of :meth:`shard`; drops the pad rows."""
        idx = jnp.asarray(self.unperm)
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, idx, axis=0), tree)


class CurvatureEngine:
    """Runs ``Kfac``'s bucketed factor work sharded over ``mesh[axis]``
    (bucket slots), optionally × ``mesh[row_axis]`` (dense-M rows).

    Attach with ``Kfac(cfg, taps, curvature=engine)`` or
    ``opt.curvature = engine`` — ``Kfac.update`` delegates to
    :meth:`factor_work` whenever an engine is present (bucketed mode).
    The engine is static metadata only (mesh + per-bucket ShardPlans +
    row-block sizes); it owns no arrays.

    ``row_axis`` enables the 2D path: a bucket whose factor side d is
    divisible by the row-axis size keeps its dense M row-sharded there
    (``row_blocks[bi]`` = d / N_rows); non-divisible buckets fall back
    to row-replicated M (matching ``sharding.fit_spec``).
    ``compress_rank`` routes the U all-gather through the PowerSGD
    projection of ``distributed/compress.py`` (lossy, opt-in).
    """

    def __init__(self, mesh: Mesh, axis: str, factor_buckets,
                 row_axis: Optional[str] = None,
                 compress_rank: Optional[int] = None):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}; "
                             f"axes: {mesh.axis_names}")
        if row_axis is not None and row_axis not in mesh.axis_names:
            raise ValueError(f"mesh has no row axis {row_axis!r}; "
                             f"axes: {mesh.axis_names}")
        if row_axis == axis:
            raise ValueError("row_axis must differ from the curvature "
                             f"(slot) axis, both were {axis!r}")
        self.mesh = mesh
        self.axis = axis
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.n_devices = int(sizes[axis])
        self.row_axis = row_axis if (row_axis is not None
                                     and sizes[row_axis] > 1) else None
        self.n_rows = int(sizes[row_axis]) if self.row_axis else 1
        #: scheduler alignment: heavy ranges must split across slots
        #: (curvature axis) AND across row members (heavy chunking)
        self.align = self.n_devices * self.n_rows
        self.compress_rank = (int(compress_rank)
                              if compress_rank else None)
        self.specs = tuple(b.spec for b in factor_buckets)
        self.plans = tuple(ShardPlan.build(b.total, self.n_devices)
                           for b in factor_buckets)
        #: per-bucket local row-block height of the dense M, or None when
        #: the bucket's M stays row-replicated (no row axis / pure-Brand
        #: placeholder / d not divisible by the row-axis size)
        self.row_blocks = tuple(
            (s.d // self.n_rows)
            if (self.row_axis is not None and s.needs_m
                and s.d % self.n_rows == 0) else None
            for s in self.specs)

    @classmethod
    def for_kfac(cls, opt, mesh: Mesh, axis: str,
                 row_axis: Optional[str] = None,
                 compress_rank: Optional[int] = None) -> "CurvatureEngine":
        eng = cls(mesh, axis, opt.factor_buckets, row_axis=row_axis,
                  compress_rank=compress_rank)
        opt.curvature = eng
        return eng

    # -- job accounting (benchmarks / logs) --------------------------------
    def job_counts(self) -> Tuple[int, int]:
        """(replicated, per-device) factor-job slot counts: a replicated
        device steps every slot of every bucket; a sharded device steps
        its ⌈B/N⌉ local shard of each."""
        rep = sum(p.total for p in self.plans)
        dev = sum(p.per_device for p in self.plans)
        return rep, dev

    def m_bytes(self) -> Tuple[int, int]:
        """(replicated, per-device) dense-M bytes across all buckets —
        the memory the row sharding divides.  Per-device M is
        B/N_curv · d/N_rows · d floats for row-sharded buckets."""
        rep = dev = 0
        for spec, plan, rb in zip(self.specs, self.plans,
                                  self.row_blocks):
            if not spec.needs_m:
                continue
            rep += plan.total * spec.d * spec.d * 4
            rows = rb if rb is not None else spec.d
            dev += plan.per_device * rows * spec.d * 4
        return rep, dev

    def collective_bytes(self) -> Dict[str, int]:
        """Static per-full-refresh bytes-on-wire of the (U, λ, aux)
        gathers, computed from the exact traced array shapes:
        ``uncompressed`` is what the raw U gather moves, ``on_wire`` what
        the engine actually ships (rank-q (P, Q) pairs under
        ``compress_rank``, else the same).  λ/aux always ride raw."""
        raw_u = wire_u = small = 0
        for spec, plan in zip(self.specs, self.plans):
            B, d, w = plan.padded, spec.d, spec.width
            raw_u += B * d * w * 4
            small += B * (w + kfactor.AUX_WIDTH) * 4
            if self.compress_rank is not None:
                q = min(self.compress_rank, d, w)
                wire_u += B * (d + w) * q * 4
            else:
                wire_u += B * d * w * 4
        return {"uncompressed": raw_u + small,
                "on_wire": wire_u + small}

    # -- the sharded factor work -------------------------------------------
    def factor_work(self, opt, factors, inflight, acts, probe_grads,
                    n_tokens, rng, first, work: schedule.StepWork,
                    landing=None, phi=None):
        """Drop-in for ``Kfac._bucketed_factor_work``: same operands, same
        per-slot numerics, 1/N of the factor work per device.  The bucket
        loop (operand collection, no-op skip, gather/scatter, per-slot
        keys) is Kfac's own — only the inner per-bucket program is
        substituted with the shard_map-wrapped one.

        Async launch/land phases run *inside* the sharded program: each
        device snapshots and lands only its ⌈B/N⌉ local slots, so the
        heavy cost of a landing is 1/N of the replicated pipeline's, the
        landed low-rank reps ride the same all-gather as the synchronous
        path, and the in-flight snapshot of the dense M — like the live
        M — never leaves its owning device (and stays row-sharded on a
        2D mesh).  Pre-computed ``landing`` operands are a
        replicated-path optimization and are rejected here (the engine
        lands in-graph)."""
        if landing:
            raise ValueError("the distributed curvature engine computes "
                             "landings in-graph; overlapped landing "
                             "operands are a replicated-path feature")

        def bucket_step(bi, bucket, st, X, keys, buf, landed):
            launch, land = opt._work_ranges(work, bi)
            return self._bucket_step(bucket.spec, self.plans[bi],
                                     self.row_blocks[bi], st, X,
                                     keys, first, work.stats, work.light,
                                     work.heavy[bi], launch, land, buf,
                                     opt.cfg.use_kernels)

        return opt._bucketed_factor_work(factors, inflight, acts,
                                         probe_grads, n_tokens, rng,
                                         first, work,
                                         bucket_step=bucket_step,
                                         phi=phi)

    # -- gather helpers (inside shard_map bodies) --------------------------
    def _gather_u(self, U_loc: Array) -> Array:
        """All-gather the local (B_loc, d, w) U blocks over the curvature
        axis — raw, or as rank-q PowerSGD factors (``compress_rank``).
        Every member (owner included) uses the decompressed result, so
        the logically-replicated out-spec stays consistent."""
        if self.compress_rank is None:
            return jax.lax.all_gather(U_loc, self.axis, axis=0, tiled=True)
        Pl, Ql = compress_lib.compress_batched(U_loc, self.compress_rank)
        Pg = jax.lax.all_gather(Pl, self.axis, axis=0, tiled=True)
        Qg = jax.lax.all_gather(Ql, self.axis, axis=0, tiled=True)
        return (Pg @ jnp.swapaxes(Qg, -1, -2)).astype(U_loc.dtype)

    def _gather_rep(self, st: KFactorState) -> KFactorState:
        """Gather the low-rank rep (U via :meth:`_gather_u`, λ/aux raw)
        over the curvature axis; M keeps its (possibly row-) shard."""
        U = self._gather_u(st.U)
        D = jax.lax.all_gather(st.D, self.axis, axis=0, tiled=True)
        aux = jax.lax.all_gather(st.aux, self.axis, axis=0, tiled=True)
        return KFactorState(U=U, D=D, M=st.M, aux=aux)

    def _heavy_rows(self, spec, st: KFactorState, keys: Array,
                    llo: int, lhi: int, rb: int) -> KFactorState:
        """One local heavy range on row-sharded M: gather the firing
        slots' M rows to full (transient — O(range·d²), not O(B·d²)),
        split the range across the row members so the heavy FLOPs shard
        over both axes, and re-gather the refreshed chunks.  No heavy op
        writes M, so the live row shard passes through untouched."""
        sub = jax.tree_util.tree_map(lambda x: x[llo:lhi], st)
        Mfull = jax.lax.all_gather(sub.M, self.row_axis, axis=1,
                                   tiled=True)
        subf = KFactorState(U=sub.U, D=sub.D, M=Mfull, aux=sub.aux)
        ksub = keys[llo:lhi]
        bh = lhi - llo
        if bh >= self.n_rows and bh % self.n_rows == 0:
            w = bh // self.n_rows
            o = jax.lax.axis_index(self.row_axis) * w
            chunk = jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, o, w, axis=0),
                subf)
            ck = jax.lax.dynamic_slice_in_dim(ksub, o, w, axis=0)
            out = kfactor.heavy_overwrite_batched(spec, chunk, ck)
            g0 = lambda x: jax.lax.all_gather(x, self.row_axis, axis=0,
                                              tiled=True)
            U, D, aux = g0(out.U), g0(out.D), g0(out.aux)
        else:
            # range shorter than (or misaligned with) the row-member
            # count: every row member computes the whole range — still
            # exact, just row-replicated work for this (tail) range
            out = kfactor.heavy_overwrite_batched(spec, subf, ksub)
            U, D, aux = out.U, out.D, out.aux
        return KFactorState(U=st.U.at[llo:lhi].set(U),
                            D=st.D.at[llo:lhi].set(D), M=st.M,
                            aux=st.aux.at[llo:lhi].set(aux))

    def _bucket_step(self, spec, plan: ShardPlan, rb: Optional[int],
                     st: KFactorState, X: Array, keys: Array,
                     first: Array, stats: bool, light: bool, ranges,
                     launch, land, buf, use_kernel: bool):
        """One bucket's step under shard_map: each curvature member runs
        the shared per-bucket program on its ⌈B/N⌉ local slots, then
        all-gathers the O(d·r) low-rank rep; the O(d²) dense M — live
        and in-flight snapshot alike — stays device-sharded (and, with
        ``rb``, row-sharded on the row axis)."""
        loc = lambda r: buckets.localize_ranges(r, plan.total, plan.n)
        local_heavy, local_launch, local_land = loc(ranges), loc(launch), \
            loc(land)
        st = plan.shard(st)
        X = plan.shard(X)
        keys = plan.shard(keys)
        axis, row_axis = self.axis, self.row_axis
        m_spec = P(axis, row_axis) if rb is not None else P(axis)
        st_in = KFactorState(U=P(axis), D=P(axis), M=m_spec, aux=P(axis))
        st_out = KFactorState(U=P(), D=P(), M=m_spec, aux=P())

        def sync_local(st, X, keys, first):
            """The per-member synchronous program: the replicated bucket
            step when M is whole, the row-block decomposition of the
            same math when M is row-sharded."""
            if rb is None:
                return kfactor.bucket_factor_step(
                    spec, st, X, keys, first, stats, light, local_heavy,
                    use_kernel)
            if stats:
                with obs_trace.span("stats_rows"):
                    r0 = jax.lax.axis_index(row_axis) * rb
                    M = kfactor.ea_update_m_rows(st.M, X, r0, rb,
                                                 spec.rho, first)
                    st = KFactorState(U=st.U, D=st.D, M=M, aux=st.aux)
            if (light or local_heavy) and spec.mode in kfactor._HAS_BRAND:
                with obs_trace.span("light_brand"):
                    st = kfactor.brand_step(spec, st, X, first,
                                            use_kernel)
            for llo, lhi in local_heavy:
                with obs_trace.span(f"heavy_{llo}_{lhi}"):
                    st = self._heavy_rows(spec, st, keys, llo, lhi, rb)
            return st

        if buf is None:
            def body(st, X, keys, first):
                st = sync_local(st, X, keys, first)
                return self._gather_rep(st)

            out = shard_map(
                body, mesh=self.mesh,
                in_specs=(st_in, P(axis), P(axis), P()),
                out_specs=st_out,
                check_rep=False,
            )(st, X, keys, first)
            # U/D came back gathered in device-major layout; M sharded in
            # the same layout.  One static take restores slot order
            # everywhere.
            return plan.unshard(out), None

        buf = plan.shard(buf)
        buf_spec = jax.tree_util.tree_map(lambda _: P(axis), buf)
        if rb is not None:
            buf_spec = dataclasses.replace(buf_spec, M=m_spec)

        def body(st, X, keys, first, buf):
            if rb is None:
                st, buf = kfactor.bucket_factor_step_async(
                    spec, st, X, keys, first, stats, light, local_heavy,
                    local_launch, local_land, buf, use_kernel)
                return self._gather_rep(st), buf
            # 2D path: row-block stats first (exact), then — only when
            # this step's local shard fires or lands heavy work — gather
            # the live and in-flight M rows transiently around the
            # unchanged async program and re-slice both row blocks.
            # Launch-only / light-only steps run directly on row blocks
            # (the snapshot copy slices the slot axis only).
            if stats:
                with obs_trace.span("stats_rows"):
                    r0 = jax.lax.axis_index(row_axis) * rb
                    M = kfactor.ea_update_m_rows(st.M, X, r0, rb,
                                                 spec.rho, first)
                    st = KFactorState(U=st.U, D=st.D, M=M, aux=st.aux)
            if local_heavy or local_land:
                g1 = lambda x: jax.lax.all_gather(x, row_axis, axis=1,
                                                  tiled=True)
                stf = KFactorState(U=st.U, D=st.D, M=g1(st.M),
                                   aux=st.aux)
                buff = dataclasses.replace(buf, M=g1(buf.M))
                stf, buff = kfactor.bucket_factor_step_async(
                    spec, stf, X, keys, first, False, light,
                    local_heavy, local_launch, local_land, buff,
                    use_kernel)
                r0 = jax.lax.axis_index(row_axis) * rb
                s1 = lambda x: jax.lax.dynamic_slice_in_dim(x, r0, rb,
                                                            axis=1)
                st = KFactorState(U=stf.U, D=stf.D, M=s1(stf.M),
                                  aux=stf.aux)
                buf = dataclasses.replace(buff, M=s1(buff.M))
            else:
                st, buf = kfactor.bucket_factor_step_async(
                    spec, st, X, keys, first, False, light, (),
                    local_launch, (), buf, use_kernel)
            return self._gather_rep(st), buf

        out, buf = shard_map(
            body, mesh=self.mesh,
            in_specs=(st_in, P(axis), P(axis), P(), buf_spec),
            out_specs=(st_out, buf_spec),
            check_rep=False,
        )(st, X, keys, first, buf)
        return plan.unshard(out), plan.unshard(buf)

    def describe(self) -> str:
        parts = [f"axis={self.axis} n={self.n_devices}"]
        if self.row_axis is not None:
            parts.append(f"rows={self.row_axis} n_rows={self.n_rows}")
        if self.compress_rank is not None:
            parts.append(f"compress_q={self.compress_rank}")
        for p, rb in zip(self.plans, self.row_blocks):
            tail = f" rb={rb}" if rb is not None else ""
            parts.append(f"[B={p.total}→{p.padded} "
                         f"/dev={p.per_device}{tail}]")
        return " ".join(parts)

"""Distributed curvature engine: shard the bucketed K-factor pipeline
across a mesh axis.

The paper's preconditioning cost is linear in layer size, but a replicated
optimizer still makes *every* device run *every* layer's curvature work —
stats absorbs, Brand panels/CholeskyQR2, and the heavy EVD/RSVD/correction
overwrites are recomputed N-fold on an N-device mesh.  KAISA and the
distributed K-FAC line (PAPERS.md) fix this by assigning each factor to
one device and broadcasting the small inverse representation; this module
is that idea applied to the *bucketed* pipeline of ``core/buckets.py``:

  * each factor bucket's flat batch axis is partitioned across the mesh's
    **curvature axis** with a round-robin slot → device assignment
    (``buckets.shard_perm``): slot ``s`` lives on device ``s % N``, so
    every device owns an equal ``⌈B/N⌉`` share of every bucket;
  * inside ``jax.experimental.shard_map`` each device runs the SAME
    per-bucket program as the replicated path
    (``kfactor.bucket_factor_step``) on its local shard — stats, Brand,
    and the scheduled heavy ranges all cost 1/N of the replicated work;
  * the updated low-rank reps (U, λ) are **all-gathered** — they are
    O(d·r) per factor, far cheaper to communicate than to recompute —
    while the dense EA factor M (O(d²)) is *never all-gathered*: only
    the slot's owning device ever reads it, so its out_spec keeps it
    sharded on the curvature axis.  (The shard/unshard *permutation*
    between the per-tap state layout and the engine's device-major
    layout can still move M rows point-to-point where the persisted
    sharding disagrees with the assignment;
    ``sharding.kfac_state_sharding(curvature_axis=...)`` minimizes that
    for stacked taps, and keeping the whole factor state bucket-resident
    between steps — eliminating the permutation entirely — is the
    natural next step.)

Work masks from ``core/schedule.py`` compose with sharding: a heavy range
aligned to the device count (the Scheduler's ``align=N`` contract) maps to
the same static local row range on every device, so staggering and
sharding multiply — per-device heavy cost per step is
``#units / (T · N)`` of the spiky replicated baseline.

Numerics are exactly those of the replicated bucketed path (same per-slot
programs, same per-slot PRNG keys): ``tests/test_distributed_curvature.py``
asserts allclose parity on an 8-device host mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import buckets, kfactor, schedule
from repro.core.kfactor import KFactorState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static layout of one bucket's batch axis on the curvature axis."""
    total: int                   # true bucket batch
    n: int                       # devices on the curvature axis
    padded: int                  # total padded up to a multiple of n
    perm: Tuple[int, ...]        # device-major round-robin gather indices
    unperm: Tuple[int, ...]      # slot → device-major position

    @classmethod
    def build(cls, total: int, n: int) -> "ShardPlan":
        return cls(total=total, n=n,
                   padded=buckets.padded_total(total, n),
                   perm=tuple(buckets.shard_perm(total, n)),
                   unperm=tuple(buckets.shard_unperm(total, n)))

    @property
    def per_device(self) -> int:
        return self.padded // self.n

    def shard(self, tree):
        """(total, …) leaves → (padded, …) in device-major round-robin
        order (one static take; pad rows wrap onto real slots)."""
        idx = jnp.asarray(self.perm)
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, idx, axis=0), tree)

    def unshard(self, tree):
        """Inverse of :meth:`shard`; drops the pad rows."""
        idx = jnp.asarray(self.unperm)
        return jax.tree_util.tree_map(
            lambda x: jnp.take(x, idx, axis=0), tree)


class CurvatureEngine:
    """Runs ``Kfac``'s bucketed factor work sharded over ``mesh[axis]``.

    Attach with ``Kfac(cfg, taps, curvature=engine)`` or
    ``opt.curvature = engine`` — ``Kfac.update`` delegates to
    :meth:`factor_work` whenever an engine is present (bucketed mode).
    The engine is static metadata only (mesh + per-bucket ShardPlans);
    it owns no arrays.
    """

    def __init__(self, mesh: Mesh, axis: str, factor_buckets):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}; "
                             f"axes: {mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.n_devices = int(dict(zip(mesh.axis_names,
                                      mesh.devices.shape))[axis])
        self.plans = tuple(ShardPlan.build(b.total, self.n_devices)
                           for b in factor_buckets)

    @classmethod
    def for_kfac(cls, opt, mesh: Mesh, axis: str) -> "CurvatureEngine":
        eng = cls(mesh, axis, opt.factor_buckets)
        opt.curvature = eng
        return eng

    # -- job accounting (benchmarks / logs) --------------------------------
    def job_counts(self) -> Tuple[int, int]:
        """(replicated, per-device) factor-job slot counts: a replicated
        device steps every slot of every bucket; a sharded device steps
        its ⌈B/N⌉ local shard of each."""
        rep = sum(p.total for p in self.plans)
        dev = sum(p.per_device for p in self.plans)
        return rep, dev

    # -- the sharded factor work -------------------------------------------
    def factor_work(self, opt, factors, inflight, acts, probe_grads,
                    n_tokens, rng, first, work: schedule.StepWork,
                    landing=None, phi=None):
        """Drop-in for ``Kfac._bucketed_factor_work``: same operands, same
        per-slot numerics, 1/N of the factor work per device.  The bucket
        loop (operand collection, no-op skip, gather/scatter, per-slot
        keys) is Kfac's own — only the inner per-bucket program is
        substituted with the shard_map-wrapped one.

        Async launch/land phases run *inside* the sharded program: each
        device snapshots and lands only its ⌈B/N⌉ local slots, so the
        heavy cost of a landing is 1/N of the replicated pipeline's, the
        landed low-rank reps ride the same all-gather as the synchronous
        path, and the in-flight snapshot of the dense M — like the live
        M — never leaves its owning device.  Pre-computed ``landing``
        operands are a replicated-path optimization and are rejected
        here (the engine lands in-graph)."""
        if landing:
            raise ValueError("the distributed curvature engine computes "
                             "landings in-graph; overlapped landing "
                             "operands are a replicated-path feature")

        def bucket_step(bi, bucket, st, X, keys, buf, landed):
            launch, land = opt._work_ranges(work, bi)
            return self._bucket_step(bucket.spec, self.plans[bi], st, X,
                                     keys, first, work.stats, work.light,
                                     work.heavy[bi], launch, land, buf,
                                     opt.cfg.use_kernels)

        return opt._bucketed_factor_work(factors, inflight, acts,
                                         probe_grads, n_tokens, rng,
                                         first, work,
                                         bucket_step=bucket_step,
                                         phi=phi)

    def _bucket_step(self, spec, plan: ShardPlan, st: KFactorState,
                     X: Array, keys: Array, first: Array, stats: bool,
                     light: bool, ranges, launch, land, buf,
                     use_kernel: bool):
        """One bucket's step under shard_map: each device runs the shared
        per-bucket program on its ⌈B/N⌉ local slots, then all-gathers the
        O(d·r) low-rank rep; the O(d²) dense M — live and in-flight
        snapshot alike — stays device-sharded."""
        loc = lambda r: buckets.localize_ranges(r, plan.total, plan.n)
        local_heavy, local_launch, local_land = loc(ranges), loc(launch), \
            loc(land)
        st = plan.shard(st)
        X = plan.shard(X)
        keys = plan.shard(keys)
        axis = self.axis

        if buf is None:
            def body(st, X, keys, first):
                st = kfactor.bucket_factor_step(spec, st, X, keys, first,
                                                stats, light, local_heavy,
                                                use_kernel)
                U = jax.lax.all_gather(st.U, axis, axis=0, tiled=True)
                D = jax.lax.all_gather(st.D, axis, axis=0, tiled=True)
                aux = jax.lax.all_gather(st.aux, axis, axis=0, tiled=True)
                return KFactorState(U=U, D=D, M=st.M, aux=aux)

            out = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(axis), P(axis), P(axis), P()),
                out_specs=KFactorState(U=P(), D=P(), M=P(axis), aux=P()),
                check_rep=False,
            )(st, X, keys, first)
            # U/D came back gathered in device-major layout; M sharded in
            # the same layout.  One static take restores slot order
            # everywhere.
            return plan.unshard(out), None

        buf = plan.shard(buf)
        buf_spec = jax.tree_util.tree_map(lambda _: P(axis), buf)

        def body(st, X, keys, first, buf):
            st, buf = kfactor.bucket_factor_step_async(
                spec, st, X, keys, first, stats, light, local_heavy,
                local_launch, local_land, buf, use_kernel)
            U = jax.lax.all_gather(st.U, axis, axis=0, tiled=True)
            D = jax.lax.all_gather(st.D, axis, axis=0, tiled=True)
            aux = jax.lax.all_gather(st.aux, axis, axis=0, tiled=True)
            return KFactorState(U=U, D=D, M=st.M, aux=aux), buf

        out, buf = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), buf_spec),
            out_specs=(KFactorState(U=P(), D=P(), M=P(axis), aux=P()),
                       buf_spec),
            check_rep=False,
        )(st, X, keys, first, buf)
        return plan.unshard(out), plan.unshard(buf)

    def describe(self) -> str:
        parts = [f"axis={self.axis} n={self.n_devices}"]
        for p in self.plans:
            parts.append(f"[B={p.total}→{p.padded} /dev={p.per_device}]")
        return " ".join(parts)

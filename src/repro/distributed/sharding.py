"""Parameter / optimizer-state / batch sharding rules.

Path-pattern → PartitionSpec rules, applied to the param pytree (and
mirrored onto K-FAC factor states).  Conventions on the (pod, data, model)
mesh:

  * embeddings & LM head : vocab on "model"
  * attention q/kv/o     : head (fused out) dim on "model"
  * FFN wi / wo          : hidden dim on "model"
  * MoE expert stacks    : expert dim on "model" (EP)
  * K-FAC low-rank U     : factor rows (d) on "model" — each model shard
                           owns the rows of its weight shard's factor
  * small vectors (norms, biases, D/A_log/…) : replicated
  * batch                : ("pod", "data")
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kfac as kfac_lib


def _key_str(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _leaf_path(kp) -> str:
    return "/".join(_key_str(k) for k in kp)


#: (regex, trailing-dims builder); first match wins.  Builders describe the
#: trailing two dims (d_in, d_out); leading scan-stack dims get None.
_RULES = [
    # fan-in on model (output projections)
    (re.compile(r"mix/(wo|x_wo|out_proj)$"), lambda tp: (tp, None)),
    # fan-out on model (input/qkv/gate projections)
    (re.compile(r"mix/(wq|wkv|x_wq|x_wkv|wq_a|wq_b|wkv_a|wkv_b|in_proj|"
                r"wi|wg)$"), lambda tp: (None, tp)),
    (re.compile(r"ffn/wo_f$"), lambda tp: (tp, None)),
    (re.compile(r"ffn/shared_wi$"), lambda tp: (None, tp)),
    (re.compile(r"ffn/shared_wo$"), lambda tp: (tp, None)),
    (re.compile(r"ffn/router$"), lambda tp: (None, None)),
    # embeddings / head: vocab on model
    (re.compile(r"^embed$"), lambda tp: (tp, None)),
    (re.compile(r"^head/w$"), lambda tp: (None, tp)),
    (re.compile(r"^mtp/w$"), lambda tp: (None, tp)),
]

_FFN_WI_WO = re.compile(r"ffn/(wi|wo)$")


def param_spec(path: str, ndim: int, mesh: Mesh) -> P:
    tp = "model" if "model" in mesh.axis_names else None
    m = _FFN_WI_WO.search(path)
    if m:
        if ndim >= 4:
            # MoE experts (…, E, d_in, d_out): expert dim on model (EP)
            return P(*((None,) * (ndim - 3) + (tp, None, None)))
        # dense FFN (…, d_in, d_out): hidden dim on model
        dims = (None, tp) if m.group(1) == "wi" else (tp, None)
        return P(*((None,) * (ndim - 2) + dims))
    for rx, fn in _RULES:
        if rx.search(path):
            dims = fn(tp)
            n_lead = ndim - len(dims)
            if n_lead < 0:      # rank-1 target (bias-like): replicate
                return P()
            return P(*((None,) * n_lead + tuple(dims)))
    return P()                   # norms, biases, scalars: replicated


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that do not divide the corresponding dim (e.g. a
    51865-entry vocab on a 16-way model axis — production systems pad the
    vocab; here the exact assigned dims are kept and the offending axis is
    replicated instead)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(entry):
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in names:
            n *= sizes.get(a, 1)
        return n

    fitted = []
    for i, entry in enumerate(tuple(spec)):
        if i >= len(shape) or shape[i] % axis_size(entry) != 0:
            fitted.append(None)
        else:
            fitted.append(entry)
    return P(*fitted)


def params_sharding(params, mesh: Mesh):
    """NamedSharding pytree for a param tree."""
    def one(kp, leaf):
        spec = param_spec(_leaf_path(kp), leaf.ndim, mesh)
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def params_sharding_fsdp(params, mesh: Mesh):
    """FSDP/ZeRO-3 plan: every ≥2D leaf fully sharded over ALL mesh axes on
    its largest divisible dim; weights are all-gathered transiently per
    layer during compute.  The right plan for ≤8B models where tensor
    parallelism is collective-bound (EXPERIMENTS.md §Perf, train cells)."""
    axes = tuple(mesh.axis_names)
    n = mesh.devices.size

    def one(kp, leaf):
        if leaf.ndim >= 2:
            order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
            for i in order:
                if leaf.shape[i] % n == 0 and leaf.shape[i] >= n:
                    spec = [None] * leaf.ndim
                    spec[i] = axes
                    return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, params)


def kfac_state_sharding(opt_state, mesh: Mesh, curvature_axis=None,
                        row_axis=None):
    """K-FAC optimizer state: factor U/M rows on "model", D replicated;
    AdamW fallback mirrors the param sharding; scalars replicated.

    ``curvature_axis`` (the axis the distributed curvature engine shards
    bucket batches over) additionally places stacked taps' dense M on
    that axis along the leading stack dim — the round-robin slot → device
    assignment means each device only ever *reads* the M rows of its own
    slots, so the O(d²) factors need not be replicated between steps.

    ``row_axis`` (the 2D engine's second axis) is the row rule: the
    dense M of every factor — live and in-flight snapshot alike — is
    additionally sharded by rows over that axis (rows dim = -2), so
    per-device K-factor memory is O(d²/(N_curv·N_rows)).  Non-divisible
    stacks / factor sides fall back to replication (fit_spec), matching
    the engine's per-bucket row-block eligibility."""
    tp = "model" if "model" in mesh.axis_names else None

    def one(kp, leaf):
        path = _leaf_path(kp)
        if path.startswith("inflight"):
            # async in-flight buffers (bucket-slot-major): the dense M
            # snapshot follows the live M onto the curvature axis (only
            # the slot's owning device ever reads it — same round-robin
            # assignment) and its rows onto the row axis; U/D/keys/
            # panels replicate like the live low-rank rep, which is
            # all-gathered at every landing.
            field = path.rsplit("/", 1)[-1]
            if field == "M" and curvature_axis is not None and \
                    leaf.ndim >= 3 and leaf.shape[-1] > 1:
                spec = P(*((curvature_axis, row_axis)
                           + (None,) * (leaf.ndim - 2)))
                return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
            return NamedSharding(mesh, P())
        if "/factors/" in "/" + path + "/" or path.startswith("factors"):
            # KFactorState leaves: U (…, d, w), M (…, d, d), D (…, w)
            field = path.rsplit("/", 1)[-1]
            if field in ("U", "M") and leaf.ndim >= 2 and \
                    leaf.shape[-1] > 1:
                lead = (None,) * (leaf.ndim - 2)
                rows = tp
                if field == "M":
                    if curvature_axis is not None and leaf.ndim >= 3:
                        lead = (curvature_axis,) + \
                            (None,) * (leaf.ndim - 3)
                    if row_axis is not None:
                        rows = row_axis
                spec = P(*(lead + (rows, None)))
                return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
            return NamedSharding(mesh, P())
        if path.startswith("fallback") or path.startswith("momentum"):
            # mirror param sharding where shapes allow
            sub = re.sub(r"^(fallback/(mu|nu)|momentum)/", "", path)
            spec = param_spec(sub, leaf.ndim, mesh)
            return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_state)


def batch_sharding(batch, mesh: Mesh):
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def one(leaf):
        spec = (dp,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, batch)


#: cache leaves with a sequence axis at position 2 (stacked: (reps, B, S, …))
_SEQ_CACHE_LEAVES = {"k", "v", "xk", "xv", "c_kv", "k_rope"}


def cache_sharding(cache, mesh: Mesh, shard_seq: bool = False,
                   layout: str = "seq", small_seq_threshold: int = 0):
    """KV/state caches.  Default: batch on the data axes + seq on the model
    axis.  layout="heads": KV heads (replicated to the model-axis size by
    the model) go on the model axis — cache writes stay local.
    Long-context (B=1): shard the *sequence* axis of KV-like leaves
    instead; recurrent states (tiny) replicate."""
    dp = tuple(a for a in mesh.axis_names if a != "model")

    tp = "model" if "model" in mesh.axis_names else None

    def one(kp, leaf):
        name = _leaf_path(kp).rsplit("/", 1)[-1]
        if name in _SEQ_CACHE_LEAVES and leaf.ndim >= 3:
            # stacked (reps, B, S, …): seq on model (flash-decoding style),
            # matching ShardPolicy.kv_cache; long-context shards seq on all
            if shard_seq:
                spec = (None, None, dp + ((tp,) if tp else ()))
            elif leaf.shape[2] <= small_seq_threshold:
                spec = (None, dp, None)
            elif layout == "heads" and leaf.ndim >= 5:
                spec = (None, dp, None, tp)
            else:
                spec = (None, dp, tp)
            sh = P(*(spec + (None,) * (leaf.ndim - len(spec))))
            return NamedSharding(mesh, fit_spec(sh, leaf.shape, mesh))
        if shard_seq:               # B == 1: states replicate
            return NamedSharding(mesh, P())
        if leaf.ndim >= 2:          # (reps, B, ...): batch on data axes
            spec = (None, dp) + (None,) * (leaf.ndim - 2)
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)

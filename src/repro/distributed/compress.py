"""Gradient compression for the DP all-reduce (PowerSGD-style low-rank with
error feedback, Vogels et al. 2019) — reusing the same range-finder
numerics as RS-KFAC (core/rsvd.py): one code path, shared tests.

For a gradient matrix G (m, n), rank-q compression all-reduces
P = G Q (m, q) and Q' = Gᵀ P (n, q) instead of G — a (m+n)·q / (m·n)
volume reduction.  The residual G − P Q'ᵀ is fed back into the next step's
gradient (error feedback keeps SGD convergent).

``compress_tree`` applies this to every ≥2D leaf above a size threshold;
small leaves all-reduce uncompressed.  The collective itself is XLA's —
this module only reshapes what enters it; under pjit the psum of the
factors is emitted instead of the psum of the full gradient.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    rank: int = 8
    min_size: int = 65536       # leaves smaller than this stay dense
    n_power_iter: int = 1


def _as_matrix(g: Array) -> Tuple[Array, Tuple[int, ...]]:
    shape = g.shape
    m = shape[0] if g.ndim == 2 else int(jnp.prod(jnp.asarray(shape[:-1])))
    return g.reshape(m, shape[-1]), shape


def compress(g: Array, err: Array, q_prev: Optional[Array], cfg
             ) -> Tuple[Array, Array, Array]:
    """→ (P, Q, new_error).  Caller psums P (and Q on odd rounds)."""
    G2, shape = _as_matrix(g.astype(jnp.float32) + err.astype(jnp.float32))
    m, n = G2.shape
    q = min(cfg.rank, m, n)
    if q_prev is None or q_prev.shape != (n, q):
        # warm start: deterministic basis (seeded per shape)
        key = jax.random.PRNGKey(m * 1315423911 + n)
        q_prev = jax.random.normal(key, (n, q))
    # Orthonormalization stays Householder here, unlike the RS-KFAC range
    # finder (core/rsvd.py, routed through kernels/ops.py::orthonormalize):
    # PowerSGD measurably *relies* on QR's arbitrary orthonormal completion
    # — when power iteration aligns the rank-q basis toward the top
    # eigendirections, the invented orthogonal columns still pick up signal
    # through Q = G2ᵀP, while a spectral factorization maps them to an
    # exactly-null subspace and wastes the rank (rank-2 EF-SGD convergence
    # regresses ~0.05 → 0.06 relative residual).  These (m, ≤8) panels sit
    # far below the kernel pad-growth guard anyway, so there is no batched
    # Pallas launch to share.
    P = G2 @ q_prev                                   # (m, q)
    for _ in range(cfg.n_power_iter):
        P, _ = jnp.linalg.qr(P)
        P = G2 @ (G2.T @ P)
    P, _ = jnp.linalg.qr(P)                           # orthonormal basis
    Q = G2.T @ P                                      # (n, q)
    approx = (P @ Q.T).reshape(shape)
    new_err = g.astype(jnp.float32) - approx
    return P, Q, new_err


def decompress(P: Array, Q: Array, shape: Tuple[int, ...]) -> Array:
    return (P @ Q.T).reshape(shape)


def compress_batched(G: Array, rank: int, n_power_iter: int = 1
                     ) -> Tuple[Array, Array]:
    """Memoryless batched PowerSGD projection — the curvature engine's
    (U, λ) collective path.  G (*stack, m, n) → P (*stack, m, q),
    Q (*stack, n, q) with q = min(rank, m, n); the caller gathers the
    factors and every mesh member decompresses with ``P @ Qᵀ``.

    Unlike :func:`compress`, there is no error feedback: EF exists so a
    compressed *stream of increments* stays unbiased over time, but here
    each round re-projects the exact current state (the engine's local
    U block), so the per-round error never accumulates.  The basis is
    the same deterministic per-shape seed as :func:`compress`'s cold
    start, making the projection SPMD-uniform — every mesh member builds
    the identical basis with no communication."""
    m, n = G.shape[-2:]
    q = min(int(rank), m, n)
    key = jax.random.PRNGKey(m * 1315423911 + n)
    basis = jax.random.normal(key, (n, q)).astype(G.dtype)
    qr = lambda p: jnp.linalg.qr(p)[0]          # batched natively
    P = G @ basis
    for _ in range(n_power_iter):
        P = qr(P)
        P = G @ (jnp.swapaxes(G, -1, -2) @ P)
    P = qr(P)
    Q = jnp.swapaxes(G, -1, -2) @ P
    return P, Q


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressState:
    """Per-leaf carry of the error-feedback compressor: ``err`` is the
    residual fed back into the next round, ``q`` the previous round's Q
    factor — PowerSGD's warm start, which lets the single power
    iteration (n_power_iter=1) keep sharpening the rank-q basis across
    rounds; dropping it (the old ``compress_tree`` passed
    ``q_prev=None`` every round) silently restarts the iteration from
    the seeded basis each step.  Leaves the
    config leaves uncompressed carry a zero-size ``q`` sentinel so the
    pytree structure stays static under jit."""
    err: Any
    q: Any


def _compressible(g, cfg: CompressConfig) -> bool:
    return g.ndim >= 2 and g.size >= cfg.min_size


def _cold_q(g, cfg: CompressConfig) -> Array:
    """The deterministic seeded basis :func:`compress` cold-starts from —
    used as the *initial* warm-start carry so round 1 of the stateful
    path is bit-identical to the old stateless one."""
    shape = g.shape
    m = shape[0] if g.ndim == 2 else int(np_prod(shape[:-1]))
    n = shape[-1]
    q = min(cfg.rank, m, n)
    key = jax.random.PRNGKey(m * 1315423911 + n)
    return jax.random.normal(key, (n, q))


def np_prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def init_state(params, cfg: CompressConfig) -> CompressState:
    """Fresh compressor carry: zero error feedback + the seeded cold-start
    basis per compressible leaf (zero-size sentinel otherwise)."""
    err = init_errors(params)
    q = jax.tree_util.tree_map(
        lambda p: _cold_q(p, cfg) if _compressible(p, cfg)
        else jnp.zeros((0,), jnp.float32), params)
    return CompressState(err=err, q=q)


def compress_tree(grads, state: CompressState, cfg: CompressConfig
                  ) -> Tuple[Any, CompressState]:
    """Apply error-feedback low-rank compression leaf-wise, threading the
    per-leaf warm-start Q through ``state`` (a :class:`CompressState`).

    Returns (approx_grads, new_state).  approx_grads replace the raw
    gradients *before* the (sharded) optimizer update, so the DP psum
    that XLA emits moves only the factor volume; new_state carries both
    the error feedback and the warm-started power-iteration basis into
    the next step (tests/test_mesh2d.py asserts the warm basis sharpens
    across rounds where cold restarts stay pinned at single-iteration
    quality).
    """
    def one(g, e, qp):
        if not _compressible(g, cfg):
            return g, jnp.zeros_like(e), qp
        P, Q, new_err = compress(g, e, qp if qp.size else None, cfg)
        return decompress(P, Q, g.shape).astype(g.dtype), new_err, Q

    flat = jax.tree_util.tree_map(one, grads, state.err, state.q)
    istuple = lambda t: isinstance(t, tuple)
    pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], flat,
                                            is_leaf=istuple)
    return pick(0), CompressState(err=pick(1), q=pick(2))


def init_errors(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""Gradient compression for the DP all-reduce (PowerSGD-style low-rank with
error feedback, Vogels et al. 2019) — reusing the same range-finder
numerics as RS-KFAC (core/rsvd.py): one code path, shared tests.

For a gradient matrix G (m, n), rank-q compression all-reduces
P = G Q (m, q) and Q' = Gᵀ P (n, q) instead of G — a (m+n)·q / (m·n)
volume reduction.  The residual G − P Q'ᵀ is fed back into the next step's
gradient (error feedback keeps SGD convergent).

``compress_tree`` applies this to every ≥2D leaf above a size threshold;
small leaves all-reduce uncompressed.  The collective itself is XLA's —
this module only reshapes what enters it; under pjit the psum of the
factors is emitted instead of the psum of the full gradient.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    rank: int = 8
    min_size: int = 65536       # leaves smaller than this stay dense
    n_power_iter: int = 1


def _as_matrix(g: Array) -> Tuple[Array, Tuple[int, ...]]:
    shape = g.shape
    m = shape[0] if g.ndim == 2 else int(jnp.prod(jnp.asarray(shape[:-1])))
    return g.reshape(m, shape[-1]), shape


def compress(g: Array, err: Array, q_prev: Optional[Array], cfg
             ) -> Tuple[Array, Array, Array]:
    """→ (P, Q, new_error).  Caller psums P (and Q on odd rounds)."""
    G2, shape = _as_matrix(g.astype(jnp.float32) + err.astype(jnp.float32))
    m, n = G2.shape
    q = min(cfg.rank, m, n)
    if q_prev is None or q_prev.shape != (n, q):
        # warm start: deterministic basis (seeded per shape)
        key = jax.random.PRNGKey(m * 1315423911 + n)
        q_prev = jax.random.normal(key, (n, q))
    # Orthonormalization stays Householder here, unlike the RS-KFAC range
    # finder (core/rsvd.py, routed through kernels/ops.py::orthonormalize):
    # PowerSGD measurably *relies* on QR's arbitrary orthonormal completion
    # — when power iteration aligns the rank-q basis toward the top
    # eigendirections, the invented orthogonal columns still pick up signal
    # through Q = G2ᵀP, while a spectral factorization maps them to an
    # exactly-null subspace and wastes the rank (rank-2 EF-SGD convergence
    # regresses ~0.05 → 0.06 relative residual).  These (m, ≤8) panels sit
    # far below the kernel pad-growth guard anyway, so there is no batched
    # Pallas launch to share.
    P = G2 @ q_prev                                   # (m, q)
    for _ in range(cfg.n_power_iter):
        P, _ = jnp.linalg.qr(P)
        P = G2 @ (G2.T @ P)
    P, _ = jnp.linalg.qr(P)                           # orthonormal basis
    Q = G2.T @ P                                      # (n, q)
    approx = (P @ Q.T).reshape(shape)
    new_err = g.astype(jnp.float32) - approx
    return P, Q, new_err


def decompress(P: Array, Q: Array, shape: Tuple[int, ...]) -> Array:
    return (P @ Q.T).reshape(shape)


def compress_tree(grads, errors, cfg: CompressConfig):
    """Apply error-feedback low-rank compression leaf-wise.

    Returns (approx_grads, new_errors).  approx_grads replace the raw
    gradients *before* the (sharded) optimizer update, so the DP psum that
    XLA emits moves only the factor volume.
    """
    def one(g, e):
        if g.ndim < 2 or g.size < cfg.min_size:
            return g, jnp.zeros_like(e)
        P, Q, new_err = compress(g, e, None, cfg)
        return decompress(P, Q, g.shape).astype(g.dtype), new_err

    flat = jax.tree_util.tree_map(one, grads, errors)
    istuple = lambda t: isinstance(t, tuple)
    approx = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=istuple)
    errs = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=istuple)
    return approx, errs


def init_errors(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

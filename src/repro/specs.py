"""Typed configuration specs for the public training/serving entry points.

``run_kfac_training`` accreted ~18 loose keyword arguments over PRs 3-9:
every subsystem (mesh sharding, telemetry, health, chaos, checkpointing)
widened the signature further, and the same knots re-appeared on
``make_scheduled_kfac_step`` and ``launch/steps.build_train_step``.  This
module groups them into four frozen dataclasses — one per subsystem — so
an entry point takes at most four spec objects instead of a dozen
co-dependent scalars:

  * :class:`DistSpec`        mesh / curvature_axis / row_axis /
                             curvature_compress  (docs/distributed.md)
  * :class:`ObsSpec`         writer / metrics_every / profile knobs
                             (docs/observability.md)
  * :class:`CkptSpec`        ckpt dir / cadence / retention
  * :class:`ResilienceSpec`  health guards / remediation policy / chaos
                             (docs/robustness.md)

The old flat kwargs keep working for one deprecation cycle through
:func:`consolidate_training_kwargs`: each legacy name warns **once per
process** and is folded into the equivalent spec.  Passing a spec AND one
of the legacy kwargs it subsumes is an error (two sources of truth).

Construction is cheap and dependency-free; anything heavier (the
curvature engine, the metrics meter) is built lazily by the ``attach``/
``make_meter`` helpers so importing this module never drags in the
distributed or observability machinery.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Set, Tuple

_WARNED: Set[str] = set()


def warn_once(key: str, msg: str, stacklevel: int = 3) -> None:
    """Emit a DeprecationWarning once per process per ``key`` — repeated
    legacy calls (training loops, parametrized tests) stay quiet after
    the first."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Distributed-execution spec: where factor work shards.

    ``mesh`` + ``curvature_axis`` attach the distributed curvature engine
    (factor-bucket slots shard over that axis); ``row_axis`` adds the 2D
    path (each slot's dense M row-sharded over it); ``curvature_compress``
    routes the engine's (U, λ) gathers through rank-q PowerSGD factors
    (lossy, opt-in)."""
    mesh: Any = None
    curvature_axis: Optional[str] = None
    row_axis: Optional[str] = None
    curvature_compress: Optional[int] = None

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.curvature_axis is not None

    def attach(self, opt) -> Optional[Any]:
        """Build + attach the curvature engine for ``opt`` (a Kfac); a
        no-op returning None when no mesh/axis is configured."""
        if not self.active:
            return None
        from repro.distributed import curvature as curvature_lib
        return curvature_lib.CurvatureEngine.for_kfac(
            opt, self.mesh, self.curvature_axis, row_axis=self.row_axis,
            compress_rank=self.curvature_compress)


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability spec: the run's telemetry writer plus the in-graph
    metrics cadence (``metrics_every`` steps per flush window; 0 = off)
    and optional profiler-trace knobs."""
    writer: Any = None                  # repro.obs.TelemetryWriter
    metrics_every: int = 0
    profile_dir: Optional[str] = None
    profile_steps: int = 3

    def make_meter(self, opt) -> Optional[Any]:
        """An in-graph curvature Meter flushing to ``writer`` every
        ``metrics_every`` steps, or None when metrics are off."""
        if self.metrics_every <= 0 or self.writer is None:
            return None
        from repro.obs import metrics as obs_metrics
        catalog = obs_metrics.catalog_for(opt)
        kinds = {s.name: s.kind for s in catalog}
        return obs_metrics.Meter(catalog, self.writer.metrics_sink(kinds),
                                 every=self.metrics_every)


@dataclasses.dataclass(frozen=True)
class CkptSpec:
    """Checkpointing spec: snapshot directory, save cadence (healthy
    steps between saves), and ring retention."""
    dir: Optional[str] = None
    every: int = 5
    keep: int = 3

    @property
    def active(self) -> bool:
        return self.dir is not None


@dataclasses.dataclass(frozen=True)
class ResilienceSpec:
    """Resilience spec: ``health`` (truthy, or a
    ``repro.train.health.HealthConfig``) arms the in-graph guards + staged
    remediation ladder; a caller-built ``RemediationPolicy`` can ride as
    ``policy`` for inspection; ``chaos`` (a ``ChaosMonkey``) injects its
    fault plan into the loop's hooks."""
    health: Any = None
    policy: Any = None
    chaos: Any = None

    @property
    def active(self) -> bool:
        return bool(self.health) or self.policy is not None


#: legacy run_kfac_training kwarg → (spec slot, spec field)
_LEGACY_TRAINING_KWARGS: Dict[str, Tuple[str, str]] = {
    "mesh": ("dist", "mesh"),
    "curvature_axis": ("dist", "curvature_axis"),
    "row_axis": ("dist", "row_axis"),
    "curvature_compress": ("dist", "curvature_compress"),
    "writer": ("obs", "writer"),
    "metrics_every": ("obs", "metrics_every"),
    "health": ("resilience", "health"),
    "policy": ("resilience", "policy"),
    "chaos": ("resilience", "chaos"),
    "ckpt_dir": ("ckpt", "dir"),
    "ckpt_every": ("ckpt", "every"),
    "ckpt_keep": ("ckpt", "keep"),
}

_SPEC_TYPES = {"dist": DistSpec, "obs": ObsSpec, "ckpt": CkptSpec,
               "resilience": ResilienceSpec}


def consolidate_training_kwargs(
        legacy: Dict[str, Any], *, dist: Optional[DistSpec] = None,
        obs: Optional[ObsSpec] = None, ckpt: Optional[CkptSpec] = None,
        resilience: Optional[ResilienceSpec] = None, caller: str = "",
        ) -> Tuple[DistSpec, ObsSpec, CkptSpec, ResilienceSpec]:
    """Fold legacy flat kwargs into the four specs (deprecation shim).

    Unknown kwargs raise TypeError (same contract as a real signature);
    a legacy kwarg whose subsuming spec was also passed raises ValueError
    (two sources of truth).  Every accepted legacy kwarg warns once per
    process, naming its replacement."""
    given = {"dist": dist, "obs": obs, "ckpt": ckpt,
             "resilience": resilience}
    overrides: Dict[str, Dict[str, Any]] = {}
    for name, value in legacy.items():
        if name not in _LEGACY_TRAINING_KWARGS:
            raise TypeError(f"{caller or 'run_kfac_training'}() got an "
                            f"unexpected keyword argument {name!r}")
        slot, field = _LEGACY_TRAINING_KWARGS[name]
        if given[slot] is not None:
            raise ValueError(
                f"{caller or 'run_kfac_training'}(): legacy kwarg "
                f"{name!r} conflicts with the {slot}= spec that was also "
                f"passed — set {_SPEC_TYPES[slot].__name__}.{field} "
                f"instead")
        warn_once(f"training-kwarg:{name}",
                  f"{caller or 'run_kfac_training'}({name}=...) is "
                  f"deprecated; pass {slot}="
                  f"{_SPEC_TYPES[slot].__name__}({field}=...) "
                  f"(repro.specs)", stacklevel=4)
        overrides.setdefault(slot, {})[field] = value
    out = {}
    for slot, spec_type in _SPEC_TYPES.items():
        spec = given[slot]
        if spec is None:
            spec = spec_type(**overrides.get(slot, {}))
        out[slot] = spec
    return out["dist"], out["obs"], out["ckpt"], out["resilience"]

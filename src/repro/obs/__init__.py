"""Telemetry for the K-FAC hot path: jit-safe metrics, structured JSONL
events, and profiler tracing hooks.

Three layers, strictly observational (numerics-inert by construction —
asserted in tests/test_obs.py):

  * :mod:`repro.obs.metrics` — an in-graph :class:`~repro.obs.metrics.Meter`
    over a closed per-optimizer metric catalog.  The hot path calls
    ``metrics.record(name, value)``; outside an active collector that is
    a no-op, so un-instrumented runs trace byte-identical graphs.  The
    accumulated buffer is flushed to host via ``jax.experimental.io_callback``
    at a configurable cadence — steady-state steps add no host sync.
  * :mod:`repro.obs.events` — :class:`~repro.obs.events.TelemetryWriter`,
    schema-versioned JSONL events with a human-readable console sink
    (the structured replacement for the trainer's bare ``print``\\ s).
  * :mod:`repro.obs.trace` — ``jax.named_scope`` / profiler annotations
    around the bucketed factor/precondition launches and the async
    runner's worker thread, plus a step-ranged profile capturer.

``python -m repro.obs.summary run/telemetry.jsonl`` renders a run's
event log into a per-phase timing + curvature-health report.
"""
from repro.obs.events import (SCHEMA_VERSION, TelemetryWriter,  # noqa: F401
                              read_events, validate_event)
from repro.obs.metrics import Meter, active, record  # noqa: F401
from repro.obs.trace import StepProfiler, host_span, span  # noqa: F401

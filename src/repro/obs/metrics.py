"""Jit/shard_map-safe in-graph metrics for the K-FAC hot path.

Design constraints (in order):

  1. **Numerically inert.**  Instrumentation must never change a single
     bit of the optimizer's output.  Every metric is computed *from* hot
     path values, never fed back; expensive derived metrics (the
     inversion-error proxy) are only added to the graph when a collector
     is active, so metrics-off runs trace the exact un-instrumented
     graph.
  2. **No per-step host sync.**  Metrics accumulate in a
     :func:`Meter.init` buffer — a flat dict of named f32 scalars, a
     fixed pytree that rides through the jitted step like any other
     carry — and reach the host through one unordered
     ``jax.experimental.io_callback`` every ``every`` steps (under
     ``lax.cond``, so non-flush steps run callback-free).
  3. **Static structure.**  The catalog is *closed* per optimizer
     (:func:`catalog_for`): every step variant's buffer has identical
     keys, so the scheduler's many static work masks all share one
     buffer pytree and recompilation stays bounded.

The hot path records through a thread-local collector stack:
``record(name, value)`` is a no-op unless the caller's trace sits
inside a ``with meter.collecting() as col:`` block, and ``value`` may
be a zero-arg callable that is only evaluated (i.e. only enters the
graph) when a collector is active.  Two accumulation kinds:

  * ``counter`` — summed across the flush window, reset to 0 at flush;
  * ``gauge``   — last written value wins, persists across flushes.

shard_map note: nothing here may run *inside* a ``shard_map`` body
(recording a tracer from an inner mesh context into an outer-trace
collector is a tracer leak).  The curvature engine instead all-gathers
the per-slot ``KFactorState.aux`` diagnostics, and the optimizer
records from the post-gather state at the outer trace level — which is
also why the 8-device sharded run flushes valid metrics
(tests/test_obs.py).
"""
from __future__ import annotations

import contextlib
import itertools
import threading
from typing import Any, Callable, Dict, List, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

COUNTER = "counter"
GAUGE = "gauge"

#: modes whose heavy overwrite truncates a spectrum (AUX_TRUNC channel)
_TRUNC_MODES = ("evd", "rsvd", "brand_rsvd")


class MetricSpec(NamedTuple):
    """One named scalar in the closed catalog."""
    name: str
    kind: str
    doc: str = ""


def catalog_for(opt) -> Tuple[MetricSpec, ...]:
    """The closed metric catalog for one ``Kfac`` optimizer (duck-typed:
    only ``factor_buckets`` / ``_async_buckets`` statics are read).
    Per-bucket entries exist only where the bucket's mode can produce
    them, so the buffer stays small on single-variant configs."""
    specs: List[MetricSpec] = [
        MetricSpec("work/stats_fired", COUNTER,
                   "steps that absorbed a stats batch"),
        MetricSpec("work/light_fired", COUNTER,
                   "steps that ran the Brand light update"),
        MetricSpec("work/heavy_slots", COUNTER,
                   "factor slots whose heavy op fired inline"),
        MetricSpec("work/launch_slots", COUNTER,
                   "factor slots snapshotted into the async pipeline"),
        MetricSpec("work/land_slots", COUNTER,
                   "factor slots whose async heavy result landed"),
        MetricSpec("precond/damping_phi", GAUGE,
                   "damping ratio φ_λ at the last step"),
        # resilience layer (train/health.py) — all zero on healthy runs
        MetricSpec("health/guard_trips", COUNTER,
                   "steps the in-graph guard skipped (update reverted)"),
        MetricSpec("health/grad_nonfinite", COUNTER,
                   "nonfinite gradient entries seen by the guard"),
        MetricSpec("health/update_nonfinite", COUNTER,
                   "nonfinite preconditioned-update entries seen"),
    ]
    for bi, bucket in enumerate(opt.factor_buckets):
        mode = bucket.spec.mode.value
        p = f"bucket{bi}"
        specs.append(MetricSpec(f"{p}/heavy_slots", COUNTER,
                                f"[{mode}] slots refreshed (inline+landed)"))
        specs.append(MetricSpec(f"health/{p}/factor_nonfinite", COUNTER,
                                "nonfinite factor-state entries seen by "
                                "the guard"))
        if mode == "ns":
            specs.append(MetricSpec(f"{p}/ns_lam", GAUGE,
                                    "mean λ̂ of the last NS refresh"))
            specs.append(MetricSpec(f"{p}/ns_res", GAUGE,
                                    "worst-slot NS Frobenius residual "
                                    "(≥0.5 ⇒ dense fallback fired)"))
        if mode in _TRUNC_MODES:
            specs.append(MetricSpec(f"{p}/trunc_mass", GAUGE,
                                    "worst-slot truncated spectral-mass "
                                    "fraction of the last overwrite"))
        if bucket.spec.needs_m:
            specs.append(MetricSpec(f"{p}/inv_err", GAUGE,
                                    "row-sampled ‖(M+λI)X−I‖_F/√k of the "
                                    "freshly refreshed slots"))
        if bi in getattr(opt, "_async_buckets", {}):
            specs.append(MetricSpec(f"{p}/replay_depth", GAUGE,
                                    "interim Brand panels replayed per "
                                    "landing (static)"))
    return tuple(specs)


# ---------------------------------------------------------------------------
# thread-local collector stack — record() is the hot path's only API
# ---------------------------------------------------------------------------

_TLS = threading.local()


def _stack() -> List["Collector"]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def active() -> bool:
    """True iff a collector is listening on this thread — guard for
    metrics whose *computation* should stay out of un-instrumented
    graphs (cheap statics can just call :func:`record`)."""
    return bool(_stack())


def record(name: str, value: Union[Any, Callable[[], Any]]) -> None:
    """Record one named scalar into the innermost active collector.
    No-op (and ``value`` untouched, if callable) when none is active;
    silently ignores names outside the collector's catalog so shared
    code paths can record unconditionally."""
    st = _stack()
    if st:
        st[-1].record(name, value)


class Collector:
    """Per-traced-step scratch: the values one optimizer step recorded,
    keyed by catalog name, merged into the persistent buffer after the
    step body ran."""

    def __init__(self, catalog: Tuple[MetricSpec, ...]):
        self.kinds: Dict[str, str] = {s.name: s.kind for s in catalog}
        self.values: Dict[str, Any] = {}

    def record(self, name: str, value) -> None:
        kind = self.kinds.get(name)
        if kind is None:
            return
        if callable(value):
            value = value()
        v = jnp.asarray(value, jnp.float32)
        if kind == COUNTER and name in self.values:
            self.values[name] = self.values[name] + v
        else:
            self.values[name] = v


# ---------------------------------------------------------------------------
# host-side sink registry (io_callback closures carry only a static id)
# ---------------------------------------------------------------------------

_SINKS: Dict[int, Callable] = {}
_SINK_IDS = itertools.count()


def register_sink(fn: Callable[[int, int, Dict[str, float]], None]) -> int:
    """Register ``fn(step, window_steps, values)`` and return its id."""
    sid = next(_SINK_IDS)
    _SINKS[sid] = fn
    return sid


class Meter:
    """Static handle tying a metric catalog to a flush cadence + sink.

    Not a pytree — captured by closure in the step function (like the
    optimizer itself).  The mutable state is the buffer returned by
    :meth:`init`, threaded through the jitted step as a donatable carry.
    """

    def __init__(self, catalog: Tuple[MetricSpec, ...], sink: Callable,
                 every: int = 10):
        if every <= 0:
            raise ValueError(f"flush cadence must be positive, got {every}")
        self.catalog = catalog
        self.every = int(every)
        self.sink_id = register_sink(sink)
        self._names = tuple(s.name for s in catalog)
        self._kinds = {s.name: s.kind for s in catalog}

    @classmethod
    def for_opt(cls, opt, sink: Callable, every: int = 10) -> "Meter":
        return cls(catalog_for(opt), sink, every=every)

    # -- buffer lifecycle ---------------------------------------------------
    def init(self) -> Dict[str, jax.Array]:
        buf = {n: jnp.zeros((), jnp.float32) for n in self._names}
        buf["_steps"] = jnp.zeros((), jnp.int32)
        return buf

    def collecting(self):
        """Context manager entered around the optimizer call *inside*
        the traced step; yields the :class:`Collector`."""
        return _collecting(self.catalog)

    def merge(self, buf: Dict[str, jax.Array], col: Collector
              ) -> Dict[str, jax.Array]:
        """Fold one step's collector into the persistent buffer."""
        out = dict(buf)
        out["_steps"] = buf["_steps"] + 1
        for name, v in col.values.items():
            if self._kinds[name] == COUNTER:
                out[name] = buf[name] + v
            else:
                out[name] = v
        return out

    # -- flushing -----------------------------------------------------------
    def maybe_flush(self, buf: Dict[str, jax.Array], step: jax.Array
                    ) -> Dict[str, jax.Array]:
        """Emit the buffer through the sink and reset the window — only
        when the window is full, under ``lax.cond`` so steady-state
        steps carry no callback.  ``step`` is the (traced) optimizer
        step stamped onto the flush."""
        names, kinds, sid = self._names, self._kinds, self.sink_id

        def _emit(step_v, steps_v, *vals):
            sink = _SINKS.get(sid)
            if sink is not None:
                sink(int(step_v), int(steps_v),
                     {n: float(v) for n, v in zip(names, vals)})

        def _flush(b):
            io_callback(_emit, None, step, b["_steps"],
                        *[b[n] for n in names], ordered=False)
            out = dict(b)
            out["_steps"] = jnp.zeros_like(b["_steps"])
            for n in names:
                if kinds[n] == COUNTER:
                    out[n] = jnp.zeros_like(b[n])
            return out

        return jax.lax.cond(buf["_steps"] >= self.every, _flush,
                            lambda b: dict(b), buf)

    def drain(self, buf, step: int) -> None:
        """Host-side final flush of a partial window (end of run)."""
        vals = jax.device_get(buf)
        window = int(vals["_steps"])
        if window == 0:
            return
        sink = _SINKS.get(self.sink_id)
        if sink is not None:
            sink(int(step), window,
                 {n: float(vals[n]) for n in self._names})

    def kinds(self) -> Dict[str, str]:
        return dict(self._kinds)


@contextlib.contextmanager
def _collecting(catalog):
    col = Collector(catalog)
    _stack().append(col)
    try:
        yield col
    finally:
        _stack().pop()

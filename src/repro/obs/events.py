"""Structured event log: schema-versioned JSONL + human-readable console.

Every event is one JSON object per line with a fixed envelope::

    {"schema": 1, "t": <unix time>, "type": "<event type>", ...fields}

The per-type required fields live in :data:`EVENT_TYPES`; extra fields
are allowed (forward-compatible readers ignore them), missing required
fields are a :class:`EventSchemaError` at *write* time, so a malformed
emitter fails its own run instead of poisoning the log.

:class:`TelemetryWriter` is the trainer's single output object — the
structured replacement for the bare ``print(f"[train] ...")`` calls.
The console sink (on by default) renders the familiar human-readable
lines; the JSONL sink (a path) makes the same events machine-readable
for ``repro.obs.summary`` and the CI telemetry-smoke job.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterator, Optional

#: Event-log schema version — bump when an existing event type changes
#: incompatibly (adding new types or optional fields is compatible).
#:   v1  PR 7: initial schema
SCHEMA_VERSION = 1

#: event type → required field names (beyond the envelope)
EVENT_TYPES: Dict[str, frozenset] = {
    # lifecycle
    "run_start": frozenset({"config"}),
    "run_end": frozenset({"steps", "loss_first", "loss_last", "s_per_step"}),
    "log": frozenset({"msg"}),
    # training
    "step": frozenset({"step", "loss", "dt_s", "phase"}),
    "metrics": frozenset({"step", "window_steps", "values", "kinds"}),
    "sched": frozenset({"detail"}),
    # async heavy pipeline
    "async_launch": frozenset({"step", "bucket", "lo", "hi"}),
    "async_land": frozenset({"step", "bucket", "lo", "hi", "overlapped"}),
    "async_miss": frozenset({"step", "bucket", "lo", "hi"}),
    # fault tolerance / elasticity
    "ckpt_save": frozenset({"step", "path"}),
    "ckpt_restore": frozenset({"step", "path"}),
    "repartition": frozenset({"detail"}),
    # resilience layer (train/health.py): one event per enacted ladder
    # action.  ``stage`` is the ladder rung (0 skip, 1 damping, 2 forced
    # refresh, 3 rollback, 4 elastic/repartition), ``action`` the verb.
    # ``async_miss`` events additionally carry an optional ``reason``
    # field (timeout | crash | resume | dropped) — optional, so v1 logs
    # stay valid.
    "remediation": frozenset({"step", "stage", "action", "detail"}),
    # serving.  ``serve_request`` optionally carries ``tenant`` (bank
    # slot) and ``kind`` (infer | finetune) — optional, so v1 logs stay
    # valid; ``tenant_update`` is one completed fine-tune step of one
    # tenant's stacked optimizer state (multi-tenant service, PR 10).
    "serve_request": frozenset({"uid", "wait_s", "total_s", "n_new"}),
    "tenant_update": frozenset({"tenant", "step", "loss", "phase"}),
}


class EventSchemaError(ValueError):
    """An event violates the JSONL schema (unknown type / missing field)."""


def validate_event(ev: Dict[str, Any]) -> None:
    for field in ("schema", "t", "type"):
        if field not in ev:
            raise EventSchemaError(f"event missing envelope field "
                                   f"{field!r}: {ev!r}")
    etype = ev["type"]
    required = EVENT_TYPES.get(etype)
    if required is None:
        raise EventSchemaError(f"unknown event type {etype!r}")
    missing = required - ev.keys()
    if missing:
        raise EventSchemaError(f"event {etype!r} missing required "
                               f"fields {sorted(missing)}: {ev!r}")


def read_events(path: str, validate: bool = True) -> Iterator[dict]:
    """Parse (and by default validate) a JSONL event log."""
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise EventSchemaError(
                    f"{path}:{lineno}: not valid JSON: {e}") from e
            if validate:
                try:
                    validate_event(ev)
                except EventSchemaError as e:
                    raise EventSchemaError(f"{path}:{lineno}: {e}") from e
            yield ev


def _fmt_console(ev: dict) -> Optional[str]:
    """Human-readable rendering — preserves the trainer's familiar
    ``[train] ...`` lines; returns None for types kept off the console
    (high-rate machine-facing events)."""
    t = ev["type"]
    if t == "log":
        return f"[train] {ev['msg']}"
    if t == "step":
        return (f"[train] step {ev['step']:5d} loss {ev['loss']:8.4f} "
                f"({ev['dt_s'] * 1e3:6.0f}ms {ev['phase']})")
    if t == "run_end":
        return (f"[train] done: loss {ev['loss_first']:.4f} -> "
                f"{ev['loss_last']:.4f} ({ev['s_per_step']:.2f}s/step)")
    if t == "ckpt_save":
        return f"[train] checkpoint saved @ step {ev['step']}"
    if t == "ckpt_restore":
        return f"[train] resumed at step {ev['step']}"
    if t == "sched":
        return f"[train] {ev['detail']}"
    if t == "async_miss":
        reason = ev.get("reason", "resume")
        return (f"[train] async landing miss ({reason}): bucket "
                f"{ev['bucket']} slots [{ev['lo']},{ev['hi']}) @ step "
                f"{ev['step']} (landing in-graph)")
    if t == "remediation":
        return (f"[train] remediation stage {ev['stage']} "
                f"({ev['action']}) @ step {ev['step']}: {ev['detail']}")
    if t == "repartition":
        return f"[train] repartition: {ev['detail']}"
    return None     # metrics / launch / land / serve: JSONL only


class TelemetryWriter:
    """Emit schema-validated events to a JSONL file and/or the console.

    ``path=None`` keeps console-only operation (the default trainer
    experience); ``console=False`` makes it log-file-only (benchmarks,
    tests).  Safe to use as a context manager; ``close()`` is
    idempotent."""

    def __init__(self, path: Optional[str] = None, console: bool = True,
                 console_fn: Callable[[str], None] = None):
        self.path = path
        self._console = console
        self._print = console_fn if console_fn is not None else (
            lambda s: print(s, flush=True))
        self._f = open(path, "a") if path else None

    def emit(self, etype: str, **fields) -> dict:
        ev = {"schema": SCHEMA_VERSION, "t": time.time(), "type": etype,
              **fields}
        validate_event(ev)
        if self._f is not None:
            self._f.write(json.dumps(ev) + "\n")
            self._f.flush()
        if self._console:
            line = _fmt_console(ev)
            if line is not None:
                self._print(line)
        return ev

    def log(self, msg: str) -> None:
        """Free-form console line, structured as a ``log`` event."""
        self.emit("log", msg=msg)

    def metrics_sink(self, kinds: Dict[str, str]) -> Callable:
        """A ``Meter`` flush sink that lands each window as one
        ``metrics`` event (kinds ride along so the summary can sum
        counters and last-value gauges without out-of-band state)."""
        def sink(step: int, window_steps: int,
                 values: Dict[str, float]) -> None:
            self.emit("metrics", step=step, window_steps=window_steps,
                      values=values, kinds=kinds)
        return sink

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TelemetryWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

"""Tracing hooks: name the hot path for the jax profiler.

Two kinds of annotation, matching where the code runs:

  * :func:`span` — ``jax.named_scope`` for *traced* code.  Zero runtime
    cost (it only labels operations during tracing), but every bucketed
    factor / precondition launch then shows up in a captured profile —
    and in dumped HLO — under a readable ``kfac/...`` path instead of a
    fusion soup.
  * :func:`host_span` — ``jax.profiler.TraceAnnotation`` for *host*
    code (the AsyncInverseRunner's worker thread, checkpoint IO), which
    emits a real TraceMe at runtime so overlap is visible on the
    profile's host track.

:class:`StepProfiler` drives ``--profile-dir``: capture a profiler
trace for a contiguous window of training steps (skipping step 0 by
default so compilation doesn't drown the steady state).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax


@contextlib.contextmanager
def span(name: str):
    """Label traced operations (named_scope) — nestable, trace-time only."""
    with jax.named_scope(name):
        yield


@contextlib.contextmanager
def host_span(name: str):
    """Label host-side work with a runtime profiler TraceAnnotation."""
    with jax.profiler.TraceAnnotation(name):
        yield


class StepProfiler:
    """Capture a jax profiler trace for steps [first, first+steps).

    ``tick(k)`` brackets the capture from the training loop;
    ``close()`` stops a still-running capture (early exit).  Inactive
    (``log_dir=None``) instances are no-ops, so the loop can call
    ``tick`` unconditionally."""

    def __init__(self, log_dir: Optional[str], first: int = 1,
                 steps: int = 3):
        self.log_dir = log_dir or None
        self.first = int(first)
        self.last = int(first) + int(steps)     # exclusive
        self._running = False

    def tick(self, k: int) -> None:
        if self.log_dir is None:
            return
        if not self._running and self.first <= k < self.last:
            jax.profiler.start_trace(self.log_dir)
            self._running = True
        elif self._running and k >= self.last:
            jax.profiler.stop_trace()
            self._running = False

    def close(self) -> None:
        if self._running:
            jax.profiler.stop_trace()
            self._running = False

"""Render a run's JSONL event log into a per-phase timing +
curvature-health report.

    PYTHONPATH=src python -m repro.obs.summary run/telemetry.jsonl
    PYTHONPATH=src python -m repro.obs.summary run/telemetry.jsonl --json

``--validate`` exits non-zero on any schema violation without printing
the report (the CI telemetry-smoke gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Sequence

from repro.obs import events as ev_lib


def _pct(xs: Sequence[float], q: float) -> float:
    xs = sorted(xs)
    if not xs:
        return float("nan")
    i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return xs[i]


def summarize(path: str) -> dict:
    """Aggregate one event log into a JSON-able report dict."""
    events = list(ev_lib.read_events(path))
    out: Dict = {"path": path, "n_events": len(events)}

    steps = [e for e in events if e["type"] == "step"]
    phases: Dict[str, List[float]] = {}
    for e in steps:
        phases.setdefault(e["phase"], []).append(e["dt_s"])
    out["steps"] = {
        "count": len(steps),
        "phases": {ph: {"count": len(ts),
                        "p50_ms": 1e3 * _pct(ts, 0.5),
                        "p99_ms": 1e3 * _pct(ts, 0.99),
                        "total_s": sum(ts)}
                   for ph, ts in sorted(phases.items())},
    }
    if steps:
        out["loss"] = {"first": steps[0]["loss"], "last": steps[-1]["loss"]}

    # metrics windows: counters sum across windows, gauges take the last
    metrics = [e for e in events if e["type"] == "metrics"]
    if metrics:
        agg: Dict[str, float] = {}
        kinds: Dict[str, str] = {}
        for e in metrics:
            kinds.update(e["kinds"])
            for name, v in e["values"].items():
                if e["kinds"].get(name) == "counter":
                    agg[name] = agg.get(name, 0.0) + v
                else:
                    agg[name] = v
        out["metrics"] = {"windows": len(metrics),
                          "last_step": metrics[-1]["step"],
                          "values": agg, "kinds": kinds}

    launches = [e for e in events if e["type"] == "async_launch"]
    lands = [e for e in events if e["type"] == "async_land"]
    misses = [e for e in events if e["type"] == "async_miss"]
    if launches or lands or misses:
        by_reason: Dict[str, int] = {}
        for e in misses:
            r = e.get("reason", "resume")
            by_reason[r] = by_reason.get(r, 0) + 1
        out["async"] = {
            "launches": len(launches),
            "lands": len(lands),
            "overlapped_lands": sum(bool(e["overlapped"]) for e in lands),
            "misses": len(misses),
            "miss_reasons": by_reason,
        }

    # resilience: remediation ladder actions + elastic repartitions
    remedies = [e for e in events if e["type"] == "remediation"]
    reparts = [e for e in events if e["type"] == "repartition"]
    if remedies or reparts:
        by_action: Dict[str, int] = {}
        for e in remedies:
            by_action[e["action"]] = by_action.get(e["action"], 0) + 1
        out["resilience"] = {
            "remediations": len(remedies),
            "actions": by_action,
            "repartitions": len(reparts),
            "last": remedies[-1]["detail"] if remedies else None,
        }

    saves = [e for e in events if e["type"] == "ckpt_save"]
    restores = [e for e in events if e["type"] == "ckpt_restore"]
    if saves or restores:
        out["checkpoint"] = {"saves": len(saves), "restores": len(restores)}

    serve = [e for e in events if e["type"] == "serve_request"]
    tenant_updates = [e for e in events if e["type"] == "tenant_update"]
    if serve:
        tot = [e["total_s"] for e in serve]
        out["serve"] = {"requests": len(serve),
                        "p50_ms": 1e3 * _pct(tot, 0.5),
                        "p99_ms": 1e3 * _pct(tot, 0.99)}
        # per-tenant breakdown (multi-tenant service; events without a
        # tenant field are the single-model engine and stay aggregate)
        by_tenant: Dict[str, List[dict]] = {}
        for e in serve:
            if "tenant" in e:
                by_tenant.setdefault(str(e["tenant"]), []).append(e)
        if by_tenant:
            out["serve"]["tenants"] = {
                t: {"requests": len(es),
                    "finetunes": sum(e.get("kind") == "finetune"
                                     for e in es),
                    "p50_ms": 1e3 * _pct([e["total_s"] for e in es], 0.5),
                    "p99_ms": 1e3 * _pct([e["total_s"] for e in es], 0.99)}
                for t, es in sorted(by_tenant.items(), key=lambda kv:
                                    int(kv[0]))}
    if tenant_updates:
        by_t: Dict[str, List[dict]] = {}
        for e in tenant_updates:
            by_t.setdefault(str(e["tenant"]), []).append(e)
        out["tenant_updates"] = {
            t: {"steps": len(es), "last_step": es[-1]["step"],
                "loss_first": es[0]["loss"], "loss_last": es[-1]["loss"]}
            for t, es in sorted(by_t.items(), key=lambda kv: int(kv[0]))}
    return out


def render(s: dict) -> str:
    lines = [f"== telemetry summary: {s['path']} ({s['n_events']} events) =="]
    st = s.get("steps", {})
    if st.get("count"):
        lines.append(f"steps: {st['count']}")
        lines.append(f"  {'phase':8s} {'count':>6s} {'p50':>9s} "
                     f"{'p99':>9s} {'total':>8s}")
        for ph, row in st["phases"].items():
            lines.append(f"  {ph:8s} {row['count']:6d} "
                         f"{row['p50_ms']:7.1f}ms {row['p99_ms']:7.1f}ms "
                         f"{row['total_s']:7.2f}s")
    if "loss" in s:
        lines.append(f"loss: {s['loss']['first']:.4f} -> "
                     f"{s['loss']['last']:.4f}")
    m = s.get("metrics")
    if m:
        lines.append(f"metrics: {m['windows']} windows "
                     f"(last @ step {m['last_step']})")
        for name in sorted(m["values"]):
            kind = m["kinds"].get(name, "?")
            lines.append(f"  {name:28s} {m['values'][name]:12.6g}  "
                         f"[{kind}]")
    a = s.get("async")
    if a:
        reasons = ", ".join(f"{k}={v}" for k, v in
                            sorted(a.get("miss_reasons", {}).items()))
        lines.append(f"async pipeline: {a['launches']} launches, "
                     f"{a['lands']} lands "
                     f"({a['overlapped_lands']} overlapped), "
                     f"{a['misses']} misses"
                     + (f" [{reasons}]" if reasons else ""))
    r = s.get("resilience")
    if r:
        acts = ", ".join(f"{k}={v}" for k, v in sorted(r["actions"].items()))
        lines.append(f"resilience: {r['remediations']} remediations"
                     + (f" ({acts})" if acts else "")
                     + f", {r['repartitions']} repartitions")
    c = s.get("checkpoint")
    if c:
        lines.append(f"checkpoints: {c['saves']} saved, "
                     f"{c['restores']} restored")
    sv = s.get("serve")
    if sv:
        lines.append(f"serving: {sv['requests']} requests, "
                     f"p50 {sv['p50_ms']:.1f}ms p99 {sv['p99_ms']:.1f}ms")
        for t, row in sv.get("tenants", {}).items():
            lines.append(f"  tenant {t}: {row['requests']} requests "
                         f"({row['finetunes']} finetune), "
                         f"p50 {row['p50_ms']:.1f}ms "
                         f"p99 {row['p99_ms']:.1f}ms")
    tu = s.get("tenant_updates")
    if tu:
        lines.append(f"tenant fine-tuning: {len(tu)} tenants")
        for t, row in tu.items():
            lines.append(f"  tenant {t}: {row['steps']} steps "
                         f"(-> step {row['last_step']}), loss "
                         f"{row['loss_first']:.4f} -> "
                         f"{row['loss_last']:.4f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs telemetry JSONL log")
    ap.add_argument("path", help="path to telemetry.jsonl")
    ap.add_argument("--json", action="store_true",
                    help="emit the raw report dict as JSON")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate only; exit 1 on violation")
    args = ap.parse_args(argv)
    if args.validate:
        try:
            n = sum(1 for _ in ev_lib.read_events(args.path))
        except ev_lib.EventSchemaError as e:
            print(f"schema violation: {e}", file=sys.stderr)
            return 1
        print(f"ok: {n} events valid against schema "
              f"v{ev_lib.SCHEMA_VERSION}")
        return 0
    report = summarize(args.path)
    print(json.dumps(report, indent=2) if args.json else render(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())

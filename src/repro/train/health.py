"""In-graph numerical-health guards + the staged remediation ladder.

The paper's graceful-degradation contract (Props 4.1/4.2: a stale or
B-only inverse strictly beats *no* update) means the safe response to
almost any numerical fault is "do less curvature work, never apply a
poisoned update" — which is exactly what this module enacts, in four
escalating stages:

  stage 0  **skip**      — the in-graph guard: a step whose grads,
                           preconditioned updates, or post-step factor
                           states contain nonfinite values (or explode
                           past a threshold) applies *no* update at all;
                           params and optimizer state revert via a
                           bitwise ``where`` select, so the poisoned
                           step simply never happened.
  stage 1  **escalate**  — persistent faults or loss divergence scale
                           the damping ratio φ up (``damping_scale``,
                           a traced scalar into ``Kfac.update``), the
                           classic trust-region response.  De-escalates
                           after ``recovery_steps`` healthy steps.
  stage 2  **refresh**   — a *forced out-of-cadence heavy refresh*
                           (:meth:`Kfac.remedial_work`): the inverse rep
                           is re-established from the live M this step
                           and every in-flight async snapshot is
                           discarded (``Kfac.clear_inflight``) — the
                           RS-KFAC-style "re-establish curvature from
                           scratch" escape hatch.
  stage 3  **rollback**  — restore the newest *healthy* checkpoint
                           (``checkpoint.restore_latest_healthy``) when
                           the fault persists past the refresh.

Detection is **jit/shard_map-safe and in-graph**: per-bucket checks run
at the outer trace level off the post-step factor states (post
all-gather under the sharded curvature engine, exactly like
``Kfac._record_bucket_metrics``), NS-residual blowup rides the existing
``KFactorState.aux`` channels, and the same values feed the obs metric
buffer when a collector is active — so replicated and sharded runs
report identically.  The policy itself
(:class:`RemediationPolicy`) is host-side python: it consumes the tiny
:func:`health_report` dict the step returns (the trainer already syncs
the loss every step, so this adds no extra device round-trip) and
decides the *next* step's remediation.

**Inertness contract** (the PR 7 meter's, extended): a healthy run with
guards on is *bit-for-bit identical* to one with them off.  The guard
only reads hot-path values; the final select is ``where(ok, new, old)``
— an exact element pick, no arithmetic — and the stage-1 knob
multiplies φ by exactly 1.0 until escalated.  Asserted across all six
policy variants, the async pipeline, and the 8-device sharded engine in
tests/test_chaos.py and the ``step/health_on_vs_off`` bench row.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import kfactor
from repro.models import layers
from repro.obs import metrics as obs_metrics
from repro.optim import base as optbase

Array = jax.Array

#: remediation-ladder stage codes (the ``stage`` field of
#: ``remediation`` telemetry events)
STAGE_SKIP = 0
STAGE_DAMP = 1
STAGE_REFRESH = 2
STAGE_ROLLBACK = 3
STAGE_ELASTIC = 4


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds for the in-graph guards + ladder pacing.

    The explosion thresholds are deliberately loose (guards are a last
    line of defense, not a clipper — ``KfacConfig.clip`` already bounds
    healthy updates); the ladder counters are in *consecutive faulty
    steps*.
    """
    grad_abs_max: float = 1e8        # |g|_max past this trips the guard
    update_abs_max: float = 1e8      # |Δ|_max past this trips the guard
    loss_div_factor: float = 30.0    # loss > factor × EMA ⇒ divergence
    loss_ema: float = 0.9            # EMA decay for the divergence ref
    ns_res_max: float = kfactor._NS_RES_MAX   # NS residual blowup
    escalation: float = 8.0          # φ multiplier per stage-1 action
    max_escalations: int = 2
    refresh_after: int = 3           # faulty streak ⇒ forced refresh
    rollback_after: int = 6          # faulty streak ⇒ checkpoint rollback
    recovery_steps: int = 4          # healthy streak ⇒ de-escalate φ


# ---------------------------------------------------------------------------
# in-graph report
# ---------------------------------------------------------------------------

def _count_nonfinite(tree) -> Array:
    n = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            n = n + jnp.sum(~jnp.isfinite(leaf)).astype(jnp.float32)
    return n


def _abs_max(tree) -> Array:
    m = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            m = jnp.maximum(m, jnp.max(jnp.abs(leaf)).astype(jnp.float32))
    return m


def factor_report(opt, factors) -> Dict[str, Array]:
    """Per-bucket factor-state checks off the live (post-step) states:
    nonfinite counts over (U, D[, M]) and, for NS buckets, the worst
    residual from the ``aux`` diagnostics channel.  Runs at the outer
    trace level — under the sharded curvature engine the states here are
    the post-all-gather ones, so every host computes the same report."""
    out: Dict[str, Array] = {}
    for bi, bucket in enumerate(opt.factor_buckets):
        bad = jnp.zeros((), jnp.float32)
        res = jnp.zeros((), jnp.float32)
        for e in bucket.entries:
            st = getattr(factors[e.name], e.side)
            bad = bad + _count_nonfinite((st.U, st.D))
            if bucket.spec.needs_m:
                bad = bad + _count_nonfinite(st.M)
            if bucket.spec.mode is kfactor.Mode.NS:
                res = jnp.maximum(res,
                                  jnp.max(st.aux[..., kfactor.AUX_RES]))
        out[f"bucket{bi}/factor_nonfinite"] = bad
        if bucket.spec.mode is kfactor.Mode.NS:
            out[f"bucket{bi}/ns_res"] = res
    return out


def health_report(hcfg: HealthConfig, opt, loss, grads, updates,
                  opt_state) -> Dict[str, Array]:
    """The step's health vector: a flat dict of f32 scalars with a fixed
    key set (same pytree for every step variant).  ``ok`` is the
    in-graph guard verdict — 1.0 iff the step is safe to apply."""
    rep: Dict[str, Array] = {}
    rep["grad_nonfinite"] = _count_nonfinite(grads)
    rep["grad_abs_max"] = _abs_max(grads)
    rep["update_nonfinite"] = _count_nonfinite(updates)
    rep["update_abs_max"] = _abs_max(updates)
    frep = factor_report(opt, opt_state.factors)
    rep.update(frep)
    factor_bad = jnp.zeros((), jnp.float32)
    for k, v in frep.items():
        if k.endswith("factor_nonfinite"):
            factor_bad = factor_bad + v
    ok = (jnp.isfinite(loss)
          & (rep["grad_nonfinite"] == 0)
          & (rep["grad_abs_max"] < hcfg.grad_abs_max)
          & (rep["update_nonfinite"] == 0)
          & (rep["update_abs_max"] < hcfg.update_abs_max)
          & (factor_bad == 0))
    rep["ok"] = ok.astype(jnp.float32)
    return rep


def _record_health(report: Dict[str, Array]) -> None:
    """Mirror the report into the obs metric buffer (no-op without an
    active collector — the metrics-off graph is untouched)."""
    if not obs_metrics.active():
        return
    obs_metrics.record("health/guard_trips", 1.0 - report["ok"])
    obs_metrics.record("health/grad_nonfinite", report["grad_nonfinite"])
    obs_metrics.record("health/update_nonfinite",
                       report["update_nonfinite"])
    for k, v in report.items():
        if k.endswith("factor_nonfinite"):
            obs_metrics.record(f"health/{k}", v)


def _select(ok, new, old):
    """Bitwise per-leaf pick: ``new`` where ok, else ``old`` — exact
    (no arithmetic), so ok=True returns ``new`` bit-for-bit."""
    return jax.tree_util.tree_map(lambda n, o: jnp.where(ok, n, o),
                                  new, old)


def make_resilient_kfac_step(loss_fn, opt, n_tokens: int,
                             health: Optional[HealthConfig] = None,
                             probe_dtype=jnp.float32, meter=None):
    """``make_scheduled_kfac_step`` with the in-graph guard wrapped
    around it.  Returns ``step(state, batch, work, landing=None,
    mbuf=None, damping_scale=None) -> (state, loss, report[, mbuf])`` —
    jit with ``static_argnames=("work",)``.

    A step whose report says not-ok applies nothing: params and the
    whole optimizer state (factors, inflight buffers, counters) revert
    to their pre-step values, so a poisoned batch can neither move the
    params nor seed the curvature statistics.  ``damping_scale`` is the
    ladder's stage-1 knob (traced, so escalation never recompiles)."""
    from repro.train import loop as loop_lib
    hcfg = health if health is not None else HealthConfig()

    def step(state, batch, work, landing=None, mbuf=None,
             damping_scale=None):
        rng, sub = jax.random.split(state.rng)
        probes = layers.make_probes(opt.taps, probe_dtype)
        loss, acts, gp, gprobe = loop_lib.kfac_grads(
            loss_fn, state.params, probes, batch)

        def body():
            updates, opt_state = opt.update(
                gp, state.opt, state.params, acts=acts,
                probe_grads=gprobe, n_tokens=n_tokens, rng=sub,
                work=work, landing=landing, damping_scale=damping_scale)
            report = health_report(hcfg, opt, loss, gp, updates,
                                   opt_state)
            _record_health(report)
            ok = report["ok"] > 0
            params = optbase.apply_updates(state.params, updates)
            params = _select(ok, params, state.params)
            opt_state = _select(ok, opt_state, state.opt)
            return params, opt_state, report

        if meter is None:
            params, opt_state, report = body()
            return (loop_lib.TrainState(params=params, opt=opt_state,
                                        rng=rng), loss, report)
        with meter.collecting() as col:
            params, opt_state, report = body()
        mbuf = meter.maybe_flush(meter.merge(mbuf, col), opt_state.step)
        return (loop_lib.TrainState(params=params, opt=opt_state,
                                    rng=rng), loss, report, mbuf)

    return step


# ---------------------------------------------------------------------------
# the staged policy (host side)
# ---------------------------------------------------------------------------

class RemediationPolicy:
    """Consumes one :func:`health_report` per step and decides the next
    step's remediation.  Pure host-side state machine; every enacted
    action lands in ``self.actions`` and (when a writer is attached) as
    a ``remediation`` telemetry event.

    The trainer's contract (see ``loop.run_kfac_training``):

      * pass ``jnp.float32(policy.damping_scale)`` into the resilient
        step each step;
      * before building a step's work mask, if :meth:`take_refresh` is
        true, substitute ``opt.remedial_work()``, clear the in-flight
        buffers, and drop any pending async futures;
      * after the step, call :meth:`observe`;
      * if :meth:`take_rollback` is true, restore the newest healthy
        checkpoint and call :meth:`notify_rollback`.
    """

    def __init__(self, cfg: Optional[HealthConfig] = None, writer=None):
        self.cfg = cfg if cfg is not None else HealthConfig()
        self.writer = writer
        self.damping_scale: float = 1.0
        self.actions: List[dict] = []
        self._streak = 0
        self._healthy = 0
        self._escalations = 0
        self._loss_ema: Optional[float] = None
        self._refresh_pending = False
        self._rollback_pending = False

    # -- event plumbing ----------------------------------------------------
    def _emit(self, step: int, stage: int, action: str, detail: str):
        rec = dict(step=int(step), stage=int(stage), action=action,
                   detail=detail)
        self.actions.append(rec)
        if self.writer is not None:
            self.writer.emit("remediation", **rec)

    # -- per-step observation ----------------------------------------------
    def observe(self, step: int, loss: float,
                report: Dict[str, float]) -> bool:
        """Feed one step's (host-fetched) loss + health report.  Returns
        True iff the step was faulty."""
        cfg = self.cfg
        ok = report.get("ok", 1.0) >= 1.0
        diverged = not math.isfinite(loss)
        if not diverged and self._loss_ema is not None:
            diverged = loss > cfg.loss_div_factor * max(self._loss_ema,
                                                        1e-12)
        ns_blow = any(v >= cfg.ns_res_max for k, v in report.items()
                      if k.endswith("/ns_res"))
        fault = (not ok) or diverged or ns_blow
        if not fault:
            self._loss_ema = (loss if self._loss_ema is None else
                              cfg.loss_ema * self._loss_ema
                              + (1.0 - cfg.loss_ema) * loss)
            self._streak = 0
            self._healthy += 1
            if (self.damping_scale != 1.0
                    and self._healthy >= cfg.recovery_steps):
                self.damping_scale = 1.0
                self._escalations = 0
                self._emit(step, STAGE_DAMP, "deescalate",
                           f"healthy for {self._healthy} steps: damping "
                           f"scale -> 1")
            return False
        self._healthy = 0
        self._streak += 1
        why = []
        if not ok:
            why.append("in-graph guard tripped "
                       f"(grad_nonfinite={report.get('grad_nonfinite', 0):g}"
                       f", update_nonfinite="
                       f"{report.get('update_nonfinite', 0):g})")
        if diverged:
            ref = self._loss_ema if self._loss_ema is not None else 0.0
            why.append(f"loss divergence ({loss:.4g} vs ema {ref:.4g})")
        if ns_blow:
            why.append("NS residual blowup")
        detail = "; ".join(why)
        if not ok:
            self._emit(step, STAGE_SKIP, "skip",
                       f"update skipped in-graph: {detail}")
        if self._streak >= cfg.rollback_after:
            self._rollback_pending = True
            self._streak = 0
            self._emit(step, STAGE_ROLLBACK, "rollback",
                       f"{detail}; restoring newest healthy checkpoint")
        elif self._streak % cfg.refresh_after == 0:
            self._refresh_pending = True
            self._emit(step, STAGE_REFRESH, "refresh",
                       f"{detail}; forcing out-of-cadence heavy refresh "
                       f"(in-flight snapshots discarded)")
        elif self._escalations < cfg.max_escalations:
            self._escalations += 1
            old = self.damping_scale
            self.damping_scale = old * cfg.escalation
            self._emit(step, STAGE_DAMP, "escalate",
                       f"{detail}; damping scale {old:g} -> "
                       f"{self.damping_scale:g}")
        return True

    # -- trainer hooks ------------------------------------------------------
    def take_refresh(self) -> bool:
        """True once per scheduled forced refresh (consumed)."""
        pending, self._refresh_pending = self._refresh_pending, False
        return pending

    def take_rollback(self) -> bool:
        """True once per scheduled checkpoint rollback (consumed)."""
        pending, self._rollback_pending = self._rollback_pending, False
        return pending

    def notify_rollback(self, step: int, restored_step: int,
                        path: str) -> None:
        self._emit(step, STAGE_ROLLBACK, "restored",
                   f"rolled back to healthy step {restored_step} "
                   f"from {path}")

    def count(self, action: str) -> int:
        return sum(1 for a in self.actions if a["action"] == action)

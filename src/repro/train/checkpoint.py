"""Fault-tolerant checkpointing: sharded npz + JSON manifest, atomic
rename, async writer thread, restore-with-resharding.

Layout:
    <dir>/step_000123/
        manifest.json        {step, tree structure, mesh, timestamp, done}
        arrays.npz           flat {escaped-path: np.ndarray}
    <dir>/LATEST             atomic pointer file

Restore never requires the original mesh: arrays land on host and are
``device_put`` with the *new* sharding (elastic remesh path — see
train/elastic.py).  A checkpoint is only visible once its manifest has
``done: true`` and LATEST points at it (crash-consistent).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "|"

#: Manifest schema version — bump whenever the trained pytree structure
#: changes incompatibly, and record the change here so restore failures
#: can say what actually happened:
#:   v1  seed .. PR 2   (KfacState without `phase`)
#:   v2  PR 3           (KfacState.phase: schedule position for resume)
#:   v3  PR 5           (KfacState.inflight: async heavy pipeline's
#:                       in-flight snapshot buffers — saved mid-lag and
#:                       restored so pending landings still fire)
#:   v4  PR 7           (KFactorState.aux: per-slot heavy-op diagnostics
#:                       — NS λ̂/residual promoted out of the D[:2] stash,
#:                       EVD/RSVD truncation mass — one (AUX_WIDTH,) leaf
#:                       per factor side)
#:   v5  PR 8           (manifest gains per-array crc32 ``checksums``,
#:                       verified on restore; pytree unchanged — v4
#:                       checkpoints restore fine, just unverified)
#:   v6  PR 10          (manifest gains a first-class ``tenants`` table —
#:                       the multi-tenant service's per-tenant
#:                       {tenant, slot, step} rows, mapping each tenant
#:                       id onto its TenantBank slot and local schedule
#:                       position; pytree unchanged for single-tenant
#:                       states, stacked [N, ...] leaves for banks — v5
#:                       checkpoints restore fine, tenants just absent)
#: Leaf-compatible additions (e.g. inflight == {} when async is off)
#: restore across versions; the schema is used to *explain* mismatches,
#: not to reject compatible checkpoints.
SCHEMA_VERSION = 6

_SCHEMA_HISTORY = {
    1: "seed..PR2 pytree (KfacState without `phase`)",
    2: "PR3 pytree (added KfacState.phase)",
    3: "PR5 pytree (added KfacState.inflight async buffers)",
    4: "PR7 pytree (added KFactorState.aux heavy-op diagnostics)",
    5: "PR8 manifest (per-array crc32 checksums; same pytree as v4)",
    6: "PR10 manifest (per-tenant `tenants` table for TenantBank states; "
       "same pytree rules as v5)",
}


def _step_dir(step: int) -> str:
    return f"step_{step:09d}"


def _digest(arr: np.ndarray) -> str:
    """crc32 over the raw bytes (stdlib-only; this is torn-write/bit-rot
    detection, not cryptographic integrity)."""
    return f"{zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xffffffff:08x}"


def _key_str(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = SEP.join(_key_str(k) for k in kp)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = SEP.join(_key_str(k) for k in kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree, extra: Optional[dict] = None,
         tenants: Optional[List[dict]] = None) -> str:
    """Synchronous checkpoint write with atomic publish.

    ``tenants`` (schema v6) is the multi-tenant service's table — one
    ``{"tenant": id, "slot": bank_slot, "step": local_step}`` row per
    tenant in a stacked TenantBank state — recorded first-class in the
    manifest so a restore can re-seat every tenant at its own schedule
    position.  Omitted (the single-tenant trainer), the manifest carries
    ``tenants: None`` and restores exactly as before."""
    os.makedirs(directory, exist_ok=True)
    name = _step_dir(step)
    tmp = os.path.join(directory, f".tmp_{name}_{os.getpid()}")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "schema": SCHEMA_VERSION,
        "time": time.time(),
        "n_arrays": len(arrays),
        "bytes": int(sum(a.nbytes for a in arrays.values())),
        "checksums": {k: _digest(a) for k, a in arrays.items()},
        "extra": extra or {},
        "tenants": tenants,
        "done": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    man = os.path.join(directory, name, "manifest.json")
    if not os.path.exists(man):
        return None
    try:
        with open(man) as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    return m["step"] if m.get("done") else None


class SchemaMismatchError(RuntimeError):
    """A checkpoint's pytree structure does not match the template —
    raised with the manifest schema versions so the operator knows
    whether to migrate or re-run (instead of the opaque KeyError the
    raw leaf lookup produces)."""


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint's on-disk bytes are damaged — truncated archive,
    unreadable manifest, or an array whose crc32 disagrees with the
    manifest's recorded digest.  The message names the offending file
    (and, for digest mismatches, expected vs found), so the operator
    knows *which* snapshot to delete; ``restore_latest_healthy`` walks
    past these automatically."""


def restore(directory: str, template, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, dict]:
    """Load a checkpoint into the template's structure.  ``shardings`` (a
    matching pytree of NamedSharding) re-lays the arrays onto any mesh.

    A checkpoint written by an older pytree schema (e.g. pre-PR-3 states
    without ``KfacState.phase``, or pre-async states restored into an
    ``async_heavy`` template) fails with a :class:`SchemaMismatchError`
    naming both schema versions and what changed between them."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, _step_dir(step))
    man_path = os.path.join(path, "manifest.json")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint manifest {man_path} is unreadable ({e}); the "
            f"snapshot is damaged — delete {path} or use "
            f"restore_latest_healthy() to fall back to an older one."
        ) from e
    npz_path = os.path.join(path, "arrays.npz")
    try:
        with np.load(npz_path) as z:
            arrays = {k: z[k] for k in z.files}
    except (OSError, ValueError, zipfile.BadZipFile, EOFError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint archive {npz_path} is truncated or unreadable "
            f"({type(e).__name__}: {e}); likely a torn write — delete "
            f"{path} or use restore_latest_healthy() to fall back."
        ) from e
    for key, expect in manifest.get("checksums", {}).items():
        if key not in arrays:
            raise CheckpointCorruptionError(
                f"checkpoint {npz_path}: array {key!r} listed in the "
                f"manifest is missing from the archive (torn write).")
        found = _digest(arrays[key])
        if found != expect:
            raise CheckpointCorruptionError(
                f"checkpoint {npz_path}: array {key!r} failed integrity "
                f"check — expected crc32 {expect}, found {found}.  The "
                f"snapshot is corrupt; delete {path} or use "
                f"restore_latest_healthy() to fall back.")
    try:
        tree = _unflatten_into(template, arrays)
    except KeyError as e:
        found = manifest.get("schema", 1)
        raise SchemaMismatchError(
            f"checkpoint {path} has manifest schema v{found} "
            f"({_SCHEMA_HISTORY.get(found, 'unknown layout')}) but this "
            f"build restores schema v{SCHEMA_VERSION} "
            f"({_SCHEMA_HISTORY[SCHEMA_VERSION]}): leaf {e.args[0]!r} is "
            f"missing from the saved arrays.  Re-run training from "
            f"scratch, or migrate the checkpoint (load it with the "
            f"writing build's state template, then re-save with this "
            f"one).  Async note: a pre-async checkpoint restores fine "
            f"when async_heavy is off; turning async on mid-run needs a "
            f"fresh (or migrated) checkpoint because the in-flight "
            f"buffers join the pytree.") from e
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


def available_steps(directory: str) -> List[int]:
    """All snapshot step numbers present on disk, oldest first (whether
    healthy or not — in-progress ``.tmp_`` dirs excluded)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_"):
            try:
                out.append(int(d[len("step_"):]))
            except ValueError:
                continue
    return sorted(out)


def restore_latest_healthy(directory: str, template,
                           shardings=None) -> Tuple[Any, dict]:
    """Restore the newest snapshot that passes integrity verification,
    walking the kept ring past corrupted/truncated/mismatched ones (the
    rollback stage of the remediation ladder, and the elastic restart
    path when the newest write was torn by the failure itself).

    The returned manifest carries ``skipped_corrupt``: a list of
    ``{step, error}`` records for every newer snapshot that was walked
    past, so the rollback telemetry can say what was discarded.  Raises
    ``FileNotFoundError`` if no healthy snapshot exists at all."""
    skipped: List[dict] = []
    for step in reversed(available_steps(directory)):
        try:
            tree, manifest = restore(directory, template, step=step,
                                     shardings=shardings)
        except (CheckpointCorruptionError, SchemaMismatchError,
                OSError, KeyError, ValueError) as e:
            skipped.append({"step": step,
                            "error": f"{type(e).__name__}: {e}"})
            continue
        if not manifest.get("done"):
            skipped.append({"step": step, "error": "manifest not done"})
            continue
        manifest = dict(manifest)
        manifest["skipped_corrupt"] = skipped
        return tree, manifest
    detail = "; ".join(f"step {s['step']}: {s['error'].splitlines()[0]}"
                       for s in skipped) or "directory empty"
    raise FileNotFoundError(
        f"no healthy checkpoint in {directory} ({detail})")


def prune(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


class AsyncCheckpointer:
    """Background writer: device→host copy happens on the caller thread
    (cheap, avoids mutation races), serialization + IO on a worker."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save(self.directory, step, host_tree, extra)
                prune(self.directory, self.keep)
            except BaseException as e:      # surfaced on next submit/close
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree, extra: Optional[dict] = None):
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err

    def close(self):
        self._q.join()
        self._q.put(None)
        self._thread.join(timeout=10)
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err

"""Elastic scaling + failure handling.

At 1000+ nodes the failure model is: a pod (or slice) drops, the job must
resume on the surviving capacity within minutes.  The policy here:

  1. every `ckpt_every` steps an AsyncCheckpointer snapshot is published;
  2. on failure, the launcher picks the largest healthy mesh from
     ``FALLBACK_MESHES``, rebuilds shardings for it, and restores the last
     checkpoint with resharding (train/checkpoint.py restore(shardings=…));
  3. batch schedule is deterministic in step (data/synthetic.py), so the
     resumed run replays the exact stream — no data-loss bookkeeping;
  4. K-FAC factor states are checkpointed too (they are small for Brand
     modes) — a restart never loses curvature history.

``ElasticRunner`` drives this loop in-process; failures are injected by
tests through ``FailureInjector`` (we cannot kill real pods in CI).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax

from repro.launch import mesh as mesh_lib
from repro.train import checkpoint as ckpt_lib


def device_ladder(n_devices: Optional[int] = None,
                  axes: Tuple[str, ...] = ("data",),
                  shape: Optional[Tuple[int, ...]] = None
                  ) -> Tuple[Tuple[Tuple[int, ...], Tuple[str, ...]], ...]:
    """The recovery ladder derived from the devices that actually exist:
    full capacity, then successive halvings down to a single device.
    This replaces the old hardcoded pod-scale table, which never matched
    the process's real topology — on an 8-device host every rung of that
    table failed ``make_mesh`` and collapsed straight to ``(1,)``,
    skipping the surviving-capacity meshes entirely.

    Without ``shape``, the first axis absorbs the device count and
    trailing axes get 1 (the 1D ladder).  With an explicit starting
    ``shape`` (e.g. ``(4, 2)`` on a ``("data", "curv")`` mesh), each
    rung halves the *largest* dimension (ties break leftmost), modelling
    both 2D shrink paths — dropping a data row vs. dropping a curvature
    column — until every axis is 1.  :func:`shrunk_axes` names which
    axis a given rung-to-rung transition shrank (ElasticRunner emits it
    in the ``repartition`` event)."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    if shape is None:
        shape = (max(1, n),) + (1,) * (len(axes) - 1)
    shape = tuple(max(1, int(x)) for x in shape)
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} does not match axes {axes}")
    ladder = [(shape, tuple(axes))]
    while any(x > 1 for x in shape):
        i = max(range(len(shape)), key=lambda j: shape[j])
        shape = shape[:i] + (shape[i] // 2,) + shape[i + 1:]
        ladder.append((shape, tuple(axes)))
    return tuple(ladder)


def shrunk_axes(prev: Tuple[int, ...], cur: Tuple[int, ...],
                axes: Tuple[str, ...]) -> Tuple[str, ...]:
    """Names of the mesh axes that shrank between two ladder rungs —
    which dimension of capacity was dropped (a data row, a curvature
    column, …).  Empty when nothing shrank (e.g. a restart on the same
    rung)."""
    return tuple(a for a, p, c in zip(axes, prev, cur) if c < p)


#: (mesh shape, axis names), largest first — the recovery ladder.
#: Kept as a module attribute for callers that pin an explicit ladder;
#: :class:`ElasticRunner` defaults to :func:`device_ladder` (the real
#: topology) when ``meshes`` is not given.
FALLBACK_MESHES: Sequence[Tuple[Tuple[int, ...], Tuple[str, ...]]] = (
    ((2, 16, 16), ("pod", "data", "model")),
    ((16, 16), ("data", "model")),
    ((8, 16), ("data", "model")),
)


class FailureInjector:
    """Test hook: schedule step indices that raise a simulated fault."""

    def __init__(self, fail_at: Sequence[int] = ()):
        self.fail_at = set(fail_at)
        self.failed: List[int] = []

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failed.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class ElasticRunner:
    """Drives train steps with checkpoint/restart + mesh fallback.

    make_state:   (mesh) -> state           (init or cold start)
    make_step:    (mesh) -> step_fn(state, step_idx) -> state
    state_shardings: (state_template, mesh) -> shardings pytree (restore)

    ``meshes=None`` (the default) derives the ladder from the devices
    that actually exist (:func:`device_ladder`).  A ``writer``
    (:class:`repro.obs.TelemetryWriter`) receives a ``repartition``
    event per mesh change and a stage-4 ``remediation`` event per
    restart, joining the health layer's remediation stream.  Restores
    go through ``restore_latest_healthy`` — a snapshot torn by the
    failure itself is walked past, not fatal.
    """
    ckpt_dir: str
    make_state: Callable
    make_step: Callable
    state_shardings: Optional[Callable] = None
    ckpt_every: int = 10
    keep: int = 2
    meshes: Optional[Sequence] = None
    injector: Optional[FailureInjector] = None
    writer: Optional[object] = None

    def _ladder(self) -> Sequence:
        return self.meshes if self.meshes is not None else device_ladder()

    def _emit(self, etype: str, **fields):
        if self.writer is not None:
            self.writer.emit(etype, **fields)

    def run(self, n_steps: int, start_mesh_idx: int = 0) -> Tuple:
        ladder = self._ladder()
        mesh_idx = start_mesh_idx
        restarts = 0
        while True:
            mesh = self._make_mesh(ladder, mesh_idx)
            state = self._restore_or_init(mesh)
            step_fn = self.make_step(mesh)
            start = ckpt_lib.latest_step(self.ckpt_dir)
            k0 = 0 if start is None else start + 1
            mesh_desc = dict(zip(mesh.axis_names, mesh.devices.shape))
            extra = {}
            if 0 < mesh_idx < len(ladder):
                p_shape, p_axes = ladder[mesh_idx - 1]
                c_shape, c_axes = ladder[mesh_idx]
                if p_axes == c_axes and len(p_shape) == len(c_shape):
                    ax = shrunk_axes(tuple(p_shape), tuple(c_shape),
                                     tuple(c_axes))
                    if ax:
                        extra["axis"] = ",".join(ax)
            self._emit("repartition",
                       detail=f"mesh {mesh_desc} "
                              f"({mesh.devices.size} devices), resuming "
                              f"at step {k0}", **extra)
            ck = ckpt_lib.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
            try:
                for k in range(k0, n_steps):
                    if self.injector is not None:
                        self.injector.check(k)
                    state = step_fn(state, k)
                    if k % self.ckpt_every == 0:
                        ck.submit(k, state, extra={"mesh_idx": mesh_idx})
                ck.close()
                return state, {"restarts": restarts, "mesh_idx": mesh_idx}
            except RuntimeError as e:
                # failure: drop to the next smaller healthy mesh and resume
                try:
                    ck.wait()
                    ck.close()
                except RuntimeError:
                    pass        # torn async write; restore walks past it
                restarts += 1
                self._emit("remediation", step=0, stage=4,
                           action="repartition",
                           detail=f"restart #{restarts} after {e}; "
                                  f"falling back down the mesh ladder")
                if mesh_idx + 1 < len(ladder):
                    mesh_idx += 1

    def _make_mesh(self, ladder, idx: int):
        shape, axes = ladder[idx]
        try:
            return mesh_lib.make_mesh(shape, axes)
        except ValueError:
            # not enough devices in this process (tests): shrink to 1-dev
            return mesh_lib.make_mesh((1,) * len(axes), axes)

    def _restore_or_init(self, mesh):
        template = self.make_state(mesh)
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return template
        sh = (self.state_shardings(template, mesh)
              if self.state_shardings else None)
        try:
            state, _ = ckpt_lib.restore_latest_healthy(
                self.ckpt_dir, template, shardings=sh)
        except FileNotFoundError:
            return template
        return state

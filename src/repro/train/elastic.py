"""Elastic scaling + failure handling.

At 1000+ nodes the failure model is: a pod (or slice) drops, the job must
resume on the surviving capacity within minutes.  The policy here:

  1. every `ckpt_every` steps an AsyncCheckpointer snapshot is published;
  2. on failure, the launcher picks the largest healthy mesh from
     ``FALLBACK_MESHES``, rebuilds shardings for it, and restores the last
     checkpoint with resharding (train/checkpoint.py restore(shardings=…));
  3. batch schedule is deterministic in step (data/synthetic.py), so the
     resumed run replays the exact stream — no data-loss bookkeeping;
  4. K-FAC factor states are checkpointed too (they are small for Brand
     modes) — a restart never loses curvature history.

``ElasticRunner`` drives this loop in-process; failures are injected by
tests through ``FailureInjector`` (we cannot kill real pods in CI).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax

from repro.launch import mesh as mesh_lib
from repro.train import checkpoint as ckpt_lib

#: (mesh shape, axis names), largest first — the recovery ladder.
FALLBACK_MESHES: Sequence[Tuple[Tuple[int, ...], Tuple[str, ...]]] = (
    ((2, 16, 16), ("pod", "data", "model")),
    ((16, 16), ("data", "model")),
    ((8, 16), ("data", "model")),
)


class FailureInjector:
    """Test hook: schedule step indices that raise a simulated fault."""

    def __init__(self, fail_at: Sequence[int] = ()):
        self.fail_at = set(fail_at)
        self.failed: List[int] = []

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failed.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class ElasticRunner:
    """Drives train steps with checkpoint/restart + mesh fallback.

    make_state:   (mesh) -> state           (init or cold start)
    make_step:    (mesh) -> step_fn(state, step_idx) -> state
    state_shardings: (state_template, mesh) -> shardings pytree (restore)
    """
    ckpt_dir: str
    make_state: Callable
    make_step: Callable
    state_shardings: Optional[Callable] = None
    ckpt_every: int = 10
    keep: int = 2
    meshes: Sequence = FALLBACK_MESHES
    injector: Optional[FailureInjector] = None

    def run(self, n_steps: int, start_mesh_idx: int = 0) -> Tuple:
        mesh_idx = start_mesh_idx
        restarts = 0
        while True:
            mesh = self._make_mesh(mesh_idx)
            state = self._restore_or_init(mesh)
            step_fn = self.make_step(mesh)
            start = ckpt_lib.latest_step(self.ckpt_dir)
            k0 = 0 if start is None else start + 1
            ck = ckpt_lib.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
            try:
                for k in range(k0, n_steps):
                    if self.injector is not None:
                        self.injector.check(k)
                    state = step_fn(state, k)
                    if k % self.ckpt_every == 0:
                        ck.submit(k, state, extra={"mesh_idx": mesh_idx})
                ck.close()
                return state, {"restarts": restarts, "mesh_idx": mesh_idx}
            except RuntimeError:
                # failure: drop to the next smaller healthy mesh and resume
                ck.wait()
                ck.close()
                restarts += 1
                if mesh_idx + 1 < len(self.meshes):
                    mesh_idx += 1

    def _make_mesh(self, idx: int):
        shape, axes = self.meshes[idx]
        try:
            return mesh_lib.make_mesh(shape, axes)
        except ValueError:
            # not enough devices in this process (tests): shrink to 1-dev
            return mesh_lib.make_mesh((1,) * len(axes), axes)

    def _restore_or_init(self, mesh):
        template = self.make_state(mesh)
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return template
        sh = (self.state_shardings(template, mesh)
              if self.state_shardings else None)
        state, _ = ckpt_lib.restore(self.ckpt_dir, template, step, sh)
        return state

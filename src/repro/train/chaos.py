"""Deterministic seed-driven fault injection for the training stack.

Generalizes ``elastic.FailureInjector`` (which only knew "raise at step
k") into a :class:`ChaosMonkey` that can inject every fault class the
resilience layer claims to survive:

  ``nan_grad``          — poison the batch with NaN so the backward pass
                          produces nonfinite grads (stage-0 skip, then
                          damping escalation / forced refresh).
  ``corrupt_inflight``  — overwrite the in-flight snapshot buffers with
                          NaN and force their ``live`` flags on, so the
                          next scheduled landing tries to swap poison
                          into the factor states (guard reverts it).
  ``drop_landing``      — discard the async runner's pending futures:
                          results never arrive, the in-graph fallback
                          recomputes from the snapshot (numerics-safe —
                          ``heavy_from_snapshot`` is pure).
  ``hang_landing``      — replace pending futures with never-completing
                          ones: exercises the landing *deadline* (the
                          pre-PR8 ``fut.result()`` blocked forever).
  ``worker_death``      — replace pending futures with ones that raise:
                          exercises the crash-miss path + pool respawn.
  ``host_loss``         — raise ``RuntimeError`` out of the step loop
                          (``.check`` is interface-compatible with
                          ``elastic.FailureInjector``, so the same plan
                          drives ``ElasticRunner`` restarts).
  ``truncate_ckpt``     — truncate the newest snapshot's array file on
                          disk: exercises checksum verification and
                          ``restore_latest_healthy``'s ring walk.

Fault plans are explicit (a tuple of :class:`Fault`) or derived from a
seed via :meth:`ChaosMonkey.from_seed` — ``numpy.random.default_rng``
only, so a plan is a pure function of ``(seed, n_steps, kinds)`` and a
chaos test failure reproduces exactly.  Everything injected is recorded
in ``self.injected`` for assertions.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

KINDS = ("nan_grad", "corrupt_inflight", "drop_landing", "hang_landing",
         "worker_death", "host_loss", "truncate_ckpt")


@dataclasses.dataclass(frozen=True)
class Fault:
    step: int
    kind: str

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class _DeadFuture:
    """Stand-in for a future whose worker thread died: ``.result``
    raises immediately, whatever the timeout."""

    def result(self, timeout=None):
        raise RuntimeError("chaos: injected worker death")

    def done(self):
        return True

    def cancel(self):
        return True


def _hung_future():
    # A bare, never-completed Future: ``.result(timeout)`` raises
    # TimeoutError after the deadline, ``.result()`` blocks forever —
    # exactly the failure mode the landing deadline exists for.
    return concurrent.futures.Future()


class ChaosMonkey:
    """Deterministic fault injector; hooks are called by the trainer
    (``loop.run_kfac_training``) and by tests.

    Every hook is a no-op unless the plan names a fault for that step,
    so a ChaosMonkey with an empty plan is inert.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self.injected: List[Tuple[int, str]] = []

    @classmethod
    def from_seed(cls, seed: int, n_steps: int,
                  kinds: Sequence[str] = ("nan_grad",),
                  n_faults: int = 3, first: int = 1) -> "ChaosMonkey":
        """Derive a reproducible plan: ``n_faults`` distinct steps in
        ``[first, n_steps)``, kinds drawn uniformly from ``kinds``."""
        rng = np.random.default_rng(seed)
        lo, hi = int(first), int(n_steps)
        if hi <= lo:
            return cls(())
        steps = rng.choice(np.arange(lo, hi),
                           size=min(int(n_faults), hi - lo),
                           replace=False)
        picks = rng.choice(np.asarray(list(kinds)), size=len(steps))
        return cls(tuple(Fault(int(s), str(k))
                         for s, k in sorted(zip(steps, picks))))

    # -- plan queries -------------------------------------------------------
    def _hits(self, step: int, kind: str) -> bool:
        return any(f.step == step and f.kind == kind for f in self.faults)

    def _mark(self, step: int, kind: str) -> None:
        self.injected.append((int(step), kind))

    # -- data-path hooks ----------------------------------------------------
    def corrupt_batch(self, step: int, batch):
        """``nan_grad``: fill every floating leaf of the batch with NaN
        (needs a float-input task, e.g. the regression MLPs the chaos
        tier trains)."""
        if not self._hits(step, "nan_grad"):
            return batch
        self._mark(step, "nan_grad")
        return jax.tree_util.tree_map(
            lambda x: (jnp.full_like(x, jnp.nan)
                       if jnp.issubdtype(jnp.asarray(x).dtype,
                                         jnp.floating) else x),
            batch)

    def corrupt_state(self, step: int, state):
        """``corrupt_inflight``: NaN out every in-flight snapshot buffer
        and force its live flags on, so scheduled landings must cope
        with a fully poisoned snapshot."""
        if not self._hits(step, "corrupt_inflight"):
            return state
        opt_state = getattr(state, "opt", state)
        if not opt_state.inflight:
            return state
        self._mark(step, "corrupt_inflight")
        # NaN every float plane of the snapshot (U/D for Brand replays,
        # M for EVD/RSVD/NS recomputes) so the poison survives whichever
        # source heavy_from_snapshot reads for the bucket's mode.
        inflight = {
            key: dataclasses.replace(
                buf,
                U=jnp.full_like(buf.U, jnp.nan),
                D=jnp.full_like(buf.D, jnp.nan),
                M=jnp.full_like(buf.M, jnp.nan),
                live=jnp.ones_like(buf.live))
            for key, buf in opt_state.inflight.items()}
        opt_state = opt_state._replace(inflight=inflight)
        if opt_state is state:
            return opt_state
        return state._replace(opt=opt_state)

    # -- async-runner hooks -------------------------------------------------
    def harass_runner(self, step: int, runner) -> None:
        """Apply ``drop_landing`` / ``hang_landing`` / ``worker_death``
        to an ``AsyncInverseRunner``'s pending futures (call *before*
        ``runner.landing``)."""
        if runner is None:
            return
        if self._hits(step, "drop_landing") and runner._pending:
            self._mark(step, "drop_landing")
            runner.drop_pending(reason="dropped")
        if self._hits(step, "hang_landing") and runner._pending:
            self._mark(step, "hang_landing")
            for key in list(runner._pending):
                runner._pending[key] = _hung_future()
        if self._hits(step, "worker_death") and runner._pending:
            self._mark(step, "worker_death")
            for key in list(runner._pending):
                runner._pending[key] = _DeadFuture()

    # -- host / disk hooks --------------------------------------------------
    def check(self, step: int) -> None:
        """``host_loss``: raise out of the step loop (same contract as
        ``elastic.FailureInjector.check``)."""
        if self._hits(step, "host_loss"):
            self._mark(step, "host_loss")
            raise RuntimeError(f"injected node failure at step {step}")

    def corrupt_ckpt(self, step: int, directory: Optional[str]) -> None:
        """``truncate_ckpt``: truncate the newest snapshot's array file
        in ``directory`` to half its size (a torn write)."""
        if directory is None or not self._hits(step, "truncate_ckpt"):
            return
        if truncate_latest(directory):
            self._mark(step, "truncate_ckpt")

    # -- bookkeeping --------------------------------------------------------
    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, kind in self.injected:
            out[kind] = out.get(kind, 0) + 1
        return out


def truncate_latest(directory: str) -> bool:
    """Truncate the newest checkpoint's ``arrays.npz`` to half its size,
    simulating a torn write / partial disk.  Returns True if a file was
    truncated."""
    from repro.train import checkpoint as ckpt_lib
    step = ckpt_lib.latest_step(directory)
    if step is None:
        return False
    path = os.path.join(directory, ckpt_lib._step_dir(step), "arrays.npz")
    if not os.path.exists(path):
        return False
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(1, size // 2))
    return True

"""Training-step factory: ties a tapped model, a loss, and an optimizer
(K-FAC family or baseline) into jit-able step functions.

The K-FAC step computes grads w.r.t. (params, probes) in one backward pass;
probe-grads and tapped activations feed the curvature machinery.

:class:`AsyncInverseRunner` is the loop-level half of the async heavy
pipeline (``KfacConfig.async_heavy``): right after a launch step writes a
factor snapshot into ``KfacState.inflight``, the runner dispatches the
heavy overwrite for those slots as a *separate* jitted program from a
worker thread — pinned to a spare device when one exists — and hands the
finished (U, D) back to the land step ``lag`` steps later.  The land step
then only swaps arrays and replays interim Brand panels; the EVD/RSVD
cost overlaps the lag window's training steps instead of sitting in any
step's critical path.  Without a runner the land step computes the same
function in-graph (same snapshot, same keys → same result), which is the
semantics tests and the sharded engine use.
"""
from __future__ import annotations

import functools
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import specs as specs_lib
from repro.core import kfac as kfac_lib
from repro.core import kfactor
from repro.models import layers
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import base as optbase

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: Any
    rng: Array


def kfac_grads(loss_fn, params, probes, batch, rng=None):
    """(loss, acts), grads w.r.t. params AND probes, one backward pass."""
    args = (params, probes, batch) + ((rng,) if rng is not None else ())
    (loss, acts), (gp, gprobe) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(*args)
    return loss, acts, gp, gprobe


def make_kfac_step(loss_fn: Callable, opt: kfac_lib.Kfac,
                   n_tokens: int, probe_dtype=jnp.float32):
    """DEPRECATED legacy three-bool step factory.  The scheduler's
    :class:`~repro.core.schedule.StepWork` masks subsumed these flags in
    PR 3; this wrapper converts them via ``opt.uniform_work`` and
    delegates to :func:`make_scheduled_kfac_step`.  Jit the result with
    ``static_argnames=("do_stats", "do_light", "do_heavy")`` as before —
    identical numerics (the uniform mask compiles to the same HLO)."""
    specs_lib.warn_once(
        "make_kfac_step",
        "make_kfac_step is deprecated; use make_scheduled_kfac_step with "
        "a StepWork mask (opt.uniform_work / opt.scheduler().work)")
    scheduled = make_scheduled_kfac_step(loss_fn, opt, n_tokens,
                                         probe_dtype=probe_dtype)

    def step(state: TrainState, batch, do_stats: bool, do_light: bool,
             do_heavy: bool):
        work = opt.uniform_work(bool(do_stats), bool(do_light),
                                bool(do_heavy))
        return scheduled(state, batch, work)

    return step


def make_scheduled_kfac_step(loss_fn: Callable, opt: kfac_lib.Kfac,
                             n_tokens: int, probe_dtype=jnp.float32,
                             meter: Optional[obs_metrics.Meter] = None,
                             grad_transform: Optional[Callable] = None,
                             obs: Optional[specs_lib.ObsSpec] = None):
    """Returns step(state, batch, work, landing=None) with ``work`` a
    static :class:`repro.core.schedule.StepWork` mask — jit with
    ``static_argnames=("work",)``.  The mask is hashable, so each distinct
    mask (at most #scheduler-units + O(1) over a schedule cycle) compiles
    once to a lean HLO, exactly like the legacy bool variants.

    ``landing`` carries pre-computed heavy results for this step's land
    ranges (see :class:`AsyncInverseRunner`); ``None`` lands in-graph.

    With a ``meter`` (repro.obs in-graph metrics) the step becomes
    ``step(state, batch, work, landing=None, mbuf=None) -> (state, loss,
    mbuf)``: the optimizer runs under the meter's collector, the metric
    buffer is merged/flushed in-graph, and the params/loss outputs are
    bit-identical to the meter-less step (asserted in
    tests/test_obs.py).

    ``grad_transform`` — ``(grads, carry) -> (grads, carry)`` — rewrites
    the parameter gradients before the optimizer sees them (the DP
    gradient-compression path: ``compress_tree`` with its
    :class:`~repro.distributed.compress.CompressState` carry); the step
    then takes/returns that carry as a trailing argument/output.

    ``obs`` (a :class:`repro.specs.ObsSpec`) is the spec-level spelling of
    ``meter``: when given and no explicit meter is passed, the meter is
    built from it (``obs.make_meter(opt)``)."""
    if obs is not None and meter is None:
        meter = obs.make_meter(opt)

    def step(state: TrainState, batch, work, landing=None, mbuf=None,
             cstate=None):
        rng, sub = jax.random.split(state.rng)
        probes = layers.make_probes(opt.taps, probe_dtype)
        loss, acts, gp, gprobe = kfac_grads(loss_fn, state.params, probes,
                                            batch)
        if grad_transform is not None:
            gp, cstate = grad_transform(gp, cstate)
        if meter is None:
            updates, opt_state = opt.update(
                gp, state.opt, state.params, acts=acts,
                probe_grads=gprobe, n_tokens=n_tokens, rng=sub, work=work,
                landing=landing)
        else:
            with meter.collecting() as col:
                updates, opt_state = opt.update(
                    gp, state.opt, state.params, acts=acts,
                    probe_grads=gprobe, n_tokens=n_tokens, rng=sub,
                    work=work, landing=landing)
            mbuf = meter.maybe_flush(meter.merge(mbuf, col),
                                     opt_state.step)
        params = optbase.apply_updates(state.params, updates)
        out = TrainState(params=params, opt=opt_state, rng=rng)
        outs = (out, loss)
        if meter is not None:
            outs += (mbuf,)
        if grad_transform is not None:
            outs += (cstate,)
        return outs if len(outs) > 2 else (out, loss)

    return step


class AsyncInverseRunner:
    """Overlapped dispatch for the async heavy pipeline (replicated path).

    ``launch(opt_state, work)`` — call right AFTER the step that executed
    ``work`` (its launch mask wrote the snapshots being read here): slices
    each launched range out of the in-flight buffer and submits the heavy
    overwrite to a worker thread as its own jitted program.  With a spare
    ``device`` the operands are committed there, so the program runs
    concurrently with the main device's training steps (CPU host devices
    and TPU cores both give real overlap); without one it still runs off
    the critical path of the dispatching thread.

    ``landing(work)`` — call right BEFORE the step that executes ``work``:
    blocks on (usually long-finished) futures for this step's land ranges
    and returns the ``landing`` operand for ``Kfac.update``.  A range
    with no pending future (fresh resume mid-lag) maps to ``None`` and
    lands in-graph from the restored snapshot — the graceful
    re-snapshot-free resume path.

    Landings are **bounded**: ``landing`` waits at most the deadline —
    ``deadline_s`` when set, else ``deadline_factor`` × the median
    observed heavy time (floored at ``min_deadline_s``) — then treats
    the range as missed, cancels the future, **respawns the worker
    pool**, and lands in-graph from the snapshot.  Because
    ``heavy_from_snapshot`` is pure and the in-graph fallback reads the
    same snapshot with the same keys, a miss (timeout, worker crash, or
    dropped/resumed pipeline) is a perf event, never a numerics event.

    ``health`` counts launched / landed / missed ranges and pool
    respawns over the runner's lifetime, with ``miss_reasons`` split by
    cause (``timeout`` / ``crash`` / ``dropped`` / ``resume``).  A
    :class:`repro.obs.TelemetryWriter` passed as ``writer`` additionally
    gets per-range ``async_launch`` / ``async_land`` / ``async_miss``
    events (misses carry their ``reason``).
    """

    def __init__(self, opt: kfac_lib.Kfac, device=None, home=None,
                 writer=None, deadline_s: Optional[float] = None,
                 deadline_factor: float = 4.0, min_deadline_s: float = 5.0):
        self.opt = opt
        self.device = device
        self.home = home if home is not None else jax.devices()[0]
        self.writer = writer
        self.deadline_s = deadline_s
        self.deadline_factor = deadline_factor
        self.min_deadline_s = min_deadline_s
        self.health = {"launched": 0, "landed": 0, "missed": 0,
                       "respawns": 0, "miss_reasons": {}}
        self._pool = ThreadPoolExecutor(max_workers=2)
        self._fns: Dict = {}
        self._pending: Dict = {}
        self._dropped: Dict = {}        # range -> miss reason tombstone
        self._durations: List[float] = []

    @classmethod
    def for_opt(cls, opt: kfac_lib.Kfac,
                writer=None) -> Optional["AsyncInverseRunner"]:
        """A runner on the first spare device, or None when the optimizer
        does not pipeline (sync config, or a curvature engine attached —
        the engine lands in-graph, sharded)."""
        if not opt._async_buckets or opt.curvature is not None:
            return None
        devs = jax.devices()
        return cls(opt, device=devs[1] if len(devs) > 1 else None,
                   writer=writer)

    def _fn(self, bi: int, count: int):
        key = (bi, count)
        if key not in self._fns:
            spec = self.opt.factor_buckets[bi].spec
            self._fns[key] = jax.jit(functools.partial(
                kfactor.heavy_from_snapshot, spec, lo=0, hi=count))
        return self._fns[key]

    def _run(self, bi: int, count: int, buf_slice):
        with obs_trace.host_span(f"async/heavy/b{bi}"):
            t0 = time.perf_counter()
            if self.device is not None:
                buf_slice = jax.device_put(buf_slice, self.device)
            out = jax.device_put(self._fn(bi, count)(buf_slice), self.home)
            jax.block_until_ready(out)
            self._durations.append(time.perf_counter() - t0)
            return out

    def _deadline(self) -> float:
        if self.deadline_s is not None:
            return self.deadline_s
        if self._durations:
            med = sorted(self._durations)[len(self._durations) // 2]
            return max(self.min_deadline_s, self.deadline_factor * med)
        # No completed heavy yet (first landing may include compile):
        # a generous fixed cap still beats the old unbounded block.
        return max(self.min_deadline_s, 60.0)

    def _respawn(self) -> None:
        """Replace a hung/crashed worker pool.  Already-running tasks
        keep their (orphaned) threads; their futures stay pending and
        will land normally if they eventually complete."""
        self._pool.shutdown(wait=False, cancel_futures=True)
        self._pool = ThreadPoolExecutor(max_workers=2)
        self.health["respawns"] += 1

    def _submit(self, bi: int, count: int, buf_slice):
        try:
            return self._pool.submit(self._run, bi, count, buf_slice)
        except RuntimeError:            # pool died between steps
            self._respawn()
            return self._pool.submit(self._run, bi, count, buf_slice)

    def drop_pending(self, reason: str = "dropped") -> None:
        """Abandon every pending future (remediation refresh, elastic
        restart): the scheduled landings will miss with ``reason`` and
        fall back in-graph."""
        for key, fut in list(self._pending.items()):
            fut.cancel()
            self._dropped[key] = reason
        self._pending.clear()

    def _miss(self, key, reason: str, step) -> None:
        self.health["missed"] += 1
        reasons = self.health["miss_reasons"]
        reasons[reason] = reasons.get(reason, 0) + 1
        if self.writer is not None:
            bi, lo, hi = key
            self.writer.emit("async_miss", step=int(step or 0),
                             bucket=bi, lo=lo, hi=hi, reason=reason)

    def launch(self, opt_state, work, step: Optional[int] = None) -> None:
        for bi, ranges in enumerate(work.launch):
            if not ranges:
                continue
            buf = opt_state.inflight[str(bi)]
            for lo, hi in ranges:
                buf_slice = jax.tree_util.tree_map(lambda x: x[lo:hi], buf)
                self._pending[(bi, lo, hi)] = self._submit(
                    bi, hi - lo, buf_slice)
                self.health["launched"] += 1
                if self.writer is not None:
                    self.writer.emit("async_launch", step=int(step or 0),
                                     bucket=bi, lo=lo, hi=hi)

    def landing(self, work, step: Optional[int] = None):
        out = {}
        for bi, ranges in enumerate(work.land):
            if not ranges:
                continue
            results = []
            for lo, hi in ranges:
                key = (bi, lo, hi)
                fut = self._pending.pop(key, None)
                if fut is None:
                    # Fresh resume mid-lag, or a deliberately dropped
                    # pipeline: land in-graph from the snapshot.
                    results.append(None)
                    self._miss(key, self._dropped.pop(key, "resume"),
                               step)
                    continue
                overlapped = fut.done()
                try:
                    res = fut.result(timeout=self._deadline())
                except FuturesTimeout:
                    fut.cancel()
                    results.append(None)
                    self._miss(key, "timeout", step)
                    self._respawn()
                    continue
                except BaseException:
                    results.append(None)
                    self._miss(key, "crash", step)
                    self._respawn()
                    continue
                results.append(res)
                self.health["landed"] += 1
                if self.writer is not None:
                    self.writer.emit("async_land", step=int(step or 0),
                                     bucket=bi, lo=lo, hi=hi,
                                     overlapped=bool(overlapped))
            out[str(bi)] = tuple(results)
        return out or None

    def close(self):
        self._pool.shutdown(wait=False)


def make_baseline_step(loss_fn: Callable, opt: optbase.Optimizer):
    """Step for probe-free optimizers (SGD/AdamW/SENG uses its own maker)."""

    def step(state: TrainState, batch):
        rng, _ = jax.random.split(state.rng)
        probes = {}
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, probes, batch)
        updates, opt_state = opt.update(grads, state.opt, state.params)
        params = optbase.apply_updates(state.params, updates)
        return TrainState(params=params, opt=opt_state, rng=rng), loss

    return step


def run_kfac_training(loss_fn, opt: kfac_lib.Kfac, params, batches,
                      n_tokens: int, seed: int = 0, jit: bool = True,
                      callback=None,
                      state: Optional[TrainState] = None,
                      overlap: bool = False,
                      dist: Optional[specs_lib.DistSpec] = None,
                      obs: Optional[specs_lib.ObsSpec] = None,
                      ckpt: Optional[specs_lib.CkptSpec] = None,
                      resilience: Optional[specs_lib.ResilienceSpec] = None,
                      **legacy):
    """Python-level driver: dispatches the statically-masked step variants
    per the paper's T_* schedules (work scheduler; ``cfg.stagger`` phases
    heavy work; ``cfg.async_heavy``/``heavy_lag`` pipeline it).
    Subsystems are configured by the four ``repro.specs`` dataclasses:

    ``dist`` (:class:`~repro.specs.DistSpec`) — mesh + curvature_axis
    attach the distributed curvature engine so factor work shards across
    that mesh axis; row_axis adds the 2D path (dense M row-sharded over
    it, heavy FLOPs split across both axes) and curvature_compress
    routes the engine's U gathers through rank-q PowerSGD factors
    (lossy, opt-in).  ``overlap=True`` additionally dispatches launched
    heavy work through an :class:`AsyncInverseRunner` (replicated async
    configs only); otherwise landings compute in-graph — same result
    either way.

    Passing a restored ``state`` resumes: the schedule position is
    re-derived from ``state.opt.phase`` (step mod schedule cycle — kept
    inside the optimizer state exactly so an elastic restart that lost
    the global step counter continues the staggered heavy cadence
    instead of re-spiking every bucket at once).  An async config
    additionally restores the in-flight snapshots from
    ``state.opt.inflight``, so a landing scheduled before the save still
    fires on time after the restore.

    ``obs`` (:class:`~repro.specs.ObsSpec`) — its writer receives
    per-step ``step`` events and the async pipeline's launch/land/miss
    events; metrics_every > 0 additionally attaches an in-graph
    :class:`repro.obs.Meter` flushing the curvature-health metric buffer
    to the writer every that many steps.  Both are numerically inert.

    ``resilience`` (:class:`~repro.specs.ResilienceSpec`) — health
    (truthy, or a :class:`repro.train.health.HealthConfig`) swaps in the
    guarded resilient step and drives the staged remediation ladder:
    skip → damping escalation → forced heavy refresh → rollback (the
    last needs a ``ckpt`` spec).  A caller-built
    :class:`~repro.train.health.RemediationPolicy` can ride as policy
    for inspection; otherwise one is created internally.  A healthy run
    with health on is bit-for-bit identical to one with it off
    (tests/test_chaos.py).  chaos (a
    :class:`repro.train.chaos.ChaosMonkey`) injects its fault plan into
    the loop's hooks.

    ``ckpt`` (:class:`~repro.specs.CkptSpec`) — checkpoints every
    ``ckpt.every`` healthy steps into ``ckpt.dir`` (pruned to
    ``ckpt.keep``) and is where rollbacks restore from, walking past
    corrupted snapshots.

    The pre-spec flat kwargs (``mesh=``, ``writer=``, ``ckpt_dir=``, …)
    still work for one deprecation cycle — each warns once and folds
    into its spec (see :func:`repro.specs.consolidate_training_kwargs`).
    Returns (final TrainState, losses)."""
    dist, obs, ckpt, resilience = specs_lib.consolidate_training_kwargs(
        legacy, dist=dist, obs=obs, ckpt=ckpt, resilience=resilience,
        caller="run_kfac_training")
    dist.attach(opt)
    writer = obs.writer
    health, policy, chaos = (resilience.health, resilience.policy,
                             resilience.chaos)
    ckpt_dir, ckpt_every, ckpt_keep = ckpt.dir, ckpt.every, ckpt.keep
    from repro.train import checkpoint as ckpt_lib
    from repro.train import health as health_lib
    sched = opt.scheduler()
    k_off = 0
    if state is None:
        state = TrainState(params=params, opt=opt.init(params),
                           rng=jax.random.PRNGKey(seed))
    else:
        k_off = int(jax.device_get(state.opt.phase))
    runner = AsyncInverseRunner.for_opt(opt, writer=writer) \
        if overlap else None
    meter = obs.make_meter(opt)
    if health or policy is not None:
        hcfg = health if isinstance(health, health_lib.HealthConfig) \
            else None
        if policy is None:
            policy = health_lib.RemediationPolicy(hcfg, writer=writer)
        step_fn = health_lib.make_resilient_kfac_step(
            loss_fn, opt, n_tokens, health=policy.cfg, meter=meter)
    else:
        step_fn = make_scheduled_kfac_step(loss_fn, opt, n_tokens,
                                           meter=meter)
    if jit:
        step_fn = jax.jit(step_fn, static_argnames=("work",))
    mbuf = meter.init() if meter is not None else None
    losses = []
    for k, batch in enumerate(batches):
        kk = k_off + k
        # Chaos faults are keyed on the wall-clock loop iteration ``k``,
        # not the schedule step ``kk`` — a rollback re-anchors kk into
        # the past, and external faults must not replay with it.
        if chaos is not None:
            chaos.check(k)                        # host_loss raises here
            batch = chaos.corrupt_batch(k, batch)
            state = chaos.corrupt_state(k, state)
        work = sched.work(kk)
        if policy is not None and policy.take_refresh():
            # Stage 2: abandon the (possibly poisoned) pipeline and
            # re-establish the inverse rep from the live M this step.
            work = opt.remedial_work()
            state = state._replace(opt=opt.clear_inflight(state.opt))
            if runner is not None:
                runner.drop_pending(reason="dropped")
        if runner is not None and chaos is not None:
            chaos.harass_runner(k, runner)
        landing = runner.landing(work, step=kk) \
            if runner is not None else None
        t0 = time.perf_counter()
        report = None
        if policy is not None:
            scale = jnp.float32(policy.damping_scale)
            if meter is None:
                state, loss, report = step_fn(state, batch, work, landing,
                                              None, scale)
            else:
                state, loss, report, mbuf = step_fn(state, batch, work,
                                                    landing, mbuf, scale)
        elif meter is None:
            state, loss = step_fn(state, batch, work, landing)
        else:
            state, loss, mbuf = step_fn(state, batch, work, landing, mbuf)
        if runner is not None:
            runner.launch(state.opt, work, step=kk)
        losses.append(float(loss))
        if writer is not None:
            writer.emit("step", step=kk, loss=float(loss),
                        dt_s=time.perf_counter() - t0, phase=work.label)
        faulty = False
        if policy is not None:
            rep = {name: float(v) for name, v in
                   jax.device_get(report).items()}
            faulty = policy.observe(kk, losses[-1], rep)
            if policy.take_rollback() and ckpt_dir is not None:
                # Stage 3: restore the newest snapshot that verifies,
                # walking past corrupt ones; re-anchor the schedule on
                # the restored phase so the staggered cadence resumes
                # without a heavy spike.
                if runner is not None:
                    runner.drop_pending(reason="dropped")
                state, man = ckpt_lib.restore_latest_healthy(ckpt_dir,
                                                             state)
                k_off = int(jax.device_get(state.opt.phase)) - (k + 1)
                policy.notify_rollback(kk, man["step"], ckpt_dir)
                if writer is not None:
                    writer.emit("ckpt_restore", step=int(man["step"]),
                                path=ckpt_dir)
                faulty = False          # restored state is healthy
        if (ckpt_dir is not None and ckpt_every > 0 and not faulty
                and kk % ckpt_every == 0):
            path = ckpt_lib.save(ckpt_dir, kk, state)
            ckpt_lib.prune(ckpt_dir, keep=ckpt_keep)
            if writer is not None:
                writer.emit("ckpt_save", step=kk, path=path)
            if chaos is not None:
                chaos.corrupt_ckpt(k, ckpt_dir)
        if callback is not None:
            callback(k, state, loss)
    if meter is not None:
        meter.drain(mbuf, int(jax.device_get(state.opt.step)))
    if runner is not None:
        runner.close()
    return state, losses

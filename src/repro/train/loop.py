"""Training-step factory: ties a tapped model, a loss, and an optimizer
(K-FAC family or baseline) into jit-able step functions.

The K-FAC step computes grads w.r.t. (params, probes) in one backward pass;
probe-grads and tapped activations feed the curvature machinery.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.models import layers
from repro.optim import base as optbase

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: Any
    rng: Array


def kfac_grads(loss_fn, params, probes, batch, rng=None):
    """(loss, acts), grads w.r.t. params AND probes, one backward pass."""
    args = (params, probes, batch) + ((rng,) if rng is not None else ())
    (loss, acts), (gp, gprobe) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(*args)
    return loss, acts, gp, gprobe


def make_kfac_step(loss_fn: Callable, opt: kfac_lib.Kfac,
                   n_tokens: int, probe_dtype=jnp.float32):
    """Returns step(state, batch, *, do_stats, do_light, do_heavy) — flags
    static; jit with static_argnames=("do_stats","do_light","do_heavy").
    Legacy three-bool variant; see make_scheduled_kfac_step for the
    work-mask (staggered / sharded) step."""

    def step(state: TrainState, batch, do_stats: bool, do_light: bool,
             do_heavy: bool):
        rng, sub = jax.random.split(state.rng)
        probes = layers.make_probes(opt.taps, probe_dtype)
        loss, acts, gp, gprobe = kfac_grads(loss_fn, state.params, probes,
                                            batch)
        updates, opt_state = opt.update(
            gp, state.opt, state.params, acts=acts, probe_grads=gprobe,
            n_tokens=n_tokens, rng=sub, do_stats=do_stats,
            do_light=do_light, do_heavy=do_heavy)
        params = optbase.apply_updates(state.params, updates)
        return TrainState(params=params, opt=opt_state, rng=rng), loss

    return step


def make_scheduled_kfac_step(loss_fn: Callable, opt: kfac_lib.Kfac,
                             n_tokens: int, probe_dtype=jnp.float32):
    """Returns step(state, batch, work) with ``work`` a static
    :class:`repro.core.schedule.StepWork` mask — jit with
    ``static_argnames=("work",)``.  The mask is hashable, so each distinct
    mask (at most #scheduler-units + O(1) over a schedule cycle) compiles
    once to a lean HLO, exactly like the legacy bool variants."""

    def step(state: TrainState, batch, work):
        rng, sub = jax.random.split(state.rng)
        probes = layers.make_probes(opt.taps, probe_dtype)
        loss, acts, gp, gprobe = kfac_grads(loss_fn, state.params, probes,
                                            batch)
        updates, opt_state = opt.update(
            gp, state.opt, state.params, acts=acts, probe_grads=gprobe,
            n_tokens=n_tokens, rng=sub, work=work)
        params = optbase.apply_updates(state.params, updates)
        return TrainState(params=params, opt=opt_state, rng=rng), loss

    return step


def make_baseline_step(loss_fn: Callable, opt: optbase.Optimizer):
    """Step for probe-free optimizers (SGD/AdamW/SENG uses its own maker)."""

    def step(state: TrainState, batch):
        rng, _ = jax.random.split(state.rng)
        probes = {}
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, probes, batch)
        updates, opt_state = opt.update(grads, state.opt, state.params)
        params = optbase.apply_updates(state.params, updates)
        return TrainState(params=params, opt=opt_state, rng=rng), loss

    return step


def run_kfac_training(loss_fn, opt: kfac_lib.Kfac, params, batches,
                      n_tokens: int, seed: int = 0, jit: bool = True,
                      callback=None, mesh=None, curvature_axis=None,
                      state: Optional[TrainState] = None):
    """Python-level driver: dispatches the statically-masked step variants
    per the paper's T_* schedules (work scheduler; ``cfg.stagger`` phases
    heavy work).  ``mesh`` + ``curvature_axis`` attach the distributed
    curvature engine so factor work shards across that mesh axis.

    Passing a restored ``state`` resumes: the schedule position is
    re-derived from ``state.opt.phase`` (step mod schedule cycle — kept
    inside the optimizer state exactly so an elastic restart that lost
    the global step counter continues the staggered heavy cadence
    instead of re-spiking every bucket at once).  Returns (final
    TrainState, losses)."""
    if mesh is not None and curvature_axis is not None:
        from repro.distributed import curvature as curvature_lib
        curvature_lib.CurvatureEngine.for_kfac(opt, mesh, curvature_axis)
    sched = opt.scheduler()
    k_off = 0
    if state is None:
        state = TrainState(params=params, opt=opt.init(params),
                           rng=jax.random.PRNGKey(seed))
    else:
        k_off = int(jax.device_get(state.opt.phase))
    step_fn = make_scheduled_kfac_step(loss_fn, opt, n_tokens)
    if jit:
        step_fn = jax.jit(step_fn, static_argnames=("work",))
    losses = []
    for k, batch in enumerate(batches):
        state, loss = step_fn(state, batch, sched.work(k_off + k))
        losses.append(float(loss))
        if callback is not None:
            callback(k, state, loss)
    return state, losses

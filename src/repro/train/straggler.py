"""Straggler detection and mitigation.

At pod scale, synchronous SPMD steps run at the speed of the slowest chip;
persistent stragglers (thermal throttling, flaky HICs) must be detected and
acted on.  Detection is *relative to peers*: each step every host reports
its local step wall-time; a host whose time exceeds ``ratio ×`` the fleet
median for ``patience`` consecutive steps is flagged (a fleet-wide slowdown
moves the median itself and flags nobody — that is a capacity problem, not
a straggler).

Mitigations (policy enum, enacted by the launcher):
  * REBALANCE  — checkpoint + elastic remesh without the slow host
    (train/elastic.py ladder) after ``rebalance_after`` slow steps;
  * DROP_STATS — skip the K-FAC heavy update on the next scheduled step.
    The paper's stale-inverse tolerance makes this safe: Prop 4.1/4.2 show
    B-updates strictly beat no-updates in the worst case, so *deferring*
    curvature work under time pressure degrades gracefully;
  * NONE — log only.
"""
from __future__ import annotations

import dataclasses
import enum
import statistics
from typing import Dict, List


class Action(enum.Enum):
    NONE = "none"
    DROP_STATS = "drop_stats"
    REBALANCE = "rebalance"


@dataclasses.dataclass
class StragglerDetector:
    """``writer`` (a :class:`repro.obs.TelemetryWriter`) mirrors every
    enacted Action into the resilience layer's remediation event stream
    (stage 4 — elastic/topology actions, same rung as ElasticRunner's
    repartitions), so ``repro.obs.summary`` counts straggler mitigations
    next to health-guard remediations."""
    ratio: float = 1.5           # slow if dt > ratio × fleet median
    patience: int = 3            # consecutive slow steps → DROP_STATS
    rebalance_after: int = 8     # consecutive slow steps → REBALANCE
    warmup: int = 3              # steps before any flagging
    writer: object = None
    mesh_desc: str = ""          # e.g. "data=4×curv=2": a REBALANCE on a
                                 # 2D mesh repartitions both axes' slot /
                                 # row ranges, so the remediation event
                                 # names the topology being rebuilt

    def __post_init__(self):
        self._streaks: Dict[str, int] = {}
        self._n = 0
        self._median_ema: float = 0.0
        self.events: List[dict] = []

    def _record(self, step: int, host: str, action: str, dt: float,
                med: float) -> None:
        self.events.append({"step": step, "host": host,
                            "action": action, "dt": dt})
        if self.writer is not None:
            mesh = f" on mesh {self.mesh_desc}" if self.mesh_desc else ""
            self.writer.emit(
                "remediation", step=int(step), stage=4, action=action,
                detail=f"straggler {host}: {dt * 1e3:.0f}ms vs fleet "
                       f"median {med * 1e3:.0f}ms{mesh}")

    def observe_step(self, step: int, times: Dict[str, float]
                     ) -> Dict[str, Action]:
        """Feed one synchronous step's per-host wall-times."""
        self._n += 1
        med = statistics.median(times.values())
        self._median_ema = (0.9 * self._median_ema + 0.1 * med
                            if self._median_ema else med)
        out: Dict[str, Action] = {}
        for host, dt in times.items():
            slow = self._n > self.warmup and dt > self.ratio * med
            streak = self._streaks.get(host, 0) + 1 if slow else 0
            self._streaks[host] = streak
            if streak >= self.rebalance_after:
                self._record(step, host, "rebalance", dt, med)
                self._streaks[host] = 0
                out[host] = Action.REBALANCE
            elif streak >= self.patience:
                self._record(step, host, "drop_stats", dt, med)
                out[host] = Action.DROP_STATS
            else:
                out[host] = Action.NONE
        return out

    @property
    def fleet_median(self) -> float:
        return self._median_ema


def apply_to_flags(action: Action, flags: Dict[str, bool]
                   ) -> Dict[str, bool]:
    """DROP_STATS: defer the K-FAC stats/inverse work this step (safe by
    Prop 4.1/4.2 — see module docstring)."""
    if action == Action.DROP_STATS:
        return dict(flags, do_stats=False, do_light=False, do_heavy=False)
    return flags


def apply_to_work(action: Action, work):
    """StepWork-mask counterpart of :func:`apply_to_flags` for the
    scheduled (staggered / sharded) step path."""
    if action == Action.DROP_STATS:
        return dataclasses.replace(
            work, stats=False, light=False,
            heavy=tuple(() for _ in work.heavy),
            launch=tuple(() for _ in work.launch),
            land=tuple(() for _ in work.land))
    return work

"""Version tolerance for the Pallas TPU API surface used by this package.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels support both so the same tree runs on the pinned CI jax and on
newer toolchains.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

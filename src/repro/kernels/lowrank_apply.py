"""Pallas TPU kernel: fused low-rank inverse application.

    Y = (X U) diag(s) Uᵀ + X/λ

with X (p, d), U (d, w), s (w,) = (D+λ)⁻¹ − 1/λ.  This is the inner loop of
K-FAC preconditioning with a low-rank K-factor representation (paper Alg 1
lines 15-17 and both factors of Alg 8).  Fusing the two tall-skinny matmuls
with the 1/λ residual path reads X once and never materializes the (p, w)
intermediate in HBM when w is small.

Stage A (``_xu``): T = (X U)·diag(s), grid (p/bm, d/bk) accumulating over d.
Stage B (``_tut``): Y = T Uᵀ + X/λ, grid (p/bm, d/bn) — row blocks of T ride
along; s applied in stage A so stage B is a plain matmul + epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _xu_kernel(x_ref, u_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], u_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] *
                      s_ref[...].astype(jnp.float32)[None, :]
                      ).astype(o_ref.dtype)


def _tut_kernel(t_ref, u_ref, x_ref, ilam_ref, o_ref):
    acc = jax.lax.dot_general(
        t_ref[...], u_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ilam = ilam_ref[0]
    o_ref[...] = (acc + ilam * x_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lowrank_apply_pallas(X: Array, U: Array, s: Array, lam: Array,
                         bm: int = 256, bn: int = 512, bk: int = 512,
                         interpret: bool = False) -> Array:
    """Y = (X U) diag(s) Uᵀ + X/λ.  X: (p, d), U: (d, w), s: (w,)."""
    p, d = X.shape
    w = U.shape[1]
    bm, bn, bk = min(bm, p), min(bn, d), min(bk, d)

    # Stage A: T = (X U) * s  — contraction over d.
    grid_a = (p // bm, d // bk)
    T = pl.pallas_call(
        functools.partial(_xu_kernel, n_k=grid_a[1]),
        grid=grid_a,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, w), lambda i, k: (k, 0)),
            pl.BlockSpec((w,), lambda i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, w), X.dtype),
        scratch_shapes=[pltpu.VMEM((bm, w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(X, U, s)

    # Stage B: Y = T Uᵀ + X/λ.
    ilam = jnp.reshape(1.0 / lam, (1,)).astype(jnp.float32)
    grid_b = (p // bm, d // bn)
    return pl.pallas_call(
        _tut_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid_b,
            in_specs=[
                pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, w), lambda i, j: (j, 0)),
                pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                pl.BlockSpec(memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((p, d), X.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(T, U, X, ilam)

"""Pallas TPU kernel: fused low-rank inverse application.

    Y = (X U) diag(s) Uᵀ + X/λ

with X (p, d), U (d, w), s (w,) = (D+λ)⁻¹ − 1/λ.  This is the inner loop of
K-FAC preconditioning with a low-rank K-factor representation (paper Alg 1
lines 15-17 and both factors of Alg 8).  Fusing the two tall-skinny matmuls
with the 1/λ residual path reads X once and never materializes the (p, w)
intermediate in HBM when w is small.

All operands carry a leading stack axis B (scanned layers / MoE experts /
plain B=1); the per-element s and 1/λ ride along indexed by the stack
coordinate, so a whole stack of applications is one batched launch.

Stage A (``_xu``): T = (X U)·diag(s), grid (B, p/bm, d/bk) accumulating over
d.  Stage B (``_tut``): Y = T Uᵀ + X/λ, grid (B, p/bm, d/bn) — row blocks of
T ride along; s applied in stage A so stage B is a plain matmul + epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _xu_kernel(x_ref, u_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], u_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] *
                    s_ref[0].astype(jnp.float32)[None, :]
                    ).astype(o_ref.dtype)


def _tut_kernel(ilam_ref, t_ref, u_ref, x_ref, o_ref):
    b = pl.program_id(0)
    acc = jax.lax.dot_general(
        t_ref[0], u_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ilam = ilam_ref[b]
    o_ref[0] = (acc + ilam * x_ref[0].astype(jnp.float32)
                ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lowrank_apply_batched_pallas(X: Array, U: Array, s: Array, ilam: Array,
                                 bm: int = 256, bn: int = 512, bk: int = 512,
                                 interpret: bool = False) -> Array:
    """Y = (X U) diag(s) Uᵀ + X·ilam, batched over the leading stack axis.

    X: (B, p, d), U: (B, d, w), s: (B, w), ilam: (B,) (= 1/λ per element).
    """
    B, p, d = X.shape
    w = U.shape[-1]
    bm, bn, bk = min(bm, p), min(bn, d), min(bk, d)
    ilam = jnp.reshape(ilam, (B,)).astype(jnp.float32)

    # Stage A: T = (X U) * s  — contraction over d (no scalars needed).
    grid_a = (B, p // bm, d // bk)
    T = pl.pallas_call(
        functools.partial(_xu_kernel, n_k=grid_a[2]),
        grid=grid_a,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, k: (b, i, k)),
            pl.BlockSpec((1, bk, w), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((1, w), lambda b, i, k: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, w), lambda b, i, k: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, p, w), X.dtype),
        scratch_shapes=[pltpu.VMEM((bm, w), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(X, U, s)

    # Stage B: Y = T Uᵀ + X·ilam.
    grid_b = (B, p // bm, d // bn)
    return pl.pallas_call(
        _tut_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid_b,
            in_specs=[
                pl.BlockSpec((1, bm, w), lambda b, i, j, *_: (b, i, 0)),
                pl.BlockSpec((1, bn, w), lambda b, i, j, *_: (b, j, 0)),
                pl.BlockSpec((1, bm, bn), lambda b, i, j, *_: (b, i, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda b, i, j, *_: (b, i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, p, d), X.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(ilam, T, U, X)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lowrank_apply_pallas(X: Array, U: Array, s: Array, lam: Array,
                         bm: int = 256, bn: int = 512, bk: int = 512,
                         interpret: bool = False) -> Array:
    """Single-factor entry point: Y = (X U) diag(s) Uᵀ + X/λ."""
    ilam = 1.0 / jnp.reshape(lam, (1,)).astype(jnp.float32)
    return lowrank_apply_batched_pallas(X[None], U[None], s[None], ilam,
                                        bm=bm, bn=bn, bk=bk,
                                        interpret=interpret)[0]

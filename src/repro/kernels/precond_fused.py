"""Pallas TPU kernel: fused two-sided K-FAC preconditioning.

    S = Γ̄⁻¹ J Ā⁻¹
      = (U_g diag(s_g) U_gᵀ + I/λ_g) J (U_a diag(s_a) U_aᵀ + I/λ_a)

with J (p, d), U_g (p, w_g), U_a (d, w_a) and s = (D+λ)⁻¹ − 1/λ for each
side (paper Alg 1 lines 14-18, both factors at once).  The baseline path in
``core/precond.py`` runs this as two ``lowrank_apply`` round-trips with an
HBM-materialized intermediate M = J Ā⁻¹ plus two transposes; here the
factored order is flipped (left side first)

    Cg = diag(s_g) (U_gᵀ J)           (w_g, d)   — rank panel
    W  = U_g Cg + J/λ_g  = Γ̄⁻¹ J     (p, d)    — never leaves VMEM
    S  = (W U_a) diag(s_a) U_aᵀ + W/λ_a

so the launch sequence is one rank-panel contraction plus one J-resident
apply pass: each (bm, d) row stripe of J is fetched into VMEM once and both
the left combine and the right two-sided apply happen against that resident
stripe (W lives only in a VMEM scratch stripe).  No transposes, and the
(p, d) intermediate never touches HBM.

All operands carry a leading stack axis B (scanned layers / MoE experts /
plain B=1): the grid's leading dimension batches the whole fusion, and the
per-element damping scalars ride in as scalar-prefetch vectors indexed by
the stack coordinate.

Apply-pass grid: (B, p/bm, 2, d/bn).  Sweep t=0 accumulates
Tw = (W U_a) diag(s_a) over the d tiles while recording W into the stripe
scratch; sweep t=1 emits S tiles from Tw and the recorded stripe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _panel_kernel(ug_ref, j_ref, sg_ref, o_ref, acc_ref, *, n_i: int):
    """Cg[b, :, j-block] = diag(s_g) · Σ_i U_g[b, i]ᵀ J[b, i, j-block]."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        ug_ref[0], j_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == n_i - 1)
    def _done():
        sg = sg_ref[0].astype(jnp.float32)
        o_ref[0] = (sg[:, None] * acc_ref[...]).astype(o_ref.dtype)


def _apply_kernel(ilam_g_ref, ilam_a_ref, j_ref, ug_ref, cg_ref, ua_ref,
                  sa_ref, o_ref, w_ref, tw_ref, *, bn: int, n_j: int):
    """Sweep 0: W stripe + Tw accumulation; sweep 1: S tiles."""
    b = pl.program_id(0)
    t = pl.program_id(2)
    j = pl.program_id(3)
    ilam_g = ilam_g_ref[b]
    ilam_a = ilam_a_ref[b]

    @pl.when(t == 0)
    def _sweep_w():
        j_blk = j_ref[0, :, pl.ds(j * bn, bn)].astype(jnp.float32)
        w_blk = jnp.dot(ug_ref[0].astype(jnp.float32), cg_ref[0],
                        preferred_element_type=jnp.float32) + ilam_g * j_blk
        w_ref[:, pl.ds(j * bn, bn)] = w_blk

        @pl.when(j == 0)
        def _init():
            tw_ref[...] = jnp.zeros_like(tw_ref)

        tw_ref[...] += jnp.dot(w_blk, ua_ref[0].astype(jnp.float32),
                               preferred_element_type=jnp.float32)
        # partial (valid-dtype) tile so the t=0 visit of the output block
        # never flushes uninitialized VMEM; t=1 overwrites it
        o_ref[0] = (ilam_a * w_blk).astype(o_ref.dtype)

    @pl.when(t == 1)
    def _sweep_out():
        sa = sa_ref[0].astype(jnp.float32)
        tw = tw_ref[...] * sa[None, :]
        w_blk = w_ref[:, pl.ds(j * bn, bn)]
        acc = jax.lax.dot_general(
            tw, ua_ref[0].astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0] = (acc + ilam_a * w_blk).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "interpret"))
def precond_fused_pallas(J: Array, U_g: Array, s_g: Array, ilam_g: Array,
                         U_a: Array, s_a: Array, ilam_a: Array,
                         bm: int = 128, bn: int = 256,
                         interpret: bool = False) -> Array:
    """S = Γ̄⁻¹ J Ā⁻¹ for a whole stack in one batched launch sequence.

    J: (B, p, d), U_g: (B, p, w_g), s_g: (B, w_g), ilam_g: (B,),
    U_a: (B, d, w_a), s_a: (B, w_a), ilam_a: (B,).
    Requires p % bm == 0 and d % bn == 0 (ops.py pads / falls back).
    """
    B, p, d = J.shape
    w_g = U_g.shape[-1]
    w_a = U_a.shape[-1]
    bm, bn = min(bm, p), min(bn, d)
    ilam_g = jnp.reshape(ilam_g, (B,)).astype(jnp.float32)
    ilam_a = jnp.reshape(ilam_a, (B,)).astype(jnp.float32)

    # Launch 1 — rank panel Cg = diag(s_g) U_gᵀ J, contraction over p
    # (no damping scalars involved).
    grid_p = (B, d // bn, p // bm)
    Cg = pl.pallas_call(
        functools.partial(_panel_kernel, n_i=grid_p[2]),
        grid=grid_p,
        in_specs=[
            pl.BlockSpec((1, bm, w_g), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bm, bn), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, w_g), lambda b, j, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_g, bn), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, w_g, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((w_g, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(U_g, J, s_g)

    # Launch 2 — J-resident two-sided apply.
    grid_a = (B, p // bm, 2, d // bn)
    return pl.pallas_call(
        functools.partial(_apply_kernel, bn=bn, n_j=grid_a[3]),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid_a,
            in_specs=[
                pl.BlockSpec((1, bm, d), lambda b, i, t, j, *_: (b, i, 0)),
                pl.BlockSpec((1, bm, w_g), lambda b, i, t, j, *_: (b, i, 0)),
                pl.BlockSpec((1, w_g, bn), lambda b, i, t, j, *_: (b, 0, j)),
                pl.BlockSpec((1, bn, w_a), lambda b, i, t, j, *_: (b, j, 0)),
                pl.BlockSpec((1, w_a), lambda b, i, t, j, *_: (b, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda b, i, t, j, *_: (b, i, j)),
            scratch_shapes=[
                pltpu.VMEM((bm, d), jnp.float32),    # W row stripe
                pltpu.VMEM((bm, w_a), jnp.float32),  # Tw accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, p, d), J.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(ilam_g, ilam_a, J, U_g, Cg, U_a, s_a)

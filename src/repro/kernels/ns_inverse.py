"""Pallas TPU kernel: batched GEMM with a fused scale-and-add epilogue,
``out = alpha·C + beta·A B`` — the building block of the Newton–Schulz
inverse-refinement heavy path (Mode.NS).

One NS/Hotelling step  X ← X (2I − M̂ X) = 2X − X (M̂ X)  is two launches
of this kernel:

    T = M̂ X                  (alpha = 0, beta = 1; C rides along unused)
    X' = 2·X − X T            (alpha = 2, beta = −1, C = X)

Both are pure MXU matmuls — no eigh/qr/svd anywhere in the heavy firing,
which is the whole point of the NS variant.  The tiling is the ``ea_syrk``
pattern verbatim: grid (B, d/bm, d/bn, d/bk), float32 VMEM accumulator
over the k axis, epilogue fused into the last k step so C and the output
tile make exactly one HBM round-trip.  All operands carry a leading stack
axis B so a whole factor bucket refines in one launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _gemm_update_kernel(alpha_ref, beta_ref, c_ref, a_ref, b_ref, o_ref,
                        acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0], b_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        alpha = alpha_ref[0]
        beta = beta_ref[0]
        out = alpha * c_ref[0].astype(jnp.float32) + beta * acc_ref[...]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def gemm_update_batched_pallas(C: Array, A: Array, B: Array,
                               alpha, beta,
                               bm: int = 256, bn: int = 256, bk: int = 256,
                               interpret: bool = False) -> Array:
    """out = alpha·C + beta·A B.  C: (B, m, n), A: (B, m, k), B: (B, k, n);
    requires m % bm == n % bn == k % bk == 0 after the ops.py block pick
    (it pads / falls back otherwise).  ``alpha``/``beta`` are shared
    across the stack (the NS schedule is global)."""
    nb, m, kk = A.shape
    n = B.shape[-1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kk)
    grid = (nb, m // bm, n // bn, kk // bk)
    alpha = jnp.reshape(jnp.asarray(alpha), (1,)).astype(jnp.float32)
    beta = jnp.reshape(jnp.asarray(beta), (1,)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_gemm_update_kernel, n_k=grid[3]),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bn),
                             lambda b, i, j, k, *_: (b, i, j)),  # C tile
                pl.BlockSpec((1, bm, bk),
                             lambda b, i, j, k, *_: (b, i, k)),  # A rows
                pl.BlockSpec((1, bk, bn),
                             lambda b, i, j, k, *_: (b, k, j)),  # B cols
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda b, i, j, k, *_: (b, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nb, m, n), C.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(alpha, beta, C, A, B)

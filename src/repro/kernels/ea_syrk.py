"""Pallas TPU kernel: EA K-factor SYRK update  M ← keep·M + coef·X Xᵀ.

This is the per-stats-step hot spot of every K-FAC variant that materializes
the dense EA factor (EVD / RSVD / B-R / B-C modes).  On TPU the natural
mapping is an MXU-tiled SYRK with the EA decay fused into the epilogue so M
is read and written exactly once (one HBM round-trip instead of three for
the naive  ρ·M  then  + (1-ρ)·X Xᵀ  sequence).

Grid: (d/bm, d/bn, n/bk). The k axis accumulates partial X Xᵀ products in a
float32 VMEM accumulator; on the last k step the decayed M tile is added and
the tile is written out.  Block dims are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ea_syrk_kernel(keep_ref, coef_ref, m_ref, xi_ref, xj_ref, o_ref,
                    acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xi_ref[...], xj_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        keep = keep_ref[0]
        coef = coef_ref[0]
        out = keep * m_ref[...].astype(jnp.float32) + coef * acc_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def ea_syrk_pallas(M: Array, X: Array, keep: Array, coef: Array,
                   bm: int = 256, bn: int = 256, bk: int = 256,
                   interpret: bool = False) -> Array:
    """M: (d, d), X: (d, n); requires d % bm == d % bn == 0, n % bk == 0
    (ops.py pads/falls back otherwise)."""
    d, n = X.shape
    bm, bn, bk = min(bm, d), min(bn, d), min(bk, n)
    grid = (d // bm, d // bn, n // bk)
    keep = jnp.reshape(keep, (1,)).astype(jnp.float32)
    coef = jnp.reshape(coef, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_ea_syrk_kernel, n_k=grid[2]),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),  # M tile
                pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k)),  # X rows
                pl.BlockSpec((bn, bk), lambda i, j, k, *_: (j, k)),  # X cols
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((d, d), M.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(keep, coef, M, X, X)

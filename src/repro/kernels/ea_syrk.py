"""Pallas TPU kernel: EA K-factor SYRK update  M ← keep·M + coef·X Xᵀ.

This is the per-stats-step hot spot of every K-FAC variant that materializes
the dense EA factor (EVD / RSVD / B-R / B-C modes).  On TPU the natural
mapping is an MXU-tiled SYRK with the EA decay fused into the epilogue so M
is read and written exactly once (one HBM round-trip instead of three for
the naive  ρ·M  then  + (1-ρ)·X Xᵀ  sequence).

All operands carry a leading stack axis B (scanned layers / MoE experts /
plain B=1) so a whole stack of factors updates in one launch instead of a
vmap of per-layer launches.

Grid: (B, d/bm, d/bn, n/bk).  The k axis accumulates partial X Xᵀ products
in a float32 VMEM accumulator; on the last k step the decayed M tile is
added and the tile is written out.  Block dims are 128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _ea_syrk_kernel(keep_ref, coef_ref, m_ref, xi_ref, xj_ref, o_ref,
                    acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xi_ref[0], xj_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        keep = keep_ref[0]
        coef = coef_ref[0]
        out = keep * m_ref[0].astype(jnp.float32) + coef * acc_ref[...]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def ea_syrk_batched_pallas(M: Array, X: Array, keep: Array, coef: Array,
                           bm: int = 256, bn: int = 256, bk: int = 256,
                           interpret: bool = False) -> Array:
    """M: (B, d, d), X: (B, d, n); requires d % bm == d % bn == 0 and
    n % bk == 0 after the ops.py block pick (it pads / falls back
    otherwise).  ``keep``/``coef`` are shared across the stack (the EA
    schedule is global)."""
    B, d, n = X.shape
    bm, bn, bk = min(bm, d), min(bn, d), min(bk, n)
    grid = (B, d // bm, d // bn, n // bk)
    keep = jnp.reshape(keep, (1,)).astype(jnp.float32)
    coef = jnp.reshape(coef, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_ea_syrk_kernel, n_k=grid[3]),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bn),
                             lambda b, i, j, k, *_: (b, i, j)),  # M tile
                pl.BlockSpec((1, bm, bk),
                             lambda b, i, j, k, *_: (b, i, k)),  # X rows
                pl.BlockSpec((1, bn, bk),
                             lambda b, i, j, k, *_: (b, j, k)),  # X cols
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda b, i, j, k, *_: (b, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, d, d), M.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(keep, coef, M, X, X)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def ea_syrk_pallas(M: Array, X: Array, keep: Array, coef: Array,
                   bm: int = 256, bn: int = 256, bk: int = 256,
                   interpret: bool = False) -> Array:
    """Single-factor entry point: M (d, d), X (d, n)."""
    return ea_syrk_batched_pallas(M[None], X[None], keep, coef,
                                  bm=bm, bn=bn, bk=bk, interpret=interpret)[0]

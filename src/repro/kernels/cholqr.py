"""Pallas TPU kernels: CholeskyQR2 tall-skinny QR (paper Alg 3 line 3).

The last O(d) op of the Brand update still in XLA was the QR of the
(d, n) orthogonal-complement panel A⊥.  Householder QR is sequential in n
and maps poorly onto the MXU; the CholeskyQR2 iteration reformulates it
as two passes of

    G = AᵀA                 (n, n)   — batched SYRK, contraction over d
    R, B = clamped √G, √G⁻¹ (n, n)   — tiny spectral root, stays in XLA
    Q = A B                 (d, n)   — row-parallel apply

(Yamamoto et al.'s CholeskyQR² data flow; the second pass repairs the
first pass's loss of orthogonality).  The small factorization is a
*clamped spectral root* rather than a raw Cholesky: Gram eigenvalues
below the fp32 resolvability floor were already destroyed by rounding
when AᵀA was formed, and a Cholesky — shifted or not — either goes
negative there or renormalizes that noise into unit-norm garbage basis
vectors.  The clamp maps them to an exactly-null subspace instead, so
for *any* fp32 panel (A⊥ is near rank-deficient whenever incoming
directions already lie in span(U)) QᵀQ is a rank-k projector to machine
precision and Q R reconstructs the retained spectral content of A.

Both O(d·n²) passes are Pallas kernels with a leading stack axis B so a
whole bucket of panels runs as one batched launch; the (n, n) eigh-based
root is O(n³) on tiny operands and stays in XLA *between* the launches
(``ref.gram_inv_sqrt`` — shared verbatim with the oracle).

Kernel 1 (``_syrk_tn``): grid (B, d/bk); accumulates AᵀA in an (n, n)
float32 VMEM accumulator (n ≤ 1024 → ≤ 4 MB).

Kernel 2 (``_rinv_apply``): grid (B, d/bm); each row block reads its A
tile once, multiplies by the resident (n, n) R⁻¹ and writes Q.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _syrk_tn_kernel(a_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]
    acc_ref[...] += jax.lax.dot_general(
        a, a, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _rinv_apply_kernel(a_ref, r_ref, o_ref):
    o_ref[0] = jnp.dot(a_ref[0], r_ref[0],
                       preferred_element_type=jnp.float32
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def syrk_tn_batched_pallas(A: Array, bk: int = 512,
                           interpret: bool = False) -> Array:
    """G = AᵀA in float32.  A: (B, d, n); d % bk == 0."""
    B, d, n = A.shape
    bk = min(bk, d)
    assert d % bk == 0, f"d={d} not divisible by bk={bk} (rows would drop)"
    grid = (B, d // bk)
    return pl.pallas_call(
        functools.partial(_syrk_tn_kernel, n_k=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bk, n), lambda b, k: (b, k, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda b, k: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def rinv_apply_batched_pallas(A: Array, Rinv: Array, bm: int = 512,
                              interpret: bool = False) -> Array:
    """Q = A @ R⁻¹.  A: (B, d, n), Rinv: (B, n, n); d % bm == 0."""
    B, d, n = A.shape
    bm = min(bm, d)
    assert d % bm == 0, f"d={d} not divisible by bm={bm} (rows would drop)"
    grid = (B, d // bm)
    return pl.pallas_call(
        _rinv_apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d, n), A.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(A, Rinv)


def cholqr2_batched_pallas(A: Array, n_true: int | None = None,
                           bk: int = 512, interpret: bool = False
                           ) -> Tuple[Array, Array]:
    """(Q, R) = CholeskyQR2-style tall-skinny QR for a whole stack in one
    batched launch sequence — the same two-round schedule as the
    ``ref.cholqr2`` oracle (Gram SYRK → clamped spectral inverse root →
    apply, twice).  A: (B, d, n) float32.  ``n_true`` is accepted for
    call-site symmetry with the dispatch layer; the spectral floors are
    trace-/max-relative and therefore padding-invariant on their own.
    """
    del n_true
    G1 = syrk_tn_batched_pallas(A, bk=bk, interpret=interpret)
    R1, B1 = ref.gram_inv_sqrt(G1, ref.CHOLQR_FLOOR_RESOLVE, "tr")
    Q0 = rinv_apply_batched_pallas(A, B1, bm=bk, interpret=interpret)
    G2 = syrk_tn_batched_pallas(Q0, bk=bk, interpret=interpret)
    R2, B2 = ref.gram_inv_sqrt(G2, ref.CHOLQR_FLOOR_REFINE, "max")
    Q = rinv_apply_batched_pallas(Q0, B2, bm=bk, interpret=interpret)
    return Q, R2 @ R1


def cholqr2_pallas(A: Array, bk: int = 512, interpret: bool = False
                   ) -> Tuple[Array, Array]:
    """Single-panel entry point: (Q, R) = CholeskyQR2(A), A (d, n)."""
    Q, R = cholqr2_batched_pallas(A[None], bk=bk, interpret=interpret)
    return Q[0], R[0]

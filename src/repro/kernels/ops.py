"""Public kernel entry points with automatic dispatch.

Each op routes to its Pallas kernel when (a) kernels are enabled for the
backend and (b) shapes are tile-aligned; otherwise it falls back to the
pure-jnp oracle in ``ref.py`` (identical semantics, asserted by tests).

Dispatch policy:
  * TPU backend            → Pallas (compiled).
  * ``REPRO_PALLAS=interpret`` env  → Pallas interpret mode (CPU validation).
  * otherwise (CPU/GPU)    → oracle.  CPU interpret mode is orders of
    magnitude slower than jnp and is only meant for correctness tests.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import ea_syrk as _ea
from repro.kernels import brand_panel as _bp
from repro.kernels import lowrank_apply as _la

Array = jax.Array

_LANE = 128  # TPU lane width; all tile dims must divide by this


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "off":
        return "ref"
    if env == "interpret":
        return "interpret"
    try:
        backend = jax.default_backend()
    except Exception:  # pragma: no cover
        backend = "cpu"
    return "pallas" if backend == "tpu" else "ref"


def _aligned(*dims: int) -> bool:
    return all(d % _LANE == 0 for d in dims)


def ea_syrk(M: Array, X: Array, rho, first) -> Array:
    """M ← keep·M + coef·X Xᵀ (EA update, paper eq. 5)."""
    mode = _mode()
    d, n = X.shape
    if mode == "ref" or not _aligned(d, n):
        return ref.ea_syrk(M, X, rho, first)
    rho = jnp.asarray(rho, jnp.float32)
    firstf = jnp.asarray(first, jnp.float32)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    return _ea.ea_syrk_pallas(M, X, keep, coef,
                              interpret=(mode == "interpret"))


def brand_panel(U: Array, A: Array):
    """(C, A⊥) = (UᵀA, A − U(UᵀA))."""
    mode = _mode()
    d, r = U.shape
    n = A.shape[1]
    if mode == "ref" or not _aligned(d) or r % 8 or n % _LANE:
        return ref.brand_panel(U, A)
    return _bp.brand_panel_pallas(U, A, interpret=(mode == "interpret"))


def lowrank_apply(X: Array, U: Array, s: Array, lam) -> Array:
    """Y = (X U) diag(s) Uᵀ + X/λ."""
    mode = _mode()
    p, d = X.shape
    w = U.shape[1]
    if mode == "ref" or not _aligned(d) or p % _LANE or w % 8:
        return ref.lowrank_apply(X, U, s, lam)
    lam = jnp.asarray(lam, X.dtype)
    return _la.lowrank_apply_pallas(X, U, s, lam,
                                    interpret=(mode == "interpret"))

"""Public kernel entry points with automatic dispatch.

Each op routes to its Pallas kernel when (a) kernels are enabled for the
backend and (b) shapes are tile-friendly; otherwise it falls back to the
pure-jnp oracle in ``ref.py`` (identical semantics, asserted by tests).

Stacked inputs
--------------
Every op accepts arbitrary leading stack axes (``(*stack, …)`` from scanned
layers or MoE expert stacks).  The stack is flattened to one batch axis and
the whole stack runs as a single batched Pallas launch (leading grid
dimension) instead of a vmap of per-layer launches.

Pad-to-tile
-----------
Misaligned dims no longer silently drop to the oracle: operands are
zero-padded to the next tile multiple, the kernel runs on the padded
shapes, and the result is sliced back.  Zero rows/columns are exact for
every op here (they contribute nothing to any product and the λ-residual
terms are sliced away), so padding never changes semantics.  Padding only
engages while it is profitable: if any dim would grow beyond ``_PAD_MAX``×
its size (tiny shapes), the op falls back to the oracle instead.

Dispatch policy:
  * TPU backend            → Pallas (compiled).
  * ``REPRO_PALLAS=interpret`` env  → Pallas interpret mode (CPU validation).
  * ``REPRO_PALLAS=off``    → oracle always.
  * otherwise (CPU/GPU)    → oracle.  CPU interpret mode is orders of
    magnitude slower than jnp and is only meant for correctness tests.
"""
from __future__ import annotations

import math
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import ea_syrk as _ea
from repro.kernels import ns_inverse as _ns
from repro.kernels import brand_panel as _bp
from repro.kernels import cholqr as _cq
from repro.kernels import lowrank_apply as _la
from repro.kernels import precond_fused as _pf

Array = jax.Array

_LANE = 128   # TPU lane width; matmul major dims pad to this
_SUB = 8      # sublane quantum; rank/width dims pad to this
_PAD_MAX = 2.0  # max per-dim growth factor before falling back to ref


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env == "off":
        return "ref"
    if env == "interpret":
        return "interpret"
    try:
        backend = jax.default_backend()
    except Exception:  # pragma: no cover
        backend = "cpu"
    return "pallas" if backend == "tpu" else "ref"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_ok(*dims_mults: Tuple[int, int]) -> bool:
    """True iff padding every (dim, multiple) pair stays within _PAD_MAX."""
    for dim, mult in dims_mults:
        if dim <= 0 or _round_up(dim, mult) > _PAD_MAX * dim:
            return False
    return True


def _common_stack(*xs_cores: Tuple[Array, int]) -> Tuple[int, ...]:
    """Broadcast the leading (stack) axes of all operands to one shape, so
    an operand shared across the stack (e.g. one U for every scanned layer)
    batches correctly instead of mis-indexing a size-1 axis."""
    return jnp.broadcast_shapes(
        *(x.shape[:x.ndim - core] for x, core in xs_cores))


def _flat(x: Array, core: int, stack: Tuple[int, ...]) -> Array:
    """(*stack-broadcastable, *core_shape) → (B, *core_shape)."""
    tail = x.shape[x.ndim - core:]
    x = jnp.broadcast_to(x, stack + tail)
    b = math.prod(stack) if stack else 1
    return x.reshape((b,) + tail)


def _pad_tail(x: Array, *tail: int) -> Array:
    """Zero-pad the trailing len(tail) axes of x up to the given sizes."""
    pads = [(0, 0)] * (x.ndim - len(tail))
    pads += [(0, t - s) for s, t in zip(x.shape[x.ndim - len(tail):], tail)]
    if all(lo == 0 and hi == 0 for lo, hi in pads):
        return x
    return jnp.pad(x, pads)


def _pick_block(dim: int, preferred: int, quantum: int = _LANE) -> int:
    """Largest multiple of ``quantum`` ≤ preferred that divides ``dim``
    (dim is already a multiple of quantum)."""
    b = min(preferred, dim)
    b = (b // quantum) * quantum
    while b > quantum and dim % b:
        b -= quantum
    return max(b, quantum) if dim % quantum == 0 else dim


_FUSED_VMEM_BUDGET = 8 * 1024 * 1024  # conservative: leaves double-buffer room
_SYRK_VMEM_BUDGET = 6 * 1024 * 1024   # accumulator + double-buffered operands


def syrk_blocks(d: int, n: int) -> Tuple[int, int, int]:
    """Shape-aware (bm, bn, bk) for the EA-SYRK launch over padded (d, n).

    HBM traffic for the X row/column streams scales as 1/bm + 1/bn, so the
    M tile is maximized first; the contraction depth bk (which only
    amortizes accumulator init/writeback) then takes what is left of the
    VMEM budget.  Replaces the old fixed 256/256/256 pick — small stacked
    factors no longer get over-tiled and large ones no longer under-use
    VMEM.  Recorded in bench ``derived`` output for trackability.
    """
    bm = bn = bk = _LANE
    for pref_mn in (512, 256, 128):
        bm = bn = _pick_block(d, pref_mn)
        for pref_k in (512, 256, 128):
            bk = _pick_block(n, pref_k)
            # acc + M tile + out tile, plus double-buffered X row/col blocks
            vmem = 4 * (3 * bm * bn + 2 * (bm + bn) * bk)
            if vmem <= _SYRK_VMEM_BUDGET:
                return bm, bn, bk
    return bm, bn, bk


def panel_blocks(d: int, r: int, n: int) -> int:
    """Shape-aware row/contraction block for the Brand panel kernels over
    padded (d, r, n): the (r, n) accumulator is resident, so bk takes the
    remaining VMEM (double-buffered U and A stripes).  Replaces fixed 512."""
    for pref in (512, 256, 128):
        bk = _pick_block(d, pref)
        vmem = 4 * (r * n + 2 * bk * (r + n))
        if vmem <= _SYRK_VMEM_BUDGET:
            return bk
    return bk


def cholqr_blocks(d: int, n: int) -> int:
    """Shape-aware row/contraction block for the CholeskyQR2 kernels over
    padded (d, n): the SYRK pass holds the (n, n) fp32 Gram accumulator
    *and* its (n, n) output block (the apply pass's resident R⁻¹ + Q
    stripe fits in the same envelope), plus double-buffered A stripes."""
    for pref in (512, 256, 128):
        bk = _pick_block(d, pref)
        vmem = 4 * (2 * n * n + 2 * bk * 2 * n)
        if vmem <= _SYRK_VMEM_BUDGET:
            return bk
    return bk


_CHOLQR_MAX_N = 1024  # (n, n) fp32 Gram accumulator must fit VMEM


def _fused_bm(pp: int, pd: int, pwg: int, pwa: int, bn: int):
    """Row-block size for the fused apply pass such that its VMEM working
    set (J stripe + W scratch + side blocks, fp32) fits the budget; None if
    no bm ≥ 8 fits (dispatch then falls back to the unfused kernel path)."""
    for bm in (128, 64, 32, 16, 8):
        if bm > pp:
            continue
        vmem = 4 * (2 * bm * pd            # J stripe + W scratch
                    + bm * pwa + bm * pwg  # Tw + U_g row block
                    + pwg * bn + bn * pwa  # Cg + U_a column blocks
                    + bm * bn)             # output tile
        if vmem <= _FUSED_VMEM_BUDGET:
            return bm
    return None


def _stack_lam(lam, stack: Tuple[int, ...], b: int) -> Array:
    """Per-element scalar → (B,) float32 (broadcast if python/0-d scalar)."""
    lam = jnp.asarray(lam, jnp.float32)
    lam = jnp.broadcast_to(lam, stack) if stack else lam.reshape(())
    return lam.reshape((b,))


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def ea_syrk(M: Array, X: Array, rho, first) -> Array:
    """M ← keep·M + coef·X Xᵀ (EA update, paper eq. 5).
    M: (*stack, d, d), X: (*stack, d, n)."""
    mode = _mode()
    d, n = X.shape[-2:]
    if mode == "ref" or not _pad_ok((d, _LANE), (n, _LANE)):
        return ref.ea_syrk(M, X, rho, first)
    stack = _common_stack((M, 2), (X, 2))
    Xb = _flat(X, 2, stack)
    Mb = _flat(M, 2, stack)
    pd, pn = _round_up(d, _LANE), _round_up(n, _LANE)
    Xp = _pad_tail(Xb, pd, pn)
    Mp = _pad_tail(Mb, pd, pd)
    rho = jnp.asarray(rho, jnp.float32)
    firstf = jnp.asarray(first, jnp.float32)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    bm, bn, bk = syrk_blocks(pd, pn)
    out = _ea.ea_syrk_batched_pallas(Mp, Xp, keep, coef, bm=bm, bn=bn, bk=bk,
                                     interpret=(mode == "interpret"))
    return out[..., :d, :d].reshape(stack + (d, d))


def ns_step(Mhat: Array, X: Array) -> Array:
    """One Newton–Schulz step X ← 2X − X(M̂X) — two fused-epilogue GEMM
    launches of the ``ns_inverse`` kernel (ea_syrk tiling; same pad-to-tile
    dispatch).  Mhat, X: (*stack, d, d).  Zero padding is exact: padded
    rows/columns of M̂ and X are zero, stay zero through both products
    (2·0 − 0·0 = 0), and are sliced away."""
    mode = _mode()
    d = X.shape[-1]
    if mode == "ref" or not _pad_ok((d, _LANE)):
        return ref.ns_step(Mhat, X)
    stack = _common_stack((Mhat, 2), (X, 2))
    Mb = _flat(Mhat, 2, stack)
    Xb = _flat(X, 2, stack)
    pd = _round_up(d, _LANE)
    Mp = _pad_tail(Mb, pd, pd)
    Xp = _pad_tail(Xb, pd, pd)
    bm, bn, bk = syrk_blocks(pd, pd)
    interp = mode == "interpret"
    # T = M̂ X  (C operand rides along unused: alpha = 0)
    T = _ns.gemm_update_batched_pallas(Xp, Mp, Xp, 0.0, 1.0,
                                       bm=bm, bn=bn, bk=bk, interpret=interp)
    # X' = 2X − X T
    out = _ns.gemm_update_batched_pallas(Xp, Xp, T, 2.0, -1.0,
                                         bm=bm, bn=bn, bk=bk,
                                         interpret=interp)
    return out[..., :d, :d].reshape(stack + (d, d))


def brand_panel(U: Array, A: Array):
    """(C, A⊥) = (UᵀA, A − U(UᵀA)).
    U: (*stack, d, r), A: (*stack, d, n)."""
    mode = _mode()
    d, r = U.shape[-2:]
    n = A.shape[-1]
    if mode == "ref" or not _pad_ok((d, _LANE), (r, _SUB), (n, _LANE)):
        return ref.brand_panel(U, A)
    stack = _common_stack((U, 2), (A, 2))
    Ub = _flat(U, 2, stack)
    Ab = _flat(A, 2, stack)
    pd, pr, pn = (_round_up(d, _LANE), _round_up(r, _SUB),
                  _round_up(n, _LANE))
    Up = _pad_tail(Ub, pd, pr)
    Ap = _pad_tail(Ab, pd, pn)
    bk = panel_blocks(pd, pr, pn)
    C, P = _bp.brand_panel_batched_pallas(Up, Ap, bk=bk,
                                          interpret=(mode == "interpret"))
    return (C[..., :r, :n].reshape(stack + (r, n)),
            P[..., :d, :n].reshape(stack + (d, n)))


def cholqr2(A: Array) -> Tuple[Array, Array]:
    """Tall-skinny QR  A ≈ Q R  by the CholeskyQR2 iteration with a
    clamped spectral root (one batched SYRK + apply launch pair per
    pass; the (n, n) root stays in XLA).  A: (*stack, d, n) → Q (*stack,
    d, n) in A.dtype, R (*stack, n, n) symmetric psd float32.  QᵀQ is a
    rank-k projector to machine precision for any fp32 input — sub-noise-
    floor directions map to an exactly-null subspace — and Q R
    reconstructs the retained spectral content of A (exact when nothing
    is clamped).
    """
    mode = _mode()
    d, n = A.shape[-2:]
    if (mode == "ref" or _round_up(n, _LANE) > _CHOLQR_MAX_N
            or not _pad_ok((d, _LANE), (n, _LANE))):
        return ref.cholqr2(A)
    stack = _common_stack((A, 2))
    Ab = _flat(A, 2, stack).astype(jnp.float32)
    pd, pn = _round_up(d, _LANE), _round_up(n, _LANE)
    Ap = _pad_tail(Ab, pd, pn)
    bk = cholqr_blocks(pd, pn)
    Q, R = _cq.cholqr2_batched_pallas(Ap, n_true=n, bk=bk,
                                      interpret=(mode == "interpret"))
    return (Q[..., :d, :n].astype(A.dtype).reshape(stack + (d, n)),
            R[..., :n, :n].reshape(stack + (n, n)))


def orthonormalize(Y: Array) -> Array:
    """Orthonormal basis of range(Y) via CholeskyQR2 — the Q-only entry
    point shared by the RSVD range finder and the PowerSGD compressor
    (both tall-skinny, both previously Householder ``jnp.linalg.qr``)."""
    return cholqr2(Y)[0]


def lowrank_apply(X: Array, U: Array, s: Array, lam) -> Array:
    """Y = (X U) diag(s) Uᵀ + X/λ.
    X: (*stack, p, d), U: (*stack, d, w), s: (*stack, w), lam: scalar or
    (*stack,)."""
    mode = _mode()
    p, d = X.shape[-2:]
    w = U.shape[-1]
    if mode == "ref" or not _pad_ok((p, _LANE), (d, _LANE), (w, _SUB)):
        return ref.lowrank_apply(X, U, s, lam)
    stack = _common_stack((X, 2), (U, 2), (s, 1))
    Xb = _flat(X, 2, stack)
    Ub = _flat(U, 2, stack)
    sb = _flat(s, 1, stack)
    b = Xb.shape[0]
    pp, pd, pw = (_round_up(p, _LANE), _round_up(d, _LANE),
                  _round_up(w, _SUB))
    Xp = _pad_tail(Xb, pp, pd)
    Up = _pad_tail(Ub, pd, pw)
    sp = _pad_tail(sb, pw)
    ilam = 1.0 / _stack_lam(lam, stack, b)
    bm = _pick_block(pp, 256)
    bn = _pick_block(pd, 512)
    bk = _pick_block(pd, 512)
    out = _la.lowrank_apply_batched_pallas(Xp, Up, sp, ilam, bm=bm, bn=bn,
                                           bk=bk,
                                           interpret=(mode == "interpret"))
    return out[..., :p, :d].reshape(stack + (p, d))


def precond_fused(J: Array, U_g: Array, s_g: Array, lam_g,
                  U_a: Array, s_a: Array, lam_a) -> Array:
    """S = Γ̄⁻¹ J Ā⁻¹ — the full two-sided application in one fused launch
    sequence (J read once per row stripe; the (p, d) intermediate never
    touches HBM).

    J: (*stack, p, d), U_g: (*stack, p, w_g), s_g: (*stack, w_g),
    U_a: (*stack, d, w_a), s_a: (*stack, w_a); λ's scalar or (*stack,).
    """
    mode = _mode()
    p, d = J.shape[-2:]
    w_g = U_g.shape[-1]
    w_a = U_a.shape[-1]
    if mode == "ref" or not _pad_ok((p, _LANE), (d, _LANE), (w_g, _SUB),
                                    (w_a, _SUB)):
        return ref.precond_fused(J, U_g, s_g, lam_g, U_a, s_a, lam_a)
    pp, pd = _round_up(p, _LANE), _round_up(d, _LANE)
    pwg, pwa = _round_up(w_g, _SUB), _round_up(w_a, _SUB)
    bn = _pick_block(pd, 256)
    bm = _fused_bm(pp, pd, pwg, pwa, bn)
    if bm is None:
        # d too large for the J-resident stripes — stay on kernels but
        # unfused: two lowrank_apply round-trips (the pre-fusion path)
        M = lowrank_apply(J, U_a, s_a, lam_a)
        Mt = jnp.swapaxes(M, -1, -2)
        return jnp.swapaxes(lowrank_apply(Mt, U_g, s_g, lam_g), -1, -2)
    stack = _common_stack((J, 2), (U_g, 2), (U_a, 2), (s_g, 1), (s_a, 1))
    Jb = _flat(J, 2, stack)
    Ugb = _flat(U_g, 2, stack)
    Uab = _flat(U_a, 2, stack)
    sgb = _flat(s_g, 1, stack)
    sab = _flat(s_a, 1, stack)
    b = Jb.shape[0]
    Jp = _pad_tail(Jb, pp, pd)
    Ugp = _pad_tail(Ugb, pp, pwg)
    Uap = _pad_tail(Uab, pd, pwa)
    sgp = _pad_tail(sgb, pwg)
    sap = _pad_tail(sab, pwa)
    ilam_g = 1.0 / _stack_lam(lam_g, stack, b)
    ilam_a = 1.0 / _stack_lam(lam_a, stack, b)
    out = _pf.precond_fused_pallas(Jp, Ugp, sgp, ilam_g, Uap, sap, ilam_a,
                                   bm=bm, bn=bn,
                                   interpret=(mode == "interpret"))
    return out[..., :p, :d].reshape(stack + (p, d))

"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (tests sweep shapes
and dtypes in interpret mode and assert allclose against these).

Every oracle is stacked-native: operands may carry arbitrary leading stack
axes (scanned layers, MoE experts) and broadcast like ``jnp.matmul``.
Per-element scalars (λ) may be python scalars, 0-d arrays, or arrays of the
stack shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def mt(x: Array) -> Array:
    """Matrix transpose on the trailing two axes (shared helper — the
    optimizer-side math in ``core/precond.py`` imports it too)."""
    return jnp.swapaxes(x, -1, -2)


def scal(v, like: Array):
    """Broadcast a per-element scalar (any stack shape) against the trailing
    two matrix axes of ``like`` (shared helper, see ``mt``)."""
    v = jnp.asarray(v, like.dtype)
    return v[..., None, None]


_mt, _scal = mt, scal  # internal aliases


def ea_syrk(M: Array, X: Array, rho, first) -> Array:
    """EA K-factor update:  M ← keep·M + coef·X Xᵀ with
    keep = ρ·(1-first), coef = 1-ρ·(1-first)   (paper eq. 5, κ(0)=1)."""
    rho = jnp.asarray(rho, M.dtype)
    firstf = jnp.asarray(first, M.dtype)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    return keep * M + coef * (X @ _mt(X)).astype(M.dtype)


def brand_panel(U: Array, A: Array) -> Tuple[Array, Array]:
    """The O(d·r·n) panel of Brand's update:  C = UᵀA,  A⊥ = A − U C."""
    C = _mt(U) @ A
    return C, A - U @ C


def lowrank_apply(X: Array, U: Array, s: Array, lam) -> Array:
    """Fused low-rank inverse application:
    Y = (X U) diag(s) Uᵀ + X/λ   (paper Alg 1 lines 15-17 in factored form).

    X: (..., p, d), U: (..., d, w), s: (..., w), lam: scalar or (...,).
    """
    T = (X @ U) * s[..., None, :]
    return T @ _mt(U) + X / _scal(lam, X)


def precond_fused(J: Array, U_g: Array, s_g: Array, lam_g,
                  U_a: Array, s_a: Array, lam_a) -> Array:
    """Fused two-sided application  S = Γ̄⁻¹ J Ā⁻¹  (paper Alg 1, both
    factors):

        S = (U_g diag(s_g) U_gᵀ + I/λ_g) J (U_a diag(s_a) U_aᵀ + I/λ_a)

    J: (..., p, d), U_g: (..., p, w_g), U_a: (..., d, w_a).
    """
    W = U_g @ ((_mt(U_g) @ J) * s_g[..., :, None]) + J / _scal(lam_g, J)
    return lowrank_apply(W, U_a, s_a, lam_a)

"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (tests sweep shapes
and dtypes in interpret mode and assert allclose against these).

Every oracle is stacked-native: operands may carry arbitrary leading stack
axes (scanned layers, MoE experts) and broadcast like ``jnp.matmul``.
Per-element scalars (λ) may be python scalars, 0-d arrays, or arrays of the
stack shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def mt(x: Array) -> Array:
    """Matrix transpose on the trailing two axes (shared helper — the
    optimizer-side math in ``core/precond.py`` imports it too)."""
    return jnp.swapaxes(x, -1, -2)


def scal(v, like: Array):
    """Broadcast a per-element scalar (any stack shape) against the trailing
    two matrix axes of ``like`` (shared helper, see ``mt``)."""
    v = jnp.asarray(v, like.dtype)
    return v[..., None, None]


_mt, _scal = mt, scal  # internal aliases


def ea_syrk(M: Array, X: Array, rho, first) -> Array:
    """EA K-factor update:  M ← keep·M + coef·X Xᵀ with
    keep = ρ·(1-first), coef = 1-ρ·(1-first)   (paper eq. 5, κ(0)=1)."""
    rho = jnp.asarray(rho, M.dtype)
    firstf = jnp.asarray(first, M.dtype)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    return keep * M + coef * (X @ _mt(X)).astype(M.dtype)


def brand_panel(U: Array, A: Array) -> Tuple[Array, Array]:
    """The O(d·r·n) panel of Brand's update:  C = UᵀA,  A⊥ = A − U C."""
    C = _mt(U) @ A
    return C, A - U @ C


def lowrank_apply(X: Array, U: Array, s: Array, lam) -> Array:
    """Fused low-rank inverse application:
    Y = (X U) diag(s) Uᵀ + X/λ   (paper Alg 1 lines 15-17 in factored form).

    X: (..., p, d), U: (..., d, w), s: (..., w), lam: scalar or (...,).
    """
    T = (X @ U) * s[..., None, :]
    return T @ _mt(U) + X / _scal(lam, X)


def ns_step(Mhat: Array, X: Array) -> Array:
    """One Newton–Schulz/Hotelling inverse-refinement step
    X ← X(2I − M̂X) = 2X − X(M̂X) — two GEMMs, no factorization.
    Mhat, X: (..., d, d).  Converges quadratically to M̂⁻¹ when
    ‖I − M̂X‖₂ < 1 (the caller's prescale/guard establishes this)."""
    T = Mhat @ X
    return 2.0 * X - X @ T


def syrk_tn(A: Array) -> Array:
    """Gram matrix G = AᵀA in float32 (the CholeskyQR SYRK pass)."""
    A32 = A.astype(jnp.float32)
    return _mt(A32) @ A32


def rinv_apply(A: Array, Rinv: Array) -> Array:
    """Q = A @ R⁻¹ (the CholeskyQR-style row-parallel apply, with the
    tiny (n, n) inverse root precomputed in XLA)."""
    return (A.astype(jnp.float32) @ Rinv).astype(A.dtype)


#: pass-1 spectral floor, ×tr(G): Gram eigenvalues below ~64·eps_fp32 of
#: the trace are unresolvable in an fp32 AᵀA (the products already lost
#: them to rounding) — treat them as exact zeros instead of letting the
#: inverse root inflate noise.  In σ terms this keeps directions down to
#: ~3e-3 of ‖A‖_F, far below the K-FAC damping floor (φ·λ_max, φ≈0.1).
CHOLQR_FLOOR_RESOLVE = 64 * 1.19e-7
#: pass-2 spectral floor, ×λ_max(G): after pass 1 every retained
#: direction has Gram eigenvalue ≈ 1 and every suppressed one ≈ 0, so
#: anything below a quarter of the max is pass-1 residue to keep nulled.
CHOLQR_FLOOR_REFINE = 0.25


def gram_inv_sqrt(G: Array, floor_rel: float, floor_mode: str
                  ) -> Tuple[Array, Array]:
    """Clamped spectral root of a Gram matrix: (R, B) with R = V√Λ̂Vᵀ and
    B = VΛ̂^{-1/2}Vᵀ, where Λ̂ zeroes every eigenvalue below
    floor_rel · tr(G) (``floor_mode="tr"``) or floor_rel · λ_max
    (``"max"``).

    This replaces the textbook Cholesky of CholeskyQR2: a raw (or gently
    shifted) Cholesky either goes negative or — worse — *renormalizes*
    sub-noise-floor directions into unit-norm garbage, while the clamp
    maps them to an exactly-null subspace that stays null through the
    refinement pass.  B and R are symmetric (not triangular); no consumer
    needs triangularity — the Brand update only forms products with R.

    Zero padding is exact: eigenvectors with nonzero eigenvalue of the
    block-diagonal padded Gram live entirely in the unpadded block, and
    both floors (trace / max) ignore zero padding.  Shared by the jnp
    oracle and the Pallas orchestration in ``cholqr.py`` — O(n³) on a
    tiny operand, XLA.
    """
    vals, vecs = jnp.linalg.eigh(G)                   # ascending
    if floor_mode == "tr":
        scale = jnp.trace(G, axis1=-2, axis2=-1)
    elif floor_mode == "max":
        scale = vals[..., -1]
    else:
        raise ValueError(floor_mode)
    keep = vals > floor_rel * scale[..., None] + 1e-30
    safe = jnp.where(keep, vals, 1.0)
    inv = jnp.where(keep, 1.0 / jnp.sqrt(safe), 0.0)
    sq = jnp.where(keep, jnp.sqrt(safe), 0.0)
    R = (vecs * sq[..., None, :]) @ _mt(vecs)
    B = (vecs * inv[..., None, :]) @ _mt(vecs)
    return R, B


def cholqr2(A: Array) -> Tuple[Array, Array]:
    """Tall-skinny QR by the CholeskyQR2 iteration with a clamped
    spectral root as the small factorization:  A ≈ Q R with Q (…, d, n)
    spanning an orthonormal-or-null subspace (QᵀQ is a rank-k projector
    to machine precision for *any* fp32 input, however ill-conditioned),
    R (…, n, n) symmetric psd, float32.

    Two passes of [Gram SYRK → clamped inverse root → apply], exactly the
    CholeskyQR2 data flow — both O(d·n²) steps are the Pallas kernel
    pair.  Directions whose Gram eigenvalue sits below the fp32
    resolvability floor are mapped to an exactly-null subspace (they were
    already destroyed by rounding when AᵀA was formed; a Cholesky would
    renormalize that noise into garbage basis vectors).  Q R reconstructs
    the retained spectral content of A: exact (up to fp) when nothing is
    clamped, and otherwise within ~√floor · ‖A‖_F — far below the K-FAC
    damping floor.
    """
    A32 = A.astype(jnp.float32)
    R1, B1 = gram_inv_sqrt(syrk_tn(A32), CHOLQR_FLOOR_RESOLVE, "tr")
    Q0 = rinv_apply(A32, B1)
    R2, B2 = gram_inv_sqrt(syrk_tn(Q0), CHOLQR_FLOOR_REFINE, "max")
    Q = rinv_apply(Q0, B2).astype(A.dtype)
    return Q, R2 @ R1


def precond_fused(J: Array, U_g: Array, s_g: Array, lam_g,
                  U_a: Array, s_a: Array, lam_a) -> Array:
    """Fused two-sided application  S = Γ̄⁻¹ J Ā⁻¹  (paper Alg 1, both
    factors):

        S = (U_g diag(s_g) U_gᵀ + I/λ_g) J (U_a diag(s_a) U_aᵀ + I/λ_a)

    J: (..., p, d), U_g: (..., p, w_g), U_a: (..., d, w_a).
    """
    W = U_g @ ((_mt(U_g) @ J) * s_g[..., :, None]) + J / _scal(lam_g, J)
    return lowrank_apply(W, U_a, s_a, lam_a)

"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (tests sweep shapes
and dtypes in interpret mode and assert allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ea_syrk(M: Array, X: Array, rho, first) -> Array:
    """EA K-factor update:  M ← keep·M + coef·X Xᵀ with
    keep = ρ·(1-first), coef = 1-ρ·(1-first)   (paper eq. 5, κ(0)=1)."""
    rho = jnp.asarray(rho, M.dtype)
    firstf = jnp.asarray(first, M.dtype)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    return keep * M + coef * (X @ X.T).astype(M.dtype)


def brand_panel(U: Array, A: Array):
    """The O(d·r·n) panel of Brand's update:  C = UᵀA,  A⊥ = A − U C."""
    C = U.T @ A
    return C, A - U @ C


def lowrank_apply(X: Array, U: Array, s: Array, lam) -> Array:
    """Fused low-rank inverse application:
    Y = (X U) diag(s) Uᵀ + X/λ   (paper Alg 1 lines 15-17 in factored form).
    """
    lam = jnp.asarray(lam, X.dtype)
    T = (X @ U) * s[None, :]
    return T @ U.T + X / lam

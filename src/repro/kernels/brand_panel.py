"""Pallas TPU kernels: the O(d·r·n) panel of Brand's symmetric update.

The linear-in-d cost of the B-update (paper Alg 3 lines 3-4) is exactly two
tall-skinny operations over the layer dimension d:

    C  = Uᵀ A            (r, n)   — contraction over d
    A⊥ = A − U C         (d, n)   — row-parallel over d

Everything else in the B-update is O((r+n)-sized) and stays in XLA.

All operands carry a leading stack axis B (scanned layers / MoE experts /
plain B=1) so a whole stack of panels is one batched launch.

Kernel 1 (``_ut_a``): grid (B, d/bk), accumulating the (r, n) product in a
float32 VMEM accumulator (r·n ≤ ~768·512 → ≤ 1.5 MB, fits VMEM comfortably).

Kernel 2 (``_a_perp``): grid (B, d/bm); each row block reads its U and A
tiles once and writes A⊥ — U's full width r rides along in VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

Array = jax.Array


def _ut_a_kernel(u_ref, a_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        u_ref[0], a_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _a_perp_kernel(a_ref, u_ref, c_ref, o_ref):
    uc = jnp.dot(u_ref[0], c_ref[0],
                 preferred_element_type=jnp.float32)
    o_ref[0] = (a_ref[0].astype(jnp.float32) - uc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def ut_a_batched_pallas(U: Array, A: Array, bk: int = 512,
                        interpret: bool = False) -> Array:
    """C = Uᵀ A.  U: (B, d, r), A: (B, d, n); d % bk == 0."""
    B, d, r = U.shape
    n = A.shape[-1]
    bk = min(bk, d)
    grid = (B, d // bk)
    return pl.pallas_call(
        functools.partial(_ut_a_kernel, n_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk, r), lambda b, k: (b, k, 0)),
            pl.BlockSpec((1, bk, n), lambda b, k: (b, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, n), lambda b, k: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, r, n), U.dtype),
        scratch_shapes=[pltpu.VMEM((r, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(U, A)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def a_perp_batched_pallas(A: Array, U: Array, C: Array, bm: int = 512,
                          interpret: bool = False) -> Array:
    """A⊥ = A − U C.  A: (B, d, n), U: (B, d, r), C: (B, r, n); d % bm == 0."""
    B, d, n = A.shape
    r = U.shape[-1]
    bm = min(bm, d)
    grid = (B, d // bm)
    return pl.pallas_call(
        _a_perp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, r), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, r, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d, n), A.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(A, U, C)


def brand_panel_batched_pallas(U: Array, A: Array, bk: int = 512,
                               interpret: bool = False
                               ) -> Tuple[Array, Array]:
    """(C, A⊥) = (UᵀA, A − U(UᵀA)) for a whole stack in one batched launch."""
    C = ut_a_batched_pallas(U, A, bk=bk, interpret=interpret)
    return C, a_perp_batched_pallas(A, U, C, bm=bk, interpret=interpret)


def ut_a_pallas(U: Array, A: Array, bk: int = 512,
                interpret: bool = False) -> Array:
    """Single-factor entry point: C = Uᵀ A."""
    return ut_a_batched_pallas(U[None], A[None], bk=bk,
                               interpret=interpret)[0]


def a_perp_pallas(A: Array, U: Array, C: Array, bm: int = 512,
                  interpret: bool = False) -> Array:
    """Single-factor entry point: A⊥ = A − U C."""
    return a_perp_batched_pallas(A[None], U[None], C[None], bm=bm,
                                 interpret=interpret)[0]


def brand_panel_pallas(U: Array, A: Array, bk: int = 512,
                       interpret: bool = False):
    """(C, A⊥) = (UᵀA, A − U(UᵀA)) — the full Brand panel."""
    C, P = brand_panel_batched_pallas(U[None], A[None], bk=bk,
                                      interpret=interpret)
    return C[0], P[0]

"""Pallas TPU kernels: the O(d·r·n) panel of Brand's symmetric update.

The linear-in-d cost of the B-update (paper Alg 3 lines 3-4) is exactly two
tall-skinny operations over the layer dimension d:

    C  = Uᵀ A            (r, n)   — contraction over d
    A⊥ = A − U C         (d, n)   — row-parallel over d

Everything else in the B-update is O((r+n)-sized) and stays in XLA.

Kernel 1 (``_ut_a``): grid over d/bk, accumulating the (r, n) product in a
float32 VMEM accumulator (r·n ≤ ~768·512 → ≤ 1.5 MB, fits VMEM comfortably).

Kernel 2 (``_a_perp``): grid over d/bm; each row block reads its U and A
tiles once and writes A⊥ — U's full width r rides along in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ut_a_kernel(u_ref, a_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        u_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _a_perp_kernel(a_ref, u_ref, c_ref, o_ref):
    uc = jnp.dot(u_ref[...], c_ref[...],
                 preferred_element_type=jnp.float32)
    o_ref[...] = (a_ref[...].astype(jnp.float32) - uc).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def ut_a_pallas(U: Array, A: Array, bk: int = 512,
                interpret: bool = False) -> Array:
    """C = Uᵀ A.  U: (d, r), A: (d, n); d % bk == 0."""
    d, r = U.shape
    n = A.shape[1]
    bk = min(bk, d)
    grid = (d // bk,)
    return pl.pallas_call(
        functools.partial(_ut_a_kernel, n_k=grid[0]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, r), lambda k: (k, 0)),
            pl.BlockSpec((bk, n), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((r, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), U.dtype),
        scratch_shapes=[pltpu.VMEM((r, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(U, A)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def a_perp_pallas(A: Array, U: Array, C: Array, bm: int = 512,
                  interpret: bool = False) -> Array:
    """A⊥ = A − U C.  A: (d, n), U: (d, r), C: (r, n); d % bm == 0."""
    d, n = A.shape
    r = U.shape[1]
    bm = min(bm, d)
    grid = (d // bm,)
    return pl.pallas_call(
        _a_perp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, n), A.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(A, U, C)


def brand_panel_pallas(U: Array, A: Array, bk: int = 512,
                       interpret: bool = False):
    """(C, A⊥) = (UᵀA, A − U(UᵀA)) — the full Brand panel."""
    C = ut_a_pallas(U, A, bk=bk, interpret=interpret)
    return C, a_perp_pallas(A, U, C, bm=bk, interpret=interpret)

"""Randomized low-rank decompositions (Halko-Martinsson-Tropp) — the
R-KFAC / SRE-KFAC substrate the paper builds on and compares against.

* ``rsvd_psd``        — randomized symmetric EVD of a formed psd matrix
                        (the paper's RSVD/SREVD of a K-factor), O(d²(r+r_o)).
* ``rsvd_from_factor``— randomized EVD of X Xᵀ given only X (never forms the
                        d×d product; used for low-memory overwrites).
* ``range_finder``    — subspace/power iteration; shared with the PowerSGD
                        style gradient compressor in distributed/compress.py.

Target rank ``r`` plus oversampling ``r_o`` columns are sampled; the top-r
modes are returned (descending), padded to a static width on request.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def range_finder(matvec, d: int, k: int, key: Array, n_iter: int,
                 dtype=jnp.float32) -> Array:
    """Orthonormal basis Q (d, k) approximately spanning range(M).

    ``matvec`` maps (d, k) → (d, k) (i.e. right-multiplication by M).
    Power/subspace iteration with re-orthonormalization each pass — the
    paper uses n_pwr-it = 4.  Orthonormalization is CholeskyQR2
    (``kernels/ops.py::orthonormalize``): the same tall-skinny shape as
    the Brand panel QR, so it shares the batched Pallas SYRK + apply
    kernels on TPU and the shifted-Cholesky jnp oracle elsewhere.  The
    range finder only needs *a* basis of range(Y) — near-zero columns on
    rank-deficient directions are as good as Householder's arbitrary
    orthonormal completion there.
    """
    from repro.kernels import ops as kops
    omega = jax.random.normal(key, (d, k), dtype=dtype)
    Q = kops.orthonormalize(matvec(omega))
    for _ in range(n_iter):
        Q = kops.orthonormalize(matvec(Q))
    return Q


def rsvd_psd(M: Array, r: int, r_o: int, key: Array, n_iter: int = 2,
             pad_to: int | None = None) -> Tuple[Array, Array]:
    """Randomized EVD of a symmetric psd matrix M (d, d), target rank r.

    Returns (U, D): U (d, r) orthonormal, D (r,) descending. If ``pad_to`` is
    given, zero modes pad the state to that static width.
    """
    d = M.shape[0]
    k = min(r + r_o, d)
    Q = range_finder(lambda Y: M @ Y, d, k, key, n_iter, M.dtype)
    B = Q.T @ (M @ Q)                                   # (k, k) small
    B = 0.5 * (B + B.T)
    vals, vecs = jnp.linalg.eigh(B)
    vals, vecs = vals[::-1], vecs[:, ::-1]
    U = Q @ vecs[:, :r]                                 # (d, r)
    D = jnp.maximum(vals[:r], 0.0)
    if pad_to is not None and pad_to > r:
        U = jnp.concatenate([U, jnp.zeros((d, pad_to - r), M.dtype)], axis=1)
        D = jnp.concatenate([D, jnp.zeros((pad_to - r,), M.dtype)])
    return U, D


def rsvd_from_factor(X: Array, r: int, r_o: int, key: Array, n_iter: int = 2,
                     pad_to: int | None = None) -> Tuple[Array, Array]:
    """Randomized EVD of M = X Xᵀ given only the factor X (d, n).

    Never materializes the d×d matrix — O(d·n·(r+r_o)) work — usable for
    vocab-sized factors where d² storage is impossible (paper §3.5
    low-memory property carried over to the randomized path).
    """
    d = X.shape[0]
    k = min(r + r_o, d)
    mv = lambda Y: X @ (X.T @ Y)
    Q = range_finder(mv, d, k, key, n_iter, X.dtype)
    B = Q.T @ mv(Q)
    B = 0.5 * (B + B.T)
    vals, vecs = jnp.linalg.eigh(B)
    vals, vecs = vals[::-1], vecs[:, ::-1]
    U = Q @ vecs[:, :r]
    D = jnp.maximum(vals[:r], 0.0)
    if pad_to is not None and pad_to > r:
        U = jnp.concatenate([U, jnp.zeros((d, pad_to - r), X.dtype)], axis=1)
        D = jnp.concatenate([D, jnp.zeros((pad_to - r,), X.dtype)])
    return U, D


def exact_evd(M: Array, r: int | None = None, pad_to: int | None = None
              ) -> Tuple[Array, Array]:
    """Dense EVD (the K-FAC baseline inverse path), descending, optionally
    truncated to rank r and zero-padded to a static width."""
    vals, vecs = jnp.linalg.eigh(0.5 * (M + M.T))
    vals, vecs = vals[::-1], vecs[:, ::-1]
    if r is not None:
        vals, vecs = vals[:r], vecs[:, :r]
    if pad_to is not None and pad_to > vecs.shape[1]:
        d, w = M.shape[0], vecs.shape[1]
        vecs = jnp.concatenate([vecs, jnp.zeros((d, pad_to - w), M.dtype)], 1)
        vals = jnp.concatenate([vals, jnp.zeros((pad_to - w,), M.dtype)])
    return vecs, vals


@functools.partial(jax.jit, static_argnames=("r", "r_o", "n_iter", "pad_to"))
def rsvd_psd_jit(M, r, r_o, key, n_iter=2, pad_to=None):
    return rsvd_psd(M, r, r_o, key, n_iter, pad_to)

"""Brand's (2006) fast low-rank SVD/EVD modification — the paper's §2.3.

Implements:
  * ``brand_update``            — general (non-symmetric) Algorithm 2.
  * ``sym_brand_update``        — symmetric Algorithm 3 (the one K-FAC uses).
  * ``truncate``                — optimal rank-r truncation of a held (U, D).
  * ``ea_brand_step``           — one B-KFAC K-factor step (Alg 4 lines 2-7):
                                  truncate to r, then Brand-update with the
                                  incoming EA term  ρ·M + (1-ρ)·X Xᵀ.

Conventions
-----------
Eigenvalues are kept sorted *descending*.  A Brand state is a pair
``(U, D)`` with ``U ∈ R[d, m]`` column-orthonormal and ``D ∈ R[m]`` so that
the represented matrix is ``U @ diag(D) @ U.T``.  All functions are pure and
jit/vmap friendly (static shapes; rank changes are expressed by zero modes).

Stacked-native: the symmetric path (``sym_brand_update`` / ``ea_brand_step``
/ ``init_from_factor``) accepts arbitrary leading stack axes, so a whole
bucket of K-factors (scanned layers, MoE experts, cross-layer shape
classes) updates in one batched call.

``use_kernel`` routes the two O(d)-sized ops of the symmetric update — the
projection panel (C, A⊥) and the tall-skinny QR of A⊥ — through the Pallas
kernels (``kernels/ops.py::brand_panel`` + ``cholqr2``); the remaining
O((r+n)²) eigenproblem stays in XLA.  The default path keeps Householder
``jnp.linalg.qr`` (the original oracle semantics); both agree up to
rotations inside degenerate eigenspaces, which the represented matrix
U diag(D) Uᵀ is invariant to.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import mt as _mt

Array = jax.Array


def _desc_eigh(M: Array) -> Tuple[Array, Array]:
    """eigh with eigenvalues sorted descending. Returns (vals, vecs)."""
    vals, vecs = jnp.linalg.eigh(M)
    return vals[..., ::-1], vecs[..., :, ::-1]


def _batched_diag(D: Array) -> Array:
    """(..., r) → (..., r, r) diagonal matrices."""
    return jnp.eye(D.shape[-1], dtype=D.dtype) * D[..., None, :]


def truncate(U: Array, D: Array, r: int) -> Tuple[Array, Array]:
    """Optimal rank-r truncation: keep the r strongest modes.

    ``D`` is descending, so this is a slice. Shapes shrink — use only at
    trace time with static ``r``.
    """
    return U[..., :, :r], D[..., :r]


def brand_update(U: Array, D: Array, V: Array, A: Array, B: Array
                 ) -> Tuple[Array, Array, Array]:
    """General Brand update (paper Alg 2):  X̂ = U diag(D) Vᵀ + A Bᵀ.

    U: (m, r), V: (d, r), D: (r,), A: (m, n), B: (d, n).
    Returns (U', D', V') of ranks r+n (exact thin SVD of X̂).
    """
    # Project the update onto the current subspaces and their complements.
    UtA = _mt(U) @ A                                 # (r, n)
    VtB = _mt(V) @ B                                 # (r, n)
    A_perp = A - U @ UtA
    B_perp = B - V @ VtB
    Qa, Ra = jnp.linalg.qr(A_perp)                   # (m, n), (n, n)
    Qb, Rb = jnp.linalg.qr(B_perp)                   # (d, n), (n, n)
    # M_S = [[I, UtA],[0, Ra]] @ diag(D, I) @ [[I, VtB],[0, Rb]]ᵀ  (eq. 7)
    top = jnp.concatenate([_batched_diag(D) + UtA @ _mt(VtB),
                           UtA @ _mt(Rb)], axis=-1)
    bot = jnp.concatenate([Ra @ _mt(VtB), Ra @ _mt(Rb)], axis=-1)
    Ms = jnp.concatenate([top, bot], axis=-2)        # (r+n, r+n)
    Um, Dm, Vmt = jnp.linalg.svd(Ms)
    U_new = jnp.concatenate([U, Qa], axis=-1) @ Um
    V_new = jnp.concatenate([V, Qb], axis=-1) @ _mt(Vmt)
    return U_new, Dm, V_new


def sym_brand_update(U: Array, D: Array, A: Array, use_kernel: bool = False
                     ) -> Tuple[Array, Array]:
    """Symmetric Brand update (paper Alg 3):  X̂ = U diag(D) Uᵀ + A Aᵀ.

    U: (*stack, d, r) column-orthonormal, D: (*stack, r) descending,
    A: (*stack, d, n).  Returns (U', D') with U' (…, d, r+n), D' (…, r+n)
    descending — the exact EVD of X̂ (X̂ is symmetric psd when D ≥ 0).

    Derivation: with C = UᵀA and A⊥ = A − UC = Q R,
        X̂ = [U Q] [[diag(D)+CCᵀ, CRᵀ],[RCᵀ, RRᵀ]] [U Q]ᵀ
    and the middle (r+n)² matrix is symmetric — one small eigh finishes it.

    With ``use_kernel`` the O(d·r·n) panel and the O(d·n²) tall-skinny QR
    run as batched Pallas launches (``brand_panel`` + CholeskyQR2); the
    whole light update is then linear in d with no XLA QR left.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        C, A_perp = kops.brand_panel(U, A)           # (…, r, n), (…, d, n)
        Q, R = kops.cholqr2(A_perp)                  # (…, d, n), (…, n, n)
    else:
        C = _mt(U) @ A
        A_perp = A - U @ C
        Q, R = jnp.linalg.qr(A_perp)
    top = jnp.concatenate([_batched_diag(D) + C @ _mt(C), C @ _mt(R)],
                          axis=-1)
    bot = jnp.concatenate([R @ _mt(C), R @ _mt(R)], axis=-1)
    Ms = jnp.concatenate([top, bot], axis=-2)        # (…, r+n, r+n)
    Dm, Wm = _desc_eigh(Ms)
    U_new = jnp.concatenate([U, Q], axis=-1) @ Wm    # (…, d, r+n)
    return U_new, Dm


def ea_brand_step(U: Array, D: Array, X: Array, rho: float, r: int,
                  use_kernel: bool = False) -> Tuple[Array, Array]:
    """One B-KFAC K-factor inverse-representation step (paper Alg 4).

    Held state (U, D) has rank r+n (from the previous step).  We truncate to
    the r strongest modes (paper §3.1 "Controlling the size"), then apply the
    symmetric Brand update with the incoming EA term:

        M ← ρ · trunc_r(U diag(D) Uᵀ) + (1-ρ) · X Xᵀ

    X: (*stack, d, n) — the incoming K-factor square root (activations or
    output-gradients, already transposed to column-sample layout).
    Returns (U', D') of rank r+n.
    """
    Ut, Dt = truncate(U, D, r)
    return sym_brand_update(Ut, rho * Dt, jnp.sqrt(1.0 - rho) * X,
                            use_kernel=use_kernel)


def init_from_factor(X: Array, m: int) -> Tuple[Array, Array]:
    """Initialize a Brand state from the first factor M₀ = X Xᵀ without ever
    forming the d×d product (the low-memory property of §3.5).

    X: (*stack, d, n).  Returns (U, D) padded with zero modes to width ``m``
    so the state shape is static across steps.
    """
    d, n = X.shape[-2:]
    # Thin SVD of X gives the EVD of X Xᵀ: eigvecs = left singular vectors,
    # eigvals = singular values squared.
    Ux, s, _ = jnp.linalg.svd(X, full_matrices=False)  # (…, d, n), (…, n)
    D = s * s
    if n >= m:
        return Ux[..., :, :m], D[..., :m]
    stack = X.shape[:-2]
    pad_u = jnp.zeros(stack + (d, m - n), dtype=X.dtype)
    pad_d = jnp.zeros(stack + (m - n,), dtype=X.dtype)
    return (jnp.concatenate([Ux, pad_u], axis=-1),
            jnp.concatenate([D, pad_d], axis=-1))

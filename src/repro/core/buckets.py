"""Cross-layer bucketing: group K-factors (and preconditioned taps) whose
shape-class matches into stacked super-batches, so the optimizer hot path
runs O(#shape-classes) batched launches instead of O(#layers) small ones.

A transformer has dozens-to-hundreds of tapped matmuls but only a handful
of distinct factor shapes (qkv/out projections share d_model, both MLP
ends share d_ff↔d_model, every scanned block repeats them).  The kernels
package is already stacked-native (leading batch axis → leading parallel
grid dimension), so the only missing piece is a static gather/scatter
between the per-tap optimizer state tree and per-class flat batches — this
module.  Everything here is shape metadata resolved at ``Kfac.__init__``
time; under jit the gathers/scatters are pure reshapes + concatenates.

Shape classes
-------------
*Factor* work (EA absorb, Brand light update, heavy overwrites) buckets by
the full ``KFactorSpec`` — (d, r, n_stat, mode, ρ, …) — since the spec
decides both operand shapes and the update program.  Each tap contributes
two factor jobs (A-side d_in, G-side d_out); a tap's own stack axes
(scanned layers L, experts E) are flattened into the bucket batch, so an
FC tap (count 1) and an (L, E) MoE tap (count L·E) with matching specs
share one bucket.

*Preconditioning* buckets by (A-spec, G-spec, linear_apply): the two-sided
application needs both factor shapes to line up, and Alg-8 linear-apply
taps consume gradient factors with their own shapes.

A tap falls out of a bucket (gets its own singleton bucket) whenever any
component of its class differs — see docs/bucketing.md for the rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.kfactor import KFactorSpec, KFactorState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Entry:
    """One (tap, side) slot inside a bucket's flat batch axis."""
    name: str                    # tap name
    side: str                    # "A" | "G" (factor buckets); "" (precond)
    stack: Tuple[int, ...]       # the tap's own stack axes
    offset: int                  # start row in the bucket batch
    count: int                   # prod(stack)


@dataclasses.dataclass(frozen=True)
class FactorBucket:
    """All factor jobs of one shape class, stacked along one batch axis."""
    spec: KFactorSpec
    entries: Tuple[Entry, ...]
    total: int                   # sum of entry counts


@dataclasses.dataclass(frozen=True)
class PrecondBucket:
    """All preconditioned taps of one (A-spec, G-spec, apply-mode) class."""
    spec_a: KFactorSpec
    spec_g: KFactorSpec
    linear_apply: bool
    entries: Tuple[Entry, ...]
    total: int


def _count(stack: Tuple[int, ...]) -> int:
    return math.prod(stack) if stack else 1


def build_factor_buckets(specs: Dict[str, Dict[str, KFactorSpec]],
                         stacks: Dict[str, Tuple[int, ...]]
                         ) -> Tuple[FactorBucket, ...]:
    """Group every (tap, side) factor job by its KFactorSpec.

    ``specs``: {tap: {"A": spec, "G": spec}}; ``stacks``: {tap: stack}.
    Bucket order (and entry order inside a bucket) is deterministic:
    sorted tap name, then side — the jitted update's structure must not
    depend on dict iteration order.
    """
    grouped: Dict[KFactorSpec, list] = {}
    for name in sorted(specs):
        for side in ("A", "G"):
            grouped.setdefault(specs[name][side], []).append((name, side))
    buckets = []
    for spec in sorted(grouped, key=lambda s: (s.d, s.n_stat, s.mode.value,
                                               s.r, s.n_crc)):
        entries, offset = [], 0
        for name, side in grouped[spec]:
            count = _count(stacks[name])
            entries.append(Entry(name=name, side=side, stack=stacks[name],
                                 offset=offset, count=count))
            offset += count
        buckets.append(FactorBucket(spec=spec, entries=tuple(entries),
                                    total=offset))
    return tuple(buckets)


def build_precond_buckets(specs: Dict[str, Dict[str, KFactorSpec]],
                          stacks: Dict[str, Tuple[int, ...]],
                          linear_apply: Dict[str, bool]
                          ) -> Tuple[PrecondBucket, ...]:
    """Group taps by (A-spec, G-spec, linear_apply) for the two-sided
    application — one batched (fused) preconditioning launch per class."""
    grouped: Dict[tuple, list] = {}
    for name in sorted(specs):
        key = (specs[name]["A"], specs[name]["G"], linear_apply[name])
        grouped.setdefault(key, []).append(name)
    buckets = []
    for key in sorted(grouped, key=lambda k: (k[0].d, k[1].d, k[2])):
        spec_a, spec_g, lin = key
        entries, offset = [], 0
        for name in grouped[key]:
            count = _count(stacks[name])
            entries.append(Entry(name=name, side="", stack=stacks[name],
                                 offset=offset, count=count))
            offset += count
        buckets.append(PrecondBucket(spec_a=spec_a, spec_g=spec_g,
                                     linear_apply=lin,
                                     entries=tuple(entries), total=offset))
    return tuple(buckets)


# ---------------------------------------------------------------------------
# gather / scatter (pure reshapes + concatenates under jit)
# ---------------------------------------------------------------------------

def _flatten(x: Array, entry: Entry) -> Array:
    """(*entry.stack, *core) → (count, *core)."""
    core = x.shape[len(entry.stack):]
    return x.reshape((entry.count,) + core)


def _unflatten(x: Array, entry: Entry) -> Array:
    """(count, *core) → (*entry.stack, *core)."""
    return x.reshape(entry.stack + x.shape[1:])


def gather(entries: Sequence[Entry], leaves: Dict[Tuple[str, str], Array]
           ) -> Array:
    """Stack per-entry arrays {(name, side): (*stack, *core)} into one
    (total, *core) batch along the bucket axis."""
    return jnp.concatenate(
        [_flatten(leaves[(e.name, e.side)], e) for e in entries], axis=0)


def scatter(entries: Sequence[Entry], batched: Array
            ) -> Dict[Tuple[str, str], Array]:
    """Split a (total, *core) bucket result back into per-entry arrays of
    their original stack shapes."""
    return {(e.name, e.side):
            _unflatten(batched[e.offset:e.offset + e.count], e)
            for e in entries}


def gather_states(entries: Sequence[Entry],
                  states: Dict[Tuple[str, str], KFactorState]
                  ) -> KFactorState:
    """Tree-wise gather of KFactorStates into one (total, …) state."""
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.concatenate(
            [_flatten(leaf, e) for e, leaf in zip(entries, leaves)], axis=0),
        *(states[(e.name, e.side)] for e in entries))


def scatter_states(entries: Sequence[Entry], batched: KFactorState
                   ) -> Dict[Tuple[str, str], KFactorState]:
    """Tree-wise split of a bucket state back to per-entry states."""
    return {(e.name, e.side): jax.tree_util.tree_map(
                lambda leaf, e=e: _unflatten(
                    leaf[e.offset:e.offset + e.count], e), batched)
            for e in entries}


# ---------------------------------------------------------------------------
# shard-aware layout: round-robin slot → device assignment (KAISA-style)
# ---------------------------------------------------------------------------
#
# The distributed curvature engine partitions a bucket's flat batch axis
# across the mesh's curvature axis.  Assignment is round-robin at slot
# granularity — slot s lives on device s % n at local row s // n — so
# consecutive slots (which usually belong to one stacked tap) spread
# across devices and every device gets an equal ceil(total/n) share of
# every bucket.  The helpers below are pure index bookkeeping; the data
# movement they imply is a single static `take` per gather/scatter.

def padded_total(total: int, n: int) -> int:
    """Bucket batch padded to a multiple of the device count."""
    return -(-total // n) * n


def shard_perm(total: int, n: int):
    """Index vector placing slots device-major: position d*m + k holds
    slot (k*n + d) % total — round-robin assignment, with the pad tail
    wrapping onto real slots so padding always computes on well-formed
    (discarded) operands rather than zeros."""
    m = padded_total(total, n) // n
    return [(k * n + d) % total for d in range(n) for k in range(m)]


def shard_unperm(total: int, n: int):
    """Inverse map: position of slot s in the device-major layout."""
    m = padded_total(total, n) // n
    return [(s % n) * m + s // n for s in range(total)]


def slot_device(slot: int, n: int) -> int:
    """Owning device of a bucket slot under the round-robin assignment."""
    return slot % n


def localize_ranges(ranges, total: int, n: int):
    """Global heavy slot ranges → the per-device local row ranges (equal
    on every device — the SPMD requirement).  Needs each range to start
    at a multiple of ``n`` and end at a multiple of ``n`` or at the
    bucket end (the scheduler's ``align=n`` contract); rows past
    ``total`` fall on wrapped pad slots whose results are discarded."""
    local = []
    for lo, hi in ranges:
        if lo % n != 0 or (hi % n != 0 and hi != total):
            raise ValueError(
                f"heavy range ({lo}, {hi}) not aligned to the curvature "
                f"mesh size {n}; build the Scheduler with align={n}")
        local.append((lo // n, -(-hi // n)))
    return tuple(local)


def describe(buckets: Sequence[FactorBucket]) -> str:
    """One line per bucket — for logs / benchmarks."""
    parts = []
    for b in buckets:
        parts.append(f"[d={b.spec.d} n={b.spec.n_stat} "
                     f"mode={b.spec.mode.value} B={b.total} "
                     f"taps={len(b.entries)}]")
    return " ".join(parts)

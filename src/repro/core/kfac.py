"""The K-FAC optimizer family (K-FAC / R-KFAC / B-KFAC / B-R-KFAC /
B-KFAC-C / NS-KFAC) as a single policy-driven JAX optimizer.

Model contract
--------------
A K-FAC-compatible model provides *taps*: for every preconditioned matmul
``y = x @ W`` (W of shape (d_in, d_out), possibly stacked over scanned
layers / experts) the model

  * accepts a ``probes`` pytree — zeros of shape (*stack, n_stat, d_out)
    added to the layer output on an ``n_stat``-token slice, and
  * emits ``acts`` — the corresponding inputs, (*stack, n_stat, d_in).

``jax.grad`` w.r.t. a probe is exactly ∂L/∂y on that slice, so
(acts, probe-grads) are the paper's (A_k, G_k) K-factor square roots — the
functional replacement for PyTorch's forward/backward hooks.

Scheduling (paper §2.2/§6) is *static*: the trainer calls ``update`` with
a hashable :class:`repro.core.schedule.StepWork` mask derived from the
step number, so each step variant compiles to a lean HLO (production
pattern; also keeps the dry-run rooflines honest).  ``stats``/``light``
are global booleans; heavy work is *per factor bucket* as static slot
ranges, which is what lets the scheduler stagger heavy overwrites across
the T_inv window (constant small per-step cost instead of a spike) and
lets the distributed curvature engine shard them across the mesh.  The
legacy three python bools are still accepted for one deprecation cycle
(warn once, then converted to a uniform mask):

  do_stats  = k % T_updt == 0                      (EA absorb, all variants)
  do_light  = k % T_brand == 0   (B-variants: Brand update;   else no-op)
  do_heavy  = k % T_inv  == 0    (kfac: EVD, rkfac: RSVD, nskfac: NS)
            = k % T_rsvd == 0    (brkfac: RSVD overwrite)
            = k % T_corct == 0   (bkfacc: light correction)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import buckets, kfactor, policy, precond, schedule
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import adamw as _adamw
from repro.optim import base as optbase

Array = jax.Array


# ---------------------------------------------------------------------------
# tap descriptions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TapInfo:
    """Static description of one tapped matmul family."""
    param_path: str                 # "/"-joined path to W inside params
    d_in: int
    d_out: int
    stack: Tuple[int, ...] = ()     # leading stacked dims, e.g. (L,), (L, E)
    n_stat: int = 512               # stats tokens per layer per stats step
    linear_apply: bool = False      # Alg 8: step from factors, W stop-grad'd


@dataclasses.dataclass(frozen=True)
class KfacConfig:
    policy: policy.PolicyConfig = policy.PolicyConfig()
    lr: optbase.Schedule = optbase.constant(0.3)
    damping_phi: optbase.Schedule = optbase.constant(0.1)
    momentum: float = 0.0
    weight_decay: float = 7e-4
    clip: float = 0.07              # global-norm clip on the update
    spectrum_continuation: bool = True
    use_kernels: bool = False       # route hot matmuls via kernels/ops.py
    bucketed: bool = True           # cross-layer shape-class super-batching
    T_updt: int = 25
    T_inv: int = 250                # kfac / rkfac heavy period
    T_brand: int = 25               # B-variants light period
    T_rsvd: int = 250               # brkfac overwrite period
    T_corct: int = 500              # bkfacc correction period
    stagger: bool = False           # phase heavy work across the T window
    stagger_splits: int = 1         # max entry-aligned chunks per bucket
    async_heavy: bool = False       # two-phase launch/land heavy pipeline
    heavy_lag: int = 0              # steps between snapshot and swap-in
    # fallback optimizer for non-tapped params
    fallback_lr: optbase.Schedule = optbase.constant(1e-3)
    fallback_wd: float = 0.0

    def flags(self, step: int) -> Dict[str, bool]:
        """DEPRECATED legacy three-bool view of the step variant; the
        scheduler's StepWork masks (``Kfac.scheduler().work(step)``)
        subsumed it in PR 3.  Warns once, then delegates to
        schedule.legacy_flags (the variant → heavy-period mapping lives
        in one table in core/policy.py)."""
        from repro import specs as specs_lib
        specs_lib.warn_once(
            "KfacConfig.flags",
            "KfacConfig.flags(step) is deprecated; use "
            "Kfac.scheduler().work(step) (a StepWork mask) or "
            "Kfac.uniform_work(...)")
        return schedule.legacy_flags(self, step)


class TapState(NamedTuple):
    A: kfactor.KFactorState      # forward factor  (stacked over tap.stack)
    G: kfactor.KFactorState      # backward factor


class KfacState(NamedTuple):
    step: Array
    n_stats: Array               # how many stats batches absorbed
    phase: Array                 # step mod schedule cycle — lets an
                                 # elastic restart re-derive the staggered
                                 # work masks without the global step
    factors: Dict[str, TapState]
    momentum: Any                # tree over tapped params (or None)
    fallback: Any                # AdamW state over non-tapped params
    inflight: Dict[str, Any]     # bucket idx (str) → InflightState — the
                                 # async pipeline's double buffer; {} when
                                 # cfg.async_heavy is off, so pre-async
                                 # checkpoints keep restoring (no default:
                                 # a shared mutable {} on the class would
                                 # alias across every state)


# ---------------------------------------------------------------------------
# param-tree path helpers
# ---------------------------------------------------------------------------

def get_path(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def set_path(tree, path: str, value):
    parts = path.split("/")
    def rec(node, i):
        if i == len(parts) - 1:
            new = dict(node)
            new[parts[i]] = value
            return new
        new = dict(node)
        new[parts[i]] = rec(node[parts[i]], i + 1)
        return new
    return rec(tree, 0)


def _split_params(params, taps: Dict[str, TapInfo]):
    """→ (tapped: {name: W}, rest: params-with-tapped-zeroed-out-paths)."""
    tapped = {name: get_path(params, t.param_path) for name, t in taps.items()}
    return tapped


def _untapped_mask(params, taps):
    """Boolean tree: True where the leaf is NOT owned by a tap."""
    paths = {t.param_path for t in taps.values()}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def leaf_path(kp):
        return "/".join(str(k.key) for k in kp)

    return {leaf_path(kp) for kp, _ in flat} - paths


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------

class Kfac:
    """K-FAC optimizer over a tapped model. Not a pytree — holds statics.

    ``curvature`` (optional) is a distributed curvature engine (see
    ``repro.distributed.curvature.CurvatureEngine``) that shards each
    factor bucket's batch axis across a mesh axis; when attached, the
    bucketed factor work is delegated to it.  Duck-typed so core never
    imports the distributed package.
    """

    def __init__(self, cfg: KfacConfig, taps: Dict[str, TapInfo],
                 curvature: Optional[Any] = None):
        self.cfg = cfg
        self.taps = dict(taps)
        self.curvature = curvature
        self.specs = {}
        for name, t in self.taps.items():
            self.specs[name] = dict(
                A=policy.make_factor_spec(cfg.policy, t.d_in, t.n_stat),
                G=policy.make_factor_spec(cfg.policy, t.d_out, t.n_stat),
            )
        self._fallback = _adamw.adamw(cfg.fallback_lr,
                                      weight_decay=cfg.fallback_wd)
        # cross-layer shape-class buckets (static; resolved once here).
        # Factor work and preconditioning each collapse to one batched
        # launch per bucket instead of one per tap — O(#shape-classes)
        # instead of O(#layers) launches on the hot path.
        stacks = {n: t.stack for n, t in self.taps.items()}
        lin = {n: t.linear_apply for n, t in self.taps.items()}
        self.factor_buckets = buckets.build_factor_buckets(self.specs,
                                                           stacks)
        self.precond_buckets = buckets.build_precond_buckets(self.specs,
                                                             stacks, lin)
        # (name, side) → (bucket index, slot offset, slot count): the
        # per-tap path reads its heavy mask from the same bucket-indexed
        # StepWork the bucketed path consumes — one flag plumbing.
        self._slot = {}
        for bi, b in enumerate(self.factor_buckets):
            for e in b.entries:
                self._slot[(e.name, e.side)] = (bi, e.offset, e.count)
        # async pipeline: which buckets carry an in-flight double buffer,
        # and how many interim light panels each replays at landing
        self._async_buckets: Dict[int, int] = {
            bi: schedule.n_replay_panels(cfg, b.spec)
            for bi, b in enumerate(self.factor_buckets)
            if schedule.bucket_is_async(cfg, b.spec)}
        if self._async_buckets and not cfg.bucketed:
            raise ValueError("async_heavy requires bucketed=True (the "
                             "in-flight buffers live in bucket layout)")
        self._cycle = self.scheduler().cycle

    def scheduler(self, **kw) -> schedule.Scheduler:
        """A work scheduler over this optimizer's factor buckets; when a
        curvature engine is attached, heavy chunks auto-align to its
        ``align`` (slot-axis size × row-axis size on a 2D mesh) so
        staggered chunks stay SPMD-uniform AND split evenly across the
        row members."""
        if "align" not in kw and self.curvature is not None:
            kw["align"] = getattr(self.curvature, "align",
                                  self.curvature.n_devices)
        return schedule.Scheduler(self.cfg, self.factor_buckets, **kw)

    def uniform_work(self, do_stats: bool, do_light: bool, do_heavy: bool
                     ) -> schedule.StepWork:
        return schedule.uniform_work(do_stats, do_light, do_heavy,
                                     self.factor_buckets)

    def remedial_work(self) -> schedule.StepWork:
        """The forced-refresh mask of the remediation ladder (stage 2):
        full-range inline heavy + stats/light absorb, out of cadence —
        see :func:`repro.core.schedule.remedial_work`."""
        return schedule.remedial_work(self.cfg, self.factor_buckets)

    def clear_inflight(self, state: KfacState) -> KfacState:
        """Invalidate every in-flight heavy snapshot (the remediation
        ladder's "discard the poisoned inverse rep"): zeroed ``live``
        flags turn any still-scheduled landing into a per-slot no-op,
        so a snapshot taken before a detected fault can never swap
        corrupted state back over a freshly refreshed one."""
        if not state.inflight:
            return state
        inflight = {k: dataclasses.replace(
                        buf, live=jnp.zeros_like(buf.live))
                    for k, buf in state.inflight.items()}
        return state._replace(inflight=inflight)

    # -- state ------------------------------------------------------------
    def init(self, params) -> KfacState:
        factors = {}
        for name, t in self.taps.items():
            def stacked(spec):
                st = spec.init()
                for dim in reversed(t.stack):
                    st = jax.tree_util.tree_map(
                        lambda x: jnp.broadcast_to(x, (dim,) + x.shape), st)
                return st
            factors[name] = TapState(A=stacked(self.specs[name]["A"]),
                                     G=stacked(self.specs[name]["G"]))
        mom = None
        if self.cfg.momentum > 0:
            mom = {n: jnp.zeros_like(get_path(params, t.param_path),
                                     dtype=jnp.float32)
                   for n, t in self.taps.items()}
        # fallback adamw over the full tree (updates masked to untapped)
        fb = self._fallback.init(params)
        inflight = {str(bi): kfactor.make_inflight(
                        self.factor_buckets[bi].spec,
                        self.factor_buckets[bi].total, n_replay)
                    for bi, n_replay in self._async_buckets.items()}
        return KfacState(step=jnp.zeros((), jnp.int32),
                         n_stats=jnp.zeros((), jnp.int32),
                         phase=jnp.zeros((), jnp.int32),
                         factors=factors, momentum=mom, fallback=fb,
                         inflight=inflight)

    # -- per-tap pieces -----------------------------------------------------
    def _stats_factors(self, name, acts, probe_grads, n_tokens):
        """(X_A, X_G): K-factor square roots, (*stack, d, n_stat)."""
        t = self.taps[name]
        a = acts[name]                       # (*stack, n, d_in)
        g = probe_grads[name]                # (*stack, n, d_out)
        n = a.shape[-2]
        scale = 1.0 / jnp.sqrt(jnp.asarray(n, jnp.float32))
        X_A = jnp.swapaxes(a, -1, -2).astype(jnp.float32) * scale
        # probe grads are w.r.t. the *mean* loss → per-token grads are
        # O(1/n_tokens); rescale to per-token sum-loss grads (Martens-Grosse)
        X_G = (jnp.swapaxes(g, -1, -2).astype(jnp.float32)
               * jnp.asarray(n_tokens, jnp.float32) * scale)
        return X_A, X_G

    def _factor_update(self, name, side, st, X, key, first,
                       stats, light, heavy_b):
        """Per-tap factor update (comparison path): the tap's own stack is
        flattened into a batch of prod(stack) factors and stepped through
        the SAME per-bucket program the bucketed path runs
        (``kfactor.bucket_factor_step``) — one flag/mask plumbing for
        both paths, one launch per tap per side here.  ``heavy_b`` is a
        static python bool (all-or-nothing per tap: scheduler chunks are
        entry-aligned, so a tap's slots always share a phase)."""
        spec = self.specs[name][side]
        stack = self.taps[name].stack
        count = 1
        for dim in stack:
            count *= int(dim)
        flat = jax.tree_util.tree_map(
            lambda x: x.reshape((count,) + x.shape[len(stack):]), st)
        Xf = X.reshape((count,) + X.shape[len(stack):])
        keys = jax.random.split(key, count)
        flat = kfactor.bucket_factor_step(
            spec, flat, Xf, keys, first, stats, light,
            ((0, count),) if heavy_b else (), self.cfg.use_kernels)
        return jax.tree_util.tree_map(
            lambda x: x.reshape(stack + x.shape[1:]), flat)

    def _precondition(self, name, st: TapState, grad_w, phi,
                      g_factor=None, a_factor=None):
        """Preconditioned step for W (same shape as grad_w).

        Stacked-native end to end: damping, continuation, and the two-sided
        application are batched over the tap's stack, so ``use_kernels``
        covers scanned layers / expert stacks with single batched (fused)
        Pallas launches instead of vmapped 2D fallbacks.
        """
        use_k = self.cfg.use_kernels
        cont = self.cfg.spectrum_continuation
        # NS-mode sides hold a dense damped inverse in U — plain GEMM apply
        dense_g = self.specs[name]["G"].mode is kfactor.Mode.NS
        dense_a = self.specs[name]["A"].mode is kfactor.Mode.NS
        if self.taps[name].linear_apply:
            # Alg 8: step from gradient factors; grad_w is unused (stop-grad)
            S = precond.precondition_linear_with_damping(
                g_factor, a_factor, st.G.U, st.G.D, st.A.U, st.A.D, phi,
                continuation=cont, use_kernel=use_k,
                dense_g=dense_g, dense_a=dense_a)
        else:
            J = jnp.swapaxes(grad_w, -1, -2).astype(jnp.float32)
            S = precond.precondition_with_damping(
                J, st.G.U, st.G.D, st.A.U, st.A.D, phi,
                continuation=cont, use_kernel=use_k,
                dense_g=dense_g, dense_a=dense_a)
        return jnp.swapaxes(S, -1, -2)       # back to (d_in, d_out) layout

    # -- bucketed (cross-layer) pieces --------------------------------------
    def collect_factor_operands(self, factors, acts, probe_grads,
                                n_tokens):
        """Per-(tap, side) state/stats-factor dicts in bucket-entry keying
        — shared by the replicated bucketed path and the distributed
        curvature engine."""
        states, X_all = {}, {}
        for name in sorted(self.taps):
            X_A, X_G = self._stats_factors(name, acts, probe_grads,
                                           n_tokens)
            X_all[(name, "A")], X_all[(name, "G")] = X_A, X_G
            states[(name, "A")] = factors[name].A
            states[(name, "G")] = factors[name].G
        return states, X_all

    def repack_factors(self, states) -> Dict[str, TapState]:
        return {name: TapState(A=states[(name, "A")],
                               G=states[(name, "G")])
                for name in self.taps}

    def _work_ranges(self, work: schedule.StepWork, bi: int):
        """(launch, land) per-bucket ranges — empty for legacy masks
        whose launch/land tuples were never populated."""
        launch = work.launch[bi] if bi < len(work.launch) else ()
        land = work.land[bi] if bi < len(work.land) else ()
        return launch, land

    def _bucketed_factor_work(self, factors, inflight, acts, probe_grads,
                              n_tokens, rng, first,
                              work: schedule.StepWork,
                              bucket_step=None, landing=None, phi=None):
        """Factor updates as one batched launch group per shape-class
        bucket: stats absorbs (EA SYRK), Brand panels + CholeskyQR2, and
        the scheduled heavy slot ranges each run over the bucket's flat
        batch axis; async buckets additionally run this step's pipeline
        phases (panel ring, launch snapshot, land swap) against their
        in-flight buffer.

        ``bucket_step(bi, bucket, st, X, keys, buf, landed)`` overrides
        the inner per-bucket program (the distributed curvature engine
        substitutes its shard_map-wrapped one) and returns ``(st, buf)``;
        the surrounding loop — operand collection, no-op skip, gather,
        per-slot key split, scatter — exists ONLY here, so the sharded
        path can never diverge from the replicated one structurally.

        ``landing`` optionally maps bucket idx (str) → tuple of
        pre-computed (U, D, aux) triples, one per land range, from an
        overlapped dispatch (train.loop.AsyncInverseRunner).  ``phi``
        (the step's damping ratio) only feeds telemetry — the
        inversion-error proxy needs the same λ the preconditioner will
        derive."""
        if bucket_step is None:
            def bucket_step(bi, bucket, st, X, keys, buf, landed):
                launch, land = self._work_ranges(work, bi)
                return kfactor.bucket_factor_step_async(
                    bucket.spec, st, X, keys, first, work.stats,
                    work.light, work.heavy[bi], launch, land, buf,
                    self.cfg.use_kernels, landed=landed)
        states, X_all = self.collect_factor_operands(factors, acts,
                                                     probe_grads, n_tokens)
        inflight = dict(inflight)
        bkeys = jax.random.split(rng, len(self.factor_buckets))
        for bi, (bkey, bucket) in enumerate(zip(bkeys,
                                                self.factor_buckets)):
            launch, land = self._work_ranges(work, bi)
            if not kfactor.has_work(bucket.spec, work.stats, work.light,
                                    bool(work.heavy[bi] or launch
                                         or land)):
                continue        # whole bucket is a no-op this step
            st = buckets.gather_states(bucket.entries, states)
            X = buckets.gather(bucket.entries, X_all)
            keys = jax.random.split(bkey, bucket.total)
            buf = inflight.get(str(bi))
            landed = None if landing is None else landing.get(str(bi))
            with obs_trace.span(f"kfac/factor/b{bi}_"
                                f"{bucket.spec.mode.value}"):
                st, buf = bucket_step(bi, bucket, st, X, keys, buf, landed)
            if buf is not None:
                inflight[str(bi)] = buf
            self._record_bucket_metrics(bi, bucket, st, work, land, phi)
            states.update(buckets.scatter_states(bucket.entries, st))
        return self.repack_factors(states), inflight

    # -- telemetry (repro.obs) ----------------------------------------------
    def _record_bucket_metrics(self, bi, bucket, st, work, land, phi):
        """Per-bucket metrics off the post-step state — for the sharded
        engine this is the post-all-gather state at the outer trace
        level, so nothing here ever records from inside shard_map.
        Every record is a no-op without an active collector, and the
        derived metrics below only *enter the graph* when one is active
        (the metrics-off step stays the exact un-instrumented graph)."""
        if not obs_metrics.active():
            return
        spec = bucket.spec
        fired = (sum(hi - lo for lo, hi in work.heavy[bi])
                 + sum(hi - lo for lo, hi in land))
        obs_metrics.record(f"bucket{bi}/heavy_slots", float(fired))
        if bi in self._async_buckets:
            obs_metrics.record(f"bucket{bi}/replay_depth",
                               float(self._async_buckets[bi]))
        if not fired:
            return
        if spec.mode is kfactor.Mode.NS:
            obs_metrics.record(f"bucket{bi}/ns_lam",
                               jnp.mean(st.aux[..., kfactor.AUX_LAM]))
            obs_metrics.record(f"bucket{bi}/ns_res",
                               jnp.max(st.aux[..., kfactor.AUX_RES]))
        if spec.mode in (kfactor.Mode.EVD, kfactor.Mode.RSVD,
                         kfactor.Mode.BRAND_RSVD):
            obs_metrics.record(f"bucket{bi}/trunc_mass",
                               jnp.max(st.aux[..., kfactor.AUX_TRUNC]))
        if spec.needs_m and phi is not None:
            obs_metrics.record(f"bucket{bi}/inv_err",
                               self._inv_error_proxy(spec, st, phi))

    def _inv_error_proxy(self, spec, st, phi):
        """Streaming inversion-error proxy: worst-slot
        ‖((M + λI) X − I)[rows]‖_F / √k over k ≤ 8 strided rows, where
        X is the held inverse representation and λ is exactly the
        damping the preconditioner derives (NS: the baked-in λ̂ from
        aux; low-rank: φ·max D plus the §3.5 continuation shift).
        O(k·d·w) per bucket and only computed on heavy-firing steps of
        an instrumented run — never on the metrics-off path."""
        d = spec.d
        k = min(8, d)
        idx = jnp.arange(k) * max(1, d // k)
        Mrows = jnp.take(st.M, idx, axis=-2)                 # (B, k, d)
        ek = jnp.eye(d, dtype=Mrows.dtype)[idx]              # (k, d)
        if spec.mode is kfactor.Mode.NS:
            lam = st.aux[..., kfactor.AUX_LAM]
            Y = (Mrows + lam[..., None, None] * ek) @ st.U
        else:
            D, lam = precond._damped(st.D, phi,
                                     self.cfg.spectrum_continuation)
            Y = precond.apply_inv_right(
                Mrows + lam[..., None, None] * ek, st.U, D, lam)
        R = Y - ek
        return jnp.max(jnp.sqrt(jnp.sum(R * R, axis=(-2, -1)) / k))

    def _bucketed_precondition(self, factors, grads, acts, probe_grads,
                               phi):
        """Preconditioned steps for every tap, one batched (fused) launch
        per (A-spec, G-spec, apply-mode) bucket.  Returns {name: S} with S
        in the tap's (…, d_in, d_out) parameter layout.

        Everything is gathered and applied directly in *parameter layout*:
        the inverse factors are symmetric, so  Ā⁻¹ gW Γ̄⁻¹  (the two-sided
        application with the factor roles swapped) equals the transposed
        textbook form  (Γ̄⁻¹ gWᵀ Ā⁻¹)ᵀ  without ever transposing.  This
        matters: a transpose *feeding a concatenate* must materialize
        (unlike the per-tap path, where XLA fuses it into the matmul), and
        a bucket's J gather is tens of MB per step on real models.
        """
        cont = self.cfg.spectrum_continuation
        use_k = self.cfg.use_kernels
        out = {}
        for pbi, bucket in enumerate(self.precond_buckets):
            ent = bucket.entries
            # role swap: the positional "g" slot below carries the A factor
            # (and vice versa), so the NS dense flags swap with it
            dense_swap_g = bucket.spec_a.mode is kfactor.Mode.NS
            dense_swap_a = bucket.spec_g.mode is kfactor.Mode.NS
            key = lambda e: (e.name, "")
            U_g = buckets.gather(ent, {key(e): factors[e.name].G.U
                                       for e in ent})
            D_g = buckets.gather(ent, {key(e): factors[e.name].G.D
                                       for e in ent})
            U_a = buckets.gather(ent, {key(e): factors[e.name].A.U
                                       for e in ent})
            D_a = buckets.gather(ent, {key(e): factors[e.name].A.D
                                       for e in ent})
            with obs_trace.span(f"kfac/precond/b{pbi}"):
                if bucket.linear_apply:
                    # Alg 8 with roles swapped:  S = (Ā⁻¹ A)(Gᵀ Γ̄⁻¹) —
                    # the raw (…, n, d) factors concatenate contiguously
                    # and the single post-gather transpose fuses into
                    # the matmul.
                    gfac = jnp.swapaxes(buckets.gather(ent, {
                        key(e): probe_grads[e.name] for e in ent}),
                        -1, -2).astype(jnp.float32)      # (B, d_out, n)
                    afac = jnp.swapaxes(buckets.gather(ent, {
                        key(e): acts[e.name] for e in ent}),
                        -1, -2).astype(jnp.float32)      # (B, d_in, n)
                    S = precond.precondition_linear_with_damping(
                        afac, gfac, U_a, D_a, U_g, D_g, phi,
                        continuation=cont, use_kernel=use_k,
                        dense_g=dense_swap_g, dense_a=dense_swap_a)
                else:
                    J = buckets.gather(ent, {
                        key(e): get_path(grads,
                                         self.taps[e.name].param_path)
                        for e in ent}).astype(jnp.float32)
                    S = precond.precondition_with_damping(
                        J, U_a, D_a, U_g, D_g, phi,
                        continuation=cont, use_kernel=use_k,
                        dense_g=dense_swap_g, dense_a=dense_swap_a)
            out.update({name: Se for (name, _), Se
                        in buckets.scatter(ent, S).items()})
        return out

    # -- the update ---------------------------------------------------------
    def update(self, grads, state: KfacState, params, *, acts, probe_grads,
               n_tokens, rng, work: Optional[schedule.StepWork] = None,
               do_stats: Optional[bool] = None,
               do_light: Optional[bool] = None,
               do_heavy: Optional[bool] = None, landing=None,
               damping_scale=None):
        """One optimizer step.  ``work`` is a static, hashable StepWork
        mask (jit with ``static_argnames=("work",)``); the legacy three
        python bools are accepted as a shim and converted to the
        equivalent uniform (spiky) mask.  ``landing`` optionally carries
        pre-computed heavy results (bucket idx str → ((U, D, aux), …)
        per land range) from an overlapped dispatch; absent, landings
        compute in-graph from the in-flight snapshot.

        ``damping_scale`` (optional traced scalar) multiplies the
        scheduled damping ratio φ — the remediation ladder's stage-1
        escalation knob (train/health.py).  A scale of exactly 1.0 is
        bit-inert (float multiply by 1.0 is exact), which is what keeps
        the health-guarded step's healthy-run outputs identical to the
        unguarded step's."""
        cfg = self.cfg
        if work is None:
            from repro import specs as specs_lib
            specs_lib.warn_once(
                "Kfac.update:bools",
                "Kfac.update(do_stats=, do_light=, do_heavy=) is "
                "deprecated; pass work=Kfac.uniform_work(...) (a StepWork "
                "mask, jit static_argnames=('work',))")
            work = self.uniform_work(bool(do_stats), bool(do_light),
                                     bool(do_heavy))
        first = state.n_stats == 0
        phi = cfg.damping_phi(state.step)
        if damping_scale is not None:
            phi = phi * damping_scale
        lr = cfg.lr(state.step)
        if obs_metrics.active():
            slots = lambda t: float(sum(hi - lo for r in t
                                        for lo, hi in r))
            obs_metrics.record("work/stats_fired",
                               1.0 if work.stats else 0.0)
            obs_metrics.record("work/light_fired",
                               1.0 if work.light else 0.0)
            obs_metrics.record("work/heavy_slots", slots(work.heavy))
            obs_metrics.record("work/launch_slots", slots(work.launch))
            obs_metrics.record("work/land_slots", slots(work.land))
            obs_metrics.record("precond/damping_phi", phi)

        # 1) factor updates -------------------------------------------------
        factors = dict(state.factors)
        inflight = dict(state.inflight)
        if work.any and self.curvature is not None and cfg.bucketed:
            factors, inflight = self.curvature.factor_work(
                self, factors, inflight, acts, probe_grads, n_tokens, rng,
                first, work, landing=landing, phi=phi)
        elif work.any and cfg.bucketed:
            factors, inflight = self._bucketed_factor_work(
                factors, inflight, acts, probe_grads, n_tokens, rng,
                first, work, landing=landing, phi=phi)
        elif work.any:
            if work.any_async:
                raise ValueError("async launch/land masks require the "
                                 "bucketed optimizer path")
            keys = jax.random.split(rng, 2 * len(self.taps))
            for i, name in enumerate(sorted(self.taps)):
                X_A, X_G = self._stats_factors(name, acts, probe_grads,
                                               n_tokens)
                heavy = {side: work.entry_heavy(*self._slot[(name, side)])
                         for side in ("A", "G")}
                stA = self._factor_update(name, "A", factors[name].A, X_A,
                                          keys[2 * i], first, work.stats,
                                          work.light, heavy["A"])
                stG = self._factor_update(name, "G", factors[name].G, X_G,
                                          keys[2 * i + 1], first,
                                          work.stats, work.light,
                                          heavy["G"])
                factors[name] = TapState(A=stA, G=stG)

        # 2) preconditioned updates for tapped params -----------------------
        if cfg.bucketed:
            S_all = self._bucketed_precondition(factors, grads, acts,
                                                probe_grads, phi)
        else:
            S_all = {}
            for name, t in self.taps.items():
                gW = get_path(grads, t.param_path)
                gfac = afac = None
                if t.linear_apply:
                    a = acts[name]
                    g = probe_grads[name]
                    afac = jnp.swapaxes(a, -1, -2).astype(jnp.float32)
                    gfac = jnp.swapaxes(g, -1, -2).astype(jnp.float32)
                S_all[name] = self._precondition(name, factors[name], gW,
                                                 phi, g_factor=gfac,
                                                 a_factor=afac)
        updates = grads  # start from grads; overwrite tapped leaves
        new_mom = dict(state.momentum) if state.momentum is not None else None
        for name, t in self.taps.items():
            W = get_path(params, t.param_path)
            S = S_all[name] + cfg.weight_decay * W.astype(jnp.float32)
            if new_mom is not None:
                m = cfg.momentum * new_mom[name] + S
                new_mom[name] = m
                S = m
            updates = set_path(updates, t.param_path, S)

        # 3) clip + lr for tapped; AdamW for the rest ------------------------
        tapped_paths = {t.param_path for t in self.taps.values()}
        fb_updates, fb_state = self._fallback.update(grads, state.fallback,
                                                     params)

        def finalize(path_keys, kfac_u, fb_u):
            path = "/".join(str(k.key) for k in path_keys)
            if path in tapped_paths:
                return (-lr * kfac_u.astype(jnp.float32))
            return fb_u

        updates = jax.tree_util.tree_map_with_path(finalize, updates,
                                                   fb_updates)
        if cfg.clip > 0:
            updates = optbase.clip_by_global_norm(updates,
                                                  jnp.asarray(cfg.clip))

        new_state = KfacState(
            step=state.step + 1,
            n_stats=state.n_stats + jnp.asarray(work.stats, jnp.int32),
            phase=(state.phase + 1) % jnp.asarray(self._cycle, jnp.int32),
            factors=factors,
            momentum=new_mom,
            fallback=fb_state,
            inflight=inflight,
        )
        return updates, new_state

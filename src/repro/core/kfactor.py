"""EA K-factor state and its update modes — the heart of the paper.

A K-factor is the exponential average  M_k = ρ M_{k-1} + (1-ρ) X_k X_kᵀ
(paper eq. 5/8).  Every optimizer variant in the paper is a choice of how the
*inverse representation* of M is maintained:

  mode        holds M?   update of (U, D)                         paper
  ----------  ---------  ---------------------------------------  -------
  EVD         yes        dense eigh of M every T_inv              K-FAC
  RSVD        yes        rsvd_psd(M) every T_inv                  R-KFAC
  BRAND       no         ea_brand_step every T_brand              B-KFAC
  BRAND_RSVD  yes        Brand every T_brand + RSVD overwrite     B-R-KFAC
                         every T_rsvd
  BRAND_CORR  yes        Brand every T_brand + light correction   B-KFAC-C
                         (Alg 6) every T_corct
  NS          yes        Newton–Schulz refinement of the held     NS-KFAC
                         dense inverse every T_inv (matmul-only)  (§iter.)

The state is a pytree with static shapes so it can live inside a jitted,
sharded train step and be vmapped across scan-stacked layers / experts.
``width`` (the number of held modes) is r + n_stat for Brand-family modes,
d for NS (U holds the dense refined inverse) and r for RSVD/EVD modes —
always static.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import brand, rsvd
from repro.obs import trace as obs_trace

Array = jax.Array


class Mode(enum.Enum):
    EVD = "evd"                # K-FAC baseline
    RSVD = "rsvd"              # R-KFAC (RS-KFAC of [3])
    BRAND = "brand"            # B-KFAC  (pure; low-memory, M never formed)
    BRAND_RSVD = "brand_rsvd"  # B-R-KFAC
    BRAND_CORR = "brand_corr"  # B-KFAC-C
    NS = "ns"                  # NS-KFAC (Newton–Schulz inverse refinement)


# Modes that must materialize the dense d×d EA factor.
_NEEDS_M = {Mode.EVD, Mode.RSVD, Mode.BRAND_RSVD, Mode.BRAND_CORR, Mode.NS}
# Modes that run the Brand online update.
_HAS_BRAND = {Mode.BRAND, Mode.BRAND_RSVD, Mode.BRAND_CORR}


#: Channels of :attr:`KFactorState.aux` — per-slot heavy-op diagnostics.
#: Purely observational: nothing in the optimizer math ever reads them
#: (NS bakes λ̂ into U; the low-rank apply derives λ from D), so zeroing
#: aux changes no update.  They exist so telemetry (repro.obs) and tests
#: can watch inverse health without smuggling scalars through D.
AUX_LAM = 0     # NS: λ̂ = ns_phi·λ_max(M) used at the last refresh
AUX_RES = 1     # NS: final Frobenius residual ‖I − M̂X‖_F (≥ _NS_RES_MAX
                # flags that the dense-solve fallback fired)
AUX_TRUNC = 2   # EVD/RSVD overwrites: truncated spectral-mass fraction
                # max(0, tr M − Σ retained D) / tr M
AUX_WIDTH = 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KFactorState:
    """Inverse representation of one EA K-factor.

    U: (d, width) column-orthonormal basis; D: (width,) descending eigvals
    (NS: U is the dense damped inverse and D is all-zero).
    M: (d, d) dense EA factor or a (1, 1) placeholder for pure-Brand.
    aux: (AUX_WIDTH,) heavy-op diagnostics (see the AUX_* channels above);
    never read by the update math.
    """
    U: Array
    D: Array
    M: Array
    aux: Array


def make_state(d: int, width: int, needs_m: bool, dtype=jnp.float32
               ) -> KFactorState:
    m_shape = (d, d) if needs_m else (1, 1)
    return KFactorState(
        U=jnp.zeros((d, width), dtype),
        D=jnp.zeros((width,), dtype),
        M=jnp.zeros(m_shape, dtype),
        aux=jnp.zeros((AUX_WIDTH,), dtype),
    )


@dataclasses.dataclass(frozen=True)
class KFactorSpec:
    """Static description of one K-factor's update policy."""
    d: int                      # side of the factor
    r: int                      # truncation / target rank
    n_stat: int                 # incoming factor columns per stats step
    mode: Mode
    rho: float = 0.95
    r_o: int = 10               # RSVD oversampling
    n_pwr_iter: int = 2
    n_crc: int = 0              # correction subspace size (BRAND_CORR)
    ns_iters: int = 8           # Newton–Schulz steps per heavy firing (NS)
    ns_phi: float = 0.1         # NS damping ratio λ̂ = ns_phi·λ_max(M)
    ns_guard: float = 0.9       # warm-start guard: ‖I − M̂X₀‖₂ must sit below

    @property
    def width(self) -> int:
        if self.mode is Mode.NS:
            return self.d       # U holds the dense refined inverse
        if self.mode in _HAS_BRAND:
            return min(self.r + self.n_stat, self.d)
        return min(self.r, self.d)

    @property
    def needs_m(self) -> bool:
        return self.mode in _NEEDS_M

    def init(self, dtype=jnp.float32) -> KFactorState:
        return make_state(self.d, self.width, self.needs_m, dtype)


# ---------------------------------------------------------------------------
# individual update operations (all pure; X is (d, n_stat))
# ---------------------------------------------------------------------------

def ea_update_m(M: Array, X: Array, rho: float, first: Array) -> Array:
    """M ← ρ M + (1-ρ) X Xᵀ  (κ(0)=1 on the first-ever update, eq. 5).
    Stacked-native: M (*stack, d, d), X (*stack, d, n)."""
    upd = X @ jnp.swapaxes(X, -1, -2)
    coef = jnp.where(first, 1.0, 1.0 - rho)
    keep = jnp.where(first, 0.0, rho)
    return keep * M + coef * upd


def ea_update_m_kernel(M: Array, X: Array, rho: float, first: Array) -> Array:
    """Same as ea_update_m but routed through the Pallas EA-SYRK kernel when
    shapes are tile-friendly (ops.py pads/falls back otherwise).  Stacked
    inputs run as one batched launch over the flattened stack."""
    from repro.kernels import ops as kops
    return kops.ea_syrk(M, X, rho, first)


def ea_update_m_rows(M_rows: Array, X: Array, r0, rb: int, rho: float,
                     first: Array) -> Array:
    """Row block [r0, r0+rb) of the EA absorb — *exact*, not approximate:
    every element of X Xᵀ is an independent full-length dot product (no
    reduction is split), so the row slice of :func:`ea_update_m` equals the
    update of the row slice.  This is what lets the 2D-mesh curvature
    engine keep the dense M row-sharded through stats steps and only
    gather it transiently when a heavy op needs the full matrix.

    M_rows: (*stack, rb, d) local row block; X: (*stack, d, n) — full,
    every row-shard holds the whole incoming panel (it is O(d·n), the
    cheap side); ``r0`` may be traced (e.g. ``axis_index * rb``), ``rb``
    is static.  Coefficients mirror ``kernels.ref.ea_syrk`` exactly."""
    X_rows = jax.lax.dynamic_slice_in_dim(X, r0, rb, axis=X.ndim - 2)
    rho = jnp.asarray(rho, M_rows.dtype)
    firstf = jnp.asarray(first, M_rows.dtype)
    keep = rho * (1.0 - firstf)
    coef = 1.0 - keep
    upd = (X_rows @ jnp.swapaxes(X, -1, -2)).astype(M_rows.dtype)
    return keep * M_rows + coef * upd


def brand_step(spec: KFactorSpec, st: KFactorState, X: Array, first: Array,
               use_kernel: bool = False) -> KFactorState:
    """B-update (Alg 4): truncate to r then symmetric Brand with the EA term.

    Stacked-native: st/X may carry leading stack axes (``first`` is the
    global scalar flag) — a whole bucket of Brand factors steps as one
    batched panel + CholeskyQR2 + eigh.  ``use_kernel`` routes the O(d)
    panel and QR through Pallas (see ``brand.sym_brand_update``).

    On the first-ever stats batch the state is empty — initialize from the
    factor directly (exact, low-memory)."""
    def _init(_):
        U0, D0 = brand.init_from_factor(X, spec.width)
        return KFactorState(U=U0, D=D0, M=st.M, aux=st.aux)

    def _update(_):
        U, D = brand.ea_brand_step(st.U, st.D, X, spec.rho, spec.r,
                                   use_kernel=use_kernel)
        if U.shape[-1] > spec.width:  # r + n_stat exceeded d: re-truncate
            U, D = U[..., :, :spec.width], D[..., :spec.width]
        return KFactorState(U=U, D=D, M=st.M, aux=st.aux)

    return jax.lax.cond(first, _init, _update, operand=None)


def _trunc_mass_aux(aux: Array, M: Array, D: Array) -> Array:
    """AUX_TRUNC ← truncated spectral-mass fraction of an overwrite:
    max(0, tr M − Σ retained D) / tr M — the paper's accuracy knob (rank
    truncation) made observable.  Diagnostic only; never read back."""
    tr = jnp.trace(M, axis1=-2, axis2=-1)
    kept = jnp.sum(D, axis=-1)
    frac = jnp.maximum(tr - kept, 0.0) / jnp.maximum(tr, 1e-30)
    return aux.at[..., AUX_TRUNC].set(frac.astype(aux.dtype))


def rsvd_overwrite(spec: KFactorSpec, st: KFactorState, key: Array
                   ) -> KFactorState:
    """RSVD of the dense EA factor → overwrite the low-rank state
    (R-KFAC inverse update / B-R-KFAC overwrite)."""
    U, D = rsvd.rsvd_psd(st.M, spec.r, spec.r_o, key, spec.n_pwr_iter,
                         pad_to=spec.width)
    return KFactorState(U=U, D=D, M=st.M,
                        aux=_trunc_mass_aux(st.aux, st.M, D))


def evd_overwrite(spec: KFactorSpec, st: KFactorState) -> KFactorState:
    """Dense EVD of the EA factor (K-FAC baseline inverse update)."""
    U, D = rsvd.exact_evd(st.M, r=spec.width, pad_to=spec.width)
    return KFactorState(U=U, D=D, M=st.M,
                        aux=_trunc_mass_aux(st.aux, st.M, D))


def light_correction(spec: KFactorSpec, st: KFactorState, key: Array
                     ) -> KFactorState:
    """Alg 6: re-solve the eigenproblem of M in a random n_crc-column
    subspace of U and patch those columns/eigenvalues in place.

    Correction reads the *dense* M (needs_m mode).  Columns are chosen among
    the first r (the post-truncation basis), uniformly without replacement —
    the paper argues random beats top-modes (§3.4).
    """
    n_crc = spec.n_crc
    idx = jax.random.choice(key, spec.r, shape=(n_crc,), replace=False)
    Usub = st.U[:, idx]                               # (d, n_crc)
    Ms = Usub.T @ (st.M @ Usub)                       # (n_crc, n_crc)
    Ms = 0.5 * (Ms + Ms.T)
    vals, vecs = jnp.linalg.eigh(Ms)
    vals, vecs = vals[::-1], vecs[:, ::-1]
    U_new = st.U.at[:, idx].set(Usub @ vecs)
    D_new = st.D.at[idx].set(vals)
    return KFactorState(U=U_new, D=D_new, M=st.M, aux=st.aux)


_NS_PWR_ITERS = 12   # power-iteration steps for the λ_max(M) prescale
_NS_RES_MAX = 0.5    # Frobenius residual past which a slot falls back


def _ns_sym(x: Array) -> Array:
    return 0.5 * (x + jnp.swapaxes(x, -1, -2))


def _ns_lmax(M: Array) -> Array:
    """λ_max estimate of a symmetric psd M (*stack, d, d) → (*stack,) by
    deterministic power iteration (matmul-only; Rayleigh quotient).  The
    deterministic all-ones start keeps the heavy firing key-free and
    reproducible across replicated/sharded runs; an adversarial M exactly
    orthogonal to it would underestimate, which the residual fallback in
    ``ns_overwrite`` catches."""
    d = M.shape[-1]
    v0 = jnp.full(M.shape[:-1] + (1,), 1.0 / jnp.sqrt(d), M.dtype)

    def body(_, v):
        w = M @ v
        nrm = jnp.sqrt(jnp.sum(w * w, axis=(-2, -1), keepdims=True))
        return w / jnp.maximum(nrm, 1e-30)

    # rolled loop (not unrolled python): the iteration body is traced
    # once, keeping the heavy firing's XLA graph — and compile time —
    # independent of the iteration count
    v = jax.lax.fori_loop(0, _NS_PWR_ITERS, body, v0)
    return jnp.sum(v * (M @ v), axis=(-2, -1))


def _ns_resnorm(R: Array, iters: int = 8) -> Array:
    """Spectral-norm estimate ‖R‖₂ of (*stack, d, d) → (*stack,) by power
    iteration on RᵀR (matmul-only)."""
    d = R.shape[-1]
    Rt = jnp.swapaxes(R, -1, -2)
    v0 = jnp.full(R.shape[:-1] + (1,), 1.0 / jnp.sqrt(d), R.dtype)

    def body(_, v):
        w = Rt @ (R @ v)
        nrm = jnp.sqrt(jnp.sum(w * w, axis=(-2, -1), keepdims=True))
        return w / jnp.maximum(nrm, 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    w = R @ v
    return jnp.sqrt(jnp.sum(w * w, axis=(-2, -1)))


def ns_overwrite(spec: KFactorSpec, st: KFactorState) -> KFactorState:
    """Newton–Schulz heavy refresh (Mode.NS): refine X ≈ M̂⁻¹ = (M + λ̂I)⁻¹
    with ``spec.ns_iters`` Hotelling steps X ← X(2I − M̂X) — pure GEMMs via
    ``kops.ns_step``, no eigh/qr/svd anywhere in the firing.

    Prescale and warm start (the convergence safeguard, part 1):
      * λ̂ = ns_phi · λ_max(M) from a matmul-only power iteration, so
        κ(M̂) ≤ (1 + ns_phi)/ns_phi regardless of M's conditioning;
      * warm start from the stale inverse held in U when its estimated
        residual ‖I − M̂ U‖₂ clears ``ns_guard``; otherwise cold-start from
        α·I with α = 2/(λ_max + 2λ̂), which puts the eigenvalues of αM̂ in
        (0, 2) and the initial residual at ≈ (κ−1)/(κ+1) < 1.  Either way
        the quadratic contraction r ← r² converges well within K = 8 at
        the default ns_phi = 0.1.

    Divergence fallback (part 2): if any slot's final Frobenius residual
    ‖I − M̂X‖_F fails to clear ``_NS_RES_MAX`` (NaN/Inf included — the
    comparison is written to catch them), a dense LU solve replaces that
    slot (``jnp.linalg.inv`` — still factorization-of-last-resort only, and
    still eigh/qr/svd-free).  The solve sits under ``lax.cond`` so healthy
    steps never pay for it, and a per-slot ``where`` inside keeps converged
    slots' NS results bit-identical whether or not a sibling slot diverged
    (preserving replicated ≡ sharded parity).

    Stacked-native over arbitrary leading axes; deterministic (key-free).
    The damping λ̂ is baked into the refreshed inverse — U is the inverse
    of the *damped* factor, refreshed with the spec's own ns_phi — so D
    is left all-zero (no spectrum to report) and the diagnostics go to
    their first-class channels: aux[..., AUX_LAM] = λ̂ and
    aux[..., AUX_RES] = the final Frobenius residual (≥ _NS_RES_MAX
    flags that the fallback fired).
    """
    from repro.kernels import ops as kops

    d = spec.d
    M = _ns_sym(st.M)
    lmax = jnp.maximum(_ns_lmax(M), 1e-12)
    lam = spec.ns_phi * lmax                               # (*stack,)
    eye = jnp.eye(d, dtype=M.dtype)
    Mhat = M + lam[..., None, None] * eye
    alpha = 2.0 / (lmax + 2.0 * lam)
    X_cold = alpha[..., None, None] * eye
    X_warm = _ns_sym(st.U)
    r_warm = _ns_resnorm(eye - Mhat @ X_warm)
    use_warm = r_warm < spec.ns_guard                      # NaN-safe: False
    X = jnp.where(use_warm[..., None, None], X_warm, X_cold)
    X = jax.lax.fori_loop(0, spec.ns_iters,
                          lambda _, x: kops.ns_step(Mhat, x), X)
    R = eye - Mhat @ X
    res = jnp.sqrt(jnp.sum(R * R, axis=(-2, -1)))
    bad = ~(res < _NS_RES_MAX)                             # NaN/Inf → True

    def _fallback(x):
        dense = jnp.linalg.inv(Mhat)                       # LU, no eigh/qr/svd
        return jnp.where(bad[..., None, None], dense, x)

    X = jax.lax.cond(jnp.any(bad), _fallback, lambda x: x, X)
    aux = st.aux.at[..., AUX_LAM].set(lam.astype(st.aux.dtype))
    aux = aux.at[..., AUX_RES].set(res.astype(st.aux.dtype))
    return KFactorState(U=X.astype(st.U.dtype),
                        D=jnp.zeros(st.D.shape, st.D.dtype), M=st.M,
                        aux=aux)


# ---------------------------------------------------------------------------
# fused per-step transition: stats step + (scheduled) inverse-rep step
# ---------------------------------------------------------------------------

def has_heavy_op(spec: KFactorSpec) -> bool:
    """True iff the mode has a periodic heavy op (EVD / RSVD overwrite /
    correction / NS refinement) — pure BRAND maintains its inverse rep with
    light work only, so the scheduler never assigns it a heavy phase."""
    return spec.mode in (Mode.EVD, Mode.RSVD, Mode.BRAND_RSVD,
                         Mode.BRAND_CORR, Mode.NS)


def has_work(spec: KFactorSpec, do_stats: bool, do_light: bool,
             do_heavy: bool) -> bool:
    """True iff this step's static flags actually touch the factor state.

    Lets the bucketed optimizer skip whole no-op buckets (e.g. a pure-Brand
    bucket on a stats-only step) instead of gathering, running identity
    branches, and scattering — the per-tap unrolled graph gets the same
    elision from XLA dead-code elimination, so skipping preserves parity.
    """
    if do_stats and spec.needs_m:
        return True
    if (do_light or do_heavy) and spec.mode in _HAS_BRAND:
        return True
    if do_heavy and has_heavy_op(spec):
        return True
    return False


def stats_step(spec: KFactorSpec, st: KFactorState, X: Array, first: Array
               ) -> KFactorState:
    """Absorb one incoming stats factor X into the EA (dense M if held).

    Stacked-native: st/X may carry leading stack axes — the EA absorb for a
    whole stack of factors is one batched kernel launch."""
    if spec.needs_m:
        M = ea_update_m_kernel(st.M, X, spec.rho, first)
        return KFactorState(U=st.U, D=st.D, M=M, aux=st.aux)
    return st


def inverse_rep_step(spec: KFactorSpec, st: KFactorState, X: Array,
                     key: Array, first: Array, heavy: Array,
                     use_kernel: bool = False) -> KFactorState:
    """Scheduled inverse-representation update (one 2-D factor).

    ``heavy`` selects the periodic heavy op for the mode (RSVD overwrite /
    EVD / correction); the light op is the Brand update (Brand modes) or a
    no-op (EVD/RSVD modes, matching the paper's T_inv > T_updt regime).
    """
    if spec.mode is Mode.EVD:
        return jax.lax.cond(heavy, lambda s: evd_overwrite(spec, s),
                            lambda s: s, st)
    if spec.mode is Mode.RSVD:
        return jax.lax.cond(heavy, lambda s: rsvd_overwrite(spec, s, key),
                            lambda s: s, st)
    if spec.mode is Mode.NS:
        return jax.lax.cond(heavy, lambda s: ns_overwrite(spec, s),
                            lambda s: s, st)
    if spec.mode is Mode.BRAND:
        return brand_step(spec, st, X, first, use_kernel)
    if spec.mode is Mode.BRAND_RSVD:
        st = brand_step(spec, st, X, first, use_kernel)
        return jax.lax.cond(heavy, lambda s: rsvd_overwrite(spec, s, key),
                            lambda s: s, st)
    if spec.mode is Mode.BRAND_CORR:
        st = brand_step(spec, st, X, first, use_kernel)
        return jax.lax.cond(heavy, lambda s: light_correction(spec, s, key),
                            lambda s: s, st)
    raise ValueError(spec.mode)


def heavy_overwrite_batched(spec: KFactorSpec, st: KFactorState,
                            keys: Array) -> KFactorState:
    """Unconditional heavy op over one flat batch axis (B, …): dense EVD /
    RSVD overwrite / Alg-6 correction, vmapped so the whole (sub-)bucket
    is one launch group.  The caller decides *whether* (and on *which
    slots*) this fires — scheduling is static, so no ``lax.cond`` wrapper
    ever enters the graph on steps (or slots) that skip heavy work."""
    if spec.mode is Mode.EVD:
        return jax.vmap(lambda s: evd_overwrite(spec, s))(st)
    if spec.mode is Mode.NS:
        # stacked-native (and its batched GEMMs must stay one launch, not a
        # vmap of launches); the divergence fallback is bucket-level cond +
        # per-slot where, which a vmap would defeat
        return ns_overwrite(spec, st)
    if spec.mode in (Mode.RSVD, Mode.BRAND_RSVD):
        return jax.vmap(lambda s, k: rsvd_overwrite(spec, s, k))(st, keys)
    if spec.mode is Mode.BRAND_CORR:
        return jax.vmap(lambda s, k: light_correction(spec, s, k))(st, keys)
    return st


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class InflightState:
    """Double buffer for one bucket's async heavy pipeline.

    At a *launch* step the live factor state of the firing slots is
    snapshotted here (post-stats, post-Brand — exactly what the inline
    heavy op would have read); at the *land* step, ``lag`` steps later,
    the heavy overwrite computed from the snapshot is swapped into the
    live state with the interim Brand panels replayed on top.  All
    leaves are slot-major (leading bucket batch axis) so the distributed
    curvature engine shards them with the same per-slot round-robin plan
    as the live state.

    U/D/M/keys: (B, d, w) / (B, w) / (B, d, d) / (B, 2) snapshots.
    panels: (B, n_replay, d, n_stat) ring of the last ``n_replay`` light
    panels (oldest first); ``n_replay = lag // T_brand`` is static and
    zero for non-Brand modes or ``lag < T_brand``.
    live: (B,) per-slot validity — set at launch, cleared at land.  A
    landing only swaps slots whose snapshot is live, so a launch that
    was dropped (straggler back-off) or never happened (fresh resume at
    an off-cycle phase) makes its scheduled landing a per-slot no-op
    instead of swapping in a zero or one-cycle-stale snapshot: the
    pipeline event simply defers to the next cycle.
    """
    U: Array
    D: Array
    M: Array
    keys: Array
    panels: Array
    live: Array


def make_inflight(spec: KFactorSpec, total: int, n_replay: int,
                  dtype=jnp.float32) -> InflightState:
    """Zero-initialized in-flight buffer for a bucket of ``total`` slots."""
    return InflightState(
        U=jnp.zeros((total, spec.d, spec.width), dtype),
        D=jnp.zeros((total, spec.width), dtype),
        M=jnp.zeros((total,) + ((spec.d, spec.d) if spec.needs_m
                                else (1, 1)), dtype),
        keys=jnp.zeros((total, 2), jnp.uint32),
        panels=jnp.zeros((total, n_replay, spec.d, spec.n_stat), dtype),
        live=jnp.zeros((total,), jnp.bool_),
    )


def record_panel(buf: InflightState, X: Array) -> InflightState:
    """Shift the light-panel ring left and append this step's panel."""
    if buf.panels.shape[1] == 0:
        return buf
    panels = jnp.concatenate([buf.panels[:, 1:], X[:, None]], axis=1)
    return dataclasses.replace(buf, panels=panels)


def launch_snapshot(buf: InflightState, st: KFactorState, keys: Array,
                    lo: int, hi: int) -> InflightState:
    """Snapshot the live state (and this step's per-slot keys) of slots
    [lo, hi) into the buffer — the operands of the future heavy op."""
    return InflightState(
        U=buf.U.at[lo:hi].set(st.U[lo:hi]),
        D=buf.D.at[lo:hi].set(st.D[lo:hi]),
        M=buf.M.at[lo:hi].set(st.M[lo:hi]),
        keys=buf.keys.at[lo:hi].set(keys[lo:hi]),
        panels=buf.panels,
        live=buf.live.at[lo:hi].set(True),
    )


def heavy_from_snapshot(spec: KFactorSpec, buf: InflightState,
                        lo: int, hi: int) -> Tuple[Array, Array, Array]:
    """The heavy overwrite, computed from the snapshot of slots [lo, hi)
    — a pure function of the buffer, so it can equally run in-graph at
    the land step or as a separately-dispatched program launched right
    after the snapshot (train.loop.AsyncInverseRunner).  Returns the
    landed (U, D, aux) triple; the snapshot's aux is synthesized as
    zeros — no heavy op reads it (it is write-only diagnostics), so the
    in-flight buffer does not carry an aux leaf."""
    snap = KFactorState(U=buf.U[lo:hi], D=buf.D[lo:hi], M=buf.M[lo:hi],
                        aux=jnp.zeros((hi - lo, AUX_WIDTH), buf.D.dtype))
    out = heavy_overwrite_batched(spec, snap, buf.keys[lo:hi])
    return out.U, out.D, out.aux


def replay_panels(spec: KFactorSpec, U: Array, D: Array, panels: Array,
                  use_kernel: bool = False) -> Tuple[Array, Array]:
    """Replay the interim light panels (oldest first) onto an incoming
    inverse rep — the landed state then carries every Brand absorb the
    live state received while the heavy op was in flight."""
    for j in range(panels.shape[1]):
        U, D = brand.ea_brand_step(U, D, panels[:, j], spec.rho, spec.r,
                                   use_kernel=use_kernel)
        if U.shape[-1] > spec.width:
            U, D = U[..., :, :spec.width], D[..., :spec.width]
    return U, D


def land_swap(spec: KFactorSpec, st: KFactorState, buf: InflightState,
              lo: int, hi: int, use_kernel: bool = False,
              landed=None) -> Tuple[KFactorState, InflightState]:
    """Swap the landed inverse rep of slots [lo, hi) into the live state
    atomically.  ``landed`` is an optionally pre-computed (U, D, aux)
    triple from an overlapped dispatch; when absent the heavy op runs
    in-graph from the snapshot (same function, same operands, same
    result).

    Only slots whose snapshot is ``live`` swap (and the flag is consumed
    here): a dropped or never-fired launch turns its landing into a
    per-slot no-op rather than installing a zero / stale snapshot."""
    if landed is None:
        U, D, aux = heavy_from_snapshot(spec, buf, lo, hi)
    else:
        U, D, aux = landed
    if spec.mode in _HAS_BRAND:
        U, D = replay_panels(spec, U, D, buf.panels[lo:hi], use_kernel)
    ok = buf.live[lo:hi]
    U = jnp.where(ok[:, None, None], U, st.U[lo:hi])
    D = jnp.where(ok[:, None], D, st.D[lo:hi])
    aux = jnp.where(ok[:, None], aux, st.aux[lo:hi])
    st = KFactorState(U=st.U.at[lo:hi].set(U),
                      D=st.D.at[lo:hi].set(D), M=st.M,
                      aux=st.aux.at[lo:hi].set(aux))
    buf = dataclasses.replace(buf, live=buf.live.at[lo:hi].set(False))
    return st, buf


def bucket_factor_step(spec: KFactorSpec, st: KFactorState, X: Array,
                       keys: Array, first: Array, stats: bool, light: bool,
                       heavy_ranges, use_kernel: bool = False
                       ) -> KFactorState:
    """One scheduled step for a whole shape-class bucket: st/X carry one
    flat batch axis (B, …); ``keys`` is (B, 2).  This is THE per-bucket
    program — the replicated bucketed optimizer, the per-tap comparison
    path (B = one tap's stack) and the sharded curvature engine (B = the
    device-local slot shard) all run it, so flag plumbing exists once.

    ``heavy_ranges`` is a static tuple of slot ranges (lo, hi) whose heavy
    overwrite fires this step (the work scheduler's staggering unit); the
    Brand light update runs bucket-wide whenever the step is light OR any
    heavy fires (heavy steps re-absorb the incoming panel — the seed's
    coupling, preserved; the scheduler snaps Brand-family phases to
    multiples of T_brand so staggering never adds extra Brand firings).
    """
    if stats:
        with obs_trace.span("stats"):
            st = stats_step(spec, st, X, first)
    heavy_ranges = tuple(heavy_ranges)
    if (light or heavy_ranges) and spec.mode in _HAS_BRAND:
        with obs_trace.span("light_brand"):
            st = brand_step(spec, st, X, first, use_kernel)
    for lo, hi in heavy_ranges:
        with obs_trace.span(f"heavy_{lo}_{hi}"):
            sub = jax.tree_util.tree_map(lambda x: x[lo:hi], st)
            sub = heavy_overwrite_batched(spec, sub, keys[lo:hi])
            st = jax.tree_util.tree_map(
                lambda full, part: full.at[lo:hi].set(part), st, sub)
    return st


def bucket_factor_step_async(spec: KFactorSpec, st: KFactorState, X: Array,
                             keys: Array, first: Array, stats: bool,
                             light: bool, heavy_ranges, launch_ranges,
                             land_ranges, buf: Optional[InflightState],
                             use_kernel: bool = False, landed=None
                             ) -> Tuple[KFactorState,
                                        Optional[InflightState]]:
    """One scheduled step of the async double-buffered pipeline for a
    whole bucket: the synchronous program (stats / Brand / any inline
    heavy — e.g. the step-0 warmup) runs first, then this step's pipeline
    phases, in an order that makes ``lag=0`` bit-for-bit the synchronous
    path:

      1. record this step's light panel into the replay ring,
      2. *launch*: snapshot the post-stats/post-Brand state of the
         firing slots (plus their per-slot keys) into the buffer,
      3. *land*: swap the heavy result computed from the (possibly
         ``lag``-steps-old) snapshot into the live state, interim panels
         replayed on top.  With ``lag=0`` step 3 reads the snapshot step
         2 just wrote — the same operands the inline heavy op consumes.

    ``landed`` optionally supplies pre-computed (U, D) pairs, one per
    land range, from an overlapped dispatch (AsyncInverseRunner).
    """
    st = bucket_factor_step(spec, st, X, keys, first, stats, light,
                            heavy_ranges, use_kernel)
    if buf is None:
        return st, None
    if light:
        buf = record_panel(buf, X)
    for lo, hi in tuple(launch_ranges):
        with obs_trace.span(f"launch_{lo}_{hi}"):
            buf = launch_snapshot(buf, st, keys, lo, hi)
    for i, (lo, hi) in enumerate(tuple(land_ranges)):
        with obs_trace.span(f"land_{lo}_{hi}"):
            st, buf = land_swap(spec, st, buf, lo, hi, use_kernel,
                                landed=None if landed is None
                                else landed[i])
    return st, buf


# ---------------------------------------------------------------------------
# reconstruction helpers (testing / error metrics)
# ---------------------------------------------------------------------------

def reconstruct(st: KFactorState) -> Array:
    """Dense matrix represented by the low-rank state (tests only)."""
    return (st.U * st.D) @ st.U.T


def exact_ea(Xs, rho: float) -> Array:
    """Ground-truth EA K-factor from a list of stats factors (tests only)."""
    M = Xs[0] @ Xs[0].T
    for X in Xs[1:]:
        M = rho * M + (1 - rho) * (X @ X.T)
    return M

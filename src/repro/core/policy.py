"""Per-layer K-factor method selection — the paper's §3.5 "mixture of
Randomized K-FACs and Brand New K-FACs" elevated to a policy engine.

Rules (paper §3.5 + §5):
  * the B-update only pays off when  d > r + n_stat  (wide layers);
  * the dense EA factor can only be *formed* when d ≤ max_dense_dim
    (memory gate — e.g. a 262k-vocab factor would need 275 GB);
  * modes that require M (EVD / RSVD / B-R overwrite / correction)
    therefore degrade to pure BRAND above the memory gate — this is the
    paper's "B-KFAC is a low-memory K-FAC" property;
  * below the B-threshold the factor is small: use the variant's dense-ish
    mode (EVD for kfac, RSVD otherwise).
"""
from __future__ import annotations

import dataclasses

from repro.core.kfactor import KFactorSpec, Mode

#: optimizer variant → preferred mode for (wide, narrow) factors
_VARIANT_MODES = {
    "kfac":   (Mode.EVD, Mode.EVD),
    "rkfac":  (Mode.RSVD, Mode.RSVD),
    "bkfac":  (Mode.BRAND, Mode.RSVD),
    "brkfac": (Mode.BRAND_RSVD, Mode.RSVD),
    "bkfacc": (Mode.BRAND_CORR, Mode.RSVD),
    "nskfac": (Mode.NS, Mode.NS),
}

VARIANTS = tuple(_VARIANT_MODES)

#: variant → which KfacConfig period drives its heavy (inverse-overwrite)
#: work, and whether the variant runs the Brand light update at all.  The
#: scheduler (core/schedule.py) and KfacConfig.flags both read THIS table,
#: so the per-variant period can never be shadowed by branch ordering —
#: there is exactly one period per variant, declared next to the modes it
#: schedules (paper §2.2/§6: T_inv for K-FAC/R-KFAC, T_rsvd for the
#: B-R-KFAC overwrite, T_corct for the B-KFAC-C correction; pure B-KFAC
#: has no heavy op).
_VARIANT_HEAVY_PERIOD = {
    "kfac":   "T_inv",
    "rkfac":  "T_inv",
    "bkfac":  None,
    "brkfac": "T_rsvd",
    "bkfacc": "T_corct",
    "nskfac": "T_inv",
}


def _check_variant(variant: str) -> None:
    if variant not in _VARIANT_MODES:
        raise ValueError(f"unknown K-FAC variant {variant!r}; "
                         f"one of {VARIANTS}")


def heavy_period_field(variant: str):
    """Name of the KfacConfig field holding the variant's heavy period
    (``None`` for pure B-KFAC, which has no heavy op)."""
    _check_variant(variant)
    return _VARIANT_HEAVY_PERIOD[variant]


def has_light(variant: str) -> bool:
    """True iff the variant runs the Brand light update (B-family)."""
    _check_variant(variant)
    wide_mode, _ = _VARIANT_MODES[variant]
    return wide_mode in (Mode.BRAND, Mode.BRAND_RSVD, Mode.BRAND_CORR)


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    variant: str = "bkfac"
    r: int = 256                 # truncation / target rank
    r_o: int = 10                # RSVD oversampling
    n_pwr_iter: int = 2
    rho: float = 0.95
    phi_crc: float = 0.5         # n_crc = phi_crc * r  (B-KFAC-C)
    max_dense_dim: int = 8192    # memory gate for forming the d×d factor
    ns_iters: int = 8            # NS-KFAC: Newton–Schulz steps per firing
    ns_phi: float = 0.1          # NS-KFAC: λ̂ = ns_phi·λ_max(M)
    ns_guard: float = 0.9        # NS-KFAC: warm-start residual guard


def select_mode(cfg: PolicyConfig, d: int, n_stat: int) -> Mode:
    """Pick the factor's update mode.  Boundary semantics (load-bearing
    for bucket membership AND for the work scheduler, which phases heavy
    work per mode-keyed bucket):

      * ``d > r + n_stat`` strictly → the B-update applies; at exact
        equality the Brand step has no arithmetic advantage, so the
        narrow mode wins;
      * ``d > max_dense_dim`` strictly → M cannot be formed; at exact
        equality the dense factor is still allowed;
      * ``d ≤ r + r_o`` → EVD override, applied LAST: a factor this
        small is exact and cheapest under dense EVD even when the
        memory gate just degraded it (its M is tiny by construction).
        NS is exempt — its whole point is an eigh-free heavy path, and
        at tiny d the K GEMM steps are as cheap as anything else.
    """
    _check_variant(cfg.variant)
    wide_mode, narrow_mode = _VARIANT_MODES[cfg.variant]
    r = min(cfg.r, d)
    b_applicable = d > r + n_stat          # paper's applicability condition
    mode = wide_mode if b_applicable else narrow_mode
    # memory gate: cannot form M → must be pure Brand (low-memory property).
    # NS holds M *and* a dense inverse (2·d² floats), so it degrades at the
    # same gate.
    if d > cfg.max_dense_dim and mode in (Mode.EVD, Mode.RSVD,
                                          Mode.BRAND_RSVD, Mode.BRAND_CORR,
                                          Mode.NS):
        mode = Mode.BRAND
    # tiny factors: EVD is exact and cheapest of all (except for NS, which
    # must stay factorization-free)
    if d <= r + cfg.r_o and mode is not Mode.NS:
        mode = Mode.EVD
    return mode


def make_factor_spec(cfg: PolicyConfig, d: int, n_stat: int) -> KFactorSpec:
    mode = select_mode(cfg, d, n_stat)
    r = min(cfg.r, d)
    n_crc = max(1, int(cfg.phi_crc * r)) if mode == Mode.BRAND_CORR else 0
    return KFactorSpec(d=d, r=r, n_stat=n_stat, mode=mode, rho=cfg.rho,
                       r_o=cfg.r_o, n_pwr_iter=cfg.n_pwr_iter, n_crc=n_crc,
                       ns_iters=cfg.ns_iters, ns_phi=cfg.ns_phi,
                       ns_guard=cfg.ns_guard)

"""TenantBank: N independent per-tenant K-FAC optimizer states in ONE
stacked pytree.

The multi-tenant fine-tuning service (serve/service.py) holds one adapter
+ optimizer state per tenant.  Running them as N separate ``Kfac.update``
calls would cost N× the launch count; but every tenant shares the model
architecture, so their K-factors share the same shape classes — and the
same cross-layer bucketing argument that made per-step launches
O(#shape-classes) instead of O(#layers) (core/buckets.py, PR 2) applies
across tenants.  ``TenantBank`` stacks every ``KfacState`` leaf on a
leading tenant axis and runs ``jax.vmap(Kfac.update)`` over it: the
bucketed stats/light/heavy/precond kernels each appear ONCE in the
program with an extra batch dimension, so the launch-group count stays
O(#shape-classes), not O(#tenants) (asserted by counting decomposition
call sites in the jaxpr — benchmarks/serve_bench.py).

Semantics:

* Per-tenant independence: each tenant's slice of the bank evolves
  exactly as its own ``Kfac`` run would — N-tenant stacked ≡ N
  sequential independent runs (allclose; batched ops may reassociate),
  asserted for all 6 policy variants in tests/test_tenant.py.
* N=1 is **bit-for-bit** the plain optimizer: a single-tenant bank
  squeezes the tenant axis and calls ``Kfac.update`` directly — same
  program, same bits.
* Per-tenant step/phase: ``KfacState.step``/``n_stats``/``phase`` are
  scalars per tenant, so the stacked bank carries an (N,) vector of each
  — tenants admitted at different times keep their own schedule
  positions.  The service groups tenants by their scheduler-derived
  :class:`~repro.core.schedule.StepWork` mask
  (:func:`repro.core.schedule.group_by_work`) and issues one stacked
  update per distinct mask with an ``active`` vector: inactive tenants'
  state and params are carried through **unchanged bitwise**
  (``jnp.where`` on the tenant axis selects the old leaves exactly).

The async launch/land pipeline is not threaded through the bank —
tenant fine-tune ticks use sync masks (heavy work is already amortized
across tenants by construction).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.core import schedule
from repro.optim import base as optbase

Array = jax.Array

tree_map = jax.tree_util.tree_map


def _lead_dim(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("empty pytree has no tenant axis")
    return int(leaves[0].shape[0])


def _bcast(mask: Array, leaf: Array) -> Array:
    """(N,) mask reshaped to broadcast against an (N, ...) leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def tree_stack(trees: Sequence[Any]) -> Any:
    """N per-tenant pytrees → one pytree with a leading tenant axis."""
    return tree_map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree: Any, n: Optional[int] = None) -> list:
    """Inverse of :func:`tree_stack`."""
    n = _lead_dim(tree) if n is None else n
    return [tree_map(lambda x: x[i], tree) for i in range(n)]


def tree_select(mask: Array, new: Any, old: Any) -> Any:
    """Per-tenant select: mask (N,) bool picks ``new``'s slice where True,
    ``old``'s where False — bit-exact on both sides (jnp.where copies)."""
    return tree_map(lambda a, b: jnp.where(_bcast(mask, a), a, b), new, old)


def tree_insert(bank_tree: Any, i, one: Any) -> Any:
    """Write one tenant's (unstacked) pytree into slot ``i`` of the bank
    (functional: returns the updated bank tree).  ``i`` may be traced."""
    return tree_map(lambda b, x: b.at[i].set(x.astype(b.dtype)),
                    bank_tree, one)


def tree_slot(bank_tree: Any, i) -> Any:
    """Read one tenant's pytree out of slot ``i`` (leading axis dropped)."""
    return tree_map(lambda b: b[i], bank_tree)


class TenantBank:
    """N stacked, independent optimizer states over one shared ``Kfac``.

    The bank does not own tenant bookkeeping (admission, naming, request
    queues — that is serve/service.py); it owns the stacked-state math:

      ``init(stacked_params)``        → stacked KfacState (vmap of init)
      ``update(grads, state, params, ..., rngs, work, active=None)``
                                      → (stacked updates, stacked state)
      ``apply_updates(params, updates, active=None)``
                                      → stacked params, inactive slots
                                        carried through bit-exactly
    """

    def __init__(self, opt: kfac_lib.Kfac):
        self.opt = opt

    # -- construction -------------------------------------------------------

    def init(self, stacked_params) -> kfac_lib.KfacState:
        """Stacked state from stacked params (leading tenant axis)."""
        n = _lead_dim(stacked_params)
        if n == 1:
            one = self.opt.init(tree_slot(stacked_params, 0))
            return tree_map(lambda x: x[None], one)
        return jax.vmap(self.opt.init)(stacked_params)

    @staticmethod
    def n_tenants(stacked_state: kfac_lib.KfacState) -> int:
        return int(stacked_state.step.shape[0])

    # -- the stacked update -------------------------------------------------

    def update(self, grads, state: kfac_lib.KfacState, params, *, acts,
               probe_grads, n_tokens, rngs, work: schedule.StepWork,
               active: Optional[Array] = None, damping_scale=None):
        """One stacked optimizer step over the tenant axis.

        Every array argument carries a leading tenant axis N (``rngs`` is
        an (N, 2) key batch — independent streams per tenant); ``work``
        is ONE static mask shared by the whole call (group tenants by
        mask first — :func:`repro.core.schedule.group_by_work`);
        ``active`` is an optional (N,) bool vector: inactive tenants
        still ride the batched launches (vmap is dense) but their state
        output is the input selected back bit-exactly, and
        :meth:`apply_updates` drops their param delta the same way.
        ``damping_scale`` may be an (N,) per-tenant vector.

        N=1 with no mask bypasses vmap entirely and is bit-for-bit the
        plain ``Kfac.update`` (tests/test_tenant.py)."""
        n = _lead_dim(grads)
        if n == 1 and active is None:
            sq = lambda t: tree_slot(t, 0)
            scale = None if damping_scale is None \
                else jnp.asarray(damping_scale).reshape(-1)[0]
            updates, new_state = self.opt.update(
                sq(grads), sq(state), sq(params), acts=sq(acts),
                probe_grads=sq(probe_grads), n_tokens=n_tokens,
                rng=rngs[0], work=work, damping_scale=scale)
            ex = lambda t: tree_map(lambda x: x[None], t)
            return ex(updates), ex(new_state)

        def one(g, s, p, a, pg, key, scale):
            return self.opt.update(g, s, p, acts=a, probe_grads=pg,
                                   n_tokens=n_tokens, rng=key, work=work,
                                   damping_scale=scale)

        if damping_scale is None:
            scales = jnp.ones((n,), jnp.float32)
        else:
            scales = jnp.broadcast_to(
                jnp.asarray(damping_scale, jnp.float32), (n,))
        updates, new_state = jax.vmap(one)(grads, state, params, acts,
                                           probe_grads, rngs, scales)
        if active is not None:
            mask = jnp.asarray(active, bool)
            new_state = tree_select(mask, new_state, state)
            updates = tree_map(
                lambda u: jnp.where(_bcast(mask, u), u,
                                    jnp.zeros_like(u)), updates)
        return updates, new_state

    @staticmethod
    def apply_updates(params, updates, active: Optional[Array] = None):
        """Stacked ``optbase.apply_updates``; with ``active``, inactive
        tenants' params pass through bit-exactly (selected, not +0)."""
        new = optbase.apply_updates(params, updates)
        if active is None:
            return new
        return tree_select(jnp.asarray(active, bool), new, params)

    # -- per-tenant access --------------------------------------------------

    def checkout(self, state: kfac_lib.KfacState, i) -> kfac_lib.KfacState:
        """One tenant's un-stacked KfacState (checkpointing a single
        tenant, or migrating it to a plain ``Kfac`` run)."""
        return tree_slot(state, i)

    def checkin(self, state: kfac_lib.KfacState, i,
                one: kfac_lib.KfacState) -> kfac_lib.KfacState:
        """Write a plain per-tenant KfacState back into slot ``i``."""
        return tree_insert(state, i, one)

    def admit(self, state: kfac_lib.KfacState, i, params_i
              ) -> kfac_lib.KfacState:
        """(Re)initialize slot ``i`` from that tenant's params — a fresh
        admission into a pre-allocated bank slot."""
        return self.checkin(state, i, self.opt.init(params_i))

    def steps(self, state: kfac_lib.KfacState) -> Array:
        """(N,) per-tenant step counters (host-side schedule lookups)."""
        return state.step

    def launch_groups(self) -> int:
        """Static launch-group count of one stacked step — by
        construction independent of N (the O(#shape-classes) claim)."""
        return len(self.opt.factor_buckets) + len(self.opt.precond_buckets)

"""The K-FAC work scheduler: static per-step work masks, with optional
*staggering* of the heavy inverse recomputations.

The paper's amortization argument (heavy EVD/RSVD overwrites every
``T_inv`` steps, cheap Brand updates in between) holds for the *mean*
cost per step, but the seed scheduling — one global ``do_heavy`` bool,
true on every ``k % T == 0`` — concentrates all heavy work of all layers
on the same step.  At production scale that is a replicated latency
spike: p99 step time equals the spike height, and on a synchronous mesh
every device waits for it.

This module replaces the three global bools with a :class:`StepWork`
mask: ``stats``/``light`` stay global (they are cheap and their operands
arrive every step anyway), while heavy work is described *per factor
bucket* as a tuple of static slot ranges.  The :class:`Scheduler` assigns
each schedulable unit (a bucket, or an entry-aligned chunk of one) a
phase offset spread uniformly over the heavy period, so

  * every factor still receives a heavy update exactly every ``T`` steps
    (the per-factor cadence — what the paper's error analysis depends
    on — is preserved; only the phase differs), and
  * the expected heavy cost per step drops from
    ``(all buckets, every T-th step)`` to ``#units / T`` units per step —
    a constant small cost instead of a spike.

Everything here is *static* python: a ``StepWork`` is hashable and is
meant to be passed through ``jax.jit(..., static_argnames=("work",))``,
so each distinct mask compiles to a lean HLO exactly like the seed's
three-bool step variants.  Over a full schedule cycle there are at most
``#units + O(1)`` distinct masks (units fire one phase slot at a time),
so the compile count stays bounded and small.

Phase snapping: for Brand-family buckets the inverse-rep step couples
the light Brand update to heavy firings (a heavy step re-absorbs the
incoming panel).  When ``T_brand`` divides the heavy period (the paper's
regime — 25 | 250/500), their phases are snapped to multiples of
``T_brand``, so heavy only fires on steps that are already light steps
and the Brand cadence is untouched.  When it does not divide, *no* phase
keeps every firing on a light step (phase + m·T drifts mod T_brand —
the unstaggered schedule has the same coupling at phase 0), so such
buckets are pinned to phase 0: staggered and legacy schedules then fire
identical Brand absorbs.  EVD/RSVD buckets have no light work and phase
freely.

Async launch/land (``cfg.async_heavy``): the paper's whole premise is
that the EA construction tolerates slightly-stale inverse estimates, so
heavy overwrites need not run inline at their scheduled step at all.
With ``heavy_lag = L > 0`` each unit's heavy firing becomes a two-phase
pipeline event: at its phase the factor state is *snapshotted* into the
in-flight buffer (``StepWork.launch``), and ``L`` steps later the heavy
result — computed from that snapshot, interim Brand panels replayed on
top — is swapped into the live state (``StepWork.land``).  ``lag=0``
degenerates to launch+land on the same step, which is numerically
identical to the synchronous path (the exactness contract, asserted in
tests and in the ``step/async_vs_sync`` bench row).  Per-factor landing
cadence is still exactly ``T`` — only shifted by the constant ``L``.

Two async invariants keep every mask static:

  * ``L < T`` (one in-flight event per unit: the buffer is a single
    snapshot, not a queue);
  * a Brand-family bucket pipelines only when ``T_brand | T`` (phases
    snapped): launch steps are then ≡ 0 (mod T_brand), so the number of
    interim light panels to replay at landing is the *constant*
    ``L // T_brand``.  When ``T_brand ∤ T`` the interim-panel count
    would vary per firing, so such buckets stay synchronous (inline
    heavy at phase 0, exactly the legacy coupling).

The step-0 warmup stays synchronous in async mode: EVD/RSVD states must
be populated from the very first stats batch (an empty factor has no
spectrum to damp), so step 0 fires every unit inline and the pipeline
takes over from each unit's first regular phase.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.core import policy as policy_lib

Ranges = Tuple[Tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class StepWork:
    """Static work mask for one optimizer step.

    ``heavy`` holds, for each factor bucket (in ``Kfac.factor_buckets``
    order), the slot ranges ``(lo, hi)`` of the bucket batch whose heavy
    overwrite fires *inline* this step.  ``launch``/``land`` are the
    async pipeline's two phases in the same per-bucket-ranges layout:
    ``launch`` snapshots those slots' factor state into the in-flight
    buffer, ``land`` swaps the heavy result computed from the snapshot
    (plus replayed interim Brand panels) into the live state.  They
    default empty so the synchronous masks are unchanged pytrees.
    Hashable → usable as a jit static arg.
    """
    stats: bool
    light: bool
    heavy: Tuple[Ranges, ...]
    launch: Tuple[Ranges, ...] = ()
    land: Tuple[Ranges, ...] = ()

    @property
    def any_heavy(self) -> bool:
        return any(self.heavy)

    @property
    def any_async(self) -> bool:
        return any(self.launch) or any(self.land)

    @property
    def any(self) -> bool:
        return self.stats or self.light or self.any_heavy or self.any_async

    @property
    def label(self) -> str:
        """One-word phase class for telemetry (step events group their
        timing by it): the heaviest work class this step runs."""
        if self.any_heavy or any(self.land):
            return "heavy"
        if any(self.launch):
            return "launch"
        if self.light:
            return "light"
        if self.stats:
            return "stats"
        return "idle"

    def summary(self) -> Dict[str, int]:
        """Flat JSON-able view of the mask (the ``sched`` event body)."""
        slots = lambda t: sum(hi - lo for r in t for lo, hi in r)
        return {"stats": int(self.stats), "light": int(self.light),
                "heavy_slots": slots(self.heavy),
                "launch_slots": slots(self.launch),
                "land_slots": slots(self.land)}

    def entry_heavy(self, bucket_idx: int, offset: int, count: int) -> bool:
        """True iff any firing range overlaps slot range [offset,
        offset+count) — the per-tap (unbatched) path's heavy flag for one
        bucket entry.  Scheduler chunks are entry-aligned, so overlap is
        always all-or-nothing and the two paths agree exactly."""
        return any(lo < offset + count and hi > offset
                   for lo, hi in self.heavy[bucket_idx])


def _empty(factor_buckets) -> Tuple[Ranges, ...]:
    return tuple(() for _ in factor_buckets)


def uniform_work(do_stats: bool, do_light: bool, do_heavy: bool,
                 factor_buckets) -> StepWork:
    """The legacy three-bool step as a StepWork: heavy fires for every
    bucket in full, or for none — the seed's spiky schedule."""
    heavy = tuple((((0, b.total),) if do_heavy else ())
                  for b in factor_buckets)
    return StepWork(stats=bool(do_stats), light=bool(do_light), heavy=heavy,
                    launch=_empty(factor_buckets),
                    land=_empty(factor_buckets))


def no_work(factor_buckets) -> StepWork:
    """An all-skip step (straggler back-off)."""
    return StepWork(stats=False, light=False,
                    heavy=_empty(factor_buckets),
                    launch=_empty(factor_buckets),
                    land=_empty(factor_buckets))


def remedial_work(cfg, factor_buckets) -> StepWork:
    """An out-of-cadence *forced heavy refresh* — the remediation
    ladder's stage-2 mask (train/health.py): every bucket with a heavy
    op overwrites its full slot range inline, with a stats absorb (and,
    for Brand-family variants, a light absorb) exactly like the step-0
    warmup, so the inverse rep is re-established from the live M this
    very step.  Launch/land stay empty: the poisoned in-flight pipeline
    is abandoned (the caller clears the snapshots' ``live`` flags via
    ``Kfac.clear_inflight``, so any still-scheduled landing degrades to
    a per-slot no-op instead of swapping stale state back in).  Safe
    out of cadence by the paper's Props 4.1/4.2 — a *fresher* inverse
    can only help — and composes with staggering and the async pipeline
    because it is just one more static mask; the scheduler's own
    cadence continues unchanged afterwards.  For pure-Brand buckets
    (no heavy op, e.g. bkfac) the refresh degenerates to the stats +
    light re-absorb, which is all the inverse rep those modes have.
    """
    from repro.core import kfactor
    heavy = tuple((((0, b.total),) if kfactor.has_heavy_op(b.spec) else ())
                  for b in factor_buckets)
    return StepWork(stats=True,
                    light=policy_lib.has_light(cfg.policy.variant),
                    heavy=heavy,
                    launch=_empty(factor_buckets),
                    land=_empty(factor_buckets))


def legacy_flags(cfg, step: int) -> Dict[str, bool]:
    """The seed's ``KfacConfig.flags`` semantics, driven by the variant
    table in ``core/policy.py`` — one period per variant, by declaration,
    so T_rsvd/T_corct (or any future period) cannot shadow each other."""
    variant = cfg.policy.variant
    period_field = policy_lib.heavy_period_field(variant)
    do_light = (policy_lib.has_light(variant)
                and step % cfg.T_brand == 0)
    do_heavy = (period_field is not None
                and step % getattr(cfg, period_field) == 0)
    return dict(do_stats=step % cfg.T_updt == 0, do_light=do_light,
                do_heavy=do_heavy)


@dataclasses.dataclass(frozen=True)
class Unit:
    """One schedulable chunk of heavy work: entry-aligned slot range
    [lo, hi) of factor bucket ``bucket``, firing at steps
    ``k ≡ phase (mod T)``.  ``sync_only`` marks units that must run
    their heavy op inline even under an async schedule (Brand-family
    buckets whose light period does not divide the heavy period — see
    module docstring)."""
    bucket: int
    lo: int
    hi: int
    phase: int
    sync_only: bool = False


def bucket_is_async(cfg, spec) -> bool:
    """True iff a factor bucket with this spec pipelines its heavy work
    under ``cfg.async_heavy`` (needs an in-flight buffer).  Brand-family
    buckets pipeline only when ``T_brand`` divides the variant's heavy
    period — otherwise the interim-panel count would not be static."""
    from repro.core import kfactor
    if not getattr(cfg, "async_heavy", False):
        return False
    if not kfactor.has_heavy_op(spec):
        return False
    period_field = policy_lib.heavy_period_field(cfg.policy.variant)
    if period_field is None:
        return False
    T = int(getattr(cfg, period_field))
    if (policy_lib.has_light(cfg.policy.variant)
            and spec.mode in kfactor._HAS_BRAND):
        return T % cfg.T_brand == 0
    return True


def n_replay_panels(cfg, spec) -> int:
    """Static count of interim Brand panels replayed at a landing: the
    light steps in ``(launch, launch + lag]``.  Launch phases of async
    Brand-family buckets are snapped to multiples of ``T_brand``, so the
    count is exactly ``lag // T_brand`` — zero for non-Brand modes and
    for the common ``lag < T_brand`` regime."""
    from repro.core import kfactor
    if not bucket_is_async(cfg, spec):
        return 0
    if spec.mode not in kfactor._HAS_BRAND:
        return 0
    return int(getattr(cfg, "heavy_lag", 0)) // cfg.T_brand


def _chunk_boundaries(bucket, align: int) -> Tuple[int, ...]:
    """Admissible chunk boundaries inside a bucket: entry offsets that are
    multiples of ``align`` (plus the bucket ends).  Entry alignment keeps
    the per-tap and bucketed paths exactly equivalent under any mask;
    ``align`` (= curvature-mesh size when sharded) keeps a chunk's slots
    an equal static slice on every device under the round-robin
    slot→device assignment."""
    bounds = {0, bucket.total}
    for e in bucket.entries:
        if e.offset % align == 0:
            bounds.add(e.offset)
    return tuple(sorted(bounds))


def _split_ranges(bucket, splits: int, align: int) -> Tuple[Tuple[int, int],
                                                            ...]:
    """Split a bucket into ≤ ``splits`` chunks at admissible boundaries,
    as evenly as slot counts allow (best-effort; collapses gracefully to
    one chunk when no interior boundary is admissible)."""
    bounds = _chunk_boundaries(bucket, align)
    n = min(max(1, splits), len(bounds) - 1)
    # pick n-1 interior boundaries closest to the even split points
    chosen = [0]
    interior = list(bounds[1:-1])
    for i in range(1, n):
        target = round(i * bucket.total / n)
        if not interior:
            break
        best = min(interior, key=lambda b: abs(b - target))
        if best > chosen[-1]:
            chosen.append(best)
            interior = [b for b in interior if b > best]
    chosen.append(bucket.total)
    return tuple((lo, hi) for lo, hi in zip(chosen, chosen[1:]) if hi > lo)


class Scheduler:
    """Maps a step index to a :class:`StepWork` mask.

    ``stagger=False`` reproduces :func:`legacy_flags` exactly (all units
    share phase 0).  ``stagger=True`` spreads unit phases uniformly over
    the heavy period; ``warmup=True`` (default) additionally fires every
    unit on step 0 so EVD/RSVD states are populated from the first stats
    batch, exactly as in the spiky schedule — after that, each unit's
    firings are exactly ``phase, phase+T, phase+2T, …``.

    ``async_heavy``/``lag`` turn each heavy firing into a launch/land
    pipeline event: a unit launches at ``phase + iT`` (``i ≥ 1`` — i.e.
    every regular firing step; the step-0 warmup stays inline) and lands
    at ``phase + iT + lag``.  ``lag=0`` launches and lands on the same
    step (numerically identical to inline); ``sync_only`` units keep
    firing inline at their phase.
    """

    def __init__(self, cfg, factor_buckets, *, splits: Optional[int] = None,
                 align: int = 1, stagger: Optional[bool] = None,
                 warmup: bool = True, async_heavy: Optional[bool] = None,
                 lag: Optional[int] = None):
        self.cfg = cfg
        self.buckets = tuple(factor_buckets)
        self.stagger = cfg.stagger if stagger is None else stagger
        self.warmup = warmup
        variant = cfg.policy.variant
        self.has_light = policy_lib.has_light(variant)
        period_field = policy_lib.heavy_period_field(variant)
        self.T_heavy = (None if period_field is None
                        else int(getattr(cfg, period_field)))
        self.async_heavy = (bool(getattr(cfg, "async_heavy", False))
                            if async_heavy is None else async_heavy)
        self.lag = (int(getattr(cfg, "heavy_lag", 0))
                    if lag is None else int(lag))
        if self.T_heavy is None:
            self.async_heavy = False
        if self.async_heavy:
            if not (0 <= self.lag < self.T_heavy):
                raise ValueError(
                    f"heavy_lag={self.lag} must satisfy 0 <= lag < "
                    f"T_heavy={self.T_heavy} (one in-flight snapshot "
                    f"per unit)")
        splits = cfg.stagger_splits if splits is None else splits
        self.units: Tuple[Unit, ...] = self._assign_phases(splits, align)

    # -- phase assignment --------------------------------------------------
    def _assign_phases(self, splits: int, align: int) -> Tuple[Unit, ...]:
        T = self.T_heavy
        from repro.core import kfactor   # local: avoid import at module top
        if T is None:
            # Pure-Brand variants have no periodic heavy, but shape
            # classes the policy demoted to dense modes (EVD/NS — dims
            # too small for a low-rank Brand representation) populate
            # their (U, D) ONLY through a heavy overwrite.  Give each a
            # warmup-only unit (fires once at step 0, see work()) or its
            # spectrum stays empty forever and every preconditioned
            # update drowns in the 1/λ_eps off-span term.
            return tuple(Unit(bucket=bi, lo=0, hi=b.total, phase=0,
                              sync_only=True)
                         for bi, b in enumerate(self.buckets)
                         if kfactor.has_heavy_op(b.spec))
        chunks = []                      # (bucket_idx, lo, hi, snap)
        for bi, b in enumerate(self.buckets):
            if not kfactor.has_heavy_op(b.spec):
                continue                 # mode has no heavy op (pure BRAND)
            snap = 1
            if self.has_light and b.spec.mode in kfactor._HAS_BRAND:
                # a heavy firing re-absorbs the Brand panel, so every
                # firing step of a Brand-family unit must already be a
                # light step: with T_brand | T, any phase that is a
                # multiple of T_brand works; otherwise NO phase keeps all
                # firings on light steps (phase + m·T drifts mod T_brand
                # — true for the unstaggered schedule too), so pin the
                # bucket to phase 0 and stagger it not at all rather than
                # add Brand absorbs the legacy schedule never fired.
                if T % self.cfg.T_brand == 0:
                    snap = self.cfg.T_brand
                else:
                    snap = 0             # sentinel: force phase 0
            for lo, hi in _split_ranges(b, splits if self.stagger else 1,
                                        align):
                chunks.append((bi, lo, hi, snap))
        n_units = len(chunks)
        units = []
        for i, (bi, lo, hi, snap) in enumerate(chunks):
            if not self.stagger or snap == 0:
                phase = 0
            else:
                raw = (i * T) // max(n_units, 1)
                phase = (raw // snap) * snap % T
            sync_only = (self.async_heavy and
                         not bucket_is_async(self.cfg,
                                             self.buckets[bi].spec))
            units.append(Unit(bucket=bi, lo=lo, hi=hi, phase=phase,
                              sync_only=sync_only))
        return tuple(units)

    @property
    def cycle(self) -> int:
        """Length of the full schedule cycle (distinct-mask period)."""
        c = self.cfg.T_updt
        if self.has_light:
            c = math.lcm(c, self.cfg.T_brand)
        if self.T_heavy is not None:
            c = math.lcm(c, self.T_heavy)
        return c

    def work(self, step: int) -> StepWork:
        stats = step % self.cfg.T_updt == 0
        light = self.has_light and step % self.cfg.T_brand == 0
        heavy = [[] for _ in self.buckets]
        launch = [[] for _ in self.buckets]
        land = [[] for _ in self.buckets]
        if self.T_heavy is None:
            # warmup-only units (demoted dense buckets under a pure-Brand
            # variant): one inline heavy at step 0, never again
            if self.warmup and step == 0:
                for u in self.units:
                    heavy[u.bucket].append((u.lo, u.hi))
        else:
            T, L = self.T_heavy, self.lag
            for u in self.units:
                fires = step % T == u.phase
                warm = self.warmup and step == 0
                if not self.async_heavy or u.sync_only:
                    if fires or warm:
                        heavy[u.bucket].append((u.lo, u.hi))
                    continue
                # async: warmup stays inline; regular firings pipeline
                if warm:
                    heavy[u.bucket].append((u.lo, u.hi))
                if fires and step > 0:
                    launch[u.bucket].append((u.lo, u.hi))
                if step - L > 0 and (step - L) % T == u.phase:
                    land[u.bucket].append((u.lo, u.hi))
        return StepWork(stats=stats, light=light,
                        heavy=tuple(_merge(r) for r in heavy),
                        launch=tuple(_merge(r) for r in launch),
                        land=tuple(_merge(r) for r in land))

    def flags(self, step: int) -> Dict[str, bool]:
        """Legacy three-bool view of this schedule (un-staggered)."""
        return legacy_flags(self.cfg, step)

    def describe(self) -> str:
        parts = [f"T_heavy={self.T_heavy} stagger={self.stagger} "
                 f"async={self.async_heavy} lag={self.lag} "
                 f"units={len(self.units)}"]
        for u in self.units:
            sync = " sync" if u.sync_only else ""
            parts.append(f"[b{u.bucket} {u.lo}:{u.hi} @{u.phase}{sync}]")
        return " ".join(parts)


def _merge(ranges: Sequence[Tuple[int, int]]) -> Ranges:
    """Sort and merge adjacent/overlapping ranges."""
    out: list = []
    for lo, hi in sorted(ranges):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return tuple(out)


def group_by_work(sched: "Scheduler", steps: Sequence[int]
                  ) -> Dict[StepWork, Tuple[int, ...]]:
    """Group per-tenant schedule positions by their StepWork mask.

    ``steps[i]`` is tenant i's current step counter; the result maps each
    distinct mask to the tuple of tenant indices that would execute it —
    StepWork is hashable precisely so it can key this dict.  The
    multi-tenant service issues one stacked ``TenantBank.update`` per
    entry (with the indices as the ``active`` vector), so a tick costs
    O(#distinct masks) stacked launches, and over a full schedule cycle
    the number of distinct masks is bounded by the scheduler's own
    variant count — independent of the number of tenants."""
    groups: Dict[StepWork, list] = {}
    for i, k in enumerate(steps):
        groups.setdefault(sched.work(int(k)), []).append(i)
    return {w: tuple(ix) for w, ix in groups.items()}

"""The K-FAC work scheduler: static per-step work masks, with optional
*staggering* of the heavy inverse recomputations.

The paper's amortization argument (heavy EVD/RSVD overwrites every
``T_inv`` steps, cheap Brand updates in between) holds for the *mean*
cost per step, but the seed scheduling — one global ``do_heavy`` bool,
true on every ``k % T == 0`` — concentrates all heavy work of all layers
on the same step.  At production scale that is a replicated latency
spike: p99 step time equals the spike height, and on a synchronous mesh
every device waits for it.

This module replaces the three global bools with a :class:`StepWork`
mask: ``stats``/``light`` stay global (they are cheap and their operands
arrive every step anyway), while heavy work is described *per factor
bucket* as a tuple of static slot ranges.  The :class:`Scheduler` assigns
each schedulable unit (a bucket, or an entry-aligned chunk of one) a
phase offset spread uniformly over the heavy period, so

  * every factor still receives a heavy update exactly every ``T`` steps
    (the per-factor cadence — what the paper's error analysis depends
    on — is preserved; only the phase differs), and
  * the expected heavy cost per step drops from
    ``(all buckets, every T-th step)`` to ``#units / T`` units per step —
    a constant small cost instead of a spike.

Everything here is *static* python: a ``StepWork`` is hashable and is
meant to be passed through ``jax.jit(..., static_argnames=("work",))``,
so each distinct mask compiles to a lean HLO exactly like the seed's
three-bool step variants.  Over a full schedule cycle there are at most
``#units + O(1)`` distinct masks (units fire one phase slot at a time),
so the compile count stays bounded and small.

Phase snapping: for Brand-family buckets the inverse-rep step couples
the light Brand update to heavy firings (a heavy step re-absorbs the
incoming panel).  When ``T_brand`` divides the heavy period (the paper's
regime — 25 | 250/500), their phases are snapped to multiples of
``T_brand``, so heavy only fires on steps that are already light steps
and the Brand cadence is untouched.  When it does not divide, *no* phase
keeps every firing on a light step (phase + m·T drifts mod T_brand —
the unstaggered schedule has the same coupling at phase 0), so such
buckets are pinned to phase 0: staggered and legacy schedules then fire
identical Brand absorbs.  EVD/RSVD buckets have no light work and phase
freely.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.core import policy as policy_lib

Ranges = Tuple[Tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class StepWork:
    """Static work mask for one optimizer step.

    ``heavy`` holds, for each factor bucket (in ``Kfac.factor_buckets``
    order), the slot ranges ``(lo, hi)`` of the bucket batch whose heavy
    overwrite fires this step.  Hashable → usable as a jit static arg.
    """
    stats: bool
    light: bool
    heavy: Tuple[Ranges, ...]

    @property
    def any_heavy(self) -> bool:
        return any(self.heavy)

    @property
    def any(self) -> bool:
        return self.stats or self.light or self.any_heavy

    def entry_heavy(self, bucket_idx: int, offset: int, count: int) -> bool:
        """True iff any firing range overlaps slot range [offset,
        offset+count) — the per-tap (unbatched) path's heavy flag for one
        bucket entry.  Scheduler chunks are entry-aligned, so overlap is
        always all-or-nothing and the two paths agree exactly."""
        return any(lo < offset + count and hi > offset
                   for lo, hi in self.heavy[bucket_idx])


def uniform_work(do_stats: bool, do_light: bool, do_heavy: bool,
                 factor_buckets) -> StepWork:
    """The legacy three-bool step as a StepWork: heavy fires for every
    bucket in full, or for none — the seed's spiky schedule."""
    heavy = tuple((((0, b.total),) if do_heavy else ())
                  for b in factor_buckets)
    return StepWork(stats=bool(do_stats), light=bool(do_light), heavy=heavy)


def no_work(factor_buckets) -> StepWork:
    """An all-skip step (straggler back-off)."""
    return StepWork(stats=False, light=False,
                    heavy=tuple(() for _ in factor_buckets))


def legacy_flags(cfg, step: int) -> Dict[str, bool]:
    """The seed's ``KfacConfig.flags`` semantics, driven by the variant
    table in ``core/policy.py`` — one period per variant, by declaration,
    so T_rsvd/T_corct (or any future period) cannot shadow each other."""
    variant = cfg.policy.variant
    period_field = policy_lib.heavy_period_field(variant)
    do_light = (policy_lib.has_light(variant)
                and step % cfg.T_brand == 0)
    do_heavy = (period_field is not None
                and step % getattr(cfg, period_field) == 0)
    return dict(do_stats=step % cfg.T_updt == 0, do_light=do_light,
                do_heavy=do_heavy)


@dataclasses.dataclass(frozen=True)
class Unit:
    """One schedulable chunk of heavy work: entry-aligned slot range
    [lo, hi) of factor bucket ``bucket``, firing at steps
    ``k ≡ phase (mod T)``."""
    bucket: int
    lo: int
    hi: int
    phase: int


def _chunk_boundaries(bucket, align: int) -> Tuple[int, ...]:
    """Admissible chunk boundaries inside a bucket: entry offsets that are
    multiples of ``align`` (plus the bucket ends).  Entry alignment keeps
    the per-tap and bucketed paths exactly equivalent under any mask;
    ``align`` (= curvature-mesh size when sharded) keeps a chunk's slots
    an equal static slice on every device under the round-robin
    slot→device assignment."""
    bounds = {0, bucket.total}
    for e in bucket.entries:
        if e.offset % align == 0:
            bounds.add(e.offset)
    return tuple(sorted(bounds))


def _split_ranges(bucket, splits: int, align: int) -> Tuple[Tuple[int, int],
                                                            ...]:
    """Split a bucket into ≤ ``splits`` chunks at admissible boundaries,
    as evenly as slot counts allow (best-effort; collapses gracefully to
    one chunk when no interior boundary is admissible)."""
    bounds = _chunk_boundaries(bucket, align)
    n = min(max(1, splits), len(bounds) - 1)
    # pick n-1 interior boundaries closest to the even split points
    chosen = [0]
    interior = list(bounds[1:-1])
    for i in range(1, n):
        target = round(i * bucket.total / n)
        if not interior:
            break
        best = min(interior, key=lambda b: abs(b - target))
        if best > chosen[-1]:
            chosen.append(best)
            interior = [b for b in interior if b > best]
    chosen.append(bucket.total)
    return tuple((lo, hi) for lo, hi in zip(chosen, chosen[1:]) if hi > lo)


class Scheduler:
    """Maps a step index to a :class:`StepWork` mask.

    ``stagger=False`` reproduces :func:`legacy_flags` exactly (all units
    share phase 0).  ``stagger=True`` spreads unit phases uniformly over
    the heavy period; ``warmup=True`` (default) additionally fires every
    unit on step 0 so EVD/RSVD states are populated from the first stats
    batch, exactly as in the spiky schedule — after that, each unit's
    firings are exactly ``phase, phase+T, phase+2T, …``.
    """

    def __init__(self, cfg, factor_buckets, *, splits: Optional[int] = None,
                 align: int = 1, stagger: Optional[bool] = None,
                 warmup: bool = True):
        self.cfg = cfg
        self.buckets = tuple(factor_buckets)
        self.stagger = cfg.stagger if stagger is None else stagger
        self.warmup = warmup
        variant = cfg.policy.variant
        self.has_light = policy_lib.has_light(variant)
        period_field = policy_lib.heavy_period_field(variant)
        self.T_heavy = (None if period_field is None
                        else int(getattr(cfg, period_field)))
        splits = cfg.stagger_splits if splits is None else splits
        self.units: Tuple[Unit, ...] = self._assign_phases(splits, align)

    # -- phase assignment --------------------------------------------------
    def _assign_phases(self, splits: int, align: int) -> Tuple[Unit, ...]:
        T = self.T_heavy
        if T is None:
            return ()
        chunks = []                      # (bucket_idx, lo, hi, snap)
        from repro.core import kfactor   # local: avoid import at module top
        for bi, b in enumerate(self.buckets):
            if not kfactor.has_heavy_op(b.spec):
                continue                 # mode has no heavy op (pure BRAND)
            snap = 1
            if self.has_light and b.spec.mode in kfactor._HAS_BRAND:
                # a heavy firing re-absorbs the Brand panel, so every
                # firing step of a Brand-family unit must already be a
                # light step: with T_brand | T, any phase that is a
                # multiple of T_brand works; otherwise NO phase keeps all
                # firings on light steps (phase + m·T drifts mod T_brand
                # — true for the unstaggered schedule too), so pin the
                # bucket to phase 0 and stagger it not at all rather than
                # add Brand absorbs the legacy schedule never fired.
                if T % self.cfg.T_brand == 0:
                    snap = self.cfg.T_brand
                else:
                    snap = 0             # sentinel: force phase 0
            for lo, hi in _split_ranges(b, splits if self.stagger else 1,
                                        align):
                chunks.append((bi, lo, hi, snap))
        n_units = len(chunks)
        units = []
        for i, (bi, lo, hi, snap) in enumerate(chunks):
            if not self.stagger or snap == 0:
                phase = 0
            else:
                raw = (i * T) // max(n_units, 1)
                phase = (raw // snap) * snap % T
            units.append(Unit(bucket=bi, lo=lo, hi=hi, phase=phase))
        return tuple(units)

    @property
    def cycle(self) -> int:
        """Length of the full schedule cycle (distinct-mask period)."""
        c = self.cfg.T_updt
        if self.has_light:
            c = math.lcm(c, self.cfg.T_brand)
        if self.T_heavy is not None:
            c = math.lcm(c, self.T_heavy)
        return c

    def work(self, step: int) -> StepWork:
        stats = step % self.cfg.T_updt == 0
        light = self.has_light and step % self.cfg.T_brand == 0
        heavy = [[] for _ in self.buckets]
        if self.T_heavy is not None:
            for u in self.units:
                fires = step % self.T_heavy == u.phase
                if self.warmup and step == 0:
                    fires = True
                if fires:
                    heavy[u.bucket].append((u.lo, u.hi))
        return StepWork(stats=stats, light=light,
                        heavy=tuple(_merge(r) for r in heavy))

    def flags(self, step: int) -> Dict[str, bool]:
        """Legacy three-bool view of this schedule (un-staggered)."""
        return legacy_flags(self.cfg, step)

    def describe(self) -> str:
        parts = [f"T_heavy={self.T_heavy} stagger={self.stagger} "
                 f"units={len(self.units)}"]
        for u in self.units:
            parts.append(f"[b{u.bucket} {u.lo}:{u.hi} @{u.phase}]")
        return " ".join(parts)


def _merge(ranges: Sequence[Tuple[int, int]]) -> Ranges:
    """Sort and merge adjacent/overlapping ranges."""
    out: list = []
    for lo, hi in sorted(ranges):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return tuple(out)

"""Inverse application of low-rank K-factor representations to gradients.

Paper Alg 1 (lines 14-18) — quadratic application:
    M = J V_A [(D_A+λI)⁻¹ − (1/λ)I] V_Aᵀ + (1/λ) J
    S = V_Γ [(D_Γ+λI)⁻¹ − (1/λ)I] V_Γᵀ M + (1/λ) M
i.e. (U diag(D) Uᵀ + λI)⁻¹ applied exactly on the span and as (1/λ)I off it.

Paper Alg 8 (§5, left as future work there — implemented here) — linear
application for layers where the per-step sample count n_M < d: precondition
the gradient *factors* (A, G with Mat(g)=G Aᵀ) and only then multiply.

Paper §3.5 spectrum continuation: before inverting, shift the retained
spectrum down by its smallest retained eigenvalue and fold that amount into
λ — overestimating the missing tail gives more conservative steps.

Every function here is stacked-native: operands may carry arbitrary leading
stack axes (scanned layers / MoE experts) with per-element λ, so stacked
taps run as single batched kernel launches instead of vmapped 2D fallbacks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ref import mt as _mt, scal as _scal

Array = jax.Array


def spectrum_continuation(D: Array, lam: Array) -> Tuple[Array, Array]:
    """λ ← λ + min D, D ← D − (min D)  (paper §3.5).

    min is over the *retained* (positive) modes so zero-padded static-width
    states (RSVD pad_to) get the same treatment as fully-populated Brand
    states — otherwise the continuation would act on B-variants only and
    bias the inverse comparison.  D: (..., w), lam: scalar or (...,).
    """
    pos = D > 0
    dmin = jnp.min(jnp.where(pos, D, jnp.inf), axis=-1)
    dmin = jnp.where(jnp.isfinite(dmin), dmin, 0.0)
    return jnp.maximum(D - dmin[..., None], 0.0), lam + dmin


def damping_from_spectrum(D: Array, phi: Array) -> Array:
    """Paper §6: λ = φ_λ · λ_max where λ_max is the largest (approximate)
    eigenvalue of the represented K-factor.  D: (..., w) → λ: (...,)."""
    return phi * jnp.maximum(jnp.max(D, axis=-1), 1e-12)


#: floor for λ in the inverse-diagonal split.  The decomposition
#: (D+λ)⁻¹ − 1/λ (+ J/λ off-span) divides by λ itself, so an undamped
#: config (φ = 0) or a fully-clamped spectrum (max D = 0 → λ = 0) would
#: emit inf/NaN that propagates silently through the whole application.
#: Flooring λ keeps the limit exact where it is finite: on the span the
#: diagonal tends to D⁻¹ − 1/λ_eps which recombines with the 1/λ_eps
#: off-span term to plain D⁻¹, and rank-deficient directions get the
#: (huge but finite) 1/λ_eps instead of inf.
_LAM_EPS = 1e-12


def lowrank_inv_diag(D: Array, lam: Array) -> Array:
    """The diagonal (D+λ)⁻¹ − 1/λ used on the span (negative values —
    it *removes* the over-counted 1/λ there).  lam broadcasts over the
    trailing mode axis.  λ is floored at ``_LAM_EPS`` (see above); D+λ is
    floored too so a clamped-to-zero mode cannot divide by zero."""
    lam = jnp.maximum(jnp.asarray(lam), _LAM_EPS)[..., None]
    return 1.0 / jnp.maximum(D + lam, _LAM_EPS) - 1.0 / lam


def _lam_safe(lam: Array) -> Array:
    """The same λ floor for the off-span J/λ term — every caller pairing
    ``lowrank_inv_diag`` with a 1/λ residual must divide by the *same*
    floored λ or the split stops telescoping."""
    return jnp.maximum(jnp.asarray(lam), _LAM_EPS)


def apply_inv_right(J: Array, U: Array, D: Array, lam: Array,
                    use_kernel: bool = False) -> Array:
    """J @ (U diag(D) Uᵀ + λI)⁻¹  — right application (A-side).

    J: (..., p, d), U: (..., d, w).  O(p·d·w): two tall-skinny matmuls +
    rank-1 work.
    """
    lam = _lam_safe(lam)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.lowrank_apply(J, U, lowrank_inv_diag(D, lam), lam)
    T = J @ U                                    # (..., p, w)
    T = T * lowrank_inv_diag(D, lam)[..., None, :]
    return T @ _mt(U) + J / _scal(lam, J)


def apply_inv_left(J: Array, U: Array, D: Array, lam: Array,
                   use_kernel: bool = False) -> Array:
    """(U diag(D) Uᵀ + λI)⁻¹ @ J — left application (Γ-side).
    J: (..., d, p)."""
    return _mt(apply_inv_right(_mt(J), U, D, lam, use_kernel))


def kfac_precondition(J: Array,
                      U_g: Array, D_g: Array, lam_g: Array,
                      U_a: Array, D_a: Array, lam_a: Array,
                      use_kernel: bool = False,
                      dense_g: bool = False, dense_a: bool = False) -> Array:
    """Full quadratic application (Alg 1): S = Γ̄⁻¹ J Ā⁻¹.

    J is the layer gradient in matrix form (d_out, d_in) = Mat(g);
    Γ̄ is (d_out, d_out), Ā is (d_in, d_in).

    With ``use_kernel`` the whole two-sided application dispatches to the
    fused Pallas path (one launch sequence, J resident, no transposes, no
    HBM intermediate) instead of two ``lowrank_apply`` round-trips.

    ``dense_g``/``dense_a`` mark NS-mode factors: U on that side *is* the
    dense damped inverse (U ≈ (M + λ̂I)⁻¹, symmetric), so the application
    is a plain GEMM and the (D, λ) arguments on that side are ignored —
    λ̂ was baked in at the NS refresh.
    """
    if dense_g or dense_a:
        M = J @ U_a if dense_a else apply_inv_right(J, U_a, D_a, lam_a,
                                                    use_kernel)
        return U_g @ M if dense_g else apply_inv_left(M, U_g, D_g, lam_g,
                                                      use_kernel)
    if use_kernel:
        from repro.kernels import ops as kops
        lam_g, lam_a = _lam_safe(lam_g), _lam_safe(lam_a)
        return kops.precond_fused(J,
                                  U_g, lowrank_inv_diag(D_g, lam_g), lam_g,
                                  U_a, lowrank_inv_diag(D_a, lam_a), lam_a)
    M = apply_inv_right(J, U_a, D_a, lam_a)      # J Ā⁻¹
    return apply_inv_left(M, U_g, D_g, lam_g)    # Γ̄⁻¹ (·)


def kfac_precondition_linear(G: Array, A: Array,
                             U_g: Array, D_g: Array, lam_g: Array,
                             U_a: Array, D_a: Array, lam_a: Array,
                             use_kernel: bool = False,
                             dense_g: bool = False, dense_a: bool = False
                             ) -> Array:
    """Alg 8 — linear-in-d application from gradient factors.

    The layer gradient is Mat(g) = G Aᵀ with G (d_out, n), A (d_in, n)
    (n = per-step samples).  Precondition each factor then contract:

        S = (Γ̄⁻¹ G) (Aᵀ Ā⁻¹)        — O(r·d·n) instead of O(r·d²).

    Only beneficial (and only used) when n < d (paper's applicability
    condition; holds for FC layers with n = batch).  ``dense_g``/
    ``dense_a`` as in ``kfac_precondition`` (NS sides apply by GEMM).
    """
    Gp = (U_g @ G if dense_g
          else apply_inv_left(G, U_g, D_g, lam_g, use_kernel))
    Ap = (_mt(A) @ U_a if dense_a
          else apply_inv_right(_mt(A), U_a, D_a, lam_a, use_kernel))
    return Gp @ Ap


def _damped(D: Array, phi: Array, continuation: bool
            ) -> Tuple[Array, Array]:
    """Per-element λ from the spectrum, plus the §3.5 continuation shift."""
    lam = damping_from_spectrum(D, phi)
    if continuation:
        D, lam = spectrum_continuation(D, lam)
    return D, lam


def precondition_with_damping(J: Array,
                              U_g: Array, D_g: Array,
                              U_a: Array, D_a: Array,
                              phi: Array, *,
                              continuation: bool = True,
                              use_kernel: bool = False,
                              dense_g: bool = False,
                              dense_a: bool = False) -> Array:
    """Damping + spectrum continuation + full quadratic application for a
    whole (possibly stacked) tap in one call.

    J: (*stack, d_out, d_in); U/D stacked alike; per-element λ is derived
    from each element's spectrum.  This is the entry point the optimizer
    uses — stacked taps become one batched fused kernel launch.

    A ``dense_*`` (NS-mode) side skips damping/continuation entirely: its
    U is already the inverse of the damped factor (λ̂ = ns_phi·λ_max baked
    in at the heavy refresh, D carries metadata rather than a spectrum),
    so deriving λ from D here would be meaningless.
    """
    lam_a = lam_g = jnp.asarray(1.0)
    if not dense_a:
        D_a, lam_a = _damped(D_a, phi, continuation)
    if not dense_g:
        D_g, lam_g = _damped(D_g, phi, continuation)
    return kfac_precondition(J, U_g, D_g, lam_g, U_a, D_a, lam_a, use_kernel,
                             dense_g=dense_g, dense_a=dense_a)


def precondition_linear_with_damping(G: Array, A: Array,
                                     U_g: Array, D_g: Array,
                                     U_a: Array, D_a: Array,
                                     phi: Array, *,
                                     continuation: bool = True,
                                     use_kernel: bool = False,
                                     dense_g: bool = False,
                                     dense_a: bool = False) -> Array:
    """Damping + continuation + Alg-8 linear application (from gradient
    factors) — the linear-apply counterpart of precondition_with_damping.
    ``dense_*`` sides (NS) skip damping, as in the quadratic entry point."""
    lam_a = lam_g = jnp.asarray(1.0)
    if not dense_a:
        D_a, lam_a = _damped(D_a, phi, continuation)
    if not dense_g:
        D_g, lam_g = _damped(D_g, phi, continuation)
    return kfac_precondition_linear(G, A, U_g, D_g, lam_g,
                                    U_a, D_a, lam_a, use_kernel,
                                    dense_g=dense_g, dense_a=dense_a)


def dense_inv_apply(J: Array, M_g: Array, lam_g: Array,
                    M_a: Array, lam_a: Array) -> Array:
    """O(d³) dense-solve application (K-FAC reference path, tests/bench)."""
    d_out, d_in = J.shape
    A = M_a + lam_a * jnp.eye(d_in, dtype=J.dtype)
    Gm = M_g + lam_g * jnp.eye(d_out, dtype=J.dtype)
    return jnp.linalg.solve(Gm, jnp.linalg.solve(A, J.T).T)

"""Inverse application of low-rank K-factor representations to gradients.

Paper Alg 1 (lines 14-18) — quadratic application:
    M = J V_A [(D_A+λI)⁻¹ − (1/λ)I] V_Aᵀ + (1/λ) J
    S = V_Γ [(D_Γ+λI)⁻¹ − (1/λ)I] V_Γᵀ M + (1/λ) M
i.e. (U diag(D) Uᵀ + λI)⁻¹ applied exactly on the span and as (1/λ)I off it.

Paper Alg 8 (§5, left as future work there — implemented here) — linear
application for layers where the per-step sample count n_M < d: precondition
the gradient *factors* (A, G with Mat(g)=G Aᵀ) and only then multiply.

Paper §3.5 spectrum continuation: before inverting, shift the retained
spectrum down by its smallest retained eigenvalue and fold that amount into
λ — overestimating the missing tail gives more conservative steps.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def spectrum_continuation(D: Array, lam: Array) -> Tuple[Array, Array]:
    """λ ← λ + min D, D ← D − (min D)  (paper §3.5).

    min is over the *retained* (positive) modes so zero-padded static-width
    states (RSVD pad_to) get the same treatment as fully-populated Brand
    states — otherwise the continuation would act on B-variants only and
    bias the inverse comparison.
    """
    pos = D > 0
    dmin = jnp.min(jnp.where(pos, D, jnp.inf))
    dmin = jnp.where(jnp.isfinite(dmin), dmin, 0.0)
    return jnp.maximum(D - dmin, 0.0), lam + dmin


def damping_from_spectrum(D: Array, phi: Array) -> Array:
    """Paper §6: λ = φ_λ · λ_max where λ_max is the largest (approximate)
    eigenvalue of the represented K-factor."""
    return phi * jnp.maximum(jnp.max(D), 1e-12)


def lowrank_inv_diag(D: Array, lam: Array) -> Array:
    """The diagonal (D+λ)⁻¹ − 1/λ used on the span (negative values —
    it *removes* the over-counted 1/λ there)."""
    return 1.0 / (D + lam) - 1.0 / lam


def apply_inv_right(J: Array, U: Array, D: Array, lam: Array,
                    use_kernel: bool = False) -> Array:
    """J @ (U diag(D) Uᵀ + λI)⁻¹  — right application (A-side).

    J: (p, d), U: (d, w).  O(p·d·w): two tall-skinny matmuls + rank-1 work.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.lowrank_apply(J, U, lowrank_inv_diag(D, lam), lam)
    T = J @ U                                   # (p, w)
    T = T * lowrank_inv_diag(D, lam)            # scale modes
    return T @ U.T + J / lam


def apply_inv_left(J: Array, U: Array, D: Array, lam: Array,
                   use_kernel: bool = False) -> Array:
    """(U diag(D) Uᵀ + λI)⁻¹ @ J — left application (Γ-side). J: (d, p)."""
    return apply_inv_right(J.T, U, D, lam, use_kernel).T


def kfac_precondition(J: Array,
                      U_g: Array, D_g: Array, lam_g: Array,
                      U_a: Array, D_a: Array, lam_a: Array,
                      use_kernel: bool = False) -> Array:
    """Full quadratic application (Alg 1): S = Γ̄⁻¹ J Ā⁻¹.

    J is the layer gradient in matrix form (d_out, d_in) = Mat(g);
    Γ̄ is (d_out, d_out), Ā is (d_in, d_in).
    """
    M = apply_inv_right(J, U_a, D_a, lam_a, use_kernel)     # J Ā⁻¹
    return apply_inv_left(M, U_g, D_g, lam_g, use_kernel)   # Γ̄⁻¹ (·)


def kfac_precondition_linear(G: Array, A: Array,
                             U_g: Array, D_g: Array, lam_g: Array,
                             U_a: Array, D_a: Array, lam_a: Array,
                             use_kernel: bool = False) -> Array:
    """Alg 8 — linear-in-d application from gradient factors.

    The layer gradient is Mat(g) = G Aᵀ with G (d_out, n), A (d_in, n)
    (n = per-step samples).  Precondition each factor then contract:

        S = (Γ̄⁻¹ G) (Aᵀ Ā⁻¹)        — O(r·d·n) instead of O(r·d²).

    Only beneficial (and only used) when n < d (paper's applicability
    condition; holds for FC layers with n = batch).
    """
    Gp = apply_inv_left(G, U_g, D_g, lam_g, use_kernel)     # (d_out, n)
    Ap = apply_inv_right(A.T, U_a, D_a, lam_a, use_kernel)  # (n, d_in)
    return Gp @ Ap


def dense_inv_apply(J: Array, M_g: Array, lam_g: Array,
                    M_a: Array, lam_a: Array) -> Array:
    """O(d³) dense-solve application (K-FAC reference path, tests/bench)."""
    d_out, d_in = J.shape
    A = M_a + lam_a * jnp.eye(d_in, dtype=J.dtype)
    Gm = M_g + lam_g * jnp.eye(d_out, dtype=J.dtype)
    return jnp.linalg.solve(Gm, jnp.linalg.solve(A, J.T).T)

"""gemma3-4b [dense] — hf:google/gemma-3 family. 34L d_model=2560 8H
(GQA kv=4) d_ff=10240 vocab=262144, 5:1 local(1024):global, 128k context.
34 = 5×(5L+1G) + 4 trailing local layers (remainder segment)."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

_L = LayerSpec(mixer="gqa", ffn="dense", window=1024)
_G = LayerSpec(mixer="gqa", ffn="dense", window=0)

ARCH = ArchConfig(
    name="gemma3_4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    rope_theta=1000000.0,
    subquadratic=True,       # SWA-dominant; global layers are
                             # linear-per-step at decode (DESIGN.md §4)
    segments=(
        Segment(pattern=(_L, _L, _L, _L, _L, _G), repeats=5),
        Segment(pattern=(_L, _L, _L, _L), repeats=1),
    ),
)

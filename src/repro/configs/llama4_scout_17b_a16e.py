"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E.
48L d_model=5120 40H (GQA kv=8) d_ff=8192, MoE 16 experts top-1 (+1 shared),
vocab=202048, early fusion (text-only backbone here)."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="llama4_scout_17b_a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    n_experts=16,
    top_k=1,
    d_ff_expert=8192,
    n_shared_experts=1,
    rope_theta=500000.0,
    subquadratic=False,
    segments=(
        Segment(pattern=(LayerSpec(mixer="gqa", ffn="moe"),), repeats=48),
    ),
)

"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (Griffin). 26L d_model=2560
10H (MQA kv=1) d_ff=7680 vocab=256000; RG-LRU : local-attn at 2:1
(pattern R,R,A ×8 + trailing R,R), window 2048, lru_width=2560."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

_R = LayerSpec(mixer="rglru", ffn="dense")
_A = LayerSpec(mixer="gqa", ffn="dense", window=2048)

ARCH = ArchConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    lru_width=2560,
    conv_k=4,
    subquadratic=True,
    segments=(
        Segment(pattern=(_R, _R, _A), repeats=8),
        Segment(pattern=(_R, _R), repeats=1),
    ),
)

"""qwen2-72b [dense] — arXiv:2407.10671. 80L d_model=8192 64H (GQA kv=8)
d_ff=29568 vocab=152064, QKV bias."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="qwen2_72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
    subquadratic=False,
    segments=(
        Segment(pattern=(LayerSpec(mixer="gqa", ffn="dense"),), repeats=80),
    ),
)

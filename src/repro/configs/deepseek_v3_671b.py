"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8, MTP.
arXiv:2412.19437. 61L d_model=7168 128H (MLA) d_ff_expert=2048
vocab=129280.  First 3 layers dense FFN (d_ff=18432), remaining 58 MoE."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="deepseek_v3_671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: per-head KV from a shared latent
    d_ff=18432,              # dense layers' FFN width
    vocab=129280,
    n_experts=256,
    top_k=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    mtp=True,
    mla_q_lora=1536,
    mla_kv_lora=512,
    mla_qk_nope=128,
    mla_qk_rope=64,
    mla_v_head=128,
    head_dim=128,
    subquadratic=False,      # full attention → long_500k skipped
    segments=(
        Segment(pattern=(LayerSpec(mixer="mla", ffn="dense"),), repeats=3),
        Segment(pattern=(LayerSpec(mixer="mla", ffn="moe"),), repeats=58),
    ),
)

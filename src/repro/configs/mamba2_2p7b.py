"""mamba2-2.7b [ssm] — SSD (state-space duality), arXiv:2405.21060.
64L d_model=2560, attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2·d_model = 5120, head_dim 64 → 80 SSD heads."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="mamba2_2p7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,              # SSD heads = d_inner / ssm_head_dim
    n_kv_heads=80,
    d_ff=0,                  # attention-free: no separate FFN
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=256,
    conv_k=4,
    subquadratic=True,
    segments=(
        Segment(pattern=(LayerSpec(mixer="ssm", ffn="none"),), repeats=64),
    ),
)

"""internvl2-76b [vlm] — arXiv:2404.16821 (InternViT-6B + Llama3-70B LM).
Backbone only: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings as a 256-token prefix."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="internvl2_76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    frontend="vision",
    n_prefix=256,
    subquadratic=False,
    segments=(
        Segment(pattern=(LayerSpec(mixer="gqa", ffn="dense"),), repeats=80),
    ),
)

"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (llama+mistral mix, SWA).
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding-window
attention (window 4096) on every layer → sub-quadratic-dominant."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

_W = 4096

ARCH = ArchConfig(
    name="h2o_danube_3_4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,
    subquadratic=True,       # SWA everywhere → 500k decode is bounded
    segments=(
        Segment(pattern=(LayerSpec(mixer="gqa", ffn="dense", window=_W),),
                repeats=24),
    ),
)

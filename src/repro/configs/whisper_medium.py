"""whisper-medium [audio] — arXiv:2212.04356.  Enc-dec: 24+24L d_model=1024
16H d_ff=4096 vocab=51865.  Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, seq, d).  Decoder length = seq_len / 8."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

ARCH = ArchConfig(
    name="whisper_medium",
    family="audio",
    n_layers=24,             # decoder layers (encoder listed separately)
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    head_dim=64,
    is_encdec=True,
    enc_causal=False,
    dec_ratio=8,
    frontend="audio",
    subquadratic=False,
    segments=(               # decoder stack (self+cross attention per layer)
        Segment(pattern=(LayerSpec(mixer="gqa", ffn="dense"),), repeats=24),
    ),
)

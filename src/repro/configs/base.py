"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``: a sequence of *segments*,
each segment a (pattern of LayerSpecs) × repeats — scanned over repeats at
trace time so 80-layer models compile as one block body.  Shapes are the
four assigned input-shape cells; ``input_specs`` builds ShapeDtypeStruct
stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a segment pattern."""
    mixer: str                  # 'gqa' | 'mla' | 'ssm' | 'rglru' | 'none'
    ffn: str = "dense"          # 'dense' | 'moe' | 'none'
    window: int = 0             # 0 → global attention
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # ssm | moe | dense | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Tuple[Segment, ...]
    head_dim: int = 0           # 0 → d_model // n_heads
    qkv_bias: bool = False
    logit_softcap: float = 0.0      # final-logit softcap (gemma2)
    attn_softcap: float = 0.0       # attention-logit softcap (gemma2)
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_k: int = 4
    # RG-LRU
    lru_width: int = 0
    # enc-dec (whisper)
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_causal: bool = False
    dec_ratio: int = 8          # dec_len = seq_len // dec_ratio
    # modality frontend stub
    frontend: str = "none"      # 'none' | 'audio' | 'vision'
    n_prefix: int = 0           # vision: patch-embedding prefix length
    # deepseek extras
    mtp: bool = False
    mla_q_lora: int = 1536
    mla_kv_lora: int = 512
    mla_qk_nope: int = 128
    mla_qk_rope: int = 64
    mla_v_head: int = 128
    # capabilities
    subquadratic: bool = False  # can run the long_500k cell
    # training/runtime
    dtype: str = "bfloat16"     # compute/activation dtype
    n_stat: int = 512           # K-FAC stats tokens
    aux_loss_coef: float = 0.01

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink_spec(s: LayerSpec) -> LayerSpec:
            return dataclasses.replace(s, window=min(s.window, 16) or s.window)
        segs = tuple(
            Segment(tuple(shrink_spec(s) for s in seg.pattern),
                    repeats=min(seg.repeats, 2))
            for seg in self.segments)
        return dataclasses.replace(
            self, n_layers=sum(len(s.pattern) * s.repeats for s in segs),
            d_model=64, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128, d_ff_expert=64 if self.d_ff_expert else 0,
            vocab=256, head_dim=16, segments=segs,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=8, ssm_chunk=8, lru_width=64 if self.lru_width else 0,
            mla_q_lora=32, mla_kv_lora=16, mla_qk_nope=16, mla_qk_rope=8,
            mla_v_head=16, n_prefix=min(self.n_prefix, 8),
            n_stat=16, dtype="float32")


# ---------------------------------------------------------------------------
# the four assigned shape cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k only for sub-quadratic archs
    (DESIGN.md §4); every arch here has a decoder, so decode cells run."""
    if shape == "long_500k" and not arch.subquadratic:
        return False, ("skip: pure full-attention arch — 500k decode needs "
                       "sub-quadratic attention (DESIGN.md §4)")
    return True, ""


ARCH_NAMES = (
    "mamba2_2p7b", "deepseek_v3_671b", "llama4_scout_17b_a16e",
    "whisper_medium", "internvl2_76b", "h2o_danube_3_4b", "gemma3_4b",
    "gemma2_27b", "qwen2_72b", "recurrentgemma_2b",
)


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.ARCH

"""gemma2-27b [dense] — arXiv:2408.00118. 46L d_model=4608 32H (GQA kv=16)
d_ff=36864 vocab=256000; local(4096)/global alternating 1:1, logit softcaps
(attn 50, final 30)."""
from repro.configs.base import ArchConfig, LayerSpec, Segment

_L = LayerSpec(mixer="gqa", ffn="dense", window=4096)
_G = LayerSpec(mixer="gqa", ffn="dense", window=0)

ARCH = ArchConfig(
    name="gemma2_27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    logit_softcap=30.0,
    attn_softcap=50.0,
    subquadratic=False,      # 1:1 global → long_500k skipped
    segments=(
        Segment(pattern=(_L, _G), repeats=23),
    ),
)

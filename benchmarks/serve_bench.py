"""Multi-tenant serving benchmark: one stacked ``TenantBank.update``
over N tenant adapter states vs N sequential plain ``Kfac.update`` calls
on the same per-tenant inputs.

The acceptance claim (ISSUE 10) is structural, not wall-clock: the
stacked program's launch-group / decomposition-site count is
O(#shape classes) — INDEPENDENT of the tenant count — while the
sequential path pays O(#tenants) full programs.  ``launch_invariant``
is computed by tracing the stacked update at two different tenant
counts and counting decomposition call sites (eigh/svd/qr) plus total
jaxpr equations: vmap batches every site, so both counts must be
identical at N=2 and N=4 (the regression gate turns
``launch_invariant=False`` into a hard failure).

Parity is asserted before timing:
  * stacked lane t allclose to sequential run t (batched linalg may
    reassociate reductions — same tolerance as tests/test_tenant.py);
  * the N=1 bank rides the squeeze fast path and must be BIT-identical
    to plain ``Kfac.update`` (``bitwise=True`` in the overhead row).

Usage:  python benchmarks/serve_bench.py [--quick] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.core import policy, tenant
from repro.optim import base as optbase


def _pcts(samples) -> dict:
    return {"p50_us": float(np.percentile(samples, 50) * 1e6),
            "p99_us": float(np.percentile(samples, 99) * 1e6)}


def _timeit_pair(fn_a, fn_b, reps=20, warmup=4, rounds=3):
    """Interleaved per-rep samples over independent rounds (the same
    comparative-CPU-timing statistic step_bench uses): host load hits
    both closures equally, min-of-reps is the headline, p50/p99 ride
    along."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(rounds):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_a())
            ta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fn_b())
            tb.append(time.perf_counter() - t0)
        time.sleep(0.2)
    return ta, tb


def _make_taps(quick: bool):
    """Three factor shape classes (square attn + in/out MLP pair + a
    scanned stack) — enough that bucketing is non-trivial while a
    sequential 4-tenant sweep still fits a CI tick."""
    d, h, L, N = (64, 48, 2, 16) if quick else (128, 96, 4, 32)
    return {
        "attn":    kfac_lib.TapInfo("attn/w", d, d, n_stat=N),
        "mlp_in":  kfac_lib.TapInfo("mlp_in/w", d, h, n_stat=N),
        "mlp_out": kfac_lib.TapInfo("mlp_out/w", h, d, n_stat=N),
        "scan":    kfac_lib.TapInfo("scan/w", d, d, stack=(L,), n_stat=N),
    }, N


def _opt(taps, quick: bool, variant: str = "bkfac"):
    pol = policy.PolicyConfig(variant=variant, r=8 if quick else 16,
                              max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              momentum=0.9, T_updt=1, T_brand=1,
                              bucketed=True)
    return kfac_lib.Kfac(cfg, taps)


def _tenant_data(taps, key, t):
    k = jax.random.fold_in(key, t)
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (n, tap) in enumerate(taps.items()):
        shp = tap.stack + (tap.d_in, tap.d_out)
        params[n] = {"w": jax.random.normal(jax.random.fold_in(k, i),
                                            shp) * 0.05}
        grads[n] = {"w": jax.random.normal(jax.random.fold_in(k, 10 + i),
                                           shp)}
        acts[n] = jax.random.normal(jax.random.fold_in(k, 20 + i),
                                    tap.stack + (tap.n_stat, tap.d_in))
        pgs[n] = jax.random.normal(jax.random.fold_in(k, 30 + i),
                                   tap.stack + (tap.n_stat, tap.d_out)) * 1e-3
    return params, grads, acts, pgs


def _stack_inputs(taps, n):
    per = [_tenant_data(taps, jax.random.PRNGKey(0), t) for t in range(n)]
    return (tuple(tenant.tree_stack([p[i] for p in per])
                  for i in range(4)), per)


def _rngs(n, s):
    key = jax.random.PRNGKey(7)
    return jnp.stack([jax.random.fold_in(jax.random.fold_in(key, t), s)
                      for t in range(n)])


def _stacked_step(bank, params, acts, pgs, n_tok, work):
    @jax.jit
    def step(grads, state, rngs):
        return bank.update(grads, state, params, acts=acts,
                           probe_grads=pgs, n_tokens=n_tok, rngs=rngs,
                           work=work)
    return step


def _single_step(opt, params, acts, pgs, n_tok, work):
    @jax.jit
    def step(grads, state, rng):
        return opt.update(grads, state, params, acts=acts,
                          probe_grads=pgs, n_tokens=n_tok, rng=rng,
                          work=work)
    return step


_DECOMP = ("eigh[", "svd[", "qr[")


def _program_counts(opt, taps, n, n_tok):
    """(decomposition sites, jaxpr equations) of the stacked update at
    tenant count ``n`` — both must be flat in ``n`` for the stacked
    launch story to hold."""
    (params, grads, acts, pgs), _ = _stack_inputs(taps, n)
    bank = tenant.TenantBank(opt)
    st = bank.init(params)
    work = opt.uniform_work(True, True, True)

    def fn(g, s, r):
        return bank.update(g, s, params, acts=acts, probe_grads=pgs,
                           n_tokens=n_tok, rngs=r, work=work)

    txt = str(jax.make_jaxpr(fn)(grads, st, _rngs(n, 0)))
    sites = sum(txt.count(p) for p in _DECOMP)
    return sites, txt.count("\n")


def run(quick: bool = False) -> List[dict]:
    taps, n_tok = _make_taps(quick)
    opt = _opt(taps, quick)
    n = 4
    steps_parity = 3

    # -- launch invariance: trace at N=2 and N=4, counts must match ---------
    sites2, eqns2 = _program_counts(opt, taps, 2, n_tok)
    sites4, eqns4 = _program_counts(opt, taps, 4, n_tok)
    single = _opt(taps, quick)   # fresh opt: same program, no cache reuse
    (p1, g1, a1, pg1) = _tenant_data(taps, jax.random.PRNGKey(0), 0)
    st1 = single.init(p1)
    txt1 = str(jax.make_jaxpr(
        lambda g, s, r: single.update(
            g, s, p1, acts=a1, probe_grads=pg1, n_tokens=n_tok, rng=r,
            work=single.uniform_work(True, True, True)))(
                g1, st1, jax.random.PRNGKey(7)))
    sites_seq = n * sum(txt1.count(p) for p in _DECOMP)
    invariant = (sites2 == sites4) and (eqns2 == eqns4) and sites4 > 0

    # -- parity: stacked lane t ≡ sequential run t (allclose) ---------------
    (params, grads, acts, pgs), per = _stack_inputs(taps, n)
    bank = tenant.TenantBank(opt)
    st_stk = bank.init(params)
    seq_states = [opt.init(p[0]) for p in per]
    stk_hist, seq_hist = [], []
    for s in range(steps_parity):
        work = opt.uniform_work(True, True, s == 0)
        step_stk = _stacked_step(bank, params, acts, pgs, n_tok, work)
        upd, st_stk = step_stk(grads, st_stk, _rngs(n, s))
        stk_hist.append(upd)
        row = []
        for t in range(n):
            pt, gt, at, pgt = per[t]
            u, seq_states[t] = jax.jit(
                lambda g, st, r, _p=pt, _a=at, _pg=pgt, _w=work:
                opt.update(g, st, _p, acts=_a, probe_grads=_pg,
                           n_tokens=n_tok, rng=r, work=_w))(
                               gt, seq_states[t],
                               jax.random.fold_in(
                                   jax.random.fold_in(jax.random.PRNGKey(7),
                                                      t), s))
            row.append(u)
        seq_hist.append(row)
    for s in range(steps_parity):
        for t in range(n):
            lane = tenant.tree_slot(stk_hist[s], t)
            for name in taps:
                x = np.asarray(seq_hist[s][t][name]["w"])
                y = np.asarray(lane[name]["w"])
                assert np.isfinite(x).all() and np.isfinite(y).all()
                np.testing.assert_allclose(y, x, atol=3e-4, rtol=1e-2,
                                           err_msg=f"step {s} tenant {t} "
                                                   f"{name}")

    # -- timing: steady-state serve tick (light work), N stacked vs N seq ---
    work_l = opt.uniform_work(True, True, False)
    step_stk = _stacked_step(bank, params, acts, pgs, n_tok, work_l)
    rngs = _rngs(n, steps_parity)
    seq_steps = []
    for t in range(n):
        pt, _, at, pgt = per[t]
        seq_steps.append(_single_step(opt, pt, at, pgt, n_tok, work_l))

    def run_seq():
        return [seq_steps[t](per[t][1], seq_states[t], rngs[t])[0]
                for t in range(n)]

    sa, sb = _timeit_pair(lambda: step_stk(grads, st_stk, rngs)[0],
                          run_seq)
    t_stk, t_seq = float(np.min(sa)), float(np.min(sb))
    groups = bank.launch_groups()
    rows = [{
        "name": "serve/stacked_vs_sequential",
        "us_per_call": t_stk * 1e6,
        **_pcts(sa),
        "derived": f"tenants={n} sequential_us={t_seq * 1e6:.1f} "
                   f"sequential_p99_us={np.percentile(sb, 99) * 1e6:.1f} "
                   f"speedup={t_seq / t_stk:.2f}x "
                   f"launch_groups={groups} "
                   f"decomp_sites_n2={sites2} decomp_sites_n4={sites4} "
                   f"jaxpr_eqns_n2={eqns2} jaxpr_eqns_n4={eqns4} "
                   f"decomp_sites_sequential={sites_seq} "
                   f"launch_invariant={bool(invariant)} "
                   f"allclose=True "
                   f"(stacked program size is flat in tenant count; the "
                   f"sequential path pays N full programs)",
    }]
    rows.extend(run_single_tenant_overhead(taps, n_tok, quick))
    return rows


def run_single_tenant_overhead(taps, n_tok, quick) -> List[dict]:
    """N=1 bank (the squeeze fast path) vs plain ``Kfac.update``: the
    bank must be bit-identical AND ~free — a single-tenant service pays
    nothing for the multi-tenant machinery."""
    opt = _opt(taps, quick)
    p, g, a, pg = _tenant_data(taps, jax.random.PRNGKey(0), 0)
    work = opt.uniform_work(True, True, False)
    stack1 = lambda t: tenant.tree_stack([t])
    bank = tenant.TenantBank(opt)
    st_b = bank.init(stack1(p))
    st_p = opt.init(p)
    step_b = _stacked_step(bank, stack1(p), stack1(a), stack1(pg),
                           n_tok, work)
    step_p = _single_step(opt, p, a, pg, n_tok, work)
    rng = jax.random.PRNGKey(7)
    rngs = jnp.stack([rng])
    u_b, st_b2 = step_b(stack1(g), st_b, rngs)
    u_p, st_p2 = step_p(g, st_p, rng)
    bitwise = True
    for name in taps:
        x = np.asarray(tenant.tree_slot(u_b, 0)[name]["w"])
        y = np.asarray(u_p[name]["w"])
        bitwise = bitwise and np.array_equal(x, y)
    sa, sb = _timeit_pair(lambda: step_b(stack1(g), st_b2, rngs)[0],
                          lambda: step_p(g, st_p2, rng)[0],
                          reps=15, rounds=2)
    t_b, t_p = float(np.min(sa)), float(np.min(sb))
    return [{
        "name": "serve/single_tenant_overhead",
        "us_per_call": t_b * 1e6,
        **_pcts(sa),
        "derived": f"plain_us={t_p * 1e6:.1f} "
                   f"plain_p99_us={np.percentile(sb, 99) * 1e6:.1f} "
                   f"overhead_pct={(t_b / t_p - 1.0) * 100:.1f} "
                   f"bitwise={bool(bitwise)} "
                   f"(overhead is recorded, not gated — shared-CPU "
                   f"timing of a ~0 cost is noise; the bitwise claim "
                   f"is the contract)",
    }]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=str, default=None,
                    help="write a JSON artifact (e.g. BENCH_serve.json)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for row in rows:
        print(row)
    if args.out:
        artifact = {
            "bench": "serve",
            "backend": jax.default_backend(),
            "quick": bool(args.quick),
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

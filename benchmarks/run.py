"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the paper-scale
settings (slow on CPU); default is quick mode.  ``--only mod1,mod2``
restricts modules.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "inverse_scaling",    # §3 complexity claims (linear/quadratic/cubic)
    "error_metrics",      # §4 Figures 1-2 + Table 1
    "train_quality",      # §6 Table 2
    "kernels_bench",      # Pallas hot-spot kernels vs oracle
    "roofline",           # dry-run roofline table (§Roofline)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", type=str, default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if only and modname not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{modname}")
            rows = mod.run(quick=not args.full)
            for row in rows:
                derived = str(row.get("derived", "")).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{modname}/ERROR,0.0,{type(e).__name__}: "
                  f"{str(e)[:120]}".replace(",", ";"))
        finally:
            print(f"# {modname} took {time.time()-t0:.0f}s",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

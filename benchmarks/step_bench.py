"""Optimizer-level step benchmark: one full ``Kfac.update`` on a mixed-shape
tap set (FC + scanned stack + MoE stack), bucketed vs per-tap, for each
static step variant (stats / light / heavy) — plus the two distributed
rows this PR's acceptance gates on:

  * ``sharded_vs_replicated``: the curvature engine partitions every
    factor bucket's batch across an N-way host-device mesh (round-robin
    slot → device), so per-device factor work drops to ~1/N of the
    replicated slot count (recorded as ``slots_replicated`` vs
    ``slots_per_device``);
  * ``staggered_vs_spiky``: the work scheduler phases heavy overwrites
    across the T_inv window; per-step wall times over several schedule
    cycles are recorded as p50/p99 — the spiky baseline's p99 IS the
    spike, the staggered schedule's p99 sits near its p50, at equal mean
    cadence (identical heavy-slot count per cycle, asserted).

All timing rows record p50/p99 per-step wall time (not just the min) so
spike behaviour is visible in the BENCH_step.json artifact.  Parity
(allclose) between compared paths is asserted at bench shapes before
timing.

Runs with XLA_FLAGS=--xla_force_host_platform_device_count=8 by default
(set before the jax import below) so the sharded rows exist on CPU CI;
an externally-set XLA_FLAGS wins.

Usage:  python benchmarks/step_bench.py [--quick] [--out BENCH_step.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np
import jax

from repro.core import kfac as kfac_lib
from repro.core import policy
from repro.distributed import curvature as curv_lib
from repro.launch import mesh as mesh_lib
from repro.optim import base as optbase


def _pcts(samples) -> dict:
    return {"p50_us": float(np.percentile(samples, 50) * 1e6),
            "p99_us": float(np.percentile(samples, 99) * 1e6)}


def _timeit_pair(fn_a, fn_b, reps=25, warmup=5, rounds=3):
    """Per-rep samples over several independent rounds of *interleaved*
    reps for two closures.  Interleaving makes host load hit both sides
    equally, the warmup lets post-compile background work (jit cache
    writes, GC) settle, and spreading the reps across separate rounds
    widens the total window so each side catches at least one calm
    stretch — shared-CPU contention bursts routinely outlast a single
    tight rep loop (comparative CPU timing).  Returns the two sample
    lists; the headline number stays min-of-reps, p50/p99 ride along."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(rounds):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_a())
            ta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fn_b())
            tb.append(time.perf_counter() - t0)
        time.sleep(0.2)
    return ta, tb


def _make_model(quick: bool):
    """A mixed-shape tapped 'network' in the regime bucketing targets: an
    *unrolled* transformer trunk (many separately-named taps repeating two
    matmul shapes — the per-tap python loop launches each one on its own)
    plus a scanned block stack and a two-level MoE stack.  Everything
    collapses to two factor shape classes per side."""
    d, dff, L, E, N, n_blk = ((128, 192, 4, 2, 32, 4) if quick
                              else (256, 512, 6, 4, 64, 8))
    taps = {
        "embed_out": kfac_lib.TapInfo("embed_out/w", d, dff, n_stat=N),
        "head_in":   kfac_lib.TapInfo("head_in/w", dff, d, n_stat=N),
        "scan":      kfac_lib.TapInfo("scan/w", d, dff, stack=(L,),
                                      n_stat=N),
        "experts":   kfac_lib.TapInfo("experts/w", d, dff,
                                      stack=(L // 2, E), n_stat=N),
    }
    for i in range(n_blk):   # the unrolled trunk: 2 taps per block
        taps[f"blk{i}_in"] = kfac_lib.TapInfo(f"blk{i}_in/w", d, dff,
                                              n_stat=N)
        taps[f"blk{i}_out"] = kfac_lib.TapInfo(f"blk{i}_out/w", dff, d,
                                               n_stat=N)
    key = jax.random.PRNGKey(0)
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (name, t) in enumerate(taps.items()):
        shp = t.stack + (t.d_in, t.d_out)
        params[name] = {"w": jax.random.normal(
            jax.random.fold_in(key, i), shp) * 0.05}
        grads[name] = {"w": jax.random.normal(
            jax.random.fold_in(key, 10 + i), shp)}
        acts[name] = jax.random.normal(
            jax.random.fold_in(key, 20 + i), t.stack + (t.n_stat, t.d_in))
        pgs[name] = jax.random.normal(
            jax.random.fold_in(key, 30 + i),
            t.stack + (t.n_stat, t.d_out)) * 1e-3
    return taps, params, grads, acts, pgs, N


def _opt(taps, bucketed: bool, quick: bool, variant: str = "bkfac"):
    pol = policy.PolicyConfig(variant=variant, r=32 if quick else 96)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              T_updt=1, T_brand=1, bucketed=bucketed)
    return kfac_lib.Kfac(cfg, taps)


def _step_fn(opt, params, acts, pgs, n_tokens, flags):
    work = opt.uniform_work(*flags)

    @jax.jit
    def step(grads, state, rng):
        return opt.update(grads, state, params, acts=acts, probe_grads=pgs,
                          n_tokens=n_tokens, rng=rng, work=work)
    return step


def run(quick: bool = False) -> List[dict]:
    taps, params, grads, acts, pgs, N = _make_model(quick)
    rng = jax.random.PRNGKey(42)
    # stats/light time the B-KFAC hot path (all-BRAND factors, where
    # do_heavy is a no-op); the heavy row uses the K-FAC baseline so the
    # periodic overwrite is both *live* and deterministic (an EVD — a
    # randomized overwrite would break the bucketed-vs-per-tap parity
    # assert, since the two paths draw different keys).
    variants = {
        "stats": ("bkfac", (True, False, False)),
        "light": ("bkfac", (True, True, False)),
        "heavy": ("kfac", (True, False, True)),
    }
    rows = []
    n_taps = len(taps)
    for vname, (variant, flags) in variants.items():
        opt_b = _opt(taps, bucketed=True, quick=quick, variant=variant)
        opt_p = _opt(taps, bucketed=False, quick=quick, variant=variant)
        # launch-group counts: factor work + preconditioning, per step
        launches_b = len(opt_b.factor_buckets) + len(opt_b.precond_buckets)
        launches_p = 2 * n_taps + n_taps
        # warm one stats step so the timed step runs on a populated state
        # (first-step init takes a different branch)
        st_b = opt_b.init(params)
        st_p = opt_p.init(params)
        warm_flags = (True, False, False)
        warm = _step_fn(opt_b, params, acts, pgs, N, warm_flags)
        _, st_b = warm(grads, st_b, rng)
        warm_p = _step_fn(opt_p, params, acts, pgs, N, warm_flags)
        _, st_p = warm_p(grads, st_p, rng)

        step_b = _step_fn(opt_b, params, acts, pgs, N, flags)
        step_p = _step_fn(opt_p, params, acts, pgs, N, flags)
        upd_b, _ = step_b(grads, st_b, rng)
        upd_p, _ = step_p(grads, st_p, rng)
        for name in taps:
            np.testing.assert_allclose(np.asarray(upd_b[name]["w"]),
                                       np.asarray(upd_p[name]["w"]),
                                       rtol=2e-3, atol=2e-3)
        sa, sb = _timeit_pair(lambda: step_b(grads, st_b, rng)[0],
                              lambda: step_p(grads, st_p, rng)[0])
        t_b, t_p = float(np.min(sa)), float(np.min(sb))
        rows.append({
            "name": f"step/{vname}_bucketed_vs_per_tap",
            "us_per_call": t_b * 1e6,
            **_pcts(sa),
            "derived": f"variant={variant} per_tap_us={t_p * 1e6:.1f} "
                       f"per_tap_p99_us={np.percentile(sb, 99) * 1e6:.1f} "
                       f"speedup={t_p / t_b:.2f}x "
                       f"launch_groups={launches_b}vs{launches_p} "
                       f"taps={n_taps} "
                       f"factor_buckets={len(opt_b.factor_buckets)} "
                       f"precond_buckets={len(opt_b.precond_buckets)} "
                       f"allclose=True",
        })
    rows.extend(run_ns_vs_evd(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_sharded(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_2d_mesh(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_staggered(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_async(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_telemetry(taps, params, grads, acts, pgs, N, quick))
    rows.extend(run_health(taps, params, grads, acts, pgs, N, quick))
    return rows


def run_ns_vs_evd(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Heavy-step cost of the Newton–Schulz refinement variant vs the
    EVD baseline at identical cadence: one full ``Kfac.update`` with the
    heavy flag live, bucketed, on the mixed-shape model.  NS's heavy
    firing is K GEMM pairs (matmul-only — no factorization primitive),
    so it rides the accelerator's dense-FLOP path the eigendecomposition
    can't; on CPU the ratio mostly reflects FLOP counts, on real
    accelerators the gap widens.  Finiteness of both updates is asserted
    (the two algorithms produce different — both valid — directions, so
    there is no allclose between them)."""
    rows = []
    opt_ns = _opt(taps, bucketed=True, quick=quick, variant="nskfac")
    opt_ev = _opt(taps, bucketed=True, quick=quick, variant="kfac")
    flags = (True, False, True)
    rng = jax.random.PRNGKey(7)
    steps, states = {}, {}
    for label, opt in (("ns", opt_ns), ("evd", opt_ev)):
        st = opt.init(params)
        warm = _step_fn(opt, params, acts, pgs, N, (True, False, False))
        _, st = warm(grads, st, rng)
        steps[label] = _step_fn(opt, params, acts, pgs, N, flags)
        states[label] = st
        upd, _ = steps[label](grads, st, rng)
        for name in taps:
            assert np.isfinite(np.asarray(upd[name]["w"])).all(), \
                (label, name)
    sn, se = _timeit_pair(
        lambda: steps["ns"](grads, states["ns"], rng)[0],
        lambda: steps["evd"](grads, states["evd"], rng)[0],
        reps=10, rounds=2)
    t_n, t_e = float(np.min(sn)), float(np.min(se))
    rows.append({
        "name": "step/ns_vs_evd",
        "us_per_call": t_n * 1e6,
        **_pcts(sn),
        "derived": f"evd_us={t_e * 1e6:.1f} "
                   f"evd_p99_us={np.percentile(se, 99) * 1e6:.1f} "
                   f"evd/ns={t_e / t_n:.2f}x "
                   f"ns_iters={opt_ns.cfg.policy.ns_iters} "
                   f"finite=True",
    })
    return rows


# ---------------------------------------------------------------------------
# distributed rows
# ---------------------------------------------------------------------------

def _sched_step_fn(opt, params, acts, pgs, n_tokens):
    def step(grads, state, rng, work, landing=None):
        return opt.update(grads, state, params, acts=acts, probe_grads=pgs,
                          n_tokens=n_tokens, rng=rng, work=work,
                          landing=landing)
    return jax.jit(step, static_argnames=("work",))


def run_sharded(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Replicated vs mesh-sharded curvature: same step, same numerics
    (asserted), 1/n of the factor-work slots per device."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        print("[step_bench] <2 devices; skipping sharded rows")
        return []
    mesh = mesh_lib.make_mesh((n_dev,), ("curv",))
    rows = []
    for vname, variant, flags in (("light", "bkfac", (True, True, False)),
                                  ("heavy", "kfac", (True, False, True))):
        opt_r = _opt(taps, bucketed=True, quick=quick, variant=variant)
        opt_s = _opt(taps, bucketed=True, quick=quick, variant=variant)
        eng = curv_lib.CurvatureEngine.for_kfac(opt_s, mesh, "curv")
        slots_rep, slots_dev = eng.job_counts()
        work_r = opt_r.uniform_work(*flags)
        work_s = opt_s.uniform_work(*flags)
        step_r = _sched_step_fn(opt_r, params, acts, pgs, N)
        step_s = _sched_step_fn(opt_s, params, acts, pgs, N)
        st_r, st_s = opt_r.init(params), opt_s.init(params)
        warm = opt_r.uniform_work(True, False, False)
        rng = jax.random.PRNGKey(42)
        _, st_r = step_r(grads, st_r, rng, warm)
        _, st_s = step_s(grads, st_s, rng, opt_s.uniform_work(
            True, False, False))
        upd_r, _ = step_r(grads, st_r, rng, work_r)
        upd_s, _ = step_s(grads, st_s, rng, work_s)
        for name in taps:
            np.testing.assert_allclose(np.asarray(upd_s[name]["w"]),
                                       np.asarray(upd_r[name]["w"]),
                                       rtol=2e-3, atol=2e-3)
        ss, sr = _timeit_pair(lambda: step_s(grads, st_s, rng, work_s)[0],
                              lambda: step_r(grads, st_r, rng, work_r)[0])
        t_s, t_r = float(np.min(ss)), float(np.min(sr))
        rows.append({
            "name": f"step/{vname}_sharded_vs_replicated",
            "us_per_call": t_s * 1e6,
            **_pcts(ss),
            "derived": f"variant={variant} devices={n_dev} "
                       f"replicated_us={t_r * 1e6:.1f} "
                       f"speedup={t_r / t_s:.2f}x "
                       f"slots_replicated={slots_rep} "
                       f"slots_per_device={slots_dev} "
                       f"work_fraction={slots_dev / slots_rep:.3f} "
                       f"allclose=True "
                       f"(CPU mesh: all 'devices' share the host's "
                       f"cores, so wall-time gain is NOT expected here — "
                       f"the per-device slot count is the scaling "
                       f"artifact)",
        })
    return rows


def run_2d_mesh(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """2D (data × curv) mesh vs the 1D curvature axis at equal device
    count: bucket slots shard over curv as before, and each slot's dense
    M additionally shards by ROWS over the data axis, so per-device
    K-factor memory drops toward 1/(N_curv · N_rows) of replicated —
    recorded from the engine's static byte accounting after exact parity
    (2D ≡ 1D ≡ replicated at 8 devices) is asserted.  The compressed
    (U, λ) collective rides along: a rank-q PowerSGD projection of the
    gathered U panels cuts the cross-axis gather volume ≥4x at bench
    shapes (asserted from the traced gather shapes; the compressed path
    is lossy, so it is finiteness-checked, never parity-checked).
    Weak-scaling efficiency t_1d/t_2d is recorded for the artifact but
    not claimed — CPU host 'devices' share the same cores, so only the
    per-device memory / bytes-on-wire columns are the scaling artifact.
    """
    n_dev = len(jax.devices())
    if n_dev < 8:
        print("[step_bench] <8 devices; skipping 2d-mesh rows")
        return []
    mesh1 = mesh_lib.make_mesh((8,), ("curv",))
    mesh2 = mesh_lib.make_mesh((4, 2), ("data", "curv"))
    rng = jax.random.PRNGKey(42)
    rows = []
    for vname, variant, flags in (("light", "bkfac", (True, True, False)),
                                  ("heavy", "kfac", (True, False, True))):
        opts = {lbl: _opt(taps, bucketed=True, quick=quick,
                          variant=variant)
                for lbl in ("rep", "1d", "2d", "2dc")}
        curv_lib.CurvatureEngine.for_kfac(opts["1d"], mesh1, "curv")
        eng2 = curv_lib.CurvatureEngine.for_kfac(opts["2d"], mesh2,
                                                 "curv", row_axis="data")
        # bench compression rank: an eighth of the panel width — deep
        # enough that the (P, Q) pair beats the raw U gather ≥4x at both
        # bench shapes, shallow enough to be a real compression
        q = max(2, min(s.width for s in eng2.specs) // 8)
        engc = curv_lib.CurvatureEngine.for_kfac(
            opts["2dc"], mesh2, "curv", row_axis="data", compress_rank=q)
        bytes_ = engc.collective_bytes()
        reduction = bytes_["uncompressed"] / bytes_["on_wire"]
        assert reduction >= 4.0, (variant, q, bytes_)
        m_rep, m_dev = eng2.m_bytes()
        m_txt = (f"m_replicated_mb={m_rep / 2**20:.1f} "
                 f"m_per_device_mb={m_dev / 2**20:.1f} "
                 f"m_fraction={m_dev / m_rep:.3f} " if m_rep else "")
        steps, states, upds = {}, {}, {}
        for lbl, opt in opts.items():
            work = opt.uniform_work(*flags)
            step = _sched_step_fn(opt, params, acts, pgs, N)
            st = opt.init(params)
            _, st = step(grads, st, rng,
                         opt.uniform_work(True, False, False))
            steps[lbl], states[lbl] = (step, work), st
            upds[lbl], _ = step(grads, st, rng, work)
        for lbl in ("1d", "2d"):
            for name in taps:
                np.testing.assert_allclose(
                    np.asarray(upds[lbl][name]["w"]),
                    np.asarray(upds["rep"][name]["w"]),
                    rtol=2e-3, atol=2e-3, err_msg=f"{lbl} {name}")
        finite = all(np.isfinite(np.asarray(upds["2dc"][name]["w"])).all()
                     for name in taps)
        assert finite, "compressed (U, λ) gather produced non-finite"
        s2, s1 = _timeit_pair(
            lambda: steps["2d"][0](grads, states["2d"], rng,
                                   steps["2d"][1])[0],
            lambda: steps["1d"][0](grads, states["1d"], rng,
                                   steps["1d"][1])[0])
        t2, t1 = float(np.min(s2)), float(np.min(s1))
        rows.append({
            "name": f"step/{vname}_2d_mesh_vs_1d",
            "us_per_call": t2 * 1e6,
            **_pcts(s2),
            "derived": f"variant={variant} mesh2d=4x2 mesh1d=8 "
                       f"one_d_us={t1 * 1e6:.1f} "
                       f"one_d_p99_us={np.percentile(s1, 99) * 1e6:.1f} "
                       f"weak_scaling_efficiency={t1 / t2:.2f} "
                       f"{m_txt}"
                       f"compress_q={q} "
                       f"gather_mb_raw={bytes_['uncompressed'] / 2**20:.2f} "
                       f"gather_mb_wire={bytes_['on_wire'] / 2**20:.2f} "
                       f"bytes_reduction={reduction:.2f}x "
                       f"reduction_ge4={reduction >= 4.0} "
                       f"allclose=True compressed_finite={bool(finite)} "
                       f"(CPU mesh: shared host cores — per-device M "
                       f"bytes and gather bytes-on-wire are the scaling "
                       f"artifacts, not wall time)",
        })
    return rows


def run_staggered(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Spiky (all heavy on k % T == 0) vs staggered (phase offsets spread
    across the T window) schedules: per-step wall times over several full
    cycles, p50/p99 recorded; equal mean cadence asserted by slot count."""
    T = 8
    pol = policy.PolicyConfig(variant="kfac", r=32 if quick else 96)
    rows_cfg = {
        "spiky": kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                                     T_updt=1, T_inv=T, stagger=False),
        "staggered": kfac_lib.KfacConfig(policy=pol,
                                         lr=optbase.constant(0.05),
                                         T_updt=1, T_inv=T, stagger=True,
                                         stagger_splits=T),
    }
    cycles_warm, cycles_timed = 2, 4

    def slots(work):
        return sum(hi - lo for r in work.heavy for lo, hi in r)

    runs, cadence = {}, {}
    for label, cfg in rows_cfg.items():
        opt = kfac_lib.Kfac(cfg, taps)
        sched = opt.scheduler()
        step = _sched_step_fn(opt, params, acts, pgs, N)
        st = opt.init(params)
        rng = jax.random.PRNGKey(3)
        cadence[label] = sum(slots(sched.work(k)) for k in range(T, 2 * T))
        # warm every distinct mask (compile), advancing past step-0 warmup
        for k in range(cycles_warm * T):
            _, st = step(grads, st, jax.random.fold_in(rng, k),
                         sched.work(k))
        runs[label] = dict(step=step, st=st, sched=sched, rng=rng,
                           prof=[[] for _ in range(T)])
    assert cadence["spiky"] == cadence["staggered"], cadence
    # interleave whole cycles of the two schedules so shared-CPU
    # contention bursts hit both; per step-index keep the min over
    # cycles (the calm-case per-step profile — the spike is a property
    # of the schedule, the bursts are not)
    for c in range(cycles_timed):
        for label in rows_cfg:
            r = runs[label]
            k0 = (cycles_warm + c) * T
            for k in range(k0, k0 + T):
                w = r["sched"].work(k)
                t0 = time.perf_counter()
                upd, r["st"] = r["step"](grads, r["st"],
                                         jax.random.fold_in(r["rng"], k), w)
                jax.block_until_ready(upd)
                r["prof"][k % T].append(time.perf_counter() - t0)
    spiky = [min(s) for s in runs["spiky"]["prof"]]
    stag = [min(s) for s in runs["staggered"]["prof"]]
    rows = [{
        "name": "step/staggered_vs_spiky",
        "us_per_call": float(np.percentile(stag, 50) * 1e6),
        **_pcts(stag),
        "derived": f"T_inv={T} cycles_timed={cycles_timed} "
                   f"profile=min-per-step-index "
                   f"spiky_p50_us={np.percentile(spiky, 50) * 1e6:.1f} "
                   f"spiky_p99_us={np.percentile(spiky, 99) * 1e6:.1f} "
                   f"stag_p99/spiky_p99="
                   f"{np.percentile(stag, 99) / np.percentile(spiky, 99):.2f} "
                   f"heavy_slots_per_cycle={cadence['spiky']} "
                   f"(equal mean cadence) "
                   f"mean_us={np.mean(stag) * 1e6:.1f} "
                   f"spiky_mean_us={np.mean(spiky) * 1e6:.1f}",
    }]
    return rows


def run_async(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Async double-buffered heavy pipeline vs the staggered-synchronous
    baseline.  Two contracts:

      * exactness — ``lag=0`` (launch and land on the same step) is
        asserted allclose against the synchronous path, step by step,
        over two full schedule cycles;
      * perf — ``lag>0`` with the overlapped runner (heavy overwrites
        dispatched to a spare host device during the lag window) must
        beat the staggered-synchronous p99 per-step wall time at equal
        heavy cadence (landed slots per cycle == inline heavy slots per
        cycle, asserted) — the heavy compute leaves every step's
        critical path; only snapshot writes and array swaps remain.
    """
    import dataclasses as _dc

    from repro.train import loop as loop_lib

    # one unit per bucket: each heavy event is big enough that inline
    # execution is a visible spike, which is exactly what the pipeline
    # removes (finer staggering already flattens p99 by itself — async
    # then only helps on hardware where the offload device has its own
    # cores; CPU host devices share them)
    T, lag = 8, 4
    pol = policy.PolicyConfig(variant="kfac", r=32 if quick else 96)
    cfg_sync = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                                   T_updt=1, T_inv=T, stagger=True,
                                   stagger_splits=1)
    cfg_lag0 = _dc.replace(cfg_sync, async_heavy=True, heavy_lag=0)
    cfg_lagN = _dc.replace(cfg_sync, async_heavy=True, heavy_lag=lag)
    rng = jax.random.PRNGKey(3)

    def make(cfg):
        opt = kfac_lib.Kfac(cfg, taps)
        return opt, opt.scheduler(), _sched_step_fn(opt, params, acts,
                                                    pgs, N), opt.init(params)

    # -- exactness: lag=0 ≡ sync, step by step ------------------------------
    opt_s, sched_s, step_s, st_s = make(cfg_sync)
    opt_0, sched_0, step_0, st_0 = make(cfg_lag0)
    for k in range(2 * T):
        key = jax.random.fold_in(rng, k)
        upd_s, st_s = step_s(grads, st_s, key, sched_s.work(k))
        upd_0, st_0 = step_0(grads, st_0, key, sched_0.work(k))
        for name in taps:
            np.testing.assert_allclose(np.asarray(upd_0[name]["w"]),
                                       np.asarray(upd_s[name]["w"]),
                                       rtol=2e-3, atol=2e-3,
                                       err_msg=f"lag=0 step {k} {name}")

    # -- cadence: landed slots per cycle == sync heavy slots per cycle ------
    opt_a, sched_a, _, _ = make(cfg_lagN)

    def slots(ranges_tuple):
        return sum(hi - lo for r in ranges_tuple for lo, hi in r)

    lo_k, hi_k = 2 * T, 4 * T
    sync_slots = sum(slots(sched_s.work(k).heavy) for k in range(lo_k, hi_k))
    land_slots = sum(slots(sched_a.work(k).land) for k in range(lo_k, hi_k))
    assert sync_slots == land_slots, (sync_slots, land_slots)

    # -- timing: overlapped lag>0 vs staggered-sync -------------------------
    cycles_warm, cycles_timed = 2, 4
    runs = {}
    for label, cfg in (("sync", cfg_sync), ("async", cfg_lagN)):
        opt, sched, step, st = make(cfg)
        runner = (loop_lib.AsyncInverseRunner.for_opt(opt)
                  if label == "async" else None)
        # warm every distinct (mask, landing-structure) variant
        for k in range(cycles_warm * T):
            w = sched.work(k)
            landing = runner.landing(w) if runner else None
            _, st = step(grads, st, jax.random.fold_in(rng, k), w, landing)
            if runner:
                runner.launch(st, w)
        runs[label] = dict(step=step, st=st, sched=sched, runner=runner,
                           prof=[[] for _ in range(T)])
    for c in range(cycles_timed):
        for label in runs:
            r = runs[label]
            k0 = (cycles_warm + c) * T
            for k in range(k0, k0 + T):
                w = r["sched"].work(k)
                t0 = time.perf_counter()
                landing = (r["runner"].landing(w) if r["runner"]
                           else None)
                upd, r["st"] = r["step"](grads, r["st"],
                                         jax.random.fold_in(rng, k), w,
                                         landing)
                jax.block_until_ready(upd)
                if r["runner"]:
                    r["runner"].launch(r["st"], w)
                r["prof"][k % T].append(time.perf_counter() - t0)
    runner = runs["async"]["runner"]
    health = dict(runner.health) if runner else {}
    if runner:
        runner.close()
    sync = [min(s) for s in runs["sync"]["prof"]]
    asy = [min(s) for s in runs["async"]["prof"]]
    # pipeline-health accounting: a missed landing silently falls back to
    # in-graph recompute — same numbers, none of the overlap win — so the
    # regression gate treats a risen miss count (or overlap_healthy=False)
    # as a failure even when the timing still looks fine
    missed = int(health.get("missed", 0))
    return [{
        "name": "step/async_vs_sync",
        "us_per_call": float(np.percentile(asy, 50) * 1e6),
        **_pcts(asy),
        "derived": f"T_inv={T} lag={lag} profile=min-per-step-index "
                   f"sync_p50_us={np.percentile(sync, 50) * 1e6:.1f} "
                   f"sync_p99_us={np.percentile(sync, 99) * 1e6:.1f} "
                   f"async_p99/sync_p99="
                   f"{np.percentile(asy, 99) / np.percentile(sync, 99):.2f} "
                   f"landed_slots_per_cycle={land_slots} "
                   f"(equal heavy cadence) lag0_allclose=True "
                   f"async_launched={int(health.get('launched', 0))} "
                   f"async_landed={int(health.get('landed', 0))} "
                   f"async_missed={missed} "
                   f"overlap_healthy={missed == 0} "
                   f"offload={'spare device' if len(jax.devices()) > 1 else 'in-thread'}",
    }]


def run_telemetry(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Telemetry overhead at default cadence: the in-graph Meter
    (collector + buffer merge + lax.cond'ed io_callback flush) wrapped
    around the light-path ``Kfac.update`` vs the same step bare.  The
    gated claim is ``telemetry_inert=True`` — the instrumented step's
    update must be *bit-identical* to the bare one (metrics only read
    hot-path values); the overhead percentage is recorded for the
    artifact but not claimed (shared-CPU timing of a ~0 cost is noise).
    """
    from repro.obs import metrics as obs_metrics

    opt = _opt(taps, bucketed=True, quick=quick, variant="bkfac")
    work = opt.uniform_work(True, True, False)
    meter = obs_metrics.Meter(obs_metrics.catalog_for(opt),
                              lambda *a: None, every=10)
    rng = jax.random.PRNGKey(11)

    def step_off(grads, state, rng, work):
        return opt.update(grads, state, params, acts=acts, probe_grads=pgs,
                          n_tokens=N, rng=rng, work=work)

    def step_on(grads, state, rng, work, mbuf):
        with meter.collecting() as col:
            upd, st = opt.update(grads, state, params, acts=acts,
                                 probe_grads=pgs, n_tokens=N, rng=rng,
                                 work=work)
        return upd, st, meter.maybe_flush(meter.merge(mbuf, col), st.step)

    step_off = jax.jit(step_off, static_argnames=("work",))
    step_on = jax.jit(step_on, static_argnames=("work",))
    st = opt.init(params)
    _, st = step_off(grads, st, rng, work)      # warm state past init
    mbuf = meter.init()
    upd_off, _ = step_off(grads, st, rng, work)
    upd_on, _, _ = step_on(grads, st, rng, work, mbuf)
    inert = all(
        np.array_equal(np.asarray(upd_on[name]["w"]),
                       np.asarray(upd_off[name]["w"]))
        for name in taps)
    son, soff = _timeit_pair(
        lambda: step_on(grads, st, rng, work, mbuf)[0],
        lambda: step_off(grads, st, rng, work)[0])
    t_on, t_off = float(np.min(son)), float(np.min(soff))
    return [{
        "name": "step/telemetry_on_vs_off",
        "us_per_call": t_on * 1e6,
        **_pcts(son),
        "derived": f"off_us={t_off * 1e6:.1f} "
                   f"off_p99_us={np.percentile(soff, 99) * 1e6:.1f} "
                   f"overhead_pct={(t_on / t_off - 1.0) * 100:.1f} "
                   f"metrics_every={meter.every} "
                   f"catalog_size={len(meter.catalog)} "
                   f"telemetry_inert={bool(inert)}",
    }]


def run_health(taps, params, grads, acts, pgs, N, quick) -> List[dict]:
    """Resilience-guard overhead: the in-graph health report + guarded
    ``where`` select (train/health.py) wrapped around the light-path
    ``Kfac.update`` vs the same step bare.  The gated claim is
    ``health_inert=True`` — on a healthy step the guarded path's update
    must be *bit-identical* to the bare one: the report only reads
    hot-path values, the final select picks the new values exactly, and
    the un-escalated damping scale multiplies φ by exactly 1.0.  The
    overhead percentage is recorded for the artifact but not claimed
    (shared-CPU timing of a ~0 cost is noise)."""
    import jax.numpy as jnp

    from repro.train import health as health_lib

    opt = _opt(taps, bucketed=True, quick=quick, variant="bkfac")
    work = opt.uniform_work(True, True, False)
    hcfg = health_lib.HealthConfig()
    rng = jax.random.PRNGKey(13)

    def step_off(grads, state, rng, work):
        return opt.update(grads, state, params, acts=acts, probe_grads=pgs,
                          n_tokens=N, rng=rng, work=work)

    def step_on(grads, state, rng, work, scale):
        upd, st = opt.update(grads, state, params, acts=acts,
                             probe_grads=pgs, n_tokens=N, rng=rng,
                             work=work, damping_scale=scale)
        rep = health_lib.health_report(hcfg, opt, jnp.float32(0.0),
                                       grads, upd, st)
        ok = rep["ok"] > 0
        upd = health_lib._select(
            ok, upd, jax.tree_util.tree_map(jnp.zeros_like, upd))
        st = health_lib._select(ok, st, state)
        return upd, st, rep

    step_off = jax.jit(step_off, static_argnames=("work",))
    step_on = jax.jit(step_on, static_argnames=("work",))
    st = opt.init(params)
    _, st = step_off(grads, st, rng, work)      # warm state past init
    scale = jnp.float32(1.0)
    upd_off, _ = step_off(grads, st, rng, work)
    upd_on, _, rep = step_on(grads, st, rng, work, scale)
    assert float(rep["ok"]) == 1.0
    inert = all(
        np.array_equal(np.asarray(upd_on[name]["w"]),
                       np.asarray(upd_off[name]["w"]))
        for name in taps)
    son, soff = _timeit_pair(
        lambda: step_on(grads, st, rng, work, scale)[0],
        lambda: step_off(grads, st, rng, work)[0])
    t_on, t_off = float(np.min(son)), float(np.min(soff))
    return [{
        "name": "step/health_on_vs_off",
        "us_per_call": t_on * 1e6,
        **_pcts(son),
        "derived": f"off_us={t_off * 1e6:.1f} "
                   f"off_p99_us={np.percentile(soff, 99) * 1e6:.1f} "
                   f"overhead_pct={(t_on / t_off - 1.0) * 100:.1f} "
                   f"guard_checks={len(rep)} "
                   f"health_inert={bool(inert)}",
    }]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=str, default=None,
                    help="write a JSON artifact (e.g. BENCH_step.json)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for row in rows:
        print(row)
    if args.out:
        artifact = {
            "bench": "step",
            "backend": jax.default_backend(),
            "quick": bool(args.quick),
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""Optimizer-level step benchmark: one full ``Kfac.update`` on a mixed-shape
tap set (FC + scanned stack + MoE stack), bucketed vs per-tap, for each
static step variant (stats / light / heavy).

This is the end-to-end number the kernel micro-bench cannot see: the
cross-layer bucketing subsystem (core/buckets.py) collapses the per-tap
python loop — O(#layers) small launches — into O(#shape-classes) batched
launches, and this bench records both the measured step time and the
launch-group counts for each path.  Parity (allclose) between the two
paths is asserted at bench shapes before timing.

Usage:  python benchmarks/step_bench.py [--quick] [--out BENCH_step.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np
import jax

from repro.core import kfac as kfac_lib
from repro.core import policy
from repro.optim import base as optbase


def _timeit_pair(fn_a, fn_b, reps=25, warmup=5, rounds=3):
    """Min over several independent rounds of *interleaved* reps for two
    closures.  Interleaving makes host load hit both sides equally, the
    warmup lets post-compile background work (jit cache writes, GC)
    settle, and spreading the reps across separate rounds widens the
    total window so each side catches at least one calm stretch —
    shared-CPU contention bursts routinely outlast a single tight rep
    loop (comparative CPU timing)."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(rounds):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_a())
            ta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fn_b())
            tb.append(time.perf_counter() - t0)
        time.sleep(0.2)
    return float(np.min(ta)), float(np.min(tb))


def _make_model(quick: bool):
    """A mixed-shape tapped 'network' in the regime bucketing targets: an
    *unrolled* transformer trunk (many separately-named taps repeating two
    matmul shapes — the per-tap python loop launches each one on its own)
    plus a scanned block stack and a two-level MoE stack.  Everything
    collapses to two factor shape classes per side."""
    d, dff, L, E, N, n_blk = ((128, 192, 4, 2, 32, 4) if quick
                              else (256, 512, 6, 4, 64, 8))
    taps = {
        "embed_out": kfac_lib.TapInfo("embed_out/w", d, dff, n_stat=N),
        "head_in":   kfac_lib.TapInfo("head_in/w", dff, d, n_stat=N),
        "scan":      kfac_lib.TapInfo("scan/w", d, dff, stack=(L,),
                                      n_stat=N),
        "experts":   kfac_lib.TapInfo("experts/w", d, dff,
                                      stack=(L // 2, E), n_stat=N),
    }
    for i in range(n_blk):   # the unrolled trunk: 2 taps per block
        taps[f"blk{i}_in"] = kfac_lib.TapInfo(f"blk{i}_in/w", d, dff,
                                              n_stat=N)
        taps[f"blk{i}_out"] = kfac_lib.TapInfo(f"blk{i}_out/w", dff, d,
                                               n_stat=N)
    key = jax.random.PRNGKey(0)
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (name, t) in enumerate(taps.items()):
        shp = t.stack + (t.d_in, t.d_out)
        params[name] = {"w": jax.random.normal(
            jax.random.fold_in(key, i), shp) * 0.05}
        grads[name] = {"w": jax.random.normal(
            jax.random.fold_in(key, 10 + i), shp)}
        acts[name] = jax.random.normal(
            jax.random.fold_in(key, 20 + i), t.stack + (t.n_stat, t.d_in))
        pgs[name] = jax.random.normal(
            jax.random.fold_in(key, 30 + i),
            t.stack + (t.n_stat, t.d_out)) * 1e-3
    return taps, params, grads, acts, pgs, N


def _opt(taps, bucketed: bool, quick: bool, variant: str = "bkfac"):
    pol = policy.PolicyConfig(variant=variant, r=32 if quick else 96)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              T_updt=1, T_brand=1, bucketed=bucketed)
    return kfac_lib.Kfac(cfg, taps)


def _step_fn(opt, params, acts, pgs, n_tokens, flags):
    do_stats, do_light, do_heavy = flags

    @jax.jit
    def step(grads, state, rng):
        return opt.update(grads, state, params, acts=acts, probe_grads=pgs,
                          n_tokens=n_tokens, rng=rng, do_stats=do_stats,
                          do_light=do_light, do_heavy=do_heavy)
    return step


def run(quick: bool = False) -> List[dict]:
    taps, params, grads, acts, pgs, N = _make_model(quick)
    rng = jax.random.PRNGKey(42)
    # stats/light time the B-KFAC hot path (all-BRAND factors, where
    # do_heavy is a no-op); the heavy row uses the K-FAC baseline so the
    # periodic overwrite is both *live* and deterministic (an EVD — a
    # randomized overwrite would break the bucketed-vs-per-tap parity
    # assert, since the two paths draw different keys).
    variants = {
        "stats": ("bkfac", (True, False, False)),
        "light": ("bkfac", (True, True, False)),
        "heavy": ("kfac", (True, False, True)),
    }
    rows = []
    n_taps = len(taps)
    for vname, (variant, flags) in variants.items():
        opt_b = _opt(taps, bucketed=True, quick=quick, variant=variant)
        opt_p = _opt(taps, bucketed=False, quick=quick, variant=variant)
        # launch-group counts: factor work + preconditioning, per step
        launches_b = len(opt_b.factor_buckets) + len(opt_b.precond_buckets)
        launches_p = 2 * n_taps + n_taps
        # warm one stats step so the timed step runs on a populated state
        # (first-step init takes a different branch)
        st_b = opt_b.init(params)
        st_p = opt_p.init(params)
        warm_flags = (True, False, False)
        warm = _step_fn(opt_b, params, acts, pgs, N, warm_flags)
        _, st_b = warm(grads, st_b, rng)
        warm_p = _step_fn(opt_p, params, acts, pgs, N, warm_flags)
        _, st_p = warm_p(grads, st_p, rng)

        step_b = _step_fn(opt_b, params, acts, pgs, N, flags)
        step_p = _step_fn(opt_p, params, acts, pgs, N, flags)
        upd_b, _ = step_b(grads, st_b, rng)
        upd_p, _ = step_p(grads, st_p, rng)
        for name in taps:
            np.testing.assert_allclose(np.asarray(upd_b[name]["w"]),
                                       np.asarray(upd_p[name]["w"]),
                                       rtol=2e-3, atol=2e-3)
        t_b, t_p = _timeit_pair(lambda: step_b(grads, st_b, rng)[0],
                                lambda: step_p(grads, st_p, rng)[0])
        rows.append({
            "name": f"step/{vname}_bucketed_vs_per_tap",
            "us_per_call": t_b * 1e6,
            "derived": f"variant={variant} per_tap_us={t_p * 1e6:.1f} "
                       f"speedup={t_p / t_b:.2f}x "
                       f"launch_groups={launches_b}vs{launches_p} "
                       f"taps={n_taps} "
                       f"factor_buckets={len(opt_b.factor_buckets)} "
                       f"precond_buckets={len(opt_b.precond_buckets)} "
                       f"allclose=True",
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=str, default=None,
                    help="write a JSON artifact (e.g. BENCH_step.json)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for row in rows:
        print(row)
    if args.out:
        artifact = {
            "bench": "step",
            "backend": jax.default_backend(),
            "quick": bool(args.quick),
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

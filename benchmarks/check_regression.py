"""CI perf-regression gate: diff a fresh quick-mode benchmark run against
the committed ``BENCH_step.json`` / ``BENCH_kernels.json`` baselines.

The benches have asserted *correctness* (parity allcloses, cadence
equalities, paper claims) since PR 1-3, and their artifacts have been
uploaded from CI since PR 2 — but nothing ever FAILED when a number
regressed.  This script closes that loop:

  * **parity flip** — any fresh row whose ``derived`` string carries a
    ``…=False`` marker (or a bare ``False`` claim row) fails outright;
    rows that asserted ``allclose=True`` in the baseline must still say
    so.  (A parity *assert* that trips aborts the bench process, which
    fails the gate by construction.)
  * **missing row** — every baseline row must exist in the fresh run
    (new rows are fine: that is how benches grow).
  * **p50 regression** — a fresh row's p50 per-step/per-call time may not
    exceed its baseline by more than ``--threshold`` (default 20%),
    *after machine-speed normalization*: baselines are committed from
    whatever machine produced them, so absolute times are meaningless
    across hosts.  The scale comes from a **calibration workload** — a
    fixed numpy GEMM loop, independent of the repo's code — measured at
    gate time and stamped into every artifact as ``calibration_us``.
    Its fresh/baseline ratio moves with machine speed only, so a
    uniform *code* slowdown (every bench row 2x slower) cannot
    normalize itself away.  When the committed baseline predates the
    calibration stamp, the fallback scale is the median over the
    *fastest* rows' fresh/baseline ratios (those within threshold of
    the minimum ratio): a machine-speed shift moves every row by the
    same factor, while regressed rows sit above it — medianing over ALL
    rows, as this gate originally did, let any majority-uniform real
    slowdown self-normalize and trip nothing.

Shared-runner noise defense, two layers:

  * a bench whose rows regressed is re-run (up to ``--retries`` times)
    and each row keeps its per-run MINIMUM — a load burst must hit every
    run of a row to produce a false positive, while a real regression
    persists through all of them.  Only timing failures retry; parity
    flips and missing rows fail immediately.
  * ``--update-baseline`` runs each bench ``retries+1`` times and
    commits, per row, the minimum (the hardware floor) plus the observed
    max/min spread as ``p50_noise``.  The gate then requires a
    regression to exceed ``(1+threshold) x`` the row's own demonstrated
    run-to-run noise (capped at ``--noise-cap``): a 2ms kernel that
    jitters 30% between back-to-back runs is not held to a 20% band its
    own baseline couldn't reproduce, while stable rows keep the tight
    gate.

``--update-baseline`` replaces the committed artifacts with the fresh
run (commit the result).  Exit code: 0 = green, 1 = regression(s).

Usage:
    python benchmarks/check_regression.py [--quick] [--threshold 0.2]
        [--baseline-dir .] [--update-baseline] [--skip-run]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

#: bench id → (script, committed baseline artifact)
BENCHES = {
    "step": ("step_bench.py", "BENCH_step.json"),
    "kernels": ("kernels_bench.py", "BENCH_kernels.json"),
    "serve": ("serve_bench.py", "BENCH_serve.json"),
}

_FALSE_MARK = re.compile(r"\b\w+=False\b")
_ASYNC_MISS = re.compile(r"\basync_missed=(\d+)\b")


def measure_calibration(reps: int = 5) -> float:
    """Machine-speed reference: a fixed numpy workload (chained BLAS
    GEMMs) whose runtime depends on the host, never on this repo's code.
    min-of-reps in microseconds — the same hardware-floor statistic the
    bench rows use."""
    import numpy as _np
    rng = _np.random.default_rng(0)
    A = rng.standard_normal((384, 384)).astype(_np.float32) * 0.05
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        B = A
        for _ in range(8):
            B = B @ A
        float(B.sum())              # force materialization
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run_bench(script: str, out_path: str, quick: bool) -> None:
    cmd = [sys.executable, os.path.join(HERE, script),
           "--out", out_path] + (["--quick"] if quick else [])
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # a parity-assert trip inside the bench aborts it → non-zero → gate red
    subprocess.run(cmd, check=True, env=env, cwd=REPO)


def load_artifact(path: str) -> Tuple[Dict[str, dict], Optional[float]]:
    """→ (rows by name, calibration_us or None for pre-stamp artifacts)."""
    with open(path) as f:
        artifact = json.load(f)
    cal = artifact.get("calibration_us")
    return ({r["name"]: r for r in artifact["rows"]},
            float(cal) if cal else None)


def load_rows(path: str) -> Dict[str, dict]:
    return load_artifact(path)[0]


def stamp_calibration(path: str, cal_us: float) -> None:
    """Write the gate-time calibration measurement into an artifact (the
    bench scripts don't know about it; the gate owns the stamp)."""
    with open(path) as f:
        artifact = json.load(f)
    artifact["calibration_us"] = round(float(cal_us), 1)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")


def row_p50(row: dict) -> Optional[float]:
    """The row's timing stat: p50 when recorded, us_per_call otherwise
    (kernel micro-bench rows); None for pure-claim rows."""
    v = row.get("p50_us", row.get("us_per_call"))
    return float(v) if v else None


def parity_failures(rows: Dict[str, dict], label: str) -> List[str]:
    out = []
    for name, row in rows.items():
        derived = str(row.get("derived", ""))
        if derived.strip() == "False":
            out.append(f"{label}: claim row {name} is False")
        for m in _FALSE_MARK.findall(derived):
            out.append(f"{label}: {name} reports {m}")
    return out


def async_health_failures(base: Dict[str, dict], fresh: Dict[str, dict],
                          label: str) -> List[str]:
    """A silently-degraded overlap runner still produces correct numbers
    (missed landings fall back to in-graph recompute), so timing and
    parity gates can both stay green while the pipeline rots.  Gate on
    the recorded health counters instead: a fresh row's async miss count
    may not exceed its baseline's (0 for a healthy pipeline)."""
    out = []
    for name, row in fresh.items():
        m = _ASYNC_MISS.search(str(row.get("derived", "")))
        if m is None:
            continue
        missed = int(m.group(1))
        base_m = _ASYNC_MISS.search(
            str(base.get(name, {}).get("derived", "")))
        allowed = int(base_m.group(1)) if base_m else 0
        if missed > allowed:
            out.append(f"{label}: {name} async pipeline degraded — "
                       f"{missed} missed landing(s) vs {allowed} in "
                       f"baseline (overlap silently falling back to "
                       f"in-graph recompute)")
    return out


def merge_min(a: Dict[str, dict], b: Dict[str, dict],
              track_noise: bool = False) -> Dict[str, dict]:
    """Per-row minimum of the timing stats across two runs (noise-floor
    estimate); non-timing fields keep the latest run's values.
    ``track_noise`` additionally accumulates the observed max/min spread
    of the gating stat into ``p50_noise`` (baseline updates)."""
    out = dict(b)
    for name, row_a in a.items():
        if name not in out:
            out[name] = row_a
            continue
        row = dict(out[name])
        if track_noise:
            pa, pb = row_p50(row_a), row_p50(row)
            if pa and pb:
                spread = max(pa, pb) / min(pa, pb)
                prior = max(row.get("p50_noise", 1.0),
                            row_a.get("p50_noise", 1.0))
                row["p50_noise"] = round(max(prior, spread), 3)
        for stat in ("us_per_call", "p50_us", "p99_us"):
            if stat in row and stat in row_a:
                row[stat] = min(row[stat], row_a[stat])
        out[name] = row
    return out


def machine_scale(ratios: List[float], threshold: float,
                  base_cal: Optional[float] = None,
                  fresh_cal: Optional[float] = None
                  ) -> Tuple[float, str]:
    """Machine-speed normalization factor for fresh/baseline timings.

    Preferred source: the calibration workload's own fresh/base ratio —
    it cannot be moved by a regression in the repo's code, so a uniform
    real slowdown of every bench row stays visible.  Fallback (baseline
    predates the stamp): the median over the *fastest* rows' ratios,
    where "fastest" = within (1+threshold) of the minimum ratio.  A
    machine-speed shift moves every row by the same factor so the
    fastest rows track it; genuinely regressed rows sit above the band
    and are excluded — unlike an all-rows median, which a slowdown
    hitting half the fleet (or all of it uniformly) drags along with
    itself."""
    if base_cal and fresh_cal:
        return (fresh_cal / base_cal,
                f"calibration {base_cal:.0f}us -> {fresh_cal:.0f}us")
    srt = sorted(ratios)
    pool = [r for r in srt if r <= srt[0] * (1.0 + threshold)]
    return (pool[len(pool) // 2],
            f"median of {len(pool)}/{len(srt)} fastest-row ratios; "
            f"no calibration in baseline")


def compare(base: Dict[str, dict], fresh: Dict[str, dict],
            threshold: float, label: str, noise_cap: float = 2.0,
            base_cal: Optional[float] = None,
            fresh_cal: Optional[float] = None
            ) -> Tuple[List[str], List[str]]:
    """→ (failures, report lines)."""
    failures = list(parity_failures(fresh, label))
    failures.extend(async_health_failures(base, fresh, label))
    common = []
    for name in base:
        if name not in fresh:
            failures.append(f"{label}: baseline row {name} missing from "
                            f"fresh run")
            continue
        b, f = row_p50(base[name]), row_p50(fresh[name])
        if b and f:
            noise = min(float(base[name].get("p50_noise", 1.0)),
                        noise_cap)
            common.append((name, b, f, max(noise, 1.0)))
    if not common:
        return failures, [f"{label}: no timed rows in common"]
    scale, scale_src = machine_scale([f / b for _, b, f, _ in common],
                                     threshold, base_cal, fresh_cal)
    report = [f"{label}: machine-speed scale = {scale:.2f}x "
              f"({scale_src}), threshold = +{threshold:.0%} x per-row "
              f"observed noise"]
    for name, b, f, noise in common:
        norm = f / (b * scale)
        allowed = (1.0 + threshold) * noise
        flag = ""
        if norm > allowed:
            failures.append(
                f"{label}: {name} p50 regressed {norm - 1.0:+.0%} "
                f"(baseline {b:.0f}us -> fresh {f:.0f}us scale-adjusted; "
                f"allowed +{allowed - 1.0:.0%} = threshold x observed "
                f"noise {noise:.2f}x)")
            flag = "  <-- REGRESSED"
        report.append(f"  {name:45s} base {b:10.0f}us  fresh "
                      f"{f:10.0f}us  norm {norm:5.2f}x "
                      f"(allow {allowed:4.2f}x){flag}")
    return failures, report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True,
                    help="run the benches in quick mode (default; the "
                         "committed baselines are quick-mode)")
    ap.add_argument("--full", dest="quick", action="store_false")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed normalized p50 regression (0.20 = 20%%)")
    ap.add_argument("--baseline-dir", default=REPO,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="replace the committed baselines with this run")
    ap.add_argument("--skip-run", action="store_true",
                    help="compare existing --fresh-dir artifacts instead "
                         "of running the benches")
    ap.add_argument("--fresh-dir", default=None,
                    help="where to write (or find, with --skip-run) the "
                         "fresh artifacts; default: a temp dir")
    ap.add_argument("--retries", type=int, default=2,
                    help="re-runs of a bench whose rows regressed (each "
                         "row keeps its per-run minimum)")
    ap.add_argument("--noise-cap", type=float, default=2.0,
                    help="cap on the per-row observed-noise multiplier "
                         "(keeps the gate meaningful for very jittery "
                         "rows)")
    ap.add_argument("--only", action="append", choices=sorted(BENCHES),
                    default=None, metavar="BENCH",
                    help="gate only the named bench(es) (repeatable); "
                         "default: all of them.  Baseline updates honor "
                         "it too, so one bench's baseline can be "
                         "refreshed without re-timing the others")
    args = ap.parse_args()
    benches = {k: v for k, v in BENCHES.items()
               if args.only is None or k in args.only}

    fresh_dir = args.fresh_dir or tempfile.mkdtemp(prefix="bench_fresh_")
    os.makedirs(fresh_dir, exist_ok=True)
    cal_us = measure_calibration()
    print(f"calibration workload: {cal_us:.0f}us "
          f"(machine-speed reference)")

    def run_and_stamp(script: str, path: str) -> None:
        run_bench(script, path, args.quick)
        stamp_calibration(path, cal_us)

    failures: List[str] = []
    for bench, (script, artifact) in benches.items():
        fresh_path = os.path.join(fresh_dir, artifact)
        if not args.skip_run:
            run_and_stamp(script, fresh_path)
        fresh, fresh_cal = load_artifact(fresh_path)
        if args.update_baseline and not args.skip_run:
            # a committed baseline should be the row-wise noise *floor*:
            # min-of-runs is hardware-bound from below, so extra runs only
            # tighten it — and the max/min spread across those runs is
            # the row's demonstrated run-to-run noise, committed as
            # p50_noise and honored by every future gate
            for _ in range(args.retries):
                run_and_stamp(script, fresh_path)
                fresh = merge_min(fresh, load_rows(fresh_path),
                                  track_noise=True)
        base_path = os.path.join(args.baseline_dir, artifact)
        if not os.path.exists(base_path):
            if args.update_baseline:
                base, base_cal = fresh, fresh_cal
            else:
                failures.append(
                    f"{bench}: no committed baseline {base_path} "
                    f"(run with --update-baseline to create it)")
                continue
        else:
            base, base_cal = load_artifact(base_path)
        fails, report = compare(base, fresh, args.threshold, bench,
                                args.noise_cap, base_cal, fresh_cal)
        retries = 0 if args.skip_run or args.update_baseline else \
            args.retries
        merged = False
        while retries and any("regressed" in f for f in fails):
            print(f"{bench}: timing regression(s) on a shared runner — "
                  f"re-running to separate load bursts from real "
                  f"regressions ({retries} "
                  f"retr{'y' if retries == 1 else 'ies'} left)")
            retries -= 1
            run_and_stamp(script, fresh_path)
            fresh = merge_min(fresh, load_rows(fresh_path))
            merged = True
            fails, report = compare(base, fresh, args.threshold, bench,
                                    args.noise_cap, base_cal, fresh_cal)
        if merged:
            # the artifact on disk must be the rows the gate actually
            # judged, not the last raw re-run — anyone debugging from the
            # uploaded JSON (or re-checking with --skip-run) sees the
            # same numbers this comparison used
            with open(fresh_path) as f:
                artifact_json = json.load(f)
            artifact_json["rows"] = [fresh[r["name"]]
                                     for r in artifact_json["rows"]]
            with open(fresh_path, "w") as f:
                json.dump(artifact_json, f, indent=2)
                f.write("\n")
        print("\n".join(report))
        failures.extend(fails)
        if args.update_baseline:
            with open(fresh_path) as f:
                artifact_json = json.load(f)
            artifact_json["rows"] = [fresh[r["name"]]
                                     for r in artifact_json["rows"]]
            with open(base_path, "w") as f:
                json.dump(artifact_json, f, indent=2)
                f.write("\n")
            print(f"{bench}: baseline {base_path} updated")
    if failures and not args.update_baseline:
        print("\nFAIL: " + "\n      ".join(failures))
        return 1
    if failures:
        print("\n(update-baseline: ignoring "
              f"{len(failures)} comparison failure(s))")
    print("\nOK: benchmarks within threshold of committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pallas kernel micro-bench: per-kernel timing (interpret-validated; on
CPU the oracle path is timed — the kernels are TPU-targeted) + allclose
check against the ref oracle at bench shapes."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref, ops


def _timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(quick: bool = False) -> List[dict]:
    d, n, w, p = (1024, 256, 256, 512) if quick else (4096, 512, 768, 1024)
    key = jax.random.PRNGKey(0)
    M = jax.random.normal(key, (d, d)); M = (M + M.T) / 2
    X = jax.random.normal(jax.random.fold_in(key, 1), (d, n))
    U, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 2),
                                           (d, w)))
    s = -jax.random.uniform(jax.random.fold_in(key, 3), (w,)) * 0.5
    J = jax.random.normal(jax.random.fold_in(key, 4), (p, d))
    lam = jnp.asarray(0.5)

    rows = []
    cases = [
        ("ea_syrk", lambda: ops.ea_syrk(M, X, 0.95, False),
         lambda: ref.ea_syrk(M, X, 0.95, False),
         2.0 * d * d * n),
        ("brand_panel", lambda: ops.brand_panel(U, X)[1],
         lambda: ref.brand_panel(U, X)[1],
         4.0 * d * w * n),
        ("lowrank_apply", lambda: ops.lowrank_apply(J, U, s, lam),
         lambda: ref.lowrank_apply(J, U, s, lam),
         4.0 * p * d * w),
    ]
    for name, op_fn, ref_fn, flops in cases:
        got = np.asarray(op_fn())
        want = np.asarray(ref_fn())
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        t = _timeit(jax.jit(op_fn))
        rows.append({"name": f"kernels/{name}", "us_per_call": t * 1e6,
                     "derived": f"gflops={flops/t/1e9:.1f} allclose=True"})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)

"""Pallas kernel micro-bench: per-kernel timing (interpret-validated; on
CPU the oracle path is timed — the kernels are TPU-targeted) + allclose
check against the ref oracle at bench shapes.

Beyond the per-kernel rows this times the two dispatch upgrades:

  * fused vs unfused preconditioning — ``ops.precond_fused`` (one fused
    launch sequence, J resident) against the baseline two
    ``lowrank_apply`` round-trips with intermediate transposes;
  * batched vs vmap stacking — one stack-batched launch over (L, …)
    operands against ``jax.vmap`` of the per-layer 2D op.

Usage:  python benchmarks/kernels_bench.py [--quick] [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref, ops


def _timeit(fn, *args, reps=15):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _unfused_precond(J, U_g, s_g, lam_g, U_a, s_a, lam_a):
    """Baseline two-sided application: two lowrank_apply round-trips with
    intermediate transposes (what core/precond.py did before the fusion)."""
    M = ops.lowrank_apply(J, U_a, s_a, lam_a)
    return jnp.swapaxes(
        ops.lowrank_apply(jnp.swapaxes(M, -1, -2), U_g, s_g, lam_g),
        -1, -2)


def run(quick: bool = False) -> List[dict]:
    d, n, w, p = (1024, 256, 256, 512) if quick else (4096, 512, 768, 1024)
    L = 4 if quick else 8          # stack depth for the batched rows
    key = jax.random.PRNGKey(0)
    M = jax.random.normal(key, (d, d)); M = (M + M.T) / 2
    X = jax.random.normal(jax.random.fold_in(key, 1), (d, n))
    U, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 2),
                                           (d, w)))
    s = -jax.random.uniform(jax.random.fold_in(key, 3), (w,)) * 0.5
    J = jax.random.normal(jax.random.fold_in(key, 4), (p, d))
    lam = jnp.asarray(0.5)
    U_g, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 5),
                                             (p, w)))
    s_g = -jax.random.uniform(jax.random.fold_in(key, 6), (w,)) * 0.5

    # stacked operands (one extra leading layer axis)
    Ms = jnp.broadcast_to(M, (L, d, d))
    Xs = jax.random.normal(jax.random.fold_in(key, 7), (L, d, n))
    Js = jax.random.normal(jax.random.fold_in(key, 8), (L, p, d))
    Us = jnp.broadcast_to(U, (L, d, w))
    ss = jnp.broadcast_to(s, (L, w))
    lams = jnp.full((L,), 0.5)

    # block sizes the (shape-aware) dispatch would launch with on TPU —
    # recorded in the artifact so block-pick changes show up in the diffs
    pd, pn = ops._round_up(d, ops._LANE), ops._round_up(n, ops._LANE)
    pw = ops._round_up(w, ops._SUB)
    blk_syrk = "bm%d,bn%d,bk%d" % ops.syrk_blocks(pd, pn)
    blk_panel = "bk%d" % ops.panel_blocks(pd, pw, pn)
    blk_qr = "bk%d" % ops.cholqr_blocks(pd, pn)

    rows = []
    # operands are jit ARGUMENTS (not closure constants) so XLA cannot
    # constant-fold the benchmarked work away at compile time
    cases = [
        ("ea_syrk", lambda m, x: ops.ea_syrk(m, x, 0.95, False), (M, X),
         lambda: ref.ea_syrk(M, X, 0.95, False),
         2.0 * d * d * n, blk_syrk),
        ("brand_panel", lambda u, x: ops.brand_panel(u, x)[1], (U, X),
         lambda: ref.brand_panel(U, X)[1],
         4.0 * d * w * n, blk_panel),
        ("cholqr2", lambda a: ops.cholqr2(a)[0], (X,),
         lambda: ref.cholqr2(X)[0],
         8.0 * d * n * n, blk_qr),
        ("lowrank_apply", ops.lowrank_apply, (J, U, s, lam),
         lambda: ref.lowrank_apply(J, U, s, lam),
         4.0 * p * d * w, None),
        ("precond_fused", ops.precond_fused, (J, U_g, s_g, lam, U, s, lam),
         lambda: ref.precond_fused(J, U_g, s_g, lam, U, s, lam),
         4.0 * p * d * w + 4.0 * p * d * w, None),
    ]
    for name, op_fn, args, ref_fn, flops, blocks in cases:
        got = np.asarray(op_fn(*args))
        want = np.asarray(ref_fn())
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        t = _timeit(jax.jit(op_fn), *args)
        derived = f"gflops={flops/t/1e9:.1f} allclose=True"
        if blocks:
            derived += f" blocks={blocks}"
        rows.append({"name": f"kernels/{name}", "us_per_call": t * 1e6,
                     "derived": derived})

    # CholeskyQR2 vs the Householder XLA QR it replaces in the Brand update
    t_cq = _timeit(jax.jit(lambda a: ops.cholqr2(a)[0]), X)
    t_hh = _timeit(jax.jit(lambda a: jnp.linalg.qr(a)[0]), X)
    rows.append({"name": "kernels/cholqr2_vs_householder",
                 "us_per_call": t_cq * 1e6,
                 "derived": f"householder_us={t_hh * 1e6:.1f} "
                            f"speedup={t_hh / t_cq:.2f}x"})

    # fused vs unfused two-sided application (same operands, same dispatch)
    fused_args = (J, U_g, s_g, lam, U, s, lam)
    t_fused = _timeit(jax.jit(ops.precond_fused), *fused_args)
    t_unfused = _timeit(jax.jit(_unfused_precond), *fused_args)
    rows.append({"name": "kernels/precond_fused_vs_unfused",
                 "us_per_call": t_fused * 1e6,
                 "derived": f"unfused_us={t_unfused * 1e6:.1f} "
                            f"speedup={t_unfused / t_fused:.2f}x"})

    # one batched stack launch vs jax.vmap lifting the per-layer 2D op
    for bname, batched_fn, vmap_fn, args in [
        ("ea_syrk",
         lambda m, x: ops.ea_syrk(m, x, 0.95, False),
         jax.vmap(lambda m, x: ops.ea_syrk(m, x, 0.95, False)),
         (Ms, Xs)),
        ("lowrank_apply",
         ops.lowrank_apply,
         jax.vmap(ops.lowrank_apply),
         (Js, Us, ss, lams)),
    ]:
        got = np.asarray(batched_fn(*args))
        want = np.asarray(vmap_fn(*args))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        t_b = _timeit(jax.jit(batched_fn), *args)
        t_v = _timeit(jax.jit(vmap_fn), *args)
        rows.append({"name": f"kernels/{bname}_batched_vs_vmap",
                     "us_per_call": t_b * 1e6,
                     "derived": f"stack={L} vmap_us={t_v * 1e6:.1f} "
                                f"speedup={t_v / t_b:.2f}x"})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=str, default=None,
                    help="write a JSON artifact (e.g. BENCH_kernels.json)")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for row in rows:
        print(row)
    if args.out:
        artifact = {
            "bench": "kernels",
            "backend": jax.default_backend(),
            "quick": bool(args.quick),
            "rows": rows,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

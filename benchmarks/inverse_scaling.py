"""Paper §3 complexity claims: K-factor inverse-update cost vs layer size.

  K-FAC  — dense EVD                O(d³)
  R-KFAC — RSVD                     O(d²(r+r_o))
  B-KFAC — symmetric Brand update   O(d(r+n)² + (r+n)⁴)  → linear in d

and inverse *application* (paper §5):
  dense solve O(d³) / low-rank apply O(d²r·…) quadratic / Alg 8 linear.

Measures wall time per call (jit-compiled, CPU), fits the log-log slope
over the d-sweep, and asserts the ordering. Emits CSV rows.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import brand, rsvd, precond

R, RO, NBS = 128, 10, 64


def _timeit(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _fit_slope(ds, ts):
    return float(np.polyfit(np.log(ds), np.log(ts), 1)[0])


def run(quick: bool = False) -> List[dict]:
    ds = [512, 1024, 2048] if quick else [512, 1024, 2048, 4096, 8192]
    rows = []
    times = {"kfac_evd": [], "rkfac_rsvd": [], "bkfac_brand": [],
             "apply_dense": [], "apply_lowrank": [], "apply_linear": []}
    key = jax.random.PRNGKey(0)
    for d in ds:
        r = min(R, d // 4)
        X = jax.random.normal(key, (d, NBS)) / np.sqrt(NBS)
        M = X @ X.T + 0.1 * jnp.eye(d)
        U, D = brand.init_from_factor(X, r + NBS)

        evd = jax.jit(lambda M: jnp.linalg.eigh(M))
        rs = jax.jit(lambda M, k: rsvd.rsvd_psd(M, r, RO, k))
        br = jax.jit(lambda U, D, X: brand.ea_brand_step(U, D, X, 0.95, r))
        times["kfac_evd"].append(_timeit(evd, M))
        times["rkfac_rsvd"].append(_timeit(rs, M, key))
        times["bkfac_brand"].append(_timeit(br, U, D, X))

        # inverse application to a gradient J = G Aᵀ of rank NBS
        G = jax.random.normal(key, (d, NBS))
        A = jax.random.normal(jax.random.fold_in(key, 1), (d, NBS))
        J = G @ A.T
        lam = jnp.asarray(0.1)
        dense = jax.jit(lambda J, M: precond.dense_inv_apply(
            J, M, lam, M, lam))
        lowrank = jax.jit(lambda J, U, D: precond.kfac_precondition(
            J, U, D, lam, U, D, lam))
        linear = jax.jit(lambda G, A, U, D: precond.kfac_precondition_linear(
            G, A, U, D, lam, U, D, lam))
        if d <= 4096:
            times["apply_dense"].append(_timeit(dense, J, M))
        times["apply_lowrank"].append(_timeit(lowrank, J, U, D))
        times["apply_linear"].append(_timeit(linear, G, A, U, D))

    for name, ts in times.items():
        dd = ds[: len(ts)]
        slope = _fit_slope(dd, ts)
        rows.append({"name": f"inverse_scaling/{name}",
                     "us_per_call": ts[-1] * 1e6,
                     "derived": f"loglog_slope={slope:.2f}"})
    # ordering claim at the largest size: Brand < RSVD < EVD
    rows.append({
        "name": "inverse_scaling/ordering_at_max_d",
        "us_per_call": 0.0,
        "derived": "brand<rsvd<evd=%s" % (
            times["bkfac_brand"][-1] < times["rkfac_rsvd"][-1] <
            times["kfac_evd"][-1])})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)

"""Paper §4.2-4.3 (Figures 1-2, Table 1): K-factor inverse error metrics.

Benchmark = K-FAC with T_inv = T_updt (always-exact EA inverse).  The
approximate algorithms are measured against it over a window of steps with
error metrics (paper §4.2):

  (1) ||Ã⁻¹ − A_ref⁻¹||_F / ||A_ref⁻¹||_F
  (2) same for Γ
  (3) ||s̃ − s_ref||_F / ||s_ref||_F      (preconditioned step)
  (4) 1 − cos∠(s̃, s_ref)

Algorithms (same settings as the paper, scaled to d=512/n_BS=64):
B-KFAC (T_B=10) · B-R-KFAC (T_B=10, T_R=50) · B-KFAC-C (T_B=10, T_c=50,
φ=0.5) · R-KFAC T_inv∈{10,50,300} · K-FAC T_inv=50.

The K-factor stream mimics epoch-15+ VGG statistics: fast spectral decay
with a slowly rotating basis. Spectrum continuation applied to all
truncated algorithms (paper §3.5). Emits per-step CSV + Table-1-style
averages, and checks the paper's qualitative claims.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import brand, kfactor, precond, rsvd
from repro.core.kfactor import KFactorSpec, Mode

D, NBS, RHO, R_TRUNC = 512, 32, 0.95, 48
T_UPDT = 10


def make_stream(n_steps: int, seed: int = 0, decay: float = 16.0,
                drift: float = 1e-2):
    """Stats factors X_k (D, NBS) with decaying spectrum + drifting basis."""
    key = jax.random.PRNGKey(seed)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (D, D)))
    scales = jnp.exp(-jnp.arange(D) / decay)
    Xs = []
    for k in range(n_steps):
        kk = jax.random.fold_in(key, k + 1)
        k1, k2 = jax.random.split(kk)
        # slow basis rotation
        rot = drift * jax.random.normal(k1, (D, D))
        Q, _ = jnp.linalg.qr(Q + rot @ Q)
        z = jax.random.normal(k2, (D, NBS))
        Xs.append((Q * scales) @ z)
    return Xs


class Alg:
    """One K-factor-pair maintainer with a given mode/schedule."""

    def __init__(self, name, mode, T_light=10, T_heavy=50, n_crc=0,
                 width=R_TRUNC + NBS):
        spec = KFactorSpec(d=D, r=R_TRUNC, n_stat=NBS, mode=mode, rho=RHO,
                           n_crc=n_crc, n_pwr_iter=4)
        self.name, self.spec = name, spec
        self.T_light, self.T_heavy = T_light, T_heavy
        self.stA = spec.init()
        self.stG = spec.init()
        self._step = jax.jit(
            lambda st, X, key, first, heavy: kfactor.inverse_rep_step(
                spec, kfactor.stats_step(spec, st, X, first),
                X, key, first, heavy),
            static_argnames=())
        self.key = jax.random.PRNGKey(hash(name) % (2**31))
        self.update_time = 0.0
        self.n_updates = 0

    def update(self, k, XA, XG):
        first = jnp.asarray(k == 0)
        heavy = jnp.asarray(k % self.T_heavy == 0)
        if k % self.T_light != 0 and not bool(heavy):
            # still absorb stats into the EA (cheap) if the mode holds M
            if self.spec.needs_m:
                self.stA = kfactor.stats_step(self.spec, self.stA, XA, first)
                self.stG = kfactor.stats_step(self.spec, self.stG, XG, first)
            return
        self.key, k1, k2 = jax.random.split(self.key, 3)
        t0 = time.perf_counter()
        self.stA = jax.block_until_ready(self._step(self.stA, XA, k1,
                                                    first, heavy))
        self.stG = jax.block_until_ready(self._step(self.stG, XG, k2,
                                                    first, heavy))
        self.update_time += time.perf_counter() - t0
        self.n_updates += 1

    def inverses(self, lam_phi=0.1):
        if self.spec.mode is Mode.NS:
            # NS holds the dense damped inverse itself (λ̂ = ns_phi·λ_max
            # baked at refresh — same φ as lam_phi here)
            return [self.stA.U, self.stG.U]
        out = []
        for st in (self.stA, self.stG):
            lam = precond.damping_from_spectrum(st.D, lam_phi)
            Dd, lam = precond.spectrum_continuation(st.D, lam)
            Minv = (st.U * precond.lowrank_inv_diag(Dd, lam)) @ st.U.T + \
                jnp.eye(D) / lam
            out.append(Minv)
        return out

    def step_vec(self, J, lam_phi=0.1):
        if self.spec.mode is Mode.NS:
            return self.stG.U @ J @ self.stA.U
        lamA = precond.damping_from_spectrum(self.stA.D, lam_phi)
        DA, lamA = precond.spectrum_continuation(self.stA.D, lamA)
        lamG = precond.damping_from_spectrum(self.stG.D, lam_phi)
        DG, lamG = precond.spectrum_continuation(self.stG.D, lamG)
        return precond.kfac_precondition(J, self.stG.U, DG, lamG,
                                         self.stA.U, DA, lamA)


class AsyncAlg(Alg):
    """One K-factor-pair maintainer under the async launch/land pipeline
    (core.kfactor async helpers at batch size 1): a heavy op scheduled at
    step k computes from the state *snapshotted at k* and swaps in at
    ``k + lag``, interim Brand panels replayed on top.  ``lag=0`` is the
    synchronous algorithm exactly; ``lag>0`` quantifies the staleness the
    pipeline trades for overlap — the delta the paper's EA argument says
    stays bounded."""

    def __init__(self, name, mode, T_light=10, T_heavy=50, lag=0, n_crc=0):
        super().__init__(name, mode, T_light=T_light, T_heavy=T_heavy,
                         n_crc=n_crc)
        assert lag < T_heavy and lag % T_UPDT == 0
        self.lag = lag
        n_replay = (lag // T_light
                    if self.spec.mode in kfactor._HAS_BRAND else 0)
        self.bufs = {s: kfactor.make_inflight(self.spec, 1, n_replay)
                     for s in ("A", "G")}
        self.launched: Dict[str, int] = {}
        self._astep: Dict[tuple, object] = {}

    def _async_step(self, flags):
        if flags not in self._astep:
            warm, light, launch, land = flags
            spec = self.spec
            one = ((0, 1),)
            self._astep[flags] = jax.jit(
                lambda st, X, key, first, buf:
                kfactor.bucket_factor_step_async(
                    spec, st, X, key, first, True, light,
                    one if warm else (), one if launch else (),
                    one if land else (), buf))
        return self._astep[flags]

    def update(self, k, XA, XG):
        first = jnp.asarray(k == 0)
        light = k % self.T_light == 0
        for side, X in (("A", XA), ("G", XG)):
            st = self.stA if side == "A" else self.stG
            launch = k % self.T_heavy == 0 and k > 0
            if launch:
                self.launched[side] = k
            land = (side in self.launched
                    and k >= self.launched[side] + self.lag)
            self.key, kk = jax.random.split(self.key)
            t0 = time.perf_counter()
            st1 = jax.tree_util.tree_map(lambda x: x[None], st)
            st1, buf = self._async_step((k == 0, light, launch, land))(
                st1, X[None], kk[None], first, self.bufs[side])
            st = jax.block_until_ready(
                jax.tree_util.tree_map(lambda x: x[0], st1))
            self.update_time += time.perf_counter() - t0
            self.bufs[side] = buf
            if land:
                del self.launched[side]
            if side == "A":
                self.stA = st
            else:
                self.stG = st
        self.n_updates += 1


def make_algs() -> List[Alg]:
    return [
        Alg("bkfac", Mode.BRAND, T_light=T_UPDT, T_heavy=10**9),
        Alg("brkfac", Mode.BRAND_RSVD, T_light=T_UPDT, T_heavy=50),
        Alg("bkfacc", Mode.BRAND_CORR, T_light=T_UPDT, T_heavy=50,
            n_crc=R_TRUNC // 2),
        Alg("rkfac_T10", Mode.RSVD, T_light=T_UPDT, T_heavy=10),
        Alg("rkfac_T50", Mode.RSVD, T_light=T_UPDT, T_heavy=50),
        Alg("rkfac_T300", Mode.RSVD, T_light=T_UPDT, T_heavy=300),
        Alg("kfac_T50", Mode.EVD, T_light=T_UPDT, T_heavy=50),
        Alg("nskfac_T50", Mode.NS, T_light=T_UPDT, T_heavy=50),
        # async pipeline variants: lag=0 must reproduce the synchronous
        # algorithm; lag=20 measures the staleness cost of overlapping
        # the heavy op with 2 optimizer updates' worth of training
        AsyncAlg("kfac_T50_lag0", Mode.EVD, T_light=T_UPDT, T_heavy=50,
                 lag=0),
        AsyncAlg("kfac_T50_lag20", Mode.EVD, T_light=T_UPDT, T_heavy=50,
                 lag=20),
        AsyncAlg("brkfac_lag20", Mode.BRAND_RSVD, T_light=T_UPDT,
                 T_heavy=50, lag=20),
    ]


def run(quick: bool = False) -> List[dict]:
    n_steps = 300 if quick else 500   # EA transient ≈ 200 steps
    XsA = make_stream(n_steps, seed=0)
    XsG = make_stream(n_steps, seed=1, decay=10.0)
    ref = Alg("ref_exact", Mode.EVD, T_light=T_UPDT, T_heavy=T_UPDT)
    algs = make_algs()
    key = jax.random.PRNGKey(42)
    metrics: Dict[str, List[List[float]]] = {a.name: [] for a in algs}

    for k in range(n_steps):
        if k % T_UPDT == 0:
            XA, XG = XsA[k // T_UPDT], XsG[k // T_UPDT]
            ref.update(k, XA, XG)
            for a in algs:
                a.update(k, XA, XG)
        if k % T_UPDT == 0 and k > 0:
            Ainv_r, Ginv_r = ref.inverses()
            J = jax.random.normal(jax.random.fold_in(key, k), (D, D))
            s_ref = ref.step_vec(J)
            nA, nG = jnp.linalg.norm(Ainv_r), jnp.linalg.norm(Ginv_r)
            ns = jnp.linalg.norm(s_ref)
            for a in algs:
                Ainv, Ginv = a.inverses()
                s = a.step_vec(J)
                cos = jnp.sum(s * s_ref) / (jnp.linalg.norm(s) * ns)
                metrics[a.name].append([
                    float(jnp.linalg.norm(Ainv - Ainv_r) / nA),
                    float(jnp.linalg.norm(Ginv - Ginv_r) / nG),
                    float(jnp.linalg.norm(s - s_ref) / ns),
                    float(1.0 - cos)])

    rows = []
    avg = {}
    for a in algs:
        m = np.asarray(metrics[a.name])
        tail = m[-10:]                  # steady state (past the EA transient)
        avg[a.name] = tail.mean(axis=0)
        rows.append({
            "name": f"error_metrics/{a.name}",
            "us_per_call": a.update_time / max(a.n_updates, 1) * 1e6,
            "derived": ("err1=%.3e err2=%.3e err3=%.3e err4=%.3e" %
                        tuple(avg[a.name]))})
    # paper claims (qualitative, §4.3):
    claims = {
        # B-updates beat no-update (B-KFAC vs frozen R-KFAC T300), metric 3
        "claim_bupdate_beats_noupdate":
            avg["bkfac"][2] < avg["rkfac_T300"][2],
        # RSVD overwrites improve pure B-KFAC on every metric
        "claim_brkfac_beats_bkfac":
            all(avg["brkfac"][i] <= avg["bkfac"][i] + 1e-9
                for i in range(4)),
        # correction sits between pure B and B-R on the step metric
        "claim_bkfacc_between":
            avg["brkfac"][2] - 1e-9 <= avg["bkfacc"][2]
            <= avg["bkfac"][2] + 1e-9,
        # async pipeline, lag=0: exactly the synchronous algorithm
        # (deterministic EVD mode — same snapshot, same ops)
        "claim_async_lag0_exact":
            all(abs(avg["kfac_T50_lag0"][i] - avg["kfac_T50"][i])
                <= 1e-6 + 1e-4 * abs(avg["kfac_T50"][i])
                for i in range(4)),
        # async pipeline, lag>0: the staleness penalty on the
        # preconditioned step stays bounded (≤2.5x the synchronous error
        # at lag = 2 stats periods on this fast-drifting stream; measured
        # ~2.0x for EVD and ~1.2x for B-R whose interim Brand replays
        # absorb most of the drift — the EA tolerance the pipeline banks
        # on)
        "claim_async_lag_error_bounded":
            avg["kfac_T50_lag20"][2] <= 2.5 * avg["kfac_T50"][2] + 1e-9
            and avg["brkfac_lag20"][2] <= 2.5 * avg["brkfac"][2] + 1e-9,
    }
    for cname, ok in claims.items():
        rows.append({"name": f"error_metrics/{cname}", "us_per_call": 0.0,
                     "derived": str(bool(ok))})
    by_name = {a.name: a for a in algs}
    by_name["ref_exact"] = ref
    rows.extend(ns_inversion_rows(XsA, n_steps, by_name))
    return rows


def ns_inversion_rows(XsA, n_steps, by_name) -> List[dict]:
    """Newton–Schulz iterations-vs-inversion-error curves (tentpole).

    Two families of rows against the *true dense* damped inverse
    (M_EA + λI)⁻¹ of the exact EA K-factor (oracle built with eigh —
    benchmark-side only, the shipped NS path stays matmul-only):

      * ``inv_err_<alg>``   — the delivered inverse of each algorithm
        family (truncated EVD / RSVD / Brand and the NS refinement) at
        the end of the stream; these are the horizontal reference lines
        the NS curve is read against.
      * ``ns_iters_K{K}``   — cold-start NS at exactly K steps of the
        raw recurrence X ← 2X − X(M̂X) from the α·I prescale (fallback
        bypassed so the curve shows the iteration, not the repair);
        quadratic convergence means the error square-roots per column.
      * ``ns_overwrite_K8`` — the full shipped heavy path (power-iter
        prescale + warm guard + residual check) at the default K=8,
        timed; this row powers the acceptance claim below.
    """
    from repro.kernels import ops as kops

    used = [XsA[k // T_UPDT] for k in range(0, n_steps, T_UPDT)]
    M_exact = kfactor.exact_ea(used, RHO)
    Msym = 0.5 * (M_exact + M_exact.T)
    lmax = float(jnp.max(jnp.linalg.eigvalsh(Msym)))
    lam_ref = 0.1 * lmax
    want = jnp.linalg.inv(Msym + lam_ref * jnp.eye(D))
    nw = float(jnp.linalg.norm(want))

    rows, inv_errs = [], {}
    for name in ("ref_exact", "kfac_T50", "rkfac_T50", "bkfac",
                 "nskfac_T50"):
        Ainv = by_name[name].inverses()[0]
        inv_errs[name] = float(jnp.linalg.norm(Ainv - want) / nw)
        rows.append({"name": f"error_metrics/inv_err_{name}",
                     "us_per_call": 0.0,
                     "derived": f"inv_err={inv_errs[name]:.3e}"})

    # raw-recurrence curve: same prescale the shipped path uses, but λ̂
    # and α from the oracle λ_max so the curve isolates iteration count
    Mhat = Msym + lam_ref * jnp.eye(D)
    X = (2.0 / (lmax + 2.0 * lam_ref)) * jnp.eye(D)
    step = jax.jit(kops.ns_step)
    for K in range(1, 9):
        X = step(Mhat, X)
        if K in (1, 2, 4, 8):
            err = float(jnp.linalg.norm(X - want) / nw)
            rows.append({"name": f"error_metrics/ns_iters_K{K}",
                         "us_per_call": 0.0,
                         "derived": f"inv_err={err:.3e}"})

    # shipped heavy path at default K=8, timed
    spec8 = KFactorSpec(d=D, r=R_TRUNC, n_stat=NBS, mode=Mode.NS, rho=RHO)
    st0 = kfactor.KFactorState(U=jnp.zeros((D, D)), D=jnp.zeros((D,)),
                               M=M_exact,
                               aux=jnp.zeros((kfactor.AUX_WIDTH,)))
    fn = jax.jit(lambda s: kfactor.ns_overwrite(spec8, s))
    out = jax.block_until_ready(fn(st0))          # compile + warm
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(st0))
    dt = time.perf_counter() - t0
    lam8 = float(out.aux[kfactor.AUX_LAM])
    want8 = jnp.linalg.inv(Msym + lam8 * jnp.eye(D))
    err8 = float(jnp.linalg.norm(out.U - want8) / jnp.linalg.norm(want8))
    rows.append({"name": "error_metrics/ns_overwrite_K8",
                 "us_per_call": dt * 1e6,
                 "derived": f"inv_err={err8:.3e} "
                            f"resF={float(out.aux[kfactor.AUX_RES]):.3e}"})

    # acceptance: NS at K ≤ 8 is within 2x of the EVD baseline's
    # delivered inverse — in practice orders of magnitude below it
    # (NS converges to the dense damped inverse; truncated EVD pays
    # the rank cut)
    ok = err8 <= 2.0 * inv_errs["ref_exact"] + 1e-9
    rows.append({"name": "error_metrics/claim_ns_within_2x_evd",
                 "us_per_call": 0.0, "derived": str(bool(ok))})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)

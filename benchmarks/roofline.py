"""Roofline table (assignment §ROOFLINE ANALYSIS): reads the dry-run JSONs
and emits one row per (arch × shape), single-pod mesh."""
from __future__ import annotations

import glob
import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh="pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False) -> List[dict]:
    rows = []
    for rec in load_records():
        if rec.get("opt"):
            continue             # optimized variants reported in §Perf
        name = f"roofline/{rec['arch']}/{rec['shape']}"
        if rec.get("status") == "skipped":
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": "skipped: " + rec.get("reason", "")})
            continue
        if rec.get("status") != "ok":
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": "status=" + str(rec.get("status"))})
            continue
        if "roofline" not in rec:
            # probe-less cell: scan-once lower bounds (see EXPERIMENTS.md)
            from repro.launch import dryrun as dr
            rec = dict(rec)
            rec["roofline"] = dr.roofline_terms(rec, rec["n_devices"])
            name += "~scan_once_lower_bound"
        r = rec["roofline"]
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append({
            "name": name,
            "us_per_call": dom * 1e6,      # dominant roofline term
            "derived": (f"comp={r['t_compute_s']:.3e}s "
                        f"mem={r['t_memory_s']:.3e}s "
                        f"coll={r['t_collective_s']:.3e}s "
                        f"bound={r['bottleneck']} "
                        f"frac={r['roofline_fraction']:.3f} "
                        f"useful={rec.get('useful_flops_ratio', 0):.2f}")})
    return rows


def markdown_table(mesh="pod16x16") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bound | roofline frac | MODEL/HLO flops |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh):
        if rec.get("opt"):
            continue             # optimized variants live in §Perf
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        mark = ""
        if "roofline" not in rec:
            if rec.get("status") != "ok":
                continue
            # probe-less cell: terms from scan-once totals (lower bounds
            # on compute/collective; memory term exact) — marked †
            from repro.launch import dryrun as dr
            rec = dict(rec)
            rec["roofline"] = dr.roofline_terms(rec, rec["n_devices"])
            mark = "†"
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']}{mark} | "
            f"{r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['bottleneck']} | {r['roofline_fraction']:.3f} | "
            f"{rec.get('useful_flops_ratio', 0):.2f} |")
    lines.append("")
    lines.append("† probe-less cell: compute/collective terms are "
                 "scan-once lower bounds (per-layer correction not run); "
                 "memory term exact.")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())

"""Paper §6 Table 2 analog: optimizer quality on the modified-VGG
classification task (synthetic CIFAR-like stream; offline container).

Compares {SGD, AdamW, SENG, K-FAC, R-KFAC, B-KFAC, B-R-KFAC, B-KFAC-C} on
steps- and wall-time-to-target-loss with matched schedules. The paper's
headline orderings checked:
  * every K-FAC-family run beats SGD/AdamW per-step;
  * B-KFAC has the lowest per-step optimizer overhead of the K-FAC family;
  * B variants reach the loss target in ≤ steps of R-KFAC (±1 bucket).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.core import policy as policy_lib
from repro.data.synthetic import ImageStream
from repro.models import layers
from repro.models.cnn import VggConfig, make_vgg
from repro.optim import base as optbase
from repro.optim import seng as seng_lib
from repro.optim import sgd as sgd_lib
from repro.optim import adamw as adamw_lib
from repro.train import loop


def _kfac_cfg(variant, r=96):
    pol = policy_lib.PolicyConfig(variant=variant, r=r, max_dense_dim=4096)
    return kfac_lib.KfacConfig(
        policy=pol, lr=optbase.constant(0.1),
        damping_phi=optbase.constant(0.1), weight_decay=7e-4, clip=0.5,
        T_updt=5, T_inv=25, T_brand=5, T_rsvd=25, T_corct=25,
        fallback_lr=optbase.constant(3e-3))


def run(quick: bool = False) -> List[dict]:
    n_steps = 40 if quick else 250
    batch = 64 if quick else 128
    cfg = VggConfig(stages=(8,) if quick else (16, 32, 64),
                    fc_hidden=64 if quick else 512,
                    n_stat=32 if quick else 64)
    init, loss_fn, accuracy, taps = make_vgg(cfg)
    stream = ImageStream(batch=batch, seed=0)
    batches = [stream.batch_at(i) for i in range(n_steps)]
    eval_batch = stream.batch_at(10_000)
    params0 = init(jax.random.PRNGKey(0))
    target = 1.4 if quick else 0.6   # CE loss target (10 classes: ln10≈2.3)

    results: Dict[str, dict] = {}

    def record(name, losses, wall, acc):
        hit = next((i for i, l in enumerate(losses)
                    if np.mean(losses[i: i + 5]) < target), None)
        results[name] = dict(final=float(np.mean(losses[-5:])),
                             steps_to_target=hit, wall_per_step=wall,
                             acc=float(acc))

    # --- K-FAC family ------------------------------------------------------
    for variant in policy_lib.VARIANTS:
        opt = kfac_lib.Kfac(_kfac_cfg(variant, r=32 if quick else 96), taps)
        t0 = time.perf_counter()
        state, losses = loop.run_kfac_training(
            loss_fn, opt, params0, batches, n_tokens=batch)
        wall = (time.perf_counter() - t0) / n_steps
        record(variant, losses, wall, accuracy(state.params, eval_batch))

    # --- SENG ---------------------------------------------------------------
    scfg = seng_lib.SengConfig(lr=optbase.constant(0.05), damping=2.0,
                               momentum=0.9, weight_decay=1e-2, T_fim=25,
                               fallback_lr=optbase.constant(3e-3))
    sopt = seng_lib.Seng(scfg, taps)
    sstate = loop.TrainState(params=params0, opt=sopt.init(params0),
                             rng=jax.random.PRNGKey(0))

    def seng_step(state, data, do_fim):
        probes = layers.make_probes(sopt.taps)
        loss, acts, gp, gprobe = loop.kfac_grads(loss_fn, state.params,
                                                 probes, data)
        upd, ost = sopt.update(gp, state.opt, state.params, acts=acts,
                               probe_grads=gprobe, n_tokens=batch,
                               do_fim=do_fim)
        return loop.TrainState(optbase.apply_updates(state.params, upd),
                               ost, state.rng), loss

    jstep = jax.jit(seng_step, static_argnames=("do_fim",))
    losses = []
    t0 = time.perf_counter()
    for k, b in enumerate(batches):
        sstate, l = jstep(sstate, b, **scfg.flags(k))
        losses.append(float(l))
    record("seng", losses, (time.perf_counter() - t0) / n_steps,
           accuracy(sstate.params, eval_batch))

    # --- first-order baselines ----------------------------------------------
    for name, opt in [("sgd", sgd_lib.sgd(optbase.constant(0.05),
                                          momentum=0.9, weight_decay=7e-4)),
                      ("adamw", adamw_lib.adamw(optbase.constant(1e-3),
                                                weight_decay=7e-4))]:
        step = jax.jit(loop.make_baseline_step(loss_fn, opt))
        st = loop.TrainState(params=params0, opt=opt.init(params0),
                             rng=jax.random.PRNGKey(0))
        losses = []
        t0 = time.perf_counter()
        for b in batches:
            st, l = step(st, b)
            losses.append(float(l))
        record(name, losses, (time.perf_counter() - t0) / n_steps,
               accuracy(st.params, eval_batch))

    rows = []
    for name, r in results.items():
        rows.append({"name": f"train_quality/{name}",
                     "us_per_call": r["wall_per_step"] * 1e6,
                     "derived": (f"final={r['final']:.3f} "
                                 f"steps_to_{target}={r['steps_to_target']} "
                                 f"acc={r['acc']:.3f}")})

    def s2t(n):
        v = results[n]["steps_to_target"]
        return v if v is not None else 10**9

    claims = {
        "claim_kfac_family_beats_sgd_per_step":
            all(s2t(v) <= s2t("sgd") for v in policy_lib.VARIANTS),
        "claim_bkfac_cheapest_kfac": results["bkfac"]["wall_per_step"] <=
            min(results[v]["wall_per_step"]
                for v in ("kfac", "rkfac")) * 1.10,
        "claim_b_variants_match_rkfac_steps":
            min(s2t("bkfac"), s2t("brkfac"), s2t("bkfacc"))
            <= s2t("rkfac") + 5,
    }
    for cname, ok in claims.items():
        rows.append({"name": f"train_quality/{cname}", "us_per_call": 0.0,
                     "derived": str(bool(ok))})
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)

"""The paper's §6 experiment, end-to-end: modified VGG16_bn (2×1 pooling →
widened FC0) on a CIFAR-like stream, optimizer selectable.

    PYTHONPATH=src python examples/train_vgg_kfac.py \
        --optimizer bkfac --steps 100 --preset small

Presets: ``small`` (CPU-friendly) / ``paper`` (full modified VGG16_bn —
16384×2048 FC0; needs accelerator-scale time budget).
"""
import argparse
import time

import jax
import numpy as np

from repro.core import kfac as kfac_lib
from repro.core import policy as policy_lib
from repro.data.synthetic import ImageStream
from repro.models.cnn import VggConfig, make_vgg
from repro.optim import base as optbase
from repro.train import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--optimizer", default="bkfac",
                    choices=list(policy_lib.VARIANTS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--preset", default="small",
                    choices=("small", "paper"))
    ap.add_argument("--stagger", action="store_true",
                    help="phase heavy factor work across the T_inv window "
                         "(flat per-step cost instead of periodic spikes)")
    ap.add_argument("--stagger-splits", type=int, default=4)
    args = ap.parse_args()

    if args.preset == "paper":
        cfg = VggConfig(stages=(64, 128, 256, 512, 512), fc_hidden=2048,
                        n_stat=256)
        r = 230
    else:
        cfg = VggConfig(stages=(16, 32, 64), fc_hidden=512, n_stat=64)
        r = 96

    init, loss_fn, accuracy, taps = make_vgg(cfg)
    kcfg = kfac_lib.KfacConfig(
        policy=policy_lib.PolicyConfig(variant=args.optimizer, r=r,
                                       max_dense_dim=4096),
        lr=optbase.paper_lr_schedule(steps_per_epoch=50),
        damping_phi=optbase.paper_damping_schedule(steps_per_epoch=50),
        weight_decay=7e-4, clip=0.5,
        T_updt=5, T_inv=25, T_brand=5, T_rsvd=25, T_corct=25,
        stagger=args.stagger, stagger_splits=args.stagger_splits,
        fallback_lr=optbase.constant(3e-3))
    opt = kfac_lib.Kfac(kcfg, taps)
    # run_kfac_training drives the work scheduler (staggered iff
    # cfg.stagger); pass dist=DistSpec(mesh=..., curvature_axis=...)
    # there to also shard the factor work across a device mesh
    # (docs/distributed.md, repro.specs)

    stream = ImageStream(batch=args.batch, seed=0)
    batches = [stream.batch_at(i) for i in range(args.steps)]
    params = init(jax.random.PRNGKey(0))

    t0 = time.time()
    log = []

    def cb(k, state, loss):
        if k % 10 == 0:
            acc = float(accuracy(state.params, stream.batch_at(10_000)))
            log.append((k, float(loss), acc))
            print(f"step {k:4d}  loss {float(loss):.4f}  "
                  f"holdout-acc {acc:.3f}  ({time.time()-t0:.0f}s)")

    state, losses = loop.run_kfac_training(loss_fn, opt, params, batches,
                                           n_tokens=args.batch, callback=cb)
    acc = float(accuracy(state.params, stream.batch_at(10_000)))
    print(f"[{args.optimizer}] final loss {np.mean(losses[-5:]):.4f}  "
          f"holdout-acc {acc:.3f}  total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()

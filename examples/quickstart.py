"""Quickstart: train a small MLP with B-KFAC (the paper's optimizer) in
~30 lines of user code.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.models import layers
from repro.optim import base as optbase

D_IN, D_H, D_OUT, BATCH, N_STAT = 32, 256, 8, 64, 32

# 1) a model with K-FAC taps: each tapped matmul gets a TapInfo
taps = {
    "fc0": api.TapInfo("fc0/w", D_IN, D_H, n_stat=N_STAT),
    "fc1": api.TapInfo("fc1/w", D_H, D_OUT, n_stat=N_STAT),
}


def init(key):
    k0, k1 = jax.random.split(key)
    return {"fc0": {"w": layers.dense_init(k0, D_IN, D_H)},
            "fc1": {"w": layers.dense_init(k1, D_H, D_OUT)}}


def loss_fn(params, probes, batch):
    x, y = batch
    acts = {}
    h, acts["fc0"] = layers.tapped_matmul(params["fc0"]["w"], x,
                                          probes.get("fc0"), N_STAT)
    h = jax.nn.relu(h)
    out, acts["fc1"] = layers.tapped_matmul(params["fc1"]["w"], h,
                                            probes.get("fc1"), N_STAT)
    return jnp.mean((out - y) ** 2), acts


# 2) pick a paper variant: bkfac | brkfac | bkfacc | rkfac | kfac
cfg = api.KfacConfig(
    policy=api.PolicyConfig(variant="bkfac", r=32),
    lr=optbase.constant(0.05), damping_phi=optbase.constant(0.1),
    clip=1.0, T_updt=1, T_brand=1)
opt = api.Kfac(cfg, taps)

# 3) train
key = jax.random.PRNGKey(0)
W_true = jax.random.normal(key, (D_IN, D_OUT))
batches = []
for i in range(50):
    x = jax.random.normal(jax.random.fold_in(key, i), (BATCH, D_IN))
    batches.append((x, jnp.tanh(x @ W_true)))

params = init(jax.random.PRNGKey(1))
state, losses = api.run_kfac_training(loss_fn, opt, params, batches,
                                      n_tokens=BATCH)
print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
      f"({cfg.policy.variant}, {len(losses)} steps)")
assert losses[-1] < 0.3 * losses[0]
print("OK")

"""End-to-end LM training driver with the B-KFAC hybrid optimizer —
the ~100M-parameter "train a few hundred steps" deliverable.

    PYTHONPATH=src python examples/train_lm_kfac.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm_kfac.py --preset 100m --steps 300

``100m`` is a gemma3-family config (~115M params) — tractable on
accelerators, hours on this CPU container (use ``tiny`` for smoke).
Checkpointing + deterministic data make it restart-safe (Ctrl-C and rerun).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_arch, Segment, LayerSpec
from repro.core import kfac as kfac_lib
from repro.core import policy as policy_lib
from repro.data.synthetic import TokenStream
from repro.models.lm import LM
from repro.optim import base as optbase
from repro.train import loop, checkpoint as ckpt


def preset_arch(name: str):
    g = get_arch("gemma3_4b")
    if name == "tiny":
        return g.reduced()
    # ~115M params: 8 layers, d=512, vocab=32k
    spec = LayerSpec(mixer="gqa", ffn="dense", window=256)
    return dataclasses.replace(
        g, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
        vocab=32768, head_dim=64, n_stat=128, dtype="float32",
        segments=(Segment((spec,), 8),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="bkfac",
                    choices=list(policy_lib.VARIANTS))
    ap.add_argument("--stagger", action="store_true",
                    help="phase heavy factor work across the T_inv window "
                         "(flat per-step cost instead of periodic spikes)")
    ap.add_argument("--stagger-splits", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    arch = preset_arch(args.preset)
    lm = LM(arch, remat=False)
    kcfg = kfac_lib.KfacConfig(
        policy=policy_lib.PolicyConfig(variant=args.optimizer, r=64,
                                       max_dense_dim=2048),
        lr=optbase.constant(0.02), damping_phi=optbase.constant(0.1),
        weight_decay=1e-4, clip=0.5,
        T_updt=2, T_inv=10, T_brand=2, T_rsvd=10, T_corct=10,
        stagger=args.stagger, stagger_splits=args.stagger_splits,
        fallback_lr=optbase.constant(3e-3))
    opt = kfac_lib.Kfac(kcfg, lm.taps)
    sched = opt.scheduler()
    if args.stagger:
        print(f"scheduler: {sched.describe()}")

    stream = TokenStream(vocab=arch.vocab, batch=args.batch,
                         seq_len=args.seq, seed=0)
    params = lm.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={arch.name}({args.preset})  params={n_params/1e6:.1f}M  "
          f"optimizer={args.optimizer}")

    state = loop.TrainState(params=params, opt=opt.init(params),
                            rng=jax.random.PRNGKey(1))
    start = ckpt.latest_step(args.ckpt_dir)
    if start is not None:
        state, _ = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from checkpoint step {start}")
    k0 = 0 if start is None else start + 1

    step_fn = jax.jit(loop.make_scheduled_kfac_step(
                          lm.loss_fn, opt, n_tokens=args.batch * args.seq),
                      static_argnames=("work",))
    ck = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)
    t0 = time.time()
    losses = []
    for k in range(k0, args.steps):
        batch = stream.batch_at(k)
        state, loss = step_fn(state, batch, sched.work(k))
        losses.append(float(loss))
        if k % 10 == 0:
            print(f"step {k:4d}  loss {float(loss):.4f}  "
                  f"({time.time()-t0:.0f}s)")
            ck.submit(k, state)
    ck.close()
    uniform = np.log(arch.vocab)
    print(f"final loss {np.mean(losses[-5:]):.4f} (uniform={uniform:.2f})")


if __name__ == "__main__":
    main()

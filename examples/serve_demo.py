"""Batched serving demo: continuous-batching engine over a small LM.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax

from repro.configs.base import get_arch
from repro.models.lm import LM
from repro.serve.engine import Engine, Request


def main():
    arch = get_arch("gemma3_4b").reduced()
    lm = LM(arch, remat=False)
    params = lm.init(jax.random.PRNGKey(0))
    engine = Engine(lm, params, batch_slots=4, max_len=64)

    prompts = [[1, 5, 9], [2, 4], [7, 7, 7, 7], [3], [8, 1, 2], [9, 9]]
    for i, p in enumerate(prompts):
        engine.submit(Request(uid=i, prompt=p, max_new=8))
    t0 = time.time()
    ticks = engine.run_until_drained()
    dt = time.time() - t0
    done = sorted(engine.completed)
    print(f"served {len(done)}/{len(prompts)} requests in {ticks} ticks "
          f"({dt:.1f}s, {ticks/dt:.1f} ticks/s)")
    for uid in done:
        r = engine.completed[uid]
        print(f"  req {uid}: prompt={r.prompt} -> {r.out_tokens}")
    assert len(done) == len(prompts)
    print("OK")


if __name__ == "__main__":
    main()

"""Serving path: per-slot decode lanes (engine.py) and the multi-tenant
fine-tuning service (service.py + load.py).

The headline regression: two requests admitted STAGGERED (the second
joins while the first is mid-decode) must produce exactly the tokens each
would produce alone — the seed engine's shared position counter
(`max(self._pos)`) broke this, decoding late joiners at their neighbor's
position.
"""
import glob
import json
import os

import numpy as np
import jax
import pytest

from repro.configs.base import get_arch
from repro.models.lm import LM
from repro.serve import load as load_lib
from repro.serve.engine import Engine, Request
from repro.serve.service import FinetuneRequest
from repro.train import checkpoint as ckpt_lib


@pytest.fixture(scope="module")
def small_lm():
    arch = get_arch("gemma3_4b").reduced()
    lm = LM(arch, remat=False)
    params = lm.init(jax.random.PRNGKey(0))
    return arch, lm, params


def _serve_one(lm, params, req, **kw):
    eng = Engine(lm, params, **kw)
    eng.submit(req)
    eng.run_until_drained()
    return eng.completed[req.uid].out_tokens


# ---------------------------------------------------------------------------
# per-slot positions
# ---------------------------------------------------------------------------

def test_staggered_requests_match_sequential(small_lm):
    """Admit request B while A is mid-decode: both must emit exactly the
    tokens they emit when served alone."""
    _, lm, params = small_lm
    ra = lambda: Request(uid=0, prompt=[3, 1, 4, 1, 5], max_new=6)
    rb = lambda: Request(uid=1, prompt=[2, 7], max_new=6)
    alone_a = _serve_one(lm, params, ra(), batch_slots=2, max_len=32)
    alone_b = _serve_one(lm, params, rb(), batch_slots=2, max_len=32)

    eng = Engine(lm, params, batch_slots=2, max_len=32)
    a, b = ra(), rb()
    eng.submit(a)
    for _ in range(4):          # A is 4 positions in when B arrives
        eng.step()
    eng.submit(b)
    eng.run_until_drained()
    assert eng.completed[0].out_tokens == alone_a
    assert eng.completed[1].out_tokens == alone_b


def test_slot_reuse_after_drain_matches_alone(small_lm):
    """A request admitted into a slot whose previous occupant finished
    (stale cache entries beyond its horizon) decodes as if alone."""
    _, lm, params = small_lm
    first = Request(uid=0, prompt=[9, 9, 9, 9, 9, 9], max_new=4)
    second = lambda: Request(uid=1, prompt=[5, 3], max_new=5)
    alone = _serve_one(lm, params, second(), batch_slots=1, max_len=32)
    eng = Engine(lm, params, batch_slots=1, max_len=32)
    eng.submit(first)
    eng.run_until_drained()
    r = second()
    eng.submit(r)
    eng.run_until_drained()
    assert eng.completed[1].out_tokens == alone


# ---------------------------------------------------------------------------
# checkpoint schema v6: tenant table
# ---------------------------------------------------------------------------

def test_ckpt_v6_tenant_table_roundtrip(tmp_path):
    tree = {"w": np.arange(6.0).reshape(2, 3)}
    table = [{"tenant": 0, "slot": 0, "step": 7},
             {"tenant": 1, "slot": 1, "step": 3}]
    ckpt_lib.save(str(tmp_path), 5, tree, tenants=table)
    out, manifest = ckpt_lib.restore(str(tmp_path), tree)
    assert manifest["schema"] == ckpt_lib.SCHEMA_VERSION == 6
    assert manifest["tenants"] == table
    np.testing.assert_array_equal(out["w"], tree["w"])


def test_ckpt_without_tenants_stays_compatible(tmp_path):
    """Single-tenant saves (and pre-v6 manifests, which lack the key
    entirely) read back with tenants absent — additive change."""
    tree = {"w": np.ones((2,))}
    ckpt_lib.save(str(tmp_path), 1, tree)
    _, manifest = ckpt_lib.restore(str(tmp_path), tree)
    assert manifest.get("tenants") is None
    # a v5-era manifest (no "tenants" key at all) behaves the same
    man_path = glob.glob(str(tmp_path / "step_*/manifest.json"))[0]
    with open(man_path) as f:
        man = json.load(f)
    del man["tenants"]
    man["schema"] = 5
    with open(man_path, "w") as f:
        json.dump(man, f)
    _, manifest = ckpt_lib.restore(str(tmp_path), tree)
    assert manifest.get("tenants") is None


# ---------------------------------------------------------------------------
# multi-tenant service under mixed load (slow: compiles train + decode)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mixed_load_smoke(tmp_path):
    from repro.obs import TelemetryWriter
    events = str(tmp_path / "events.jsonl")
    with TelemetryWriter(events, console=False) as writer:
        svc, arch = load_lib.build_service(tenants=3, writer=writer,
                                           max_len=32)
        ticks = load_lib.run_load(svc, arch.vocab, waves=2,
                                  infer_per_wave=2, ft_per_wave=3,
                                  ticks_between=2)
    report = svc.latency_report()
    assert report["infer"]["requests"] == 4
    assert report["finetune"]["requests"] == 6
    # every tenant that got fine-tune traffic advanced its own step
    assert sum(report["steps"]) == 6
    assert ticks < 200
    # emitted events validate and carry the per-tenant fields
    from repro.obs import events as ev_lib
    evs = list(ev_lib.read_events(events))
    kinds = {e["type"] for e in evs}
    assert "tenant_update" in kinds and "serve_request" in kinds
    assert all("tenant" in e for e in evs
               if e["type"] == "serve_request")


@pytest.mark.slow
def test_service_tenant_isolation_and_restore(tmp_path):
    """Fine-tuning tenant 0 must not move tenant 1's params; a restored
    service re-seats per-tenant steps from the v6 tenant table."""
    ckpt_dir = str(tmp_path / "ckpt")
    svc, arch = load_lib.build_service(tenants=2, max_len=32,
                                       ckpt_dir=ckpt_dir)
    rng = np.random.default_rng(0)
    B, T = svc.ft_shape
    batch = {"tokens": rng.integers(0, arch.vocab, (B, T)).astype(np.int32),
             "targets": rng.integers(0, arch.vocab, (B, T)).astype(np.int32)}
    before = jax.tree_util.tree_map(np.asarray, svc.params)
    for k in range(3):
        svc.submit(FinetuneRequest(uid=k, tenant=0, batch=batch))
    svc.run_until_drained()
    after = jax.tree_util.tree_map(np.asarray, svc.params)
    moved = any(not np.array_equal(a[0], b[0]) for a, b in
                zip(jax.tree_util.tree_leaves(after),
                    jax.tree_util.tree_leaves(before)))
    assert moved                      # tenant 0 learned
    for a, b in zip(jax.tree_util.tree_leaves(after),
                    jax.tree_util.tree_leaves(before)):
        np.testing.assert_array_equal(a[1], b[1])   # tenant 1 untouched
    assert svc.steps == [3, 0]
    svc.save_checkpoint()

    fresh, _ = load_lib.build_service(tenants=2, max_len=32,
                                      ckpt_dir=ckpt_dir)
    manifest = fresh.restore()
    assert fresh.steps == [3, 0]
    assert manifest["tenants"][0]["step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(fresh.params),
                    jax.tree_util.tree_leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), b)

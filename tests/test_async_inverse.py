"""Async double-buffered heavy-inverse pipeline (core/kfactor.py
InflightState + core/schedule.py launch/land masks + train/loop.py
AsyncInverseRunner): buffer semantics, staleness contract, overlapped ≡
in-graph landing, and state-sharding of the in-flight buffers.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.core import kfactor, policy, schedule
from synthdata import tap_data
from repro.core.kfactor import KFactorSpec, Mode
from repro.optim import base as optbase


def _taps():
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 48, 32, n_stat=16),
        "scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(3,), n_stat=16),
    }


def _data(taps, key=None):
    return tap_data(taps, key)


def _opt(variant="kfac", lag=0, **kw):
    kwargs = dict(policy=policy.PolicyConfig(variant=variant, r=8,
                                             max_dense_dim=8192),
                  lr=optbase.constant(0.05), T_updt=1, T_brand=1, T_inv=4,
                  T_rsvd=4, T_corct=4, stagger=True, stagger_splits=2,
                  async_heavy=True, heavy_lag=lag)
    kwargs.update(kw)
    return kfac_lib.Kfac(kfac_lib.KfacConfig(**kwargs), _taps())


# ---------------------------------------------------------------------------
# buffer primitives
# ---------------------------------------------------------------------------

class TestInflightPrimitives:
    def _spec(self, mode=Mode.BRAND_RSVD):
        return KFactorSpec(d=24, r=6, n_stat=8, mode=mode)

    def test_record_panel_ring_order(self):
        spec = self._spec()
        buf = kfactor.make_inflight(spec, total=2, n_replay=2)
        xs = [jnp.full((2, 24, 8), float(i)) for i in range(3)]
        for x in xs:
            buf = kfactor.record_panel(buf, x)
        # ring holds the last 2 panels, oldest first
        np.testing.assert_array_equal(np.asarray(buf.panels[:, 0]),
                                      np.asarray(xs[1]))
        np.testing.assert_array_equal(np.asarray(buf.panels[:, 1]),
                                      np.asarray(xs[2]))

    def test_record_panel_noop_without_replay(self):
        spec = self._spec()
        buf = kfactor.make_inflight(spec, total=2, n_replay=0)
        out = kfactor.record_panel(buf, jnp.ones((2, 24, 8)))
        assert out.panels.shape == (2, 0, 24, 8)

    def test_launch_snapshot_touches_only_range(self):
        spec = self._spec()
        key = jax.random.PRNGKey(1)
        st = kfactor.make_state(24, spec.width, True)
        st = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (3,) + x.shape) + 1.0, st)
        keys = jax.random.split(key, 3)
        buf = kfactor.make_inflight(spec, total=3, n_replay=0)
        buf = kfactor.launch_snapshot(buf, st, keys, 1, 2)
        np.testing.assert_array_equal(np.asarray(buf.M[1]),
                                      np.asarray(st.M[1]))
        assert float(jnp.abs(buf.M[0]).max()) == 0.0   # untouched slot
        assert float(jnp.abs(buf.M[2]).max()) == 0.0
        np.testing.assert_array_equal(np.asarray(buf.keys[1]),
                                      np.asarray(keys[1]))

    @pytest.mark.slow
    def test_land_swap_is_heavy_of_snapshot_plus_replay(self):
        """The landed rep must equal heavy(snapshot) with the ring panels
        replayed — computed here by hand from the same buffer."""
        spec = self._spec(Mode.BRAND_RSVD)
        key = jax.random.PRNGKey(2)
        B = 2
        X0 = jax.random.normal(key, (B, 24, 8))
        st = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (B,) + x.shape), spec.init())
        st = kfactor.stats_step(spec, st, X0, jnp.asarray(True))
        keys = jax.random.split(key, B)
        buf = kfactor.make_inflight(spec, total=B, n_replay=1)
        panel = jax.random.normal(jax.random.fold_in(key, 9), (B, 24, 8))
        buf = kfactor.record_panel(buf, panel)
        buf = kfactor.launch_snapshot(buf, st, keys, 0, B)
        assert bool(buf.live.all())
        landed, buf_after = kfactor.land_swap(spec, st, buf, 0, B)
        # reference: same pure functions, called explicitly
        U_ref, D_ref, _ = kfactor.heavy_from_snapshot(spec, buf, 0, B)
        U_ref, D_ref = kfactor.replay_panels(spec, U_ref, D_ref,
                                             buf.panels[0:B])
        np.testing.assert_allclose(np.asarray(landed.U), np.asarray(U_ref))
        np.testing.assert_allclose(np.asarray(landed.D), np.asarray(D_ref))
        # M is never touched by a landing; the live flag is consumed
        np.testing.assert_array_equal(np.asarray(landed.M),
                                      np.asarray(st.M))
        assert not bool(buf_after.live.any())

    @pytest.mark.slow
    def test_land_without_launch_is_noop(self):
        """A landing whose launch was dropped (straggler back-off) or
        never fired (fresh resume) must leave the live state untouched —
        NOT install the zero-initialized / consumed snapshot."""
        spec = self._spec(Mode.BRAND_RSVD)
        key = jax.random.PRNGKey(3)
        B = 2
        X0 = jax.random.normal(key, (B, 24, 8))
        st = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (B,) + x.shape), spec.init())
        st = kfactor.stats_step(spec, st, X0, jnp.asarray(True))
        st = dataclasses.replace(st, U=st.U + 0.5, D=st.D + 1.0)
        buf = kfactor.make_inflight(spec, total=B, n_replay=0)
        out, buf2 = kfactor.land_swap(spec, st, buf, 0, B)
        np.testing.assert_array_equal(np.asarray(out.U), np.asarray(st.U))
        np.testing.assert_array_equal(np.asarray(out.D), np.asarray(st.D))
        # a second landing after a consumed launch is also a no-op
        keys = jax.random.split(key, B)
        buf2 = kfactor.launch_snapshot(buf2, st, keys, 0, B)
        mid, buf3 = kfactor.land_swap(spec, st, buf2, 0, B)
        again, _ = kfactor.land_swap(spec, mid, buf3, 0, B)
        np.testing.assert_array_equal(np.asarray(again.U),
                                      np.asarray(mid.U))


# ---------------------------------------------------------------------------
# optimizer-level semantics
# ---------------------------------------------------------------------------

def _run(opt, steps=8, landing_fn=None):
    """Drive the optimizer with *step-varying* stats operands — a drifting
    M is what makes staleness observable (constant operands make every
    heavy overwrite identical and async trivially equal to sync)."""
    params = _data(opt.taps)[0]
    sched = opt.scheduler()
    st = opt.init(params)

    def step(grads, st, acts, pgs, rng, work, landing=None):
        return opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                          n_tokens=16, rng=rng, work=work, landing=landing)
    step = jax.jit(step, static_argnames=("work",))
    outs = []
    for s in range(steps):
        _, grads, acts, pgs = _data(opt.taps,
                                    jax.random.PRNGKey(100 + s))
        work = sched.work(s)
        landing = landing_fn(st, work) if landing_fn else None
        upd, st = step(grads, st, acts, pgs,
                       jax.random.fold_in(jax.random.PRNGKey(7), s),
                       work, landing)
        outs.append(upd)
    return outs, st


@pytest.mark.slow
def test_staleness_contract_lag_vs_sync():
    """lag>0 is NOT sync shifted: inside a lag window the old inverse is
    still live (sync already overwrote inline), and the landing swaps in
    heavy-of-*snapshot*, not heavy-of-current.  With drifting stats the
    two runs agree exactly on the warmup step and split from the first
    in-flight window on."""
    opt_sync = _opt("kfac", lag=0, async_heavy=False)
    opt_lag = _opt("kfac", lag=2)
    a, _ = _run(opt_sync, steps=8)
    b, _ = _run(opt_lag, steps=8)
    # step 0: warmup is inline in both — identical
    for n in opt_sync.taps:
        np.testing.assert_allclose(np.asarray(b[0][n]["w"]),
                                   np.asarray(a[0][n]["w"]),
                                   rtol=1e-5, atol=1e-6)
    # first staggered firing (k=1) opens a lag window: sync's inverse is
    # fresh, async's is still the warmup one — and the k=3 landing swaps
    # in heavy of the k=1 snapshot, not of the k=3 state
    diffs = [max(float(np.abs(np.asarray(b[k][n]["w"]) -
                              np.asarray(a[k][n]["w"])).max())
                 for n in opt_sync.taps) for k in range(8)]
    assert max(diffs[1:]) > 1e-6, diffs


@pytest.mark.slow
def test_inflight_is_part_of_state_pytree():
    opt = _opt("kfac", lag=2)
    st = opt.init(_data(opt.taps)[0])
    assert set(st.inflight) == {str(bi) for bi in opt._async_buckets}
    leaves = jax.tree_util.tree_leaves(st.inflight)
    assert leaves and all(l.ndim >= 1 for l in leaves)
    # sync configs keep the pre-async pytree (empty inflight → no leaves)
    opt_s = _opt("kfac", lag=0, async_heavy=False)
    st_s = opt_s.init(_data(opt_s.taps)[0])
    assert st_s.inflight == {}
    assert not jax.tree_util.tree_leaves(st_s.inflight)


@pytest.mark.slow
def test_overlapped_landing_equals_in_graph():
    """Feeding pre-computed heavy results through the ``landing`` operand
    must give exactly the in-graph landing's numbers (same snapshot, same
    keys, same function — just a different dispatch site)."""
    opt_a, opt_b = _opt("kfac", lag=2), _opt("kfac", lag=2)

    def precompute(st, work):
        out = {}
        for bi, ranges in enumerate(work.land):
            if not ranges:
                continue
            spec = opt_b.factor_buckets[bi].spec
            buf = st.inflight[str(bi)]
            out[str(bi)] = tuple(
                kfactor.heavy_from_snapshot(spec, buf, lo, hi)
                for lo, hi in ranges)
        return out or None

    a, sta = _run(opt_a, steps=8)
    b, stb = _run(opt_b, steps=8, landing_fn=precompute)
    for k, (ua, ub) in enumerate(zip(a, b)):
        for n in opt_a.taps:
            np.testing.assert_allclose(np.asarray(ub[n]["w"]),
                                       np.asarray(ua[n]["w"]),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"step {k} {n}")


@pytest.mark.slow
def test_async_runner_matches_in_graph_end_to_end():
    """The threaded AsyncInverseRunner (overlapped dispatch, spare device
    or not) reproduces the in-graph landing exactly through
    run_kfac_training."""
    from repro.models import layers
    from repro.train import loop

    taps = {"fc": kfac_lib.TapInfo("fc/w", 24, 8, n_stat=8)}
    cfg = kfac_lib.KfacConfig(
        policy=policy.PolicyConfig(variant="kfac", r=4),
        lr=optbase.constant(0.05), T_updt=1, T_inv=4, stagger=True,
        async_heavy=True, heavy_lag=2)
    key = jax.random.PRNGKey(0)
    params = {"fc": {"w": jax.random.normal(key, (24, 8)) * 0.1}}

    def loss_fn(p, probes, batch):
        x, y = batch
        h, act = layers.tapped_matmul(p["fc"]["w"], x, probes.get("fc"), 8)
        return jnp.mean((h - y) ** 2), {"fc": act}

    batches = [(jax.random.normal(jax.random.fold_in(key, i), (8, 24)),
                jax.random.normal(jax.random.fold_in(key, 50 + i), (8, 8)))
               for i in range(8)]
    opt_a = kfac_lib.Kfac(cfg, taps)
    _, la = loop.run_kfac_training(loss_fn, opt_a, params, batches,
                                   n_tokens=8)
    opt_b = kfac_lib.Kfac(cfg, taps)
    _, lb = loop.run_kfac_training(loss_fn, opt_b, params, batches,
                                   n_tokens=8, overlap=True)
    np.testing.assert_allclose(la, lb, rtol=1e-6)


def test_async_requires_bucketed():
    with pytest.raises(ValueError, match="bucketed"):
        _opt("kfac", lag=2, bucketed=False)


def test_inflight_sharding_rule():
    """kfac_state_sharding shards the in-flight dense-M snapshot on the
    curvature axis (like the live M) and replicates the rest."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")
    from jax.sharding import Mesh
    from repro.distributed import sharding as shd
    from repro.launch import mesh as mesh_lib
    n = len(jax.devices())
    mesh = mesh_lib.make_mesh((n,), ("curv",))
    opt = _opt("kfac", lag=2)
    st = jax.eval_shape(opt.init, _data(opt.taps)[0])
    sh = shd.kfac_state_sharding(st, mesh, curvature_axis="curv")
    for bi, buf_sh in sh.inflight.items():
        total = opt.factor_buckets[int(bi)].total
        spec_m = buf_sh.M.spec
        if total % n == 0:
            assert spec_m[0] == "curv", (bi, spec_m)
        assert all(s is None for s in buf_sh.U.spec)
        assert all(s is None for s in buf_sh.panels.spec)

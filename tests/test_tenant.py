"""TenantBank (core/tenant.py): N independent optimizer states stacked on
a leading tenant axis.

Correctness anchors (ISSUE 10):
  * N=1 bank  ≡ plain Kfac, bit-for-bit (the squeeze fast path IS the
    plain program);
  * N-tenant stacked ≡ N sequential independent runs (allclose; batched
    linalg may reassociate) — across all 6 policy variants;
  * active-masked tenants are carried through bit-exactly (state AND
    params), and active lanes are unaffected by who else is masked;
  * schedule.group_by_work partitions tenants into O(#distinct-mask)
    stacked launches.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kfac as kfac_lib, policy, schedule, tenant
from repro.optim import base as optbase

VARIANTS = ["kfac", "rkfac", "bkfac", "brkfac", "bkfacc", "nskfac"]


def _taps(N=8):
    """Two shape classes (24→16 pair + 24-wide scan) so buckets stay
    non-trivial while the arrays stay tiny."""
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 24, 16, n_stat=N),
        "fc2":  kfac_lib.TapInfo("fc2/w", 24, 16, n_stat=N),
        "scan": kfac_lib.TapInfo("scan/w", 24, 24, stack=(2,), n_stat=N),
    }


def _opt(variant, taps):
    pol = policy.PolicyConfig(variant=variant, r=4, max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              momentum=0.9, T_updt=1, T_brand=1,
                              bucketed=True)
    return kfac_lib.Kfac(cfg, taps)


def _tenant_data(taps, key, t):
    k = jax.random.fold_in(key, t)
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (n, tap) in enumerate(taps.items()):
        shp = tap.stack + (tap.d_in, tap.d_out)
        params[n] = {"w": jax.random.normal(jax.random.fold_in(k, i),
                                            shp) * 0.05}
        grads[n] = {"w": jax.random.normal(jax.random.fold_in(k, 10 + i),
                                           shp)}
        acts[n] = jax.random.normal(jax.random.fold_in(k, 20 + i),
                                    tap.stack + (tap.n_stat, tap.d_in))
        pgs[n] = jax.random.normal(jax.random.fold_in(k, 30 + i),
                                   tap.stack + (tap.n_stat, tap.d_out)) * 1e-3
    return params, grads, acts, pgs


def _work(opt, s, heavy_every=2):
    return opt.uniform_work(True, True, s % heavy_every == 0)


def _run_sequential(opt, taps, n, steps=3):
    """n independent plain-Kfac runs; returns per-tenant update/state
    histories."""
    key = jax.random.PRNGKey(0)
    rkey = jax.random.PRNGKey(7)
    outs, states = [], []
    for t in range(n):
        params, grads, acts, pgs = _tenant_data(taps, key, t)
        st = opt.init(params)
        ups = []
        for s in range(steps):
            upd, st = opt.update(
                grads, st, params, acts=acts, probe_grads=pgs,
                n_tokens=list(taps.values())[0].n_stat,
                rng=jax.random.fold_in(jax.random.fold_in(rkey, t), s),
                work=_work(opt, s))
            ups.append(upd)
        outs.append(ups)
        states.append(st)
    return outs, states


def _run_stacked(opt, taps, n, steps=3, active=None):
    key = jax.random.PRNGKey(0)
    rkey = jax.random.PRNGKey(7)
    per = [_tenant_data(taps, key, t) for t in range(n)]
    params = tenant.tree_stack([p[0] for p in per])
    grads = tenant.tree_stack([p[1] for p in per])
    acts = tenant.tree_stack([p[2] for p in per])
    pgs = tenant.tree_stack([p[3] for p in per])
    bank = tenant.TenantBank(opt)
    st = bank.init(params)
    ups = []
    for s in range(steps):
        rngs = jnp.stack([jax.random.fold_in(jax.random.fold_in(rkey, t), s)
                          for t in range(n)])
        upd, st = bank.update(grads, st, params, acts=acts, probe_grads=pgs,
                              n_tokens=list(taps.values())[0].n_stat,
                              rngs=rngs, work=_work(opt, s), active=active)
        ups.append(upd)
    return bank, ups, st


def _leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# N=1 ≡ plain Kfac, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["bkfac", "kfac"])
def test_single_tenant_bank_is_bitwise_plain_kfac(variant):
    taps = _taps()
    opt = _opt(variant, taps)
    seq, seq_states = _run_sequential(opt, taps, n=1)
    _, stk, stk_state = _run_stacked(opt, taps, n=1)
    for s_up, b_up in zip(seq[0], stk):
        _leaves_equal(s_up, tenant.tree_slot(b_up, 0))
    _leaves_equal(seq_states[0], tenant.tree_slot(stk_state, 0))


@pytest.mark.slow
@pytest.mark.parametrize("variant", VARIANTS)
def test_single_tenant_bitwise_all_variants(variant):
    taps = _taps()
    opt = _opt(variant, taps)
    seq, seq_states = _run_sequential(opt, taps, n=1)
    _, stk, stk_state = _run_stacked(opt, taps, n=1)
    for s_up, b_up in zip(seq[0], stk):
        _leaves_equal(s_up, tenant.tree_slot(b_up, 0))
    _leaves_equal(seq_states[0], tenant.tree_slot(stk_state, 0))


# ---------------------------------------------------------------------------
# N-tenant stacked ≡ N sequential (allclose)
# ---------------------------------------------------------------------------

def _assert_stacked_matches_sequential(variant, n=3, steps=3, atol=3e-4):
    # vmap changes the lowering of the batched matmul/Cholesky chains
    # (reduction order), so the comparison is absolute-dominated: lane
    # values are O(5e-3) and the batched-vs-unbatched drift stays under
    # ~1e-4 after 3 Brand steps (bitwise lane-independence — identical
    # inputs → identical lanes — is asserted separately below).
    taps = _taps()
    opt = _opt(variant, taps)
    seq, _ = _run_sequential(opt, taps, n=n, steps=steps)
    _, stk, _ = _run_stacked(opt, taps, n=n, steps=steps)
    for s in range(steps):
        for t in range(n):
            one = tenant.tree_slot(stk[s], t)
            for name in taps:
                x = np.asarray(seq[t][s][name]["w"])
                y = np.asarray(one[name]["w"])
                assert np.isfinite(x).all() and np.isfinite(y).all()
                np.testing.assert_allclose(y, x, atol=atol, rtol=1e-2)


def test_stacked_matches_sequential_bkfac():
    _assert_stacked_matches_sequential("bkfac")


@pytest.mark.slow
@pytest.mark.parametrize("variant", VARIANTS)
def test_stacked_matches_sequential_all_variants(variant):
    _assert_stacked_matches_sequential(variant)


def test_identical_inputs_give_bitwise_identical_lanes():
    """The lane-independence half of the allclose claim: tenants with
    identical inputs produce identical slices, bit for bit — any
    cross-tenant contamination in the stacked program would break it."""
    taps = _taps()
    opt = _opt("bkfac", taps)
    key = jax.random.PRNGKey(0)
    p, g, a, pg = _tenant_data(taps, key, 0)
    stack3 = lambda t: tenant.tree_stack([t, t, t])
    params = stack3(p)
    bank = tenant.TenantBank(opt)
    st = bank.init(params)
    for s in range(2):
        rngs = jnp.stack([jax.random.fold_in(key, 100 + s)] * 3)
        upd, st = bank.update(stack3(g), st, params, acts=stack3(a),
                              probe_grads=stack3(pg), n_tokens=8,
                              rngs=rngs, work=_work(opt, s))
    for tree in (upd, st):
        for leaf in jax.tree_util.tree_leaves(tree):
            x = np.asarray(leaf)
            np.testing.assert_array_equal(x[0], x[1])
            np.testing.assert_array_equal(x[0], x[2])


# ---------------------------------------------------------------------------
# active masking
# ---------------------------------------------------------------------------

def test_inactive_tenants_are_bitwise_inert():
    taps = _taps()
    opt = _opt("bkfac", taps)
    n = 3
    active = jnp.array([True, False, True])
    bank, ups_m, st_m = _run_stacked(opt, taps, n=n, active=active)
    _, ups_f, st_f = _run_stacked(opt, taps, n=n, active=None)
    st0 = bank.init(tenant.tree_stack(
        [_tenant_data(taps, jax.random.PRNGKey(0), t)[0] for t in range(n)]))
    # masked tenant 1: state identical to its init, updates exactly zero
    _leaves_equal(tenant.tree_slot(st_m, 1), tenant.tree_slot(st0, 1))
    for up in ups_m:
        for leaf in jax.tree_util.tree_leaves(tenant.tree_slot(up, 1)):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    # active tenants: identical to the all-active run, step by step
    for t in (0, 2):
        _leaves_equal(tenant.tree_slot(st_m, t), tenant.tree_slot(st_f, t))
        for um, uf in zip(ups_m, ups_f):
            _leaves_equal(tenant.tree_slot(um, t), tenant.tree_slot(uf, t))


def test_apply_updates_masks_params_bitwise():
    taps = _taps()
    opt = _opt("bkfac", taps)
    _, ups, _ = _run_stacked(opt, taps, n=2)
    params = tenant.tree_stack(
        [_tenant_data(taps, jax.random.PRNGKey(0), t)[0] for t in range(2)])
    active = jnp.array([True, False])
    new = tenant.TenantBank.apply_updates(params, ups[0], active=active)
    _leaves_equal(tenant.tree_slot(new, 1), tenant.tree_slot(params, 1))
    full = tenant.TenantBank.apply_updates(params, ups[0])
    _leaves_equal(tenant.tree_slot(new, 0), tenant.tree_slot(full, 0))


# ---------------------------------------------------------------------------
# bank plumbing: stack/unstack/checkout/admit, group_by_work
# ---------------------------------------------------------------------------

def test_checkout_checkin_roundtrip():
    taps = _taps()
    opt = _opt("bkfac", taps)
    per = [_tenant_data(taps, jax.random.PRNGKey(0), t)[0] for t in range(2)]
    bank = tenant.TenantBank(opt)
    st = bank.init(tenant.tree_stack(per))
    one = bank.checkout(st, 1)
    _leaves_equal(bank.checkin(st, 1, one), st)
    # admit re-inits a slot from fresh params
    st2 = bank.admit(st, 0, per[1])
    _leaves_equal(tenant.tree_slot(st2, 0), opt.init(per[1]))


def test_tree_stack_unstack_roundtrip():
    trees = [{"a": jnp.arange(3.0) + t} for t in range(4)]
    back = tenant.tree_unstack(tenant.tree_stack(trees))
    for a, b in zip(trees, back):
        _leaves_equal(a, b)


def test_group_by_work_partitions_tenants():
    taps = _taps()
    opt = _opt("bkfac", taps)
    sched = opt.scheduler()
    steps = [0, 1, 0, 7, 1]
    groups = schedule.group_by_work(sched, steps)
    seen = sorted(i for ix in groups.values() for i in ix)
    assert seen == list(range(len(steps)))          # exact partition
    for work, ix in groups.items():
        for i in ix:
            assert sched.work(steps[i]) == work     # mask-consistent
    # tenants at the same schedule position always share a launch group
    assert any(set(ix) >= {0, 2} for ix in groups.values())


def test_launch_groups_static_in_tenant_count():
    taps = _taps()
    opt = _opt("bkfac", taps)
    bank = tenant.TenantBank(opt)
    g = bank.launch_groups()
    assert g == len(opt.factor_buckets) + len(opt.precond_buckets)
    # the stacked program has the same decomposition-site count at any N:
    # measured in benchmarks/serve_bench.py by counting jaxpr call sites.

"""Shared synthetic operands for optimizer-level parity tests.

One generator for the (params, grads, acts, probe-grads) tuple used by
the scheduler, distributed-curvature, and async-pipeline parity tests —
keyed, so tests can drive *step-varying* stats (a drifting M is what
makes staleness and scheduling bugs observable; constant operands make
every heavy overwrite identical and parity trivially true).
"""
import jax


def tap_data(taps, key=None):
    """→ (params, grads, acts, probe_grads) for a TapInfo dict."""
    key = jax.random.PRNGKey(0) if key is None else key
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (n, t) in enumerate(taps.items()):
        shp = t.stack + (t.d_in, t.d_out)
        params[n] = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                            shp) * 0.05}
        grads[n] = {"w": jax.random.normal(jax.random.fold_in(key, 10 + i),
                                           shp)}
        acts[n] = jax.random.normal(jax.random.fold_in(key, 20 + i),
                                    t.stack + (t.n_stat, t.d_in))
        pgs[n] = jax.random.normal(jax.random.fold_in(key, 30 + i),
                                   t.stack + (t.n_stat, t.d_out)) * 1e-3
    return params, grads, acts, pgs

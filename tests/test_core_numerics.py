"""Unit tests for the core numerics: Brand updates, RSVD, preconditioning."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brand, rsvd, kfactor, precond

jax.config.update("jax_enable_x64", False)


def _rand_psd_lowrank(key, d, r):
    X = jax.random.normal(key, (d, r)) / np.sqrt(r)
    return X @ X.T


def _rand_state(key, d, r):
    """Random rank-r (U, D) with descending D."""
    k1, k2 = jax.random.split(key)
    Q, _ = jnp.linalg.qr(jax.random.normal(k1, (d, r)))
    D = jnp.sort(jax.random.uniform(k2, (r,), minval=0.1, maxval=2.0))[::-1]
    return Q, D


class TestSymBrand:
    @pytest.mark.slow
    def test_exactness(self):
        """Brand's algorithm is exact: U'D'U'ᵀ == UDUᵀ + AAᵀ."""
        key = jax.random.PRNGKey(0)
        d, r, n = 64, 12, 5
        U, D = _rand_state(key, d, r)
        A = jax.random.normal(jax.random.PRNGKey(1), (d, n))
        U2, D2 = brand.sym_brand_update(U, D, A)
        assert U2.shape == (d, r + n) and D2.shape == (r + n,)
        target = (U * D) @ U.T + A @ A.T
        got = (U2 * D2) @ U2.T
        np.testing.assert_allclose(got, target, atol=2e-4)
        # orthonormality of the new basis
        np.testing.assert_allclose(U2.T @ U2, np.eye(r + n), atol=2e-5)
        # eigenvalues descending and psd
        assert np.all(np.diff(D2) <= 1e-6)
        assert np.all(D2 >= -1e-5)

    def test_matches_exact_evd(self):
        key = jax.random.PRNGKey(2)
        d, r, n = 48, 10, 4
        U, D = _rand_state(key, d, r)
        A = jax.random.normal(jax.random.PRNGKey(3), (d, n))
        U2, D2 = brand.sym_brand_update(U, D, A)
        ref_vals = jnp.linalg.eigvalsh((U * D) @ U.T + A @ A.T)[::-1]
        np.testing.assert_allclose(D2, ref_vals[: r + n], atol=2e-4)

    @pytest.mark.slow
    def test_general_brand(self):
        key = jax.random.PRNGKey(4)
        m, d, r, n = 40, 30, 8, 3
        ku, kv, ka, kb = jax.random.split(key, 4)
        U, _ = jnp.linalg.qr(jax.random.normal(ku, (m, r)))
        V, _ = jnp.linalg.qr(jax.random.normal(kv, (d, r)))
        D = jnp.sort(jax.random.uniform(key, (r,), minval=0.1, maxval=1.0))[::-1]
        A = jax.random.normal(ka, (m, n))
        B = jax.random.normal(kb, (d, n))
        U2, D2, V2 = brand.brand_update(U, D, V, A, B)
        target = (U * D) @ V.T + A @ B.T
        got = (U2 * D2) @ V2.T
        np.testing.assert_allclose(got, target, atol=2e-4)

    def test_init_from_factor(self):
        X = jax.random.normal(jax.random.PRNGKey(5), (32, 6))
        U, D = brand.init_from_factor(X, 10)
        assert U.shape == (32, 10) and D.shape == (10,)
        np.testing.assert_allclose((U * D) @ U.T, X @ X.T, atol=2e-4)

    @pytest.mark.slow
    def test_ea_brand_step_tracks_ea(self):
        """Repeated B-updates with r >= true rank track the exact EA."""
        d, n, r, rho = 40, 4, 20, 0.9
        keys = jax.random.split(jax.random.PRNGKey(6), 6)
        Xs = [jax.random.normal(k, (d, n)) for k in keys]
        U, D = brand.init_from_factor(Xs[0], r + n)
        for X in Xs[1:]:
            U, D = brand.ea_brand_step(U, D, X, rho, r)
        exact = kfactor.exact_ea(Xs, rho)
        # rank of exact EA is 6*n=24 > r=20 → small truncation error only
        err = np.linalg.norm((U * D) @ U.T - exact) / np.linalg.norm(exact)
        assert err < 0.25
        # and with r large enough to hold everything: exact
        U, D = brand.init_from_factor(Xs[0], 24 + n)
        for X in Xs[1:]:
            U, D = brand.ea_brand_step(U, D, X, rho, 24)
        np.testing.assert_allclose((U * D) @ U.T, exact, atol=2e-4)


class TestRSVD:
    def test_psd_accuracy_decaying_spectrum(self):
        d, r = 128, 16
        key = jax.random.PRNGKey(7)
        Q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
        vals = jnp.exp(-jnp.arange(d) / 4.0)   # fast decay like EA K-factors
        M = (Q * vals) @ Q.T
        U, D = rsvd.rsvd_psd(M, r, 10, jax.random.PRNGKey(8), n_iter=3)
        best = (Q[:, :r] * vals[:r]) @ Q[:, :r].T
        got = (U * D) @ U.T
        err = np.linalg.norm(got - M)
        best_err = np.linalg.norm(best - M)
        assert err < best_err * 1.05 + 1e-6

    def test_from_factor_matches_psd(self):
        d, n, r = 96, 24, 8
        X = jax.random.normal(jax.random.PRNGKey(9), (d, n))
        U1, D1 = rsvd.rsvd_psd(X @ X.T, r, 10, jax.random.PRNGKey(10), 3)
        U2, D2 = rsvd.rsvd_from_factor(X, r, 10, jax.random.PRNGKey(10), 3)
        np.testing.assert_allclose(D1, D2, rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose((U1 * D1) @ U1.T, (U2 * D2) @ U2.T,
                                   rtol=2e-2, atol=1e-3)

    def test_pad_to(self):
        M = _rand_psd_lowrank(jax.random.PRNGKey(11), 64, 32)
        U, D = rsvd.rsvd_psd(M, 8, 4, jax.random.PRNGKey(12), pad_to=20)
        assert U.shape == (64, 20) and D.shape == (20,)
        assert np.all(D[8:] == 0)


class TestPrecond:
    def test_matches_dense_solve_full_rank(self):
        """With a full spectrum held, low-rank application == dense solve."""
        d_in, d_out, lam = 24, 16, 0.3
        ka, kg, kj = jax.random.split(jax.random.PRNGKey(13), 3)
        Ma = _rand_psd_lowrank(ka, d_in, 48)
        Mg = _rand_psd_lowrank(kg, d_out, 48)
        J = jax.random.normal(kj, (d_out, d_in))
        Ua, Da = rsvd.exact_evd(Ma)
        Ug, Dg = rsvd.exact_evd(Mg)
        got = precond.kfac_precondition(J, Ug, Dg, lam, Ua, Da, lam)
        ref = precond.dense_inv_apply(J, Mg, lam, Ma, lam)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=1e-4)

    def test_linear_application_matches_quadratic(self):
        """Alg 8 == Alg 1 application when Mat(g) = G Aᵀ."""
        d_in, d_out, n, lam = 32, 20, 6, 0.2
        ka, kg, ks = jax.random.split(jax.random.PRNGKey(14), 3)
        A = jax.random.normal(ka, (d_in, n))
        G = jax.random.normal(kg, (d_out, n))
        J = G @ A.T
        Ua, Da = _rand_state(ks, d_in, 10)
        Ug, Dg = _rand_state(jax.random.PRNGKey(15), d_out, 10)
        quad = precond.kfac_precondition(J, Ug, Dg, lam, Ua, Da, lam)
        lin = precond.kfac_precondition_linear(G, A, Ug, Dg, lam, Ua, Da, lam)
        np.testing.assert_allclose(lin, quad, rtol=2e-3, atol=1e-4)

    def test_spectrum_continuation(self):
        D = jnp.array([3.0, 2.0, 1.0, 0.5])
        D2, lam2 = precond.spectrum_continuation(D, jnp.asarray(0.1))
        np.testing.assert_allclose(D2, [2.5, 1.5, 0.5, 0.0], atol=1e-6)
        np.testing.assert_allclose(lam2, 0.6, atol=1e-6)

    def test_inv_right_identity_limit(self):
        """Zero-rank state → application is (1/λ)·J."""
        J = jax.random.normal(jax.random.PRNGKey(16), (8, 12))
        U = jnp.zeros((12, 4)); D = jnp.zeros((4,))
        got = precond.apply_inv_right(J, U, D, jnp.asarray(0.5))
        np.testing.assert_allclose(got, J / 0.5, atol=1e-6)

    def test_lam_zero_is_finite(self):
        """λ = 0 (undamped config) used to emit inf/NaN from the
        (D+λ)⁻¹ − 1/λ split; the eps floor must keep every quantity
        finite, and exact on the span (the 1/λ_eps terms telescope)."""
        diag = precond.lowrank_inv_diag(jnp.array([2.0, 1.0, 0.0]), 0.0)
        assert np.isfinite(np.asarray(diag)).all()
        # fully-clamped spectrum at λ = 0 — the worst case of both bugs
        diag0 = precond.lowrank_inv_diag(jnp.zeros((4,)), 0.0)
        assert np.isfinite(np.asarray(diag0)).all()
        # full application at λ = 0 stays finite (the floor's contract is
        # inf/NaN protection, not accuracy recovery — at λ = λ_eps the
        # 1/λ-scale intermediates dwarf fp32 precision by design)
        d = 12
        M = _rand_psd_lowrank(jax.random.PRNGKey(17), d, 24)
        U, D = rsvd.exact_evd(M)
        J = jax.random.normal(jax.random.PRNGKey(18), (8, d))
        got = precond.apply_inv_right(J, U, D, jnp.asarray(0.0))
        assert np.isfinite(np.asarray(got)).all()
        # ...and an ordinary small λ is untouched by the floor
        got1 = precond.apply_inv_right(J, U, D, jnp.asarray(1e-3))
        want1 = J @ jnp.linalg.inv(M + 1e-3 * jnp.eye(d))
        np.testing.assert_allclose(np.asarray(got1), np.asarray(want1),
                                   rtol=2e-2, atol=2e-3)

    def test_continuation_shift_parity_rank_deficient(self):
        """Satellite audit of the §3.5 λ-shift: at a rank-deficient factor
        the shifted spectrum D − dmin and the shifted λ + dmin must be
        used *together* in both the low-rank diagonal and the dense J/λ
        term — mixing shifted D with unshifted λ over-damps the null
        space.  Every caller was audited to route both through
        ``precondition_with_damping`` / ``apply_inv_right`` with the pair
        from ``spectrum_continuation``; this pins the contract against a
        dense-inverse oracle built from the same shifted quantities."""
        d, w = 16, 6
        key = jax.random.PRNGKey(19)
        Q, _ = jnp.linalg.qr(jax.random.normal(key, (d, w)))
        D = jnp.array([3.0, 2.0, 1.5, 1.0, 0.7, 0.5])  # rank 6 < d
        phi = jnp.asarray(0.3)
        lam = precond.damping_from_spectrum(D, phi)
        D2, lam2 = precond.spectrum_continuation(D, lam)
        J = jax.random.normal(jax.random.fold_in(key, 1), (8, d))
        got = precond.apply_inv_right(J, Q, D2, lam2)
        M2 = (Q * D2) @ Q.T                              # shifted factor
        want = J @ jnp.linalg.inv(M2 + lam2 * jnp.eye(d))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-5)
        # the smallest retained mode is now damped at exactly λ + dmin —
        # an unshifted-λ mix would damp it at λ and the null space at
        # λ (under-damped) instead of λ + dmin: check the null direction
        R = jax.random.normal(jax.random.fold_in(key, 2), (8, d))
        null_rows = R @ (jnp.eye(d) - Q @ Q.T)            # ⊥ span(Q)
        resp = precond.apply_inv_right(null_rows, Q, D2, lam2)
        np.testing.assert_allclose(np.asarray(resp),
                                   np.asarray(null_rows) / float(lam2),
                                   rtol=1e-4, atol=1e-6)


class TestKFactorStateMachine:
    def _spec(self, mode, d=48, r=8, n=4, **kw):
        return kfactor.KFactorSpec(d=d, r=r, n_stat=n, mode=mode, rho=0.9, **kw)

    def _run(self, spec, n_steps=6, heavy_every=2, seed=0):
        keys = jax.random.split(jax.random.PRNGKey(seed), n_steps)

        @functools.partial(jax.jit, static_argnames=())
        def step(st, X, key, first, heavy):
            st = kfactor.stats_step(spec, st, X, first)
            return kfactor.inverse_rep_step(spec, st, X, key, first, heavy)

        st = spec.init()
        Xs = []
        for i, k in enumerate(keys):
            X = jax.random.normal(k, (spec.d, spec.n_stat))
            Xs.append(X)
            st = step(st, X, k, jnp.asarray(i == 0),
                      jnp.asarray(i % heavy_every == 0))
        return st, Xs

    @pytest.mark.parametrize(
        "mode",
        [pytest.param(m, marks=pytest.mark.slow)
         if m in (kfactor.Mode.BRAND_RSVD, kfactor.Mode.BRAND_CORR) else m
         for m in kfactor.Mode])
    def test_modes_run_and_track(self, mode):
        spec = self._spec(mode, n_crc=4)
        st, Xs = self._run(spec)
        exact = kfactor.exact_ea(Xs, spec.rho)
        if mode is kfactor.Mode.NS:
            # NS holds the damped dense *inverse* in U (λ̂ and residual
            # live in st.aux) — track against inv(EA + λ̂I) at the
            # firing's own λ̂, modulo one stats step of staleness
            lam = float(st.aux[kfactor.AUX_LAM])
            want = np.linalg.inv(np.asarray(exact) + lam * np.eye(spec.d))
            rel = np.linalg.norm(st.U - want) / np.linalg.norm(want)
        else:
            rec = kfactor.reconstruct(st)
            rel = np.linalg.norm(rec - exact) / np.linalg.norm(exact)
        # all modes should produce a non-trivial approximation
        assert rel < 0.9, f"{mode}: rel err {rel}"
        if spec.needs_m:
            np.testing.assert_allclose(st.M, exact, atol=2e-4)

    def test_brand_mode_never_forms_m(self):
        spec = self._spec(kfactor.Mode.BRAND)
        st = spec.init()
        assert st.M.shape == (1, 1)   # low-memory property

    @pytest.mark.slow
    def test_correction_reduces_error(self):
        """Alg 6 can only reduce ||M - Û D̂ Ûᵀ||_F (paper §3.4)."""
        spec = self._spec(kfactor.Mode.BRAND_CORR, d=64, r=12, n=4, n_crc=6)
        st, Xs = self._run(spec, n_steps=5, heavy_every=100)  # no corrections
        exact = kfactor.exact_ea(Xs, spec.rho)
        before = np.linalg.norm(kfactor.reconstruct(st) - st.M)
        st2 = kfactor.light_correction(spec, st, jax.random.PRNGKey(42))
        after = np.linalg.norm(kfactor.reconstruct(st2) - st.M)
        assert after <= before + 1e-5


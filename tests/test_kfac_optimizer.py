"""Integration tests: the K-FAC optimizer family training a small MLP.

Every paper variant must (a) run through all step-variant flags, (b) drive
the loss down on a regression task, (c) keep finite params.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kfac as kfac_lib
from repro.core import policy
from repro.models import layers
from repro.optim import base as optbase
from repro.train import loop

D_IN, D_H, D_OUT, N_BS, N_STAT = 24, 96, 4, 64, 32


def make_mlp_taps():
    return {
        "fc0": kfac_lib.TapInfo("fc0/w", D_IN, D_H, n_stat=N_STAT),
        "fc1": kfac_lib.TapInfo("fc1/w", D_H, D_H, n_stat=N_STAT),
        "fc2": kfac_lib.TapInfo("fc2/w", D_H, D_OUT, n_stat=N_STAT),
    }


def init_mlp(key):
    ks = jax.random.split(key, 3)
    return {
        "fc0": {"w": layers.dense_init(ks[0], D_IN, D_H),
                "b": jnp.zeros((D_H,))},
        "fc1": {"w": layers.dense_init(ks[1], D_H, D_H),
                "b": jnp.zeros((D_H,))},
        "fc2": {"w": layers.dense_init(ks[2], D_H, D_OUT),
                "b": jnp.zeros((D_OUT,))},
    }


def mlp_loss(params, probes, batch):
    x, y = batch
    acts = {}
    h = x
    for i in range(3):
        name = f"fc{i}"
        h, act = layers.tapped_matmul(params[name]["w"], h,
                                      probes.get(name), N_STAT)
        acts[name] = act
        h = h + params[name]["b"]
        if i < 2:
            h = jax.nn.relu(h)
    loss = jnp.mean(jnp.square(h - y))
    return loss, acts


def make_batches(n, seed=0):
    key = jax.random.PRNGKey(seed)
    W_true = jax.random.normal(key, (D_IN, D_OUT)) / np.sqrt(D_IN)
    batches = []
    for i in range(n):
        kx = jax.random.fold_in(key, i + 1)
        x = jax.random.normal(kx, (N_BS, D_IN))
        y = jnp.tanh(x @ W_true) * 2.0
        batches.append((x, y))
    return batches


def _cfg(variant, **kw):
    pol = policy.PolicyConfig(variant=variant, r=16, max_dense_dim=512)
    kwargs = dict(
        policy=pol, lr=optbase.constant(0.05),
        damping_phi=optbase.constant(0.1), weight_decay=1e-4, clip=10.0,
        T_updt=1, T_inv=5, T_brand=1, T_rsvd=5, T_corct=5,
        fallback_lr=optbase.constant(1e-2))
    kwargs.update(kw)
    return kfac_lib.KfacConfig(**kwargs)


@pytest.mark.slow
@pytest.mark.parametrize("variant", list(policy.VARIANTS))
def test_variant_trains(variant):
    cfg = _cfg(variant)
    taps = make_mlp_taps()
    opt = kfac_lib.Kfac(cfg, taps)
    params = init_mlp(jax.random.PRNGKey(1))
    batches = make_batches(40)
    state, losses = loop.run_kfac_training(mlp_loss, opt, params, batches,
                                           n_tokens=N_BS)
    assert np.isfinite(losses).all(), f"{variant}: non-finite loss"
    assert losses[-1] < 0.5 * losses[0], \
        f"{variant}: loss {losses[0]} -> {losses[-1]}"
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_zero_damping_stays_finite():
    """Regression (λ-floor): damping_phi = 0 makes λ = 0 exactly, and the
    low-rank inverse split (D+λ)⁻¹ − 1/λ used to emit inf/NaN that walked
    silently through the whole update.  With the ``_LAM_EPS`` floor the
    optimizer must complete the run with finite losses and params."""
    cfg = _cfg("bkfac", damping_phi=optbase.constant(0.0),
               T_inv=2, T_rsvd=4, T_corct=4, clip=1.0)
    opt = kfac_lib.Kfac(cfg, make_mlp_taps())
    params = init_mlp(jax.random.PRNGKey(4))
    state, losses = loop.run_kfac_training(mlp_loss, opt, params,
                                           make_batches(10), n_tokens=N_BS)
    assert np.isfinite(losses).all(), losses
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_policy_mode_selection():
    pol = policy.PolicyConfig(variant="bkfac", r=16, max_dense_dim=512)
    from repro.core.kfactor import Mode
    # wide layer → Brand; narrow → RSVD; tiny → EVD; huge → Brand (low-mem)
    assert policy.select_mode(pol, 1024, 32) == Mode.BRAND
    assert policy.select_mode(pol, 40, 32) == Mode.RSVD
    assert policy.select_mode(pol, 20, 32) == Mode.EVD
    pol_r = policy.PolicyConfig(variant="rkfac", r=16, max_dense_dim=512)
    assert policy.select_mode(pol_r, 4096, 32) == Mode.BRAND  # memory gate
    assert policy.select_mode(pol_r, 256, 32) == Mode.RSVD


@pytest.mark.slow
def test_momentum_and_schedules():
    # NOTE: with a binding norm-clip the lr is immaterial (the paper's
    # clip=0.07 regime); momentum needs a tight cap to stay stable.
    cfg = _cfg("bkfac", momentum=0.9, clip=0.3)
    opt = kfac_lib.Kfac(cfg, make_mlp_taps())
    params = init_mlp(jax.random.PRNGKey(2))
    state, losses = loop.run_kfac_training(mlp_loss, opt, params,
                                           make_batches(15), n_tokens=N_BS)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_flags_schedule():
    from repro.core import schedule
    flags = schedule.legacy_flags
    cfg = _cfg("brkfac", T_updt=2, T_brand=2, T_rsvd=4)
    assert flags(cfg, 0) == dict(do_stats=True, do_light=True,
                                 do_heavy=True)
    assert flags(cfg, 3) == dict(do_stats=False, do_light=False,
                                 do_heavy=False)
    assert flags(cfg, 2) == dict(do_stats=True, do_light=True,
                                 do_heavy=False)
    cfg_k = _cfg("kfac", T_updt=5, T_inv=5)
    assert flags(cfg_k, 5) == dict(do_stats=True, do_light=False,
                                   do_heavy=True)
    assert flags(cfg_k, 3) == dict(do_stats=False, do_light=False,
                                   do_heavy=False)


@pytest.mark.slow
def test_kfac_beats_sgd_same_budget():
    """Sanity: preconditioning helps on this ill-conditioned problem."""
    from repro.optim import sgd as sgd_lib
    batches = make_batches(30, seed=3)
    params = init_mlp(jax.random.PRNGKey(3))

    opt = kfac_lib.Kfac(_cfg("bkfac"), make_mlp_taps())
    _, kfac_losses = loop.run_kfac_training(mlp_loss, opt, params, batches,
                                            n_tokens=N_BS)
    sgd_opt = sgd_lib.sgd(optbase.constant(0.05))
    step = jax.jit(loop.make_baseline_step(mlp_loss, sgd_opt))
    st = loop.TrainState(params=params, opt=sgd_opt.init(params),
                         rng=jax.random.PRNGKey(0))
    sgd_losses = []
    for b in batches:
        st, l = step(st, b)
        sgd_losses.append(float(l))
    assert kfac_losses[-1] < sgd_losses[-1] * 1.5  # at least competitive

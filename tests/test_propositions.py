"""Numerical verification of the paper's theoretical results
(Propositions 3.1, 3.2, 4.1, 4.2) on exact dense simulations of the
B-KFAC / R-KFAC processes (eqs. 8-10)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

D_DIM, N_BS, R, RHO = 40, 4, 8, 0.9


def _evd_trunc(M, r):
    """Optimal rank-r truncation (dense EVD)."""
    vals, vecs = np.linalg.eigh(M)
    vals, vecs = vals[::-1], vecs[:, ::-1]
    return (vecs[:, :r] * vals[:r]) @ vecs[:, :r].T


def _make_stream(n_steps, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n_steps)
    return [np.asarray(jax.random.normal(k, (D_DIM, N_BS))) for k in keys]


def _exact_ea(Xs):
    M = Xs[0] @ Xs[0].T
    for X in Xs[1:]:
        M = RHO * M + (1 - RHO) * X @ X.T
    return M


def _b_process(Xs, r=R):
    """Eq. (10): returns lists (M̃_B, B) along the stream."""
    Mb = Xs[0] @ Xs[0].T
    Mbs, Bs = [Mb], [_evd_trunc(Mb, r)]
    for X in Xs[1:]:
        Mb = RHO * Bs[-1] + (1 - RHO) * X @ X.T
        Mbs.append(Mb)
        Bs.append(_evd_trunc(Mb, r))
    return Mbs, Bs


class TestProp31:
    """B-KFAC's rank-r estimate is never better than the optimal rank-r
    truncation; its full estimate never better than optimal rank r+n."""

    def test_error_ordering(self):
        Xs = _make_stream(8)
        Mbs, Bs = _b_process(Xs)
        for k in range(1, len(Xs)):
            Mk = _exact_ea(Xs[: k + 1])
            opt_r = _evd_trunc(Mk, R)
            opt_rn = _evd_trunc(Mk, R + N_BS)
            err_B = np.linalg.norm(Mk - Bs[k])
            err_opt = np.linalg.norm(Mk - opt_r)
            err_Mb = np.linalg.norm(Mk - Mbs[k])
            err_opt_rn = np.linalg.norm(Mk - opt_rn)
            assert err_B >= err_opt - 1e-5
            assert err_Mb >= err_opt_rn - 1e-5


class TestProp32:
    """Error telescoping (12)/(13) and psd-ness of every bracketed term."""

    def test_pure_b_error_decomposition(self):
        Xs = _make_stream(7, seed=1)
        Mbs, Bs = _b_process(Xs)
        i, q = 2, 4
        Mi = _exact_ea(Xs[: i + 1])
        lhs = _exact_ea(Xs[: i + q + 1]) - Mbs[i + q]
        rhs = RHO ** q * (Mi - Bs[i])
        for j in range(1, q):
            rhs = rhs + RHO ** (q - j) * (Mbs[i + j] - Bs[i + j])
        # NOTE eq (13) sums to q-1 — the step-q truncation error enters B
        # only at q+1; the identity is exact:
        np.testing.assert_allclose(lhs, rhs, atol=1e-5)

    def test_terms_are_psd(self):
        Xs = _make_stream(7, seed=2)
        Mbs, Bs = _b_process(Xs)
        for k in range(len(Xs)):
            Mk = _exact_ea(Xs[: k + 1])
            for E in (Mk - Bs[k], Mbs[k] - Bs[k], Mk - Mbs[k]):
                w = np.linalg.eigvalsh((E + E.T) / 2)
                assert w.min() >= -1e-4 * max(1.0, abs(w).max())

    def test_overwrite_better_next_iteration(self):
        """E_{i+1}^{R@i} has smaller norm than E_{i+1}^{pure-B}."""
        Xs = _make_stream(8, seed=3)
        Mbs, Bs = _b_process(Xs)
        i = 4
        Mi = _exact_ea(Xs[: i + 1])
        # pure-B error at i+1: rho*(Mi - B_i)
        e_pure = RHO * np.linalg.norm(Mi - Bs[i])
        # overwritten: rho*(Mi - opt_r(Mi))
        e_over = RHO * np.linalg.norm(Mi - _evd_trunc(Mi, R))
        assert e_over <= e_pure + 1e-8


class TestProp41:
    """Error of doing nothing vs error of B-updates (eq. 14-16)."""

    def test_no_update_error_form(self):
        Xs = _make_stream(6, seed=4)
        M0 = Xs[0] @ Xs[0].T
        Mtilde = _evd_trunc(M0, R)       # frozen after initial truncation
        k = len(Xs) - 1
        Mk = _exact_ea(Xs)
        # eq (14)+(15): M_k − M̃ = Σ κ(i) ρ^{k-i} (M_i M_iᵀ − M̃)
        rhs = RHO ** k * (M0 - Mtilde)
        for i in range(1, k + 1):
            rhs = rhs + (1 - RHO) * RHO ** (k - i) * (Xs[i] @ Xs[i].T - Mtilde)
        np.testing.assert_allclose(Mk - Mtilde, rhs, atol=1e-5)

    def test_b_update_error_form(self):
        Xs = _make_stream(6, seed=5)
        Mbs, Bs = _b_process(Xs)
        k = len(Xs) - 1
        Mk = _exact_ea(Xs)
        # eq (14)+(16) with E_0 = M0 − trunc(M0) = M0 − B_0, E_k = 0
        rhs = RHO ** k * (Xs[0] @ Xs[0].T - Bs[0])
        for i in range(1, k):
            Ei = (Mbs[i] - Bs[i]) / (1 - RHO)
            rhs = rhs + (1 - RHO) * RHO ** (k - i) * Ei
        np.testing.assert_allclose(Mk - Mbs[k], rhs, atol=1e-5)


class TestProp42:
    """Worst-case per-step error: B-update ≤ ||M_j M_jᵀ||_F; no-update can
    reach sqrt(||M_j M_jᵀ||² + ||M̃||²)."""

    def test_b_update_bound(self):
        Xs = _make_stream(8, seed=6)
        Mbs, Bs = _b_process(Xs)
        for i in range(1, len(Xs) - 1):
            Ei = (Mbs[i] - Bs[i]) / (1 - RHO)
            bound = np.linalg.norm(Xs[i] @ Xs[i].T)
            assert np.linalg.norm(Ei) <= bound + 1e-6

    def test_no_update_can_exceed_b_bound(self):
        """Construct the orthogonal-subspace worst case of eq (17)."""
        rng = np.random.default_rng(0)
        Q, _ = np.linalg.qr(rng.standard_normal((D_DIM, D_DIM)))
        X0 = Q[:, :N_BS] * 3.0            # M̃ lives in span(Q[:, :n])
        Xj = Q[:, N_BS: 2 * N_BS]         # update orthogonal to it
        M0 = X0 @ X0.T
        Mt = _evd_trunc(M0, R)
        Ej = Xj @ Xj.T - Mt
        lhs = np.linalg.norm(Ej)
        expect = np.sqrt(np.linalg.norm(Xj @ Xj.T) ** 2 +
                         np.linalg.norm(Mt) ** 2)
        np.testing.assert_allclose(lhs, expect, rtol=1e-6)
        assert lhs > np.linalg.norm(Xj @ Xj.T)  # exceeds the B-update bound

"""2D data × curvature mesh (distributed/curvature.py ``row_axis`` path):
replicated ≡ 1D-sharded (1×8) ≡ 2D-sharded (4×2) parity for sync and
async-lag0 pipelines, row-sharded dense M bookkeeping, compressed (U, λ)
collectives, warm-started gradient compression, 2D elastic ladder
shapes, and mixed-mesh checkpoint restores (save 4×2 → resume 2×2 /
replicated).
"""
import os

import numpy as np
import pytest

# must precede backend init in THIS process; harmless if jax was already
# initialized with one device (the mesh tests then skip)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib, policy
from synthdata import tap_data
from repro.distributed import compress as compress_lib
from repro.distributed import curvature as curv
from repro.launch import mesh as mesh_lib
from repro.optim import base as optbase
from repro.train import elastic

N_STAT = 16

#: fast-tier variant subset for the expensive 8-device parity tests; the
#: slow-marked rest still run per-PR in the 2d-mesh-parity CI job, which
#: runs this file with no marker filter.
_FAST_VARIANTS = {"bkfac"}


def _marked_variants():
    return [v if v in _FAST_VARIANTS
            else pytest.param(v, marks=pytest.mark.slow)
            for v in policy.VARIANTS]


def _mixed_taps():
    """Same mixed FC + scanned + MoE model as the 1D parity suite — every
    factor side (48, 32) divides the 4-member row axis, so each bucket's
    dense M row-shards."""
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 48, 32, n_stat=N_STAT),
        "fc2":  kfac_lib.TapInfo("fc2/w", 48, 32, n_stat=N_STAT),
        "scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(3,),
                                 n_stat=N_STAT),
        "moe":  kfac_lib.TapInfo("moe/w", 48, 32, stack=(2, 2),
                                 n_stat=N_STAT),
    }


def _need8():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")


def _attach(opt, mode, compress_rank=None):
    """mode: 'rep' (no engine) | '1d' (1×8 curv) | '2d' (4×2 data×curv)."""
    if mode == "1d":
        mesh = mesh_lib.make_mesh((8,), ("curv",))
        curv.CurvatureEngine.for_kfac(opt, mesh, "curv",
                                      compress_rank=compress_rank)
    elif mode == "2d":
        mesh = mesh_lib.make_mesh((4, 2), ("data", "curv"))
        curv.CurvatureEngine.for_kfac(opt, mesh, "curv", row_axis="data",
                                      compress_rank=compress_rank)
    else:
        assert mode == "rep"


def _run(taps, variant, mode, *, stagger=False, steps=4,
         compress_rank=None):
    pol = policy.PolicyConfig(variant=variant, r=8, max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              momentum=0.9, T_updt=1, T_brand=1, T_inv=3,
                              T_rsvd=3, T_corct=3, stagger=stagger,
                              stagger_splits=4)
    opt = kfac_lib.Kfac(cfg, taps)
    _attach(opt, mode, compress_rank)
    # identical masks on all sides: align to the full mesh either way
    # (an engine-attached scheduler would pick align=8 automatically)
    sched = opt.scheduler(align=8)
    params, grads, acts, pgs = tap_data(taps)
    st = opt.init(params)

    def step(grads, st, rng, work):
        return opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                          n_tokens=N_STAT, rng=rng, work=work)
    step = jax.jit(step, static_argnames=("work",))

    outs = []
    for s in range(steps):
        upd, st = step(grads, st,
                       jax.random.fold_in(jax.random.PRNGKey(7), s),
                       sched.work(s))
        outs.append(upd)
    return outs, st


def _run_async(taps, variant, mode, *, lag, steps=5):
    pol = policy.PolicyConfig(variant=variant, r=8, max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              T_updt=1, T_brand=1, T_inv=3, T_rsvd=3,
                              T_corct=3, stagger=True, stagger_splits=2,
                              async_heavy=True, heavy_lag=lag)
    opt = kfac_lib.Kfac(cfg, taps)
    _attach(opt, mode)
    sched = opt.scheduler(align=8)
    params = tap_data(taps)[0]
    st = opt.init(params)

    def step(grads, st, acts, pgs, rng, work):
        return opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                          n_tokens=N_STAT, rng=rng, work=work)
    step = jax.jit(step, static_argnames=("work",))
    outs = []
    for s in range(steps):
        _, grads, acts, pgs = tap_data(taps, jax.random.PRNGKey(200 + s))
        upd, st = step(grads, st, acts, pgs,
                       jax.random.fold_in(jax.random.PRNGKey(7), s),
                       sched.work(s))
        outs.append(upd)
    return outs, st


def _assert_close(a, b, taps, atol):
    for n in taps:
        x, y = np.asarray(a[n]["w"]), np.asarray(b[n]["w"])
        assert np.isfinite(x).all() and np.isfinite(y).all()
        np.testing.assert_allclose(x, y, atol=atol, rtol=1e-4)


def _assert_factors_close(sta, stb, taps):
    """Factor parity up to the eigenbasis: M and U diag(D) Uᵀ (raw U
    columns of a degenerate eigenpair may rotate under fp-level input
    perturbations)."""
    for name in taps:
        for fa, fb in ((sta.factors[name].A, stb.factors[name].A),
                       (sta.factors[name].G, stb.factors[name].G)):
            np.testing.assert_allclose(np.asarray(fa.M), np.asarray(fb.M),
                                       atol=1e-5, rtol=1e-4)
            ra = np.asarray(fa.U * fa.D[..., None, :]) @ \
                np.swapaxes(np.asarray(fa.U), -1, -2)
            rb = np.asarray(fb.U * fb.D[..., None, :]) @ \
                np.swapaxes(np.asarray(fb.U), -1, -2)
            np.testing.assert_allclose(ra, rb, atol=1e-5)


# ---------------------------------------------------------------------------
# engine bookkeeping (metadata only — no parity steps)
# ---------------------------------------------------------------------------

class TestEngine2DMetadata:
    def _opt(self):
        return kfac_lib.Kfac(kfac_lib.KfacConfig(
            policy=policy.PolicyConfig(variant="bkfacc", r=8)),
            _mixed_taps())

    def test_row_blocks_and_align(self):
        _need8()
        mesh = mesh_lib.make_mesh((4, 2), ("data", "curv"))
        eng = curv.CurvatureEngine(mesh, "curv", self._opt().factor_buckets,
                                   row_axis="data")
        assert eng.n_devices == 2 and eng.n_rows == 4
        assert eng.align == 8
        for spec, rb in zip(eng.specs, eng.row_blocks):
            if spec.needs_m:
                assert rb == spec.d // 4
            else:
                assert rb is None
        assert "rows=data" in eng.describe()

    def test_m_bytes_per_device_fraction(self):
        """Per-device dense-M memory is ~1/N of replicated across the
        WHOLE 4×2 mesh (slots /2, rows /4) — the tentpole memory claim."""
        _need8()
        mesh = mesh_lib.make_mesh((4, 2), ("data", "curv"))
        eng = curv.CurvatureEngine(mesh, "curv", self._opt().factor_buckets,
                                   row_axis="data")
        rep, dev = eng.m_bytes()
        assert rep > 0
        # padding of B up to N_curv keeps the ratio ≤ padded/B / 8
        assert dev <= rep / 8 * 2   # generous: tiny buckets pad B 2→2
        mesh1 = mesh_lib.make_mesh((8,), ("curv",))
        eng1 = curv.CurvatureEngine(mesh1, "curv",
                                    self._opt().factor_buckets)
        _, dev1 = eng1.m_bytes()
        # 2D holds strictly less dense M per device than 1D at equal
        # device count: the row axis divides what slot-sharding cannot
        assert dev < dev1

    def test_collective_bytes_compression_ratio(self):
        _need8()
        mesh = mesh_lib.make_mesh((4, 2), ("data", "curv"))
        fb = self._opt().factor_buckets
        raw = curv.CurvatureEngine(mesh, "curv", fb, row_axis="data")
        cmp4 = curv.CurvatureEngine(mesh, "curv", fb, row_axis="data",
                                    compress_rank=4)
        b_raw = raw.collective_bytes()
        b_cmp = cmp4.collective_bytes()
        assert b_raw["on_wire"] == b_raw["uncompressed"]
        assert b_cmp["uncompressed"] == b_raw["uncompressed"]
        assert b_cmp["on_wire"] < b_raw["on_wire"]

    def test_row_axis_must_differ(self):
        _need8()
        mesh = mesh_lib.make_mesh((4, 2), ("data", "curv"))
        with pytest.raises(ValueError):
            curv.CurvatureEngine(mesh, "curv", self._opt().factor_buckets,
                                 row_axis="curv")


# ---------------------------------------------------------------------------
# replicated ≡ 1×8 ≡ 4×2 parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", _marked_variants())
def test_2d_sync_matches_replicated_and_1d(variant):
    """The three-way exactness contract, synchronous path: same per-slot
    programs, same per-slot keys, row-block-deterministic stats — so the
    4×2 run matches both the 1×8 and the replicated run allclose."""
    _need8()
    taps = _mixed_taps()
    a, _ = _run(taps, variant, "2d")
    b, _ = _run(taps, variant, "rep")
    c, _ = _run(taps, variant, "1d")
    for ua, ub, uc in zip(a, b, c):
        _assert_close(ua, ub, taps, atol=1e-5)
        _assert_close(ua, uc, taps, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["kfac", "bkfacc", "nskfac"])
def test_2d_staggered_matches_replicated(variant):
    """Staggered masks (align=8) localize to the curv axis AND split
    across the 4 row members; factor states agree including the
    row-sharded → re-gathered dense M."""
    _need8()
    taps = _mixed_taps()
    a, sta = _run(taps, variant, "2d", stagger=True)
    b, stb = _run(taps, variant, "rep", stagger=True)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)
    _assert_factors_close(sta, stb, taps)


@pytest.mark.parametrize("variant", _marked_variants())
def test_async_lag0_2d_matches_sync_and_1d(variant):
    """Async launch/land at lag=0 on the 2D mesh: the transient row
    gathers around the launch/land phases reproduce the synchronous
    replicated numerics exactly, across all policy variants."""
    _need8()
    taps = _mixed_taps()
    a, _ = _run_async(taps, variant, "2d", lag=0)
    b, _ = _run_async(taps, variant, "rep", lag=0)
    c, _ = _run_async(taps, variant, "1d", lag=0)
    for ua, ub, uc in zip(a, b, c):
        _assert_close(ua, ub, taps, atol=1e-5)
        _assert_close(ua, uc, taps, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["kfac", "bkfacc"])
def test_async_lag_2d_matches_replicated(variant):
    """lag>0 on the 2D mesh: the in-flight snapshot's dense M rides
    row-sharded between pipeline phases and gathers transiently at
    launch/land — per-device pipeline ≡ replicated pipeline."""
    _need8()
    taps = _mixed_taps()
    a, sta = _run_async(taps, variant, "2d", lag=2, steps=6)
    b, stb = _run_async(taps, variant, "rep", lag=2, steps=6)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)
    for bi in sta.inflight:
        np.testing.assert_allclose(np.asarray(sta.inflight[bi].M),
                                   np.asarray(stb.inflight[bi].M),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(sta.inflight[bi].panels),
                                   np.asarray(stb.inflight[bi].panels),
                                   atol=1e-5, rtol=1e-4)


def test_2d_row_split_heavy_matches_replicated():
    """An 8-slot stacked bucket: the local heavy range (4 slots per curv
    member) divides the 4-member row axis, so the engine's row-split
    branch fires — each row member computes 1 slot's EVD and the chunks
    re-gather.  The small buckets of the mixed model only exercise the
    row-replicated fallback."""
    _need8()
    taps = {"scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(8,),
                                     n_stat=N_STAT)}
    a, sta = _run(taps, "kfac", "2d", steps=4)
    b, stb = _run(taps, "kfac", "rep", steps=4)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)
    _assert_factors_close(sta, stb, taps)


# ---------------------------------------------------------------------------
# mixed-axis checkpoint restore: save on 4×2, resume on 2×2 / replicated
# ---------------------------------------------------------------------------

def _ckpt_model():
    from repro.models import layers
    taps = {"fc": kfac_lib.TapInfo("fc/w", 48, 32, n_stat=N_STAT)}
    key = jax.random.PRNGKey(0)
    params = {"fc": {"w": jax.random.normal(key, (48, 32)) * 0.1}}

    def loss_fn(p, probes, batch):
        x, y = batch
        h, act = layers.tapped_matmul(p["fc"]["w"], x,
                                      probes.get("fc"), N_STAT)
        return jnp.mean((h - y) ** 2), {"fc": act}

    batches = [(jax.random.normal(jax.random.fold_in(key, i), (16, 48)),
                jax.random.normal(jax.random.fold_in(key, 50 + i),
                                  (16, 32)))
               for i in range(8)]
    return taps, params, loss_fn, batches


def _ckpt_opt(taps, *, async_heavy=False):
    cfg = kfac_lib.KfacConfig(
        policy=policy.PolicyConfig(variant="kfac", r=4,
                                   max_dense_dim=8192),
        lr=optbase.constant(0.05), T_updt=1, T_inv=4, stagger=True,
        stagger_splits=2, async_heavy=async_heavy,
        heavy_lag=2 if async_heavy else 0)
    return kfac_lib.Kfac(cfg, taps)


def _drive(loss_fn, opt, params, batches, state=None):
    """Minimal schedule-resuming driver with align pinned to 8 so every
    mesh shape (4×2, 2×2, replicated) runs the identical work masks —
    the cross-mesh parity premise."""
    from repro.train import loop
    sched = opt.scheduler(align=8)
    k_off = 0
    if state is None:
        state = loop.TrainState(params=params, opt=opt.init(params),
                                rng=jax.random.PRNGKey(5))
    else:
        k_off = int(jax.device_get(state.opt.phase))
    step = jax.jit(loop.make_scheduled_kfac_step(loss_fn, opt, N_STAT),
                   static_argnames=("work",))
    losses = []
    for i, batch in enumerate(batches):
        state, loss = step(state, batch, sched.work(k_off + i))
        losses.append(float(loss))
    return state, losses


def _mesh2d(shape):
    return mesh_lib.make_mesh(shape, ("data", "curv"))


@pytest.mark.slow
def test_save_4x2_restore_2x2_matches_uninterrupted(tmp_path):
    """Schema is mesh-agnostic: a checkpoint from a 4×2 run (row-sharded
    M re-gathered at save) restores onto a 2×2 mesh and the resumed run
    matches the uninterrupted 4×2 one."""
    _need8()
    from repro.train import checkpoint as ckpt_lib
    from repro.train import loop
    taps, params, loss_fn, batches = _ckpt_model()

    opt_a = _ckpt_opt(taps)
    curv.CurvatureEngine.for_kfac(opt_a, _mesh2d((4, 2)), "curv",
                                  row_axis="data")
    _, ref_losses = _drive(loss_fn, opt_a, params, batches)

    opt_b = _ckpt_opt(taps)
    curv.CurvatureEngine.for_kfac(opt_b, _mesh2d((4, 2)), "curv",
                                  row_axis="data")
    mid, head = _drive(loss_fn, opt_b, params, batches[:3])
    ckpt_lib.save(str(tmp_path), 3, mid)

    opt_c = _ckpt_opt(taps)
    curv.CurvatureEngine.for_kfac(opt_c, _mesh2d((2, 2)), "curv",
                                  row_axis="data")
    template = loop.TrainState(params=params, opt=opt_c.init(params),
                               rng=mid.rng)
    restored, man = ckpt_lib.restore(str(tmp_path), template)
    assert man["schema"] == ckpt_lib.SCHEMA_VERSION
    _, tail = _drive(loss_fn, opt_c, None, batches[3:], state=restored)
    np.testing.assert_allclose(head + tail, ref_losses, rtol=1e-5,
                               atol=1e-7)


@pytest.mark.slow
def test_save_4x2_midlag_restore_replicated_matches(tmp_path):
    """Async pipeline, checkpoint taken mid-lag (heavy launched on the
    2D mesh, not yet landed): the in-flight buffers — including the
    row-sharded snapshot M, re-gathered at save — restore onto a
    replicated run and the landing still fires on schedule."""
    _need8()
    from repro.train import checkpoint as ckpt_lib
    from repro.train import loop
    taps, params, loss_fn, batches = _ckpt_model()

    opt_a = _ckpt_opt(taps, async_heavy=True)
    curv.CurvatureEngine.for_kfac(opt_a, _mesh2d((4, 2)), "curv",
                                  row_axis="data")
    _, ref_losses = _drive(loss_fn, opt_a, params, batches)

    opt_b = _ckpt_opt(taps, async_heavy=True)
    curv.CurvatureEngine.for_kfac(opt_b, _mesh2d((4, 2)), "curv",
                                  row_axis="data")
    sched = opt_b.scheduler(align=8)
    launch_k = next(k for k in range(6)
                    if any(r for r in sched.work(k).launch))
    assert any(r for k in range(launch_k + 1, 8)
               for r in sched.work(k).land), "test premise: landing later"
    mid, head = _drive(loss_fn, opt_b, params, batches[:launch_k + 1])
    assert any(x.size and float(jnp.abs(x).max()) > 0
               for x in jax.tree_util.tree_leaves(mid.opt.inflight)), \
        "test premise: snapshot actually in flight at the save"
    ckpt_lib.save(str(tmp_path), launch_k, mid)

    opt_c = _ckpt_opt(taps, async_heavy=True)     # replicated resume
    template = loop.TrainState(params=params, opt=opt_c.init(params),
                               rng=mid.rng)
    restored, _ = ckpt_lib.restore(str(tmp_path), template)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6),
        mid.opt.inflight, restored.opt.inflight)
    _, tail = _drive(loss_fn, opt_c, None, batches[launch_k + 1:],
                     state=restored)
    np.testing.assert_allclose(head + tail, ref_losses, rtol=1e-5,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# compressed (U, λ) collectives — lossy, so no strict parity: the
# contract is finite, close-to-raw preconditioning + fewer bytes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_compressed_gather_stays_close_to_raw():
    _need8()
    taps = _mixed_taps()
    a, _ = _run(taps, "bkfac", "2d", steps=3)
    c, _ = _run(taps, "bkfac", "2d", steps=3, compress_rank=8)
    for ua, uc in zip(a, c):
        for n in taps:
            x, y = np.asarray(ua[n]["w"]), np.asarray(uc[n]["w"])
            assert np.isfinite(y).all()
            # rank-8 covers the full Brand basis width on slots this
            # small only approximately; demand the right scale, not bits
            assert np.linalg.norm(x - y) <= 0.5 * np.linalg.norm(x) + 1e-6


# ---------------------------------------------------------------------------
# warm-started gradient compression (compress_tree + CompressState)
# ---------------------------------------------------------------------------

class TestWarmStartCompression:
    def test_round1_matches_stateless_cold_start(self):
        """Round 1 of the stateful path is exactly the old stateless
        cold start (the carry is initialized to the same seeded basis)."""
        G = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        cfg = compress_lib.CompressConfig(rank=4, min_size=1)
        cstate = compress_lib.init_state({"w": G}, cfg)
        approx, _ = compress_lib.compress_tree({"w": G}, cstate, cfg)
        P, Q, _ = compress_lib.compress(G, jnp.zeros_like(G), None, cfg)
        ref = compress_lib.decompress(P, Q, G.shape)
        np.testing.assert_allclose(np.asarray(approx["w"]),
                                   np.asarray(ref), atol=1e-6)

    def test_state_carries_q_and_error(self):
        G = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        cfg = compress_lib.CompressConfig(rank=4, min_size=1)
        cstate = compress_lib.init_state({"w": G}, cfg)
        _, s1 = compress_lib.compress_tree({"w": G}, cstate, cfg)
        assert s1.q["w"].shape == (32, 4)
        # the carried Q is the data-dependent factor, not the seed
        assert float(jnp.abs(s1.q["w"] - cstate.q["w"]).max()) > 1e-3
        assert float(jnp.linalg.norm(s1.err["w"])) > 0

    def test_warm_start_sharpens_basis_across_rounds(self):
        """The mechanism the carry exists for: on a fixed matrix,
        re-entering the previous round's Q makes each round another
        power iteration — the rank-q approximation error falls toward
        the best-rank-q floor, while cold restarts stay pinned at
        single-iteration quality (EF is zeroed to isolate the basis)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        # decaying spectrum so rank-4 truncation has signal to find
        s = jnp.diag(2.0 ** -jnp.arange(32, dtype=jnp.float32))
        G = jax.random.normal(k1, (64, 32)) @ s
        cfg = compress_lib.CompressConfig(rank=4, min_size=1)
        zero = jnp.zeros_like(G)

        def rounds(warm, n=6):
            qc, errs = None, []
            for _ in range(n):
                P, Q, _ = compress_lib.compress(
                    G, zero, qc if warm else None, cfg)
                if warm:
                    qc = Q
                A = compress_lib.decompress(P, Q, G.shape)
                errs.append(float(jnp.linalg.norm(G - A) /
                                  jnp.linalg.norm(G)))
            return errs

        warm, cold = rounds(True), rounds(False)
        assert all(abs(c - cold[0]) < 1e-5 for c in cold)   # pinned
        assert warm[-1] < cold[-1] - 1e-6, (warm, cold)
        assert warm[-1] <= min(warm) + 1e-6                 # monotone-ish

    @pytest.mark.slow
    def test_warm_start_convergence_parity_with_cold(self):
        """Least-squares EF-SGD, warm-started power iteration (the fixed
        ``compress_tree``) vs. cold restarts every round (the old
        behavior): both converge.  Warm is not strictly tighter here —
        on a rank-deficient toy the persistent basis locks a subspace
        and EF carries the rest, a tail-convergence quirk the per-round
        error test above shows is not a compression-quality regression."""
        X = jax.random.normal(jax.random.PRNGKey(3), (128, 16))
        Wt = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
        Y = X @ Wt
        cfg = compress_lib.CompressConfig(rank=2, min_size=1)

        def run(warm):
            W = jnp.zeros((16, 8))
            cstate = compress_lib.init_state({"w": W}, cfg)
            for _ in range(300):
                G = X.T @ (X @ W - Y) / 128
                if warm:
                    approx, cstate = compress_lib.compress_tree(
                        {"w": G}, cstate, cfg)
                    g = approx["w"]
                else:
                    P, Q, err = compress_lib.compress(
                        G, cstate.err["w"], None, cfg)
                    cstate = compress_lib.CompressState(
                        err={"w": err}, q=cstate.q)
                    g = compress_lib.decompress(P, Q, G.shape)
                W = W - 0.05 * g
            return float(jnp.linalg.norm(X @ W - Y) / jnp.linalg.norm(Y))

        warm, cold = run(True), run(False)
        assert warm < 0.1, warm
        assert cold < 0.1, cold
        assert warm <= cold * 3, (warm, cold)


# ---------------------------------------------------------------------------
# 2D elastic ladder (train/elastic.py)
# ---------------------------------------------------------------------------

class TestLadder2D:
    def test_2d_ladder_halves_largest_dim(self):
        rungs = elastic.device_ladder(8, axes=("data", "curv"),
                                      shape=(4, 2))
        assert rungs == (((4, 2), ("data", "curv")),
                         ((2, 2), ("data", "curv")),
                         ((1, 2), ("data", "curv")),
                         ((1, 1), ("data", "curv")))

    def test_1d_ladder_unchanged(self):
        # the pinned 1D shapes (test_chaos.py) must not move
        assert elastic.device_ladder(8) == (
            ((8,), ("data",)), ((4,), ("data",)),
            ((2,), ("data",)), ((1,), ("data",)))

    def test_shrunk_axes_names_the_dropped_dimension(self):
        axes = ("data", "curv")
        assert elastic.shrunk_axes((4, 2), (2, 2), axes) == ("data",)
        assert elastic.shrunk_axes((1, 2), (1, 1), axes) == ("curv",)
        assert elastic.shrunk_axes((2, 2), (2, 2), axes) == ()

    def test_runner_emits_axis_on_2d_shrink(self, tmp_path):
        """A rung-to-rung shrink on a 2D ladder names the dropped axis
        in the repartition event (which capacity dimension was lost)."""
        _need8()
        events = []

        class W:
            def emit(self, etype, **fields):
                events.append((etype, fields))

        def make_state(mesh):
            return {"x": jnp.zeros((4,))}

        def make_step(mesh):
            return lambda st, k: {"x": st["x"] + 1}

        ladder = elastic.device_ladder(8, axes=("data", "curv"),
                                       shape=(4, 2))
        runner = elastic.ElasticRunner(
            ckpt_dir=str(tmp_path), make_state=make_state,
            make_step=make_step, meshes=ladder,
            injector=elastic.FailureInjector(fail_at=[2]),
            writer=W())
        runner.run(5)
        reps = [f for e, f in events if e == "repartition"]
        assert any(f.get("axis") == "data" for f in reps), reps

"""Unit tests for the perf-regression gate's comparison logic.

The load-bearing case is the normalization fix: the gate originally
scaled by the median fresh/baseline ratio over ALL rows, so a uniform
real slowdown (every row 2x — e.g. a jit cache disabled repo-wide)
self-normalized to scale=2.0 and tripped nothing.  Now the scale comes
from a code-independent calibration workload when both artifacts carry
one, and otherwise from the fastest-row band only.
"""
import importlib.util
import os

HERE = os.path.dirname(os.path.abspath(__file__))
_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    os.path.join(HERE, os.pardir, "benchmarks", "check_regression.py"))
cr = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(cr)


def _rows(times, **extra_fields):
    out = {}
    for name, t in times.items():
        row = {"name": name, "p50_us": float(t), "derived": ""}
        row.update(extra_fields.get(name, {}))
        out[name] = row
    return out


class TestMachineScale:
    def test_calibration_wins(self):
        scale, src = cr.machine_scale([2.0, 2.0, 2.0], 0.2,
                                      base_cal=500.0, fresh_cal=500.0)
        assert scale == 1.0 and "calibration" in src

    def test_calibration_tracks_machine(self):
        scale, _ = cr.machine_scale([2.0, 2.0], 0.2,
                                    base_cal=500.0, fresh_cal=1000.0)
        assert scale == 2.0

    def test_fallback_uses_fastest_band(self):
        # 2 honest rows at ~1.0, 6 regressed at 2.0: the scale must come
        # from the honest band, not the all-rows median (which is 2.0)
        scale, src = cr.machine_scale([1.0, 1.02] + [2.0] * 6, 0.2)
        assert scale <= 1.02, (scale, src)


class TestCompare:
    def test_uniform_regression_caught_with_calibration(self):
        """THE regression this PR fixes: every row uniformly 2x slower
        with an unchanged machine (equal calibrations) must fail — the
        original all-rows-median scale absorbed it completely."""
        names = [f"r{i}" for i in range(6)]
        base = _rows({n: 100.0 for n in names})
        fresh = _rows({n: 200.0 for n in names})
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=500.0, fresh_cal=500.0)
        assert len(fails) == len(names), fails

    def test_machine_slowdown_not_flagged(self):
        """Same 2x on every row, but the calibration moved 2x too: a
        slower machine, not a regression."""
        names = [f"r{i}" for i in range(6)]
        base = _rows({n: 100.0 for n in names})
        fresh = _rows({n: 200.0 for n in names})
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=500.0, fresh_cal=1000.0)
        assert not fails, fails

    def test_majority_regression_caught_without_calibration(self):
        """Legacy baseline (no calibration stamp): 6 of 8 rows at 2x
        must still fail via the fastest-band fallback.  The all-rows
        median would have scaled by 2.0 and passed everything."""
        base = _rows({f"r{i}": 100.0 for i in range(8)})
        fresh = _rows({f"r{i}": (100.0 if i < 2 else 200.0)
                       for i in range(8)})
        fails, _ = cr.compare(base, fresh, 0.2, "t")
        assert len(fails) == 6, fails
        assert all("r0" not in f and "r1:" not in f for f in fails)

    def test_single_hot_row_flagged(self):
        base = _rows({f"r{i}": 100.0 for i in range(6)})
        times = {f"r{i}": 101.0 for i in range(6)}
        times["r3"] = 160.0
        fails, _ = cr.compare(base, _rows(times), 0.2, "t",
                              base_cal=500.0, fresh_cal=500.0)
        assert len(fails) == 1 and "r3" in fails[0], fails

    def test_noise_allowance(self):
        """A row whose own baseline demonstrated 1.5x run-to-run jitter
        gets threshold x that allowance; a stable row does not."""
        base = _rows({"jittery": 100.0, "stable": 100.0},
                     jittery={"p50_noise": 1.5})
        fresh = _rows({"jittery": 160.0, "stable": 160.0})
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=500.0, fresh_cal=500.0)
        assert len(fails) == 1 and "stable" in fails[0], fails

    def test_async_miss_regression_fails(self):
        """A degraded overlap runner keeps timing and parity green (the
        misses fall back to in-graph recompute) — only the recorded
        health counters can catch it."""
        base = _rows({"async": 100.0, "other": 100.0})
        base["async"]["derived"] = "async_launched=6 async_missed=0"
        fresh = _rows({"async": 100.0, "other": 100.0})
        fresh["async"]["derived"] = "async_launched=6 async_missed=3"
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=500.0, fresh_cal=500.0)
        assert len(fails) == 1 and "missed landing" in fails[0], fails

    def test_async_miss_at_baseline_passes(self):
        base = _rows({"async": 100.0})
        base["async"]["derived"] = "async_missed=1"
        fresh = _rows({"async": 100.0})
        fresh["async"]["derived"] = "async_missed=1"
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=500.0, fresh_cal=500.0)
        assert not fails, fails

    def test_parity_flip_and_missing_row_fail(self):
        base = _rows({"a": 100.0, "gone": 50.0})
        fresh = _rows({"a": 100.0, "claim": 0.0})
        fresh["a"]["derived"] = "speedup=2.0x allclose=False"
        fresh["claim"]["derived"] = "False"
        del fresh["claim"]["p50_us"]
        fails, _ = cr.compare(base, fresh, 0.2, "t",
                              base_cal=1.0, fresh_cal=1.0)
        msgs = "\n".join(fails)
        assert "allclose=False" in msgs
        assert "claim" in msgs and "missing" in msgs, msgs

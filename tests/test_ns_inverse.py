"""Newton–Schulz inverse-refinement heavy path (Mode.NS).

Covers the acceptance contract of the NS variant:
  * kernel dispatch parity (ops.ns_step interpret mode vs the jnp oracle),
  * cold-start convergence within K ≤ 8 iterations at the default prescale,
  * warm-start advantage (a stale inverse converges in far fewer steps),
  * the divergence fallback, deterministically triggered, including
    per-slot isolation (a diverging slot must not perturb its siblings),
  * the matmul-only guarantee: no eigh/svd/qr primitive anywhere in the
    NS heavy firing's jaxpr (the dense-solve fallback is LU-based).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kfactor
from repro.core.kfactor import KFactorSpec, Mode
from repro.kernels import ops, ref


def _psd(key, d, scale=1.0, decay=0.8):
    lam = scale * jnp.power(jnp.arange(1, d + 1, dtype=jnp.float32), -decay)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    return (Q * lam) @ Q.T


def _ns_state(M, U=None):
    U0 = jnp.zeros(M.shape) if U is None else U
    return kfactor.KFactorState(
        U=U0, D=jnp.zeros(M.shape[:-1]), M=M,
        aux=jnp.zeros(M.shape[:-2] + (kfactor.AUX_WIDTH,)))


# ---------------------------------------------------------------------------
# kernel dispatch parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128), (3, 128, 128),
                                   (2, 2, 200, 200), (96, 96)])
def test_ns_step_kernel_matches_oracle(shape, monkeypatch):
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    M = A @ jnp.swapaxes(A, -1, -2) / shape[-1]
    X = jnp.asarray(rng.standard_normal(shape), jnp.float32) * 0.1
    want = ref.ns_step(M, X)
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    got = ops.ns_step(M, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# convergence
# ---------------------------------------------------------------------------

def test_cold_start_converges_within_8_iters():
    d = 256
    M = _psd(jax.random.PRNGKey(0), d)
    spec = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS)  # ns_iters=8
    out = kfactor.ns_overwrite(spec, _ns_state(M))
    lam = float(out.aux[kfactor.AUX_LAM])
    res = float(out.aux[kfactor.AUX_RES])
    assert res < 1e-3, res                      # way under the 0.5 fallback
    want = jnp.linalg.inv(0.5 * (M + M.T) + lam * jnp.eye(d))
    rel = float(jnp.linalg.norm(out.U - want) / jnp.linalg.norm(want))
    assert rel < 1e-4, rel


def test_warm_start_beats_cold_at_low_iters():
    """After a small EA drift of M, the stale inverse passes the warm
    guard and K=2 suffices — while a cold start at K=2 is far from
    converged.  This is the whole economics of the NS heavy path."""
    d = 192
    M0 = _psd(jax.random.PRNGKey(1), d)
    spec8 = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS, ns_iters=8)
    warm_src = kfactor.ns_overwrite(spec8, _ns_state(M0))
    # drift: one EA absorb's worth of change
    P = _psd(jax.random.PRNGKey(2), d, scale=0.05)
    M1 = 0.95 * M0 + 0.05 * P
    spec2 = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS, ns_iters=2)
    warm = kfactor.ns_overwrite(spec2, _ns_state(M1, U=warm_src.U))
    cold = kfactor.ns_overwrite(spec2, _ns_state(M1))
    res_warm = float(warm.aux[kfactor.AUX_RES])
    res_cold = float(cold.aux[kfactor.AUX_RES])
    assert res_warm < 1e-3, res_warm
    assert res_warm < 0.01 * res_cold, (res_warm, res_cold)


def test_zero_init_takes_cold_path():
    """A freshly-initialized state (U = 0) must fail the warm guard and
    still converge from the α·I cold start."""
    d = 128
    M = _psd(jax.random.PRNGKey(3), d)
    spec = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS)
    out = kfactor.ns_overwrite(spec, _ns_state(M))
    assert float(out.aux[kfactor.AUX_RES]) < 1e-3


# ---------------------------------------------------------------------------
# divergence fallback
# ---------------------------------------------------------------------------

def _adversarial_m(d):
    """Top eigenvector exactly orthogonal to the power iteration's
    all-ones start: λ_max is underestimated by 2×, the cold prescale α
    overshoots (α·λ_max(M̂) > 2) and plain NS diverges — the residual
    check must catch it and the dense-solve fallback must repair it."""
    u1 = jnp.zeros((d,)).at[0].set(1.0).at[1].set(-1.0) / np.sqrt(2.0)
    return 2.0 * jnp.outer(u1, u1) + 1.0 * (jnp.eye(d) - jnp.outer(u1, u1))


def test_divergence_fallback_repairs_slot():
    d = 128
    M = _adversarial_m(d)
    spec = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS)
    out = kfactor.ns_overwrite(spec, _ns_state(M))
    # flagged: residual ≥ threshold or NaN (diverged-to-NaN iterates)
    assert not (float(out.aux[kfactor.AUX_RES]) < kfactor._NS_RES_MAX)
    lam = float(out.aux[kfactor.AUX_LAM])
    want = jnp.linalg.inv(M + lam * jnp.eye(d))
    rel = float(jnp.linalg.norm(out.U - want) / jnp.linalg.norm(want))
    assert rel < 1e-4, rel                         # ...and repaired


def test_fallback_is_per_slot():
    """One diverging slot in a batch: the healthy sibling's NS result must
    be bit-identical to running it alone (the fallback is a bucket-level
    cond with a per-slot where — parity across shardings depends on it)."""
    d = 128
    good = _psd(jax.random.PRNGKey(4), d)
    bad = _adversarial_m(d)
    spec = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS)
    alone = kfactor.ns_overwrite(spec, _ns_state(good))
    Mb = jnp.stack([good, bad])
    batched = kfactor.heavy_overwrite_batched(
        spec, _ns_state(Mb), jnp.zeros((2, 2), jnp.uint32))
    np.testing.assert_array_equal(np.asarray(batched.U[0]),
                                  np.asarray(alone.U))
    assert float(batched.aux[0, kfactor.AUX_RES]) < kfactor._NS_RES_MAX
    assert not (float(batched.aux[1, kfactor.AUX_RES])
                < kfactor._NS_RES_MAX)
    lam_bad = float(batched.aux[1, kfactor.AUX_LAM])
    want = jnp.linalg.inv(bad + lam_bad * jnp.eye(d))
    rel = float(jnp.linalg.norm(batched.U[1] - want) /
                jnp.linalg.norm(want))
    assert rel < 1e-4, rel


def test_zero_iters_residual_triggers_fallback():
    """ns_iters=0 leaves the cold α·I init in place — residual ≫ 0.5, so
    the fallback must fire and still deliver the exact damped inverse."""
    d = 96
    M = _psd(jax.random.PRNGKey(5), d)
    spec = KFactorSpec(d=d, r=16, n_stat=8, mode=Mode.NS, ns_iters=0)
    out = kfactor.ns_overwrite(spec, _ns_state(M))
    assert float(out.aux[kfactor.AUX_RES]) >= kfactor._NS_RES_MAX
    lam = float(out.aux[kfactor.AUX_LAM])
    want = jnp.linalg.inv(0.5 * (M + M.T) + lam * jnp.eye(d))
    rel = float(jnp.linalg.norm(out.U - want) / jnp.linalg.norm(want))
    assert rel < 1e-4, rel


# ---------------------------------------------------------------------------
# matmul-only guarantee
# ---------------------------------------------------------------------------

_BANNED = {"eigh", "eig", "svd", "qr", "geqrf", "householder_product",
           "schur", "tridiagonal"}


def _walk_jaxpr(jaxpr, seen):
    for eqn in jaxpr.eqns:
        seen.add(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for item in vs:
                inner = getattr(item, "jaxpr", None)
                if inner is not None:
                    _walk_jaxpr(inner, seen)


def test_ns_heavy_firing_is_matmul_only():
    """The acceptance criterion: no eigh/qr/svd primitive anywhere in the
    NS heavy firing's jaxpr — including the untaken cond branches (the
    divergence fallback is an LU solve, which is allowed)."""
    d, n, B = 64, 8, 3
    spec = KFactorSpec(d=d, r=8, n_stat=n, mode=Mode.NS)
    st = kfactor.KFactorState(U=jnp.zeros((B, d, d)),
                              D=jnp.zeros((B, d)),
                              M=jnp.zeros((B, d, d)),
                              aux=jnp.zeros((B, kfactor.AUX_WIDTH)))
    X = jnp.zeros((B, d, n))
    keys = jnp.zeros((B, 2), jnp.uint32)

    def heavy_step(st, X, keys):
        return kfactor.bucket_factor_step(spec, st, X, keys,
                                          jnp.asarray(False), stats=True,
                                          light=False,
                                          heavy_ranges=((0, B),))

    jaxpr = jax.make_jaxpr(heavy_step)(st, X, keys)
    seen = set()
    _walk_jaxpr(jaxpr.jaxpr, seen)
    offenders = seen & _BANNED
    assert not offenders, offenders
    assert any("dot" in p for p in seen)   # it IS doing matmuls
    # the fallback's LU solve is present (under cond) and allowed
    assert any("lu" in p for p in seen) or "custom_linear_solve" in seen

"""Sharding-rule unit tests on a small forced-host-device mesh."""
import os
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # multi-minute e2e tier

# must precede jax usage in THIS process; harmless if already imported with
# a single device (tests then run on a 1-device mesh and only check specs)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib


def _mesh():
    n = len(jax.devices())
    if n >= 8:
        return mesh_lib.make_mesh((4, 2), ("data", "model"))
    return mesh_lib.make_mesh((1, 1), ("data", "model"))


class TestParamSpec:
    def test_rules(self):
        mesh = _mesh()
        cases = {
            "segments/0/p0/mix/wq": (3, P(None, None, "model")),
            "segments/0/p0/mix/wo": (3, P(None, "model", None)),
            "segments/0/p0/ffn/wi": (3, P(None, None, "model")),   # dense
            "segments/0/p0/ffn/wo_f": (3, P(None, "model", None)),
            "segments/0/p0/ffn/wi_moe": (4, None),  # via ffn/wi 4D rule
            "embed": (2, P("model", None)),
            "head/w": (2, P(None, "model")),
            "segments/0/p0/mix/ln": (1, P()),
        }
        for path, (ndim, want) in cases.items():
            if path.endswith("wi_moe"):
                got = shd.param_spec("segments/0/p0/ffn/wi", 4, mesh)
                assert got == P(None, "model", None, None), got
                continue
            got = shd.param_spec(path, ndim, mesh)
            assert got == want, (path, got, want)

    def test_fit_spec_drops_nondivisible(self):
        mesh = _mesh()
        if mesh.devices.size == 1:
            pytest.skip("one device")
        # vocab 51865 not divisible by model axis (2) → replicated dim
        spec = shd.fit_spec(P("model", None), (51865, 1024), mesh)
        assert spec == P(None, None)
        spec = shd.fit_spec(P("model", None), (51864, 1024), mesh)
        assert spec == P("model", None)

    def test_params_sharding_tree(self):
        mesh = _mesh()
        params = {"embed": jnp.zeros((64, 16)),
                  "segments": {"0": {"p0": {"mix": {
                      "wq": jnp.zeros((2, 16, 32)),
                      "ln": jnp.zeros((2, 16))}}}},
                  "head": {"w": jnp.zeros((16, 64))}}
        sh = shd.params_sharding(params, mesh)
        assert sh["embed"].spec == P("model", None)
        assert sh["segments"]["0"]["p0"]["mix"]["wq"].spec == \
            P(None, None, "model")
        assert sh["segments"]["0"]["p0"]["mix"]["ln"].spec == P()


class TestEndToEndSharded:
    def test_small_train_step_on_mesh(self):
        """A reduced arch train step actually RUNS on a 4×2 mesh."""
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 host devices")
        from repro.configs.base import get_arch
        from repro.launch import steps
        mesh = _mesh()
        arch = get_arch("gemma3_4b").reduced()
        built = steps.build_train_step(arch, mesh, remat=False)
        with mesh:
            fn = jax.jit(built.step_fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings)
            lm, opt = built.lm, built.opt
            params = jax.device_put(lm.init(jax.random.PRNGKey(0)),
                                    built.in_shardings[0])
            opt_state = jax.device_put(opt.init(params),
                                       built.in_shardings[1])
            batch = {
                "tokens": jnp.zeros((8, 32), jnp.int32),
                "targets": jnp.zeros((8, 32), jnp.int32),
            }
            # reshape batch to the cell's global shape contract: use the
            # step with our own smaller shapes (jit re-traces)
            params2, opt2, loss = fn(params, opt_state,
                                     jax.device_put(batch,
                                                    shd.batch_sharding(
                                                        batch, mesh)),
                                     jax.random.PRNGKey(1).astype(
                                         jnp.uint32))
            assert np.isfinite(float(loss))

    def test_decode_step_on_mesh(self):
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 host devices")
        from repro.configs.base import get_arch
        from repro.models.lm import LM
        from repro.launch import steps
        mesh = _mesh()
        arch = get_arch("recurrentgemma_2b").reduced()
        sp = steps.shard_policy_for(mesh)
        lm = LM(arch, sp, remat=False)
        with mesh:
            params = lm.init(jax.random.PRNGKey(0))
            cache = lm.init_cache(8, 32)
            c_sh = shd.cache_sharding(cache, mesh)
            cache = jax.device_put(cache, c_sh)
            token = jnp.zeros((8, 1), jnp.int32)
            logits, cache = jax.jit(lm.decode_step)(params, cache, token,
                                                    jnp.asarray(0))
            assert np.isfinite(np.asarray(logits)).all()

"""Checkpoint/restart, elastic remesh, straggler detection, and gradient
compression tests."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt
from repro.train import elastic, straggler
from repro.distributed import compress


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (8, 4)),
                           "b": jnp.zeros((4,))},
                "opt": {"mu": jnp.ones((8, 4)) * 0.5},
                "step": jnp.asarray(7)}

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 7, tree)
        got, manifest = ckpt.restore(str(tmp_path), tree)
        assert manifest["step"] == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            tree, got)

    def test_latest_pointer_and_prune(self, tmp_path):
        tree = self._tree()
        for s in (1, 2, 3, 4):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_step(str(tmp_path)) == 4
        ckpt.prune(str(tmp_path), keep=2)
        dirs = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert len(dirs) == 2
        assert ckpt.latest_step(str(tmp_path)) == 4

    def test_async_checkpointer(self, tmp_path):
        c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        tree = self._tree()
        for s in (0, 5, 10):
            c.submit(s, tree)
        c.close()
        assert ckpt.latest_step(str(tmp_path)) == 10

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 0, self._tree())
        bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros((4,))},
               "opt": {"mu": jnp.zeros((8, 4))}, "step": jnp.asarray(0)}
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), bad)

    def test_manifest_carries_schema_version(self, tmp_path):
        import json
        path = ckpt.save(str(tmp_path), 0, self._tree())
        with open(os.path.join(path, "manifest.json")) as f:
            assert json.load(f)["schema"] == ckpt.SCHEMA_VERSION

    def test_old_pytree_fails_with_actionable_schema_error(self, tmp_path):
        """A checkpoint missing leaves the template has (the pre-PR-3 /
        pre-async trap) must fail naming both schema versions, not with
        an opaque KeyError."""
        import json
        path = ckpt.save(str(tmp_path), 0, self._tree())
        # simulate an old writer: pre-schema manifest (v1 implied)
        man = os.path.join(path, "manifest.json")
        with open(man) as f:
            m = json.load(f)
        del m["schema"]
        with open(man, "w") as f:
            json.dump(m, f)
        newer = dict(self._tree(), inflight={"0": jnp.zeros((2, 3))})
        with pytest.raises(ckpt.SchemaMismatchError) as ei:
            ckpt.restore(str(tmp_path), newer)
        msg = str(ei.value)
        assert "schema v1" in msg
        assert f"schema v{ckpt.SCHEMA_VERSION}" in msg
        assert "migrate" in msg

    def test_leaf_compatible_old_checkpoint_still_restores(self, tmp_path):
        """Schema is for explaining failures, not rejecting compatible
        trees: a v-old checkpoint whose leaves match restores fine (the
        async-off case — inflight={} adds no leaves)."""
        tree = self._tree()
        ckpt.save(str(tmp_path), 3, tree)
        template = dict(tree, inflight={})     # new field, no leaves
        got, _ = ckpt.restore(str(tmp_path), template)
        assert got["inflight"] == {}


class TestAsyncCheckpointRoundTrip:
    """The async pipeline's in-flight buffers are part of the optimizer
    pytree: a checkpoint taken mid-lag (heavy launched, not yet landed)
    must restore so the landing still fires on schedule and the run
    matches an uninterrupted one."""

    def _setup(self):
        from repro.core import kfac as kfac_lib
        from repro.core import policy
        from repro.models import layers
        from repro.optim import base as optbase

        taps = {"fc": kfac_lib.TapInfo("fc/w", 24, 8, n_stat=8)}
        cfg = kfac_lib.KfacConfig(
            policy=policy.PolicyConfig(variant="kfac", r=4),
            lr=optbase.constant(0.05), T_updt=1, T_inv=4, stagger=True,
            stagger_splits=2, async_heavy=True, heavy_lag=2)
        key = jax.random.PRNGKey(0)
        params = {"fc": {"w": jax.random.normal(key, (24, 8)) * 0.1}}

        def loss_fn(p, probes, batch):
            x, y = batch
            h, act = layers.tapped_matmul(p["fc"]["w"], x,
                                          probes.get("fc"), 8)
            return jnp.mean((h - y) ** 2), {"fc": act}

        batches = [(jax.random.normal(jax.random.fold_in(key, i),
                                      (8, 24)),
                    jax.random.normal(jax.random.fold_in(key, 50 + i),
                                      (8, 8)))
                   for i in range(8)]
        return kfac_lib, cfg, taps, params, loss_fn, batches

    @pytest.mark.slow
    def test_mid_lag_save_restore_matches_uninterrupted(self, tmp_path):
        from repro.train import loop
        kfac_lib, cfg, taps, params, loss_fn, batches = self._setup()

        # uninterrupted 8-step reference
        opt_a = kfac_lib.Kfac(cfg, taps)
        ref_state, ref_losses = loop.run_kfac_training(
            loss_fn, opt_a, params, batches, n_tokens=8)

        # split run: stop at step 3 — the launch at step 2 (phase-2
        # unit) is in flight, landing due at step 4
        opt_b = kfac_lib.Kfac(cfg, taps)
        sched = opt_b.scheduler()
        assert any(sched.work(2).launch), "test premise: launch at k=2"
        assert any(sched.work(4).land), "test premise: landing at k=4"
        mid, head = loop.run_kfac_training(loss_fn, opt_b, params,
                                           batches[:3], n_tokens=8)
        assert any(x.size and float(jnp.abs(x).max()) > 0
                   for x in jax.tree_util.tree_leaves(mid.opt.inflight)), \
            "test premise: snapshot actually in flight at the save"
        ckpt.save(str(tmp_path), 3, mid)

        # restore into a fresh template and finish the run
        opt_c = kfac_lib.Kfac(cfg, taps)
        template = loop.TrainState(params=params,
                                   opt=opt_c.init(params),
                                   rng=mid.rng)
        restored, manifest = ckpt.restore(str(tmp_path), template)
        assert manifest["schema"] == ckpt.SCHEMA_VERSION
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            mid.opt.inflight, restored.opt.inflight)
        end_state, tail_losses = loop.run_kfac_training(
            loss_fn, opt_c, None, batches[3:], n_tokens=8,
            state=restored)

        np.testing.assert_allclose(head + tail_losses, ref_losses,
                                   rtol=1e-6)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                    np.asarray(b),
                                                    rtol=1e-6, atol=1e-7),
            end_state.params, ref_state.params)

    @pytest.mark.slow
    def test_mid_lag_restore_with_overlap_runner(self, tmp_path):
        """Resuming with the overlapped runner: the landing whose launch
        predates the restore has no pending future and falls back to
        in-graph compute from the restored snapshot — same numbers."""
        from repro.train import loop
        kfac_lib, cfg, taps, params, loss_fn, batches = self._setup()
        opt_a = kfac_lib.Kfac(cfg, taps)
        _, ref_losses = loop.run_kfac_training(loss_fn, opt_a, params,
                                               batches, n_tokens=8)
        opt_b = kfac_lib.Kfac(cfg, taps)
        mid, head = loop.run_kfac_training(loss_fn, opt_b, params,
                                           batches[:3], n_tokens=8)
        ckpt.save(str(tmp_path), 3, mid)
        opt_c = kfac_lib.Kfac(cfg, taps)
        template = loop.TrainState(params=params, opt=opt_c.init(params),
                                   rng=mid.rng)
        restored, _ = ckpt.restore(str(tmp_path), template)
        _, tail = loop.run_kfac_training(loss_fn, opt_c, None,
                                         batches[3:], n_tokens=8,
                                         state=restored, overlap=True)
        np.testing.assert_allclose(head + tail, ref_losses, rtol=1e-6)


@pytest.mark.slow
class TestElastic:
    def test_failure_restart_resumes_from_checkpoint(self, tmp_path):
        """Inject a failure mid-run; the runner must resume from the last
        checkpoint on the fallback mesh and reach the same final state as
        an uninterrupted run (deterministic data)."""
        def make_state(mesh):
            return {"x": jnp.zeros((4,)), "step": jnp.asarray(0)}

        def make_step(mesh):
            def step(state, k):
                return {"x": state["x"] + (k + 1),
                        "step": jnp.asarray(k)}
            return step

        meshes = (((1,), ("data",)), ((1,), ("data",)))
        inj = elastic.FailureInjector(fail_at=[7])
        runner = elastic.ElasticRunner(
            ckpt_dir=str(tmp_path), make_state=make_state,
            make_step=make_step, ckpt_every=2, meshes=meshes, injector=inj)
        state, info = runner.run(10)
        assert info["restarts"] == 1
        assert inj.failed == [7]
        # uninterrupted reference
        ref = make_state(None)
        for k in range(10):
            ref = make_step(None)(ref, k)
        np.testing.assert_allclose(np.asarray(state["x"]),
                                   np.asarray(ref["x"]))

    def test_double_failure_walks_mesh_ladder(self, tmp_path):
        def make_state(mesh):
            return {"x": jnp.zeros(())}

        calls = []

        def make_step(mesh):
            calls.append(tuple(mesh.devices.shape))
            def step(state, k):
                return {"x": state["x"] + 1}
            return step

        meshes = (((1, 1), ("data", "model")), ((1,), ("data",)),
                  ((1,), ("data",)))
        inj = elastic.FailureInjector(fail_at=[2, 5])
        runner = elastic.ElasticRunner(
            ckpt_dir=str(tmp_path), make_state=make_state,
            make_step=make_step, ckpt_every=1, meshes=meshes, injector=inj)
        state, info = runner.run(8)
        assert info["restarts"] == 2
        assert len(calls) == 3


class TestStraggler:
    def _fleet(self, slow_host=None, slow_from=10, n=30, hosts=8):
        det = straggler.StragglerDetector(patience=3, rebalance_after=6)
        per_host_actions = {f"h{i}": [] for i in range(hosts)}
        for k in range(n):
            times = {f"h{i}": 1.0 + 0.02 * (i % 3) for i in range(hosts)}
            if slow_host is not None and k >= slow_from:
                times[slow_host] = 2.5
            acts = det.observe_step(k, times)
            for h, a in acts.items():
                per_host_actions[h].append(a)
        return det, per_host_actions

    def test_detects_persistent_straggler(self):
        det, acts = self._fleet(slow_host="h3")
        assert straggler.Action.DROP_STATS in acts["h3"]
        assert straggler.Action.REBALANCE in acts["h3"]
        for h in acts:
            if h != "h3":
                assert straggler.Action.DROP_STATS not in acts[h]

    def test_tolerates_single_blip(self):
        det = straggler.StragglerDetector(patience=3)
        flagged = []
        for k in range(25):
            times = {f"h{i}": 1.0 for i in range(6)}
            if k == 12:
                times["h2"] = 5.0
            acts = det.observe_step(k, times)
            flagged += [a for a in acts.values() if a != straggler.Action.NONE]
        assert not flagged

    def test_fleet_slowdown_flags_nobody(self):
        """Whole-fleet degradation is not a straggler."""
        det = straggler.StragglerDetector(patience=2)
        for k in range(20):
            scale = 1.0 if k < 10 else 3.0
            acts = det.observe_step(k, {f"h{i}": scale for i in range(4)})
            assert all(a == straggler.Action.NONE for a in acts.values())

    def test_drop_stats_flag_rewrite(self):
        flags = dict(do_stats=True, do_light=True, do_heavy=False)
        out = straggler.apply_to_flags(straggler.Action.DROP_STATS, flags)
        assert out == dict(do_stats=False, do_light=False, do_heavy=False)
        same = straggler.apply_to_flags(straggler.Action.NONE, flags)
        assert same == flags


class TestCompression:
    def test_lossless_for_lowrank(self):
        k = jax.random.PRNGKey(0)
        G = (jax.random.normal(k, (64, 4)) @
             jax.random.normal(jax.random.PRNGKey(1), (4, 32)))
        err = jnp.zeros_like(G)
        cfg = compress.CompressConfig(rank=4)
        P, Q, new_err = compress.compress(G, err, None, cfg)
        got = compress.decompress(P, Q, G.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(G),
                                   atol=1e-3)
        assert float(jnp.linalg.norm(new_err)) < 1e-3

    def test_error_feedback_preserves_signal(self):
        """Sum of transmitted + residual == original each round."""
        k = jax.random.PRNGKey(2)
        G = jax.random.normal(k, (48, 48))
        cfg = compress.CompressConfig(rank=4)
        P, Q, err = compress.compress(G, jnp.zeros_like(G), None, cfg)
        approx = compress.decompress(P, Q, G.shape)
        np.testing.assert_allclose(np.asarray(approx + err), np.asarray(G),
                                   atol=1e-4)

    @pytest.mark.slow
    def test_sgd_with_compression_converges(self):
        """Least squares with rank-2 EF compression still converges."""
        key = jax.random.PRNGKey(3)
        X = jax.random.normal(key, (128, 16))
        Wt = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
        Y = X @ Wt
        W = jnp.zeros((16, 8))
        cfg = compress.CompressConfig(rank=2, min_size=1)
        cstate = compress.init_state({"w": W}, cfg)
        for _ in range(300):
            G = X.T @ (X @ W - Y) / 128
            approx, cstate = compress.compress_tree({"w": G}, cstate, cfg)
            W = W - 0.05 * approx["w"]
        final = float(jnp.linalg.norm(X @ W - Y) / jnp.linalg.norm(Y))
        # warm-started power iteration locks a rank-2 subspace on this
        # rank-8 toy, so EF carries the tail — converges to ~0.063 vs
        # ~0.027 for cold restarts (see tests/test_mesh2d.py for the
        # per-round-error comparison showing the warm basis is tighter)
        assert final < 0.1, final

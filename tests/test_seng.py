"""SENG baseline: Woodbury identity correctness + training integration."""
import numpy as np
import jax
import pytest
import jax.numpy as jnp

from repro.optim import seng as seng_lib
from repro.optim import base as optbase
from repro.train import loop
from tests.test_kfac_optimizer import (make_mlp_taps, init_mlp, mlp_loss,
                                       make_batches, N_BS, N_STAT)


def test_woodbury_matches_dense():
    """_precondition == dense (λI + (1/n)VVᵀ)⁻¹ vec(J) on a tiny layer."""
    d_in, d_out, n, lam = 6, 5, 4, 0.7
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    A = jax.random.normal(k1, (d_in, n))
    G = jax.random.normal(k2, (d_out, n))
    J = jax.random.normal(k3, (d_in, d_out))
    got = seng_lib._precondition(A, G, J, jnp.asarray(lam))
    # dense reference
    V = np.stack([np.outer(A[:, i], G[:, i]).reshape(-1)
                  for i in range(n)], axis=1)           # (P, n)
    P = d_in * d_out
    F = lam * np.eye(P) + (V @ V.T) / n
    want = np.linalg.solve(F, np.asarray(J).reshape(-1)).reshape(d_in, d_out)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_seng_trains():
    cfg = seng_lib.SengConfig(lr=optbase.constant(0.05), damping=2.0,
                              momentum=0.9, weight_decay=1e-4, T_fim=5,
                              fallback_lr=optbase.constant(1e-2))
    opt = seng_lib.Seng(cfg, make_mlp_taps())
    params = init_mlp(jax.random.PRNGKey(4))
    state = loop.TrainState(params=params, opt=opt.init(params),
                            rng=jax.random.PRNGKey(0))

    def step(state, batch, do_fim):
        from repro.models import layers
        probes = layers.make_probes(opt.taps)
        loss, acts, gp, gprobe = loop.kfac_grads(mlp_loss, state.params,
                                                 probes, batch)
        updates, opt_state = opt.update(gp, state.opt, state.params,
                                        acts=acts, probe_grads=gprobe,
                                        n_tokens=N_BS, do_fim=do_fim)
        params = optbase.apply_updates(state.params, updates)
        return loop.TrainState(params, opt_state, state.rng), loss

    jstep = jax.jit(step, static_argnames=("do_fim",))
    losses = []
    for k, b in enumerate(make_batches(40, seed=5)):
        state, l = jstep(state, b, **cfg.flags(k))
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert losses[-1] < 0.5 * losses[0], losses[::8]

"""Work scheduler (core/schedule.py): exact cadences of the legacy flags
over every variant, staggered-mask invariants (per-unit cadence, full
coverage, spike reduction, Brand-phase snapping, alignment), and the
per-tap/bucketed mask equivalence contract.
"""
import math

import pytest

from repro.core import kfac as kfac_lib
from repro.core import kfactor, policy, schedule


#: variants kept in the fast tier for the expensive end-to-end parity
#: tests below; the rest run under -m slow AND unfiltered in the
#: distributed-parity CI job (which runs this whole file), so per-PR
#: coverage is unchanged — only the local/CI fast tier shrinks.
_FAST_VARIANTS = {"bkfac"}


def _marked_variants():
    return [v if v in _FAST_VARIANTS
            else pytest.param(v, marks=pytest.mark.slow)
            for v in policy.VARIANTS]


def _cfg(variant, **kw):
    kwargs = dict(policy=policy.PolicyConfig(variant=variant, r=8,
                                             max_dense_dim=8192),
                  T_updt=3, T_inv=12, T_brand=3, T_rsvd=24, T_corct=30)
    kwargs.update(kw)
    return kfac_lib.KfacConfig(**kwargs)


def _mixed_taps(N=16):
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 48, 32, n_stat=N),
        "fc2":  kfac_lib.TapInfo("fc2/w", 48, 32, n_stat=N),
        "scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(3,), n_stat=N),
        "moe":  kfac_lib.TapInfo("moe/w", 48, 32, stack=(2, 2), n_stat=N),
    }


# ---------------------------------------------------------------------------
# legacy flags: table-driven exact cadence, all 5 variants × 1000 steps
# ---------------------------------------------------------------------------

#: variant → (has light work, heavy period attr).  Declared independently
#: of core/policy.py so a regression in EITHER table (e.g. T_corct
#: shadowed by T_rsvd through branch ordering) fails here.
_EXPECTED = {
    "kfac":   (False, "T_inv"),
    "rkfac":  (False, "T_inv"),
    "bkfac":  (True, None),
    "brkfac": (True, "T_rsvd"),
    "bkfacc": (True, "T_corct"),
    "nskfac": (False, "T_inv"),
}


@pytest.mark.parametrize("variant", list(policy.VARIANTS))
def test_flags_exact_cadence_1000_steps(variant):
    cfg = _cfg(variant)
    has_light, heavy_attr = _EXPECTED[variant]
    T_heavy = None if heavy_attr is None else getattr(cfg, heavy_attr)
    for k in range(1000):
        flags = schedule.legacy_flags(cfg, k)
        assert flags["do_stats"] == (k % cfg.T_updt == 0), (variant, k)
        assert flags["do_light"] == (has_light and k % cfg.T_brand == 0), \
            (variant, k)
        want_heavy = T_heavy is not None and k % T_heavy == 0
        assert flags["do_heavy"] == want_heavy, (variant, k)


def test_variant_table_complete():
    assert set(_EXPECTED) == set(policy.VARIANTS)
    for v in policy.VARIANTS:
        assert policy.has_light(v) == _EXPECTED[v][0]
        assert policy.heavy_period_field(v) == _EXPECTED[v][1]
    with pytest.raises(ValueError):
        policy.heavy_period_field("notavariant")


def test_corct_and_rsvd_cannot_shadow():
    """brkfac must key on T_rsvd and bkfacc on T_corct even when the two
    periods disagree — the historical branch-ordering hazard."""
    cfg_b = _cfg("brkfac", T_rsvd=7, T_corct=11)
    cfg_c = _cfg("bkfacc", T_rsvd=7, T_corct=11)
    for k in range(1000):
        assert schedule.legacy_flags(cfg_b, k)["do_heavy"] == (k % 7 == 0)
        assert schedule.legacy_flags(cfg_c, k)["do_heavy"] == (k % 11 == 0)


# ---------------------------------------------------------------------------
# scheduler: un-staggered == legacy; staggered invariants
# ---------------------------------------------------------------------------

def _opt(variant, **kw):
    return kfac_lib.Kfac(_cfg(variant, **kw), _mixed_taps())


@pytest.mark.parametrize("variant", list(policy.VARIANTS))
def test_unstaggered_work_equals_legacy_flags(variant):
    opt = _opt(variant)
    sched = opt.scheduler()
    for k in range(2 * sched.cycle):
        flags = schedule.legacy_flags(opt.cfg, k)
        assert sched.work(k) == opt.uniform_work(**flags), (variant, k)


def _heavy_buckets(opt):
    return [(bi, b) for bi, b in enumerate(opt.factor_buckets)
            if kfactor.has_heavy_op(b.spec)]


@pytest.mark.parametrize("variant", [
    "kfac", "brkfac", pytest.param("bkfacc", marks=pytest.mark.slow)])
def test_staggered_unit_cadence_and_coverage(variant):
    opt = _opt(variant, stagger=True, stagger_splits=4)
    sched = opt.scheduler()
    T = sched.T_heavy
    assert T is not None and sched.units
    # units tile each heavy bucket exactly (full coverage, no overlap)
    for bi, b in _heavy_buckets(opt):
        ranges = sorted((u.lo, u.hi) for u in sched.units
                        if u.bucket == bi)
        assert ranges[0][0] == 0 and ranges[-1][1] == b.total
        for (l0, h0), (l1, h1) in zip(ranges, ranges[1:]):
            assert l1 == h0
    # per-unit cadence: fires exactly at {0 (warmup)} ∪ {phase + iT}
    fired = {u: [] for u in sched.units}
    for k in range(3 * T):
        w = sched.work(k)
        for u in sched.units:
            if any(lo <= u.lo and u.hi <= hi for lo, hi in w.heavy[u.bucket]):
                fired[u].append(k)
    for u, steps in fired.items():
        want = sorted({0} | {u.phase + i * T for i in range(3)
                             if u.phase + i * T < 3 * T})
        assert steps == want, (u, steps, want)


def test_staggering_reduces_peak_preserves_mean():
    opt = _opt("kfac", stagger=True, stagger_splits=4)
    spiky = opt.scheduler(stagger=False)
    stag = opt.scheduler(stagger=True)
    T = stag.T_heavy

    def slots(work):
        return sum(hi - lo for r in work.heavy for lo, hi in r)

    # equal mean cadence over a full cycle (ignore the step-0 warmup)
    lo, hi = T, 3 * T
    tot_spiky = sum(slots(spiky.work(k)) for k in range(lo, hi))
    tot_stag = sum(slots(stag.work(k)) for k in range(lo, hi))
    assert tot_spiky == tot_stag
    # strictly lower peak: the spike is spread across ≥2 phases
    peak_spiky = max(slots(spiky.work(k)) for k in range(lo, hi))
    peak_stag = max(slots(stag.work(k)) for k in range(lo, hi))
    assert len({u.phase for u in stag.units}) > 1
    assert peak_stag < peak_spiky


def test_brand_family_phases_snap_to_light_period():
    """Heavy firings of Brand-family buckets must land on light steps,
    otherwise staggering would add extra Brand absorbs (cadence break)."""
    opt = _opt("bkfacc", stagger=True, stagger_splits=8,
               T_brand=3, T_corct=30)
    sched = opt.scheduler()
    brand = kfactor._HAS_BRAND
    for u in sched.units:
        if opt.factor_buckets[u.bucket].spec.mode in brand:
            assert u.phase % opt.cfg.T_brand == 0, u
    # every actual firing lands on a light step (T_brand | T_corct here)
    for k in range(2 * sched.cycle):
        w = sched.work(k)
        for bi, b in enumerate(opt.factor_buckets):
            if b.spec.mode in brand and w.heavy[bi]:
                assert w.light, (k, bi)


def test_brand_phase_pinned_when_light_period_does_not_divide():
    """T_brand ∤ T_heavy: no phase keeps every firing on a light step
    (true at phase 0 too), so Brand-family buckets must pin to phase 0 —
    staggered then fires exactly the legacy absorbs, never extra ones."""
    opt = _opt("brkfac", stagger=True, stagger_splits=8,
               T_brand=3, T_rsvd=10)
    stag, spiky = opt.scheduler(stagger=True), opt.scheduler(stagger=False)
    brand = kfactor._HAS_BRAND
    assert any(opt.factor_buckets[u.bucket].spec.mode in brand
               for u in stag.units)
    for u in stag.units:
        if opt.factor_buckets[u.bucket].spec.mode in brand:
            assert u.phase == 0, u
    for k in range(2 * stag.cycle):
        ws, wu = stag.work(k), spiky.work(k)
        for bi, b in enumerate(opt.factor_buckets):
            if b.spec.mode in brand:
                assert ws.heavy[bi] == wu.heavy[bi], (k, bi)


def test_alignment_contract():
    opt = _opt("kfac", stagger=True, stagger_splits=4)
    sched = opt.scheduler(align=4)
    for u in sched.units:
        total = opt.factor_buckets[u.bucket].total
        assert u.lo % 4 == 0
        assert u.hi % 4 == 0 or u.hi == total, u


def test_entry_heavy_all_or_nothing():
    """Chunks are entry-aligned, so a tap's slots never split across
    firing and non-firing ranges — the per-tap path's heavy bool is
    exact, not an approximation."""
    opt = _opt("kfac", stagger=True, stagger_splits=6)
    sched = opt.scheduler()
    for k in range(2 * sched.cycle):
        w = sched.work(k)
        for bi, b in enumerate(opt.factor_buckets):
            for e in b.entries:
                inside = [max(lo, e.offset) < min(hi, e.offset + e.count)
                          for lo, hi in w.heavy[bi]]
                covered = sum(min(hi, e.offset + e.count) - max(lo, e.offset)
                              for (lo, hi), hit in zip(w.heavy[bi], inside)
                              if hit)
                assert covered in (0, e.count), (k, bi, e)
                assert w.entry_heavy(bi, e.offset, e.count) == \
                    (covered == e.count)


def test_stepwork_static_and_hashable():
    opt = _opt("kfac", stagger=True)
    sched = opt.scheduler()
    works = {sched.work(k) for k in range(3 * sched.cycle)}
    # bounded distinct masks: at most one per phase slot + stats/light
    # combinations — the jit-compile count stays small
    assert 1 < len(works) <= len(sched.units) + 4
    assert schedule.no_work(opt.factor_buckets).any is False


def test_cycle_lcm():
    opt = _opt("bkfacc", T_updt=4, T_brand=6, T_corct=30)
    assert opt.scheduler().cycle == math.lcm(4, 6, 30)


# ---------------------------------------------------------------------------
# async launch/land pipeline
# ---------------------------------------------------------------------------

def test_async_launch_land_cadence():
    """Each async unit launches at its regular firing steps (warmup stays
    inline at 0) and lands exactly ``lag`` steps later; lag=0 launches
    and lands on the same step."""
    for lag in (0, 3):
        opt = _opt("kfac", stagger=True, stagger_splits=4,
                   async_heavy=True, heavy_lag=lag)
        sched = opt.scheduler()
        T = sched.T_heavy
        for u in sched.units:
            assert not u.sync_only
        for k in range(3 * sched.cycle):
            w = sched.work(k)
            for u in sched.units:
                fires = k % T == u.phase
                in_launch = any(lo <= u.lo and u.hi <= hi
                                for lo, hi in w.launch[u.bucket])
                in_land = any(lo <= u.lo and u.hi <= hi
                              for lo, hi in w.land[u.bucket])
                in_heavy = any(lo <= u.lo and u.hi <= hi
                               for lo, hi in w.heavy[u.bucket])
                assert in_heavy == (k == 0), (lag, k, u)     # warmup only
                assert in_launch == (fires and k > 0), (lag, k, u)
                assert in_land == (k - lag > 0
                                   and (k - lag) % T == u.phase), \
                    (lag, k, u)


def test_async_lag_bounds_validated():
    with pytest.raises(ValueError, match="heavy_lag"):
        _opt("kfac", async_heavy=True, heavy_lag=12).scheduler()   # = T_inv
    with pytest.raises(ValueError, match="heavy_lag"):
        _opt("kfac", async_heavy=True, heavy_lag=-1).scheduler()


def test_async_unstaggered_lag0_masks_equal_sync_after_warmup():
    """lag=0 async emits launch==land at exactly the sync heavy steps —
    the masks carry the same ranges, just in the pipeline fields."""
    opt_a = _opt("kfac", async_heavy=True, heavy_lag=0)
    opt_s = _opt("kfac")
    sa, ss = opt_a.scheduler(), opt_s.scheduler()
    for k in range(1, 2 * sa.cycle):
        wa, ws = sa.work(k), ss.work(k)
        assert wa.launch == wa.land == ws.heavy, k
        assert not wa.any_heavy, k
    assert sa.work(0).heavy == ss.work(0).heavy      # inline warmup


def test_async_brand_bucket_pins_sync_when_period_not_divisible():
    """T_brand ∤ T_heavy: the interim-panel count would vary per firing,
    so Brand-family buckets must stay synchronous (inline heavy), while
    divisible configs pipeline with a static replay count."""
    opt = _opt("brkfac", stagger=True, T_brand=3, T_rsvd=10,
               async_heavy=True, heavy_lag=2)
    sched = opt.scheduler()
    brand = kfactor._HAS_BRAND
    assert sched.units
    for u in sched.units:
        assert opt.factor_buckets[u.bucket].spec.mode in brand
        assert u.sync_only, u
    # a non-Brand (RSVD) factor under the same config would still
    # pipeline: the pinning is the Brand coupling, not a global off
    narrow = policy.make_factor_spec(opt.cfg.policy, d=20, n_stat=16)
    assert narrow.mode is kfactor.Mode.RSVD
    assert schedule.bucket_is_async(opt.cfg, narrow)
    # sync_only units keep the legacy inline cadence exactly
    legacy = opt.scheduler(async_heavy=False)
    for k in range(2 * sched.cycle):
        wa, wl = sched.work(k), legacy.work(k)
        for bi, b in enumerate(opt.factor_buckets):
            if b.spec.mode in brand:
                assert wa.heavy[bi] == wl.heavy[bi], (k, bi)
                assert wa.launch[bi] == () and wa.land[bi] == (), (k, bi)


def test_async_replay_count_static_rule():
    cfg24 = _cfg("brkfac", T_brand=3, T_rsvd=24, async_heavy=True,
                 heavy_lag=7)
    cfg_nd = _cfg("brkfac", T_brand=3, T_rsvd=10, async_heavy=True,
                  heavy_lag=7)
    opt = _opt("brkfac", T_brand=3, T_rsvd=24)
    for b in opt.factor_buckets:
        if b.spec.mode in kfactor._HAS_BRAND:
            assert schedule.bucket_is_async(cfg24, b.spec)
            assert schedule.n_replay_panels(cfg24, b.spec) == 7 // 3
            assert not schedule.bucket_is_async(cfg_nd, b.spec)
            assert schedule.n_replay_panels(cfg_nd, b.spec) == 0
        elif kfactor.has_heavy_op(b.spec):
            assert schedule.bucket_is_async(cfg24, b.spec)
            assert schedule.n_replay_panels(cfg24, b.spec) == 0


def test_async_brand_landings_replay_exact_window():
    """Launches of async Brand-family units sit on light steps (snapped),
    so the light steps strictly inside every (launch, land] window number
    exactly lag // T_brand — the static ring size."""
    opt = _opt("bkfacc", stagger=True, stagger_splits=4, T_brand=3,
               T_corct=30, async_heavy=True, heavy_lag=7)
    sched = opt.scheduler()
    T, lag = sched.T_heavy, sched.lag
    brand = kfactor._HAS_BRAND
    for u in sched.units:
        if opt.factor_buckets[u.bucket].spec.mode not in brand:
            continue
        assert u.phase % opt.cfg.T_brand == 0, u
        for i in range(1, 4):
            kl = u.phase + i * T
            interim = [k for k in range(kl + 1, kl + lag + 1)
                       if k % opt.cfg.T_brand == 0]
            assert len(interim) == lag // opt.cfg.T_brand, (u, kl)


def test_straggler_backoff_clears_async_masks():
    from repro.train import straggler
    opt = _opt("kfac", stagger=True, async_heavy=True, heavy_lag=2)
    sched = opt.scheduler()
    w = next(sched.work(k) for k in range(1, 3 * sched.cycle)
             if sched.work(k).any_async)
    out = straggler.apply_to_work(straggler.Action.DROP_STATS, w)
    assert not out.any
    assert out.launch == tuple(() for _ in opt.factor_buckets)
    assert out.land == tuple(() for _ in opt.factor_buckets)


@pytest.mark.parametrize("variant", _marked_variants())
def test_async_lag0_update_equals_sync_all_variants(variant):
    """The exactness contract, replicated: lag=0 async ≡ sync through
    Kfac.update on the mixed FC+scanned+MoE model, step by step, with
    step-varying stats (a drifting M is what makes any scheduling bug
    visible — constant operands make all heavy overwrites identical)."""
    import jax
    import numpy as np

    from repro.optim import base as optbase

    from synthdata import tap_data

    taps = _mixed_taps()

    def data(key):
        return tap_data(taps, key)

    def run(async_heavy):
        cfg = _cfg(variant, T_updt=1, T_brand=1, T_inv=3, T_rsvd=3,
                   T_corct=3, lr=optbase.constant(0.05), stagger=True,
                   stagger_splits=2, async_heavy=async_heavy, heavy_lag=0)
        opt = kfac_lib.Kfac(cfg, taps)
        sched = opt.scheduler()
        params = data(jax.random.PRNGKey(0))[0]
        st = opt.init(params)

        def step(grads, st, acts, pgs, rng, work):
            return opt.update(grads, st, params, acts=acts,
                              probe_grads=pgs, n_tokens=16, rng=rng,
                              work=work)
        step = jax.jit(step, static_argnames=("work",))
        outs = []
        for s in range(5):
            _, grads, acts, pgs = data(jax.random.PRNGKey(100 + s))
            upd, st = step(grads, st, acts, pgs,
                           jax.random.fold_in(jax.random.PRNGKey(7), s),
                           sched.work(s))
            outs.append(upd)
        return outs

    a, b = run(True), run(False)
    for k, (ua, ub) in enumerate(zip(a, b)):
        for n in taps:
            np.testing.assert_allclose(np.asarray(ua[n]["w"]),
                                       np.asarray(ub[n]["w"]),
                                       atol=1e-6, rtol=1e-5,
                                       err_msg=f"{variant} step {k} {n}")


def test_resume_from_state_phase_continues_cadence():
    """run_kfac_training(state=restored) must continue the staggered
    schedule from state.opt.phase instead of re-spiking at work(0) —
    the split run's update sequence equals the unbroken run's."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import layers
    from repro.optim import base as optbase
    from repro.train import loop

    taps = {"fc": kfac_lib.TapInfo("fc/w", 24, 8, n_stat=8)}
    cfg = kfac_lib.KfacConfig(
        policy=policy.PolicyConfig(variant="kfac", r=4),
        lr=optbase.constant(0.05), T_updt=1, T_inv=4, stagger=True)
    key = jax.random.PRNGKey(0)
    params = {"fc": {"w": jax.random.normal(key, (24, 8)) * 0.1}}

    def loss_fn(p, probes, batch):
        x, y = batch
        h, act = layers.tapped_matmul(p["fc"]["w"], x, probes.get("fc"), 8)
        return jnp.mean((h - y) ** 2), {"fc": act}

    batches = [(jax.random.normal(jax.random.fold_in(key, i), (8, 24)),
                jax.random.normal(jax.random.fold_in(key, 50 + i), (8, 8)))
               for i in range(6)]

    opt_a = kfac_lib.Kfac(cfg, taps)
    _, full = loop.run_kfac_training(loss_fn, opt_a, params, batches,
                                     n_tokens=8, jit=False)
    opt_b = kfac_lib.Kfac(cfg, taps)
    mid, head = loop.run_kfac_training(loss_fn, opt_b, params, batches[:3],
                                       n_tokens=8, jit=False)
    assert int(jax.device_get(mid.opt.phase)) == 3
    _, tail = loop.run_kfac_training(loss_fn, opt_b, None, batches[3:],
                                     n_tokens=8, jit=False, state=mid)
    np.testing.assert_allclose(head + tail, full, rtol=1e-6)

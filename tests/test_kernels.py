"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests on ops dispatch.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref, ops
from repro.kernels.ea_syrk import ea_syrk_pallas
from repro.kernels.brand_panel import brand_panel_pallas
from repro.kernels.lowrank_apply import lowrank_apply_pallas


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("d,n,dtype,first", [
    (256, 128, jnp.float32, False),
    (384, 128, jnp.bfloat16, True),
    pytest.param(256, 128, jnp.float32, True, marks=pytest.mark.slow),
    pytest.param(256, 128, jnp.bfloat16, False, marks=pytest.mark.slow),
    pytest.param(384, 128, jnp.float32, False, marks=pytest.mark.slow),
    pytest.param(384, 128, jnp.bfloat16, False, marks=pytest.mark.slow),
    pytest.param(512, 256, jnp.float32, False, marks=pytest.mark.slow),
    pytest.param(512, 256, jnp.bfloat16, True, marks=pytest.mark.slow),
])
def test_ea_syrk_vs_ref(d, n, dtype, first):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + n))
    M = jax.random.normal(k1, (d, d), dtype=jnp.float32)
    M = ((M + M.T) / 2).astype(dtype)
    X = jax.random.normal(k2, (d, n), dtype=dtype)
    rho = 0.95
    keep = jnp.asarray(0.0 if first else rho, jnp.float32)
    coef = 1.0 - keep
    got = ea_syrk_pallas(M, X, keep, coef, bm=128, bn=128, bk=128,
                         interpret=True)
    want = ref.ea_syrk(M, X, rho, first)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("d,r,n,dtype", [
    (256, 64, 128, jnp.float32),
    (256, 8, 128, jnp.bfloat16),
    pytest.param(256, 64, 128, jnp.bfloat16, marks=pytest.mark.slow),
    pytest.param(256, 8, 128, jnp.float32, marks=pytest.mark.slow),
    pytest.param(512, 64, 128, jnp.float32, marks=pytest.mark.slow),
    pytest.param(1024, 256, 128, jnp.float32, marks=pytest.mark.slow),
    pytest.param(1024, 256, 128, jnp.bfloat16, marks=pytest.mark.slow),
])
def test_brand_panel_vs_ref(d, r, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + r + n))
    U, _ = jnp.linalg.qr(jax.random.normal(k1, (d, r)))
    U = U.astype(dtype)
    A = jax.random.normal(k2, (d, n), dtype=dtype)
    C_got, P_got = brand_panel_pallas(U, A, bk=256, interpret=True)
    C_want, P_want = ref.brand_panel(U, A)
    np.testing.assert_allclose(np.asarray(C_got, np.float32),
                               np.asarray(C_want, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(P_got, np.float32),
                               np.asarray(P_want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("p,d,w,dtype", [
    (256, 256, 64, jnp.float32),
    (384, 256, 8, jnp.bfloat16),
    pytest.param(256, 256, 64, jnp.bfloat16, marks=pytest.mark.slow),
    pytest.param(384, 256, 8, jnp.float32, marks=pytest.mark.slow),
    pytest.param(256, 512, 64, jnp.float32, marks=pytest.mark.slow),
    pytest.param(128, 1024, 256, jnp.float32, marks=pytest.mark.slow),
    pytest.param(128, 1024, 256, jnp.bfloat16, marks=pytest.mark.slow),
])
def test_lowrank_apply_vs_ref(p, d, w, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(p + d + w), 3)
    X = jax.random.normal(k1, (p, d), dtype=dtype)
    U, _ = jnp.linalg.qr(jax.random.normal(k2, (d, w)))
    U = U.astype(dtype)
    s = -jax.random.uniform(k3, (w,), minval=0.1, maxval=1.0).astype(dtype)
    lam = jnp.asarray(0.7, dtype)
    got = lowrank_apply_pallas(X, U, s, lam, bm=128, bn=128, bk=128,
                               interpret=True)
    want = ref.lowrank_apply(X, U, s, lam)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


class TestOpsDispatch:
    """ops.* must be semantically identical to ref.* on any backend/shape."""

    def test_ea_syrk_unaligned_falls_back(self):
        M = jnp.eye(100)
        X = jnp.ones((100, 7))
        got = ops.ea_syrk(M, X, 0.9, False)
        want = ref.ea_syrk(M, X, 0.9, False)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_lowrank_apply_matches_precond_path(self):
        from repro.core import precond
        J = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
        U, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (64, 8)))
        D = jnp.linspace(2.0, 0.1, 8)
        lam = jnp.asarray(0.5)
        got = ops.lowrank_apply(J, U, precond.lowrank_inv_diag(D, lam), lam)
        want = precond.apply_inv_right(J, U, D, lam)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_interpret_mode_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS", "interpret")
        M = jnp.zeros((128, 128))
        X = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
        got = ops.ea_syrk(M, X, 0.9, True)
        want = ref.ea_syrk(M, X, 0.9, True)
        np.testing.assert_allclose(got, want, atol=1e-4)

"""Distributed curvature engine (distributed/curvature.py): round-robin
shard-plan bookkeeping, and sharded ≡ replicated ``Kfac.update`` parity on
an 8-host-device mesh over a mixed FC + scanned + MoE model — with and
without the staggered heavy-work scheduler.
"""
import os

import numpy as np
import pytest

# must precede backend init in THIS process; harmless if jax was already
# initialized with one device (the mesh tests then skip)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import buckets, kfac as kfac_lib, policy
from synthdata import tap_data
from repro.distributed import curvature as curv
from repro.launch import mesh as mesh_lib
from repro.optim import base as optbase

N_STAT = 16


#: fast-tier variant subset for the expensive 8-device parity tests; the
#: slow-marked rest still run per-PR in the distributed-parity CI job,
#: which runs this file with no marker filter.
_FAST_VARIANTS = {"bkfac"}


def _marked_variants():
    return [v if v in _FAST_VARIANTS
            else pytest.param(v, marks=pytest.mark.slow)
            for v in policy.VARIANTS]



def _mixed_taps():
    """FC pair + scanned stack + two-level MoE stack — three shape-class
    factor buckets, stacked entries included."""
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 48, 32, n_stat=N_STAT),
        "fc2":  kfac_lib.TapInfo("fc2/w", 48, 32, n_stat=N_STAT),
        "scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(3,),
                                 n_stat=N_STAT),
        "moe":  kfac_lib.TapInfo("moe/w", 48, 32, stack=(2, 2),
                                 n_stat=N_STAT),
    }


def _data(taps):
    return tap_data(taps)


# ---------------------------------------------------------------------------
# shard-plan bookkeeping (no devices needed)
# ---------------------------------------------------------------------------

class TestShardPlan:
    @pytest.mark.parametrize("total,n", [(1, 8), (7, 8), (8, 8), (17, 8),
                                         (12, 4), (5, 2)])
    def test_perm_roundtrip(self, total, n):
        plan = curv.ShardPlan.build(total, n)
        assert plan.padded % n == 0 and plan.padded >= total
        assert plan.per_device == plan.padded // n
        x = jnp.arange(total * 3.0).reshape(total, 3)
        out = plan.unshard(plan.shard(x))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    def test_round_robin_assignment(self):
        # slot s must land on device s % n (KAISA-style round-robin)
        total, n = 11, 4
        plan = curv.ShardPlan.build(total, n)
        m = plan.per_device
        for pos, slot in enumerate(plan.perm):
            dev = pos // m
            if pos % m + 1 <= (total - dev + n - 1) // n:  # non-pad rows
                assert slot % n == dev
        for s in range(total):
            assert plan.perm[plan.unperm[s]] == s
            assert buckets.slot_device(s, n) == s % n

    def test_localize_ranges(self):
        assert buckets.localize_ranges(((0, 8),), 8, 4) == ((0, 2),)
        # tail range may end at the (unpadded) bucket end
        assert buckets.localize_ranges(((4, 11),), 11, 4) == ((1, 3),)
        with pytest.raises(ValueError):
            buckets.localize_ranges(((2, 8),), 11, 4)

    def test_job_counts(self):
        taps = _mixed_taps()
        opt = kfac_lib.Kfac(kfac_lib.KfacConfig(
            policy=policy.PolicyConfig(variant="bkfac", r=8)), taps)
        # engine metadata needs no devices — only mesh axis sizes
        if len(jax.devices()) < 8:
            pytest.skip("needs 8 host devices")
        mesh = mesh_lib.make_mesh((8,), ("curv",))
        eng = curv.CurvatureEngine(mesh, "curv", opt.factor_buckets)
        rep, dev = eng.job_counts()
        assert rep == sum(b.total for b in opt.factor_buckets)
        assert dev == sum(-(-b.total // 8) for b in opt.factor_buckets)
        assert dev <= rep // 8 + len(opt.factor_buckets)


# ---------------------------------------------------------------------------
# sharded ≡ replicated parity (8-device host mesh)
# ---------------------------------------------------------------------------

def _run(taps, variant, *, sharded, stagger=False, steps=4):
    pol = policy.PolicyConfig(variant=variant, r=8, max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              momentum=0.9, T_updt=1, T_brand=1, T_inv=3,
                              T_rsvd=3, T_corct=3, stagger=stagger,
                              stagger_splits=4)
    opt = kfac_lib.Kfac(cfg, taps)
    if sharded:
        mesh = mesh_lib.make_mesh((8,), ("curv",))
        curv.CurvatureEngine.for_kfac(opt, mesh, "curv")
    # identical masks on both sides: align to the mesh either way (an
    # engine-attached scheduler would pick align=8 automatically)
    sched = opt.scheduler(align=8)
    params, grads, acts, pgs = _data(taps)
    st = opt.init(params)

    def step(grads, st, rng, work):
        return opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                          n_tokens=N_STAT, rng=rng, work=work)
    step = jax.jit(step, static_argnames=("work",))

    outs = []
    for s in range(steps):
        upd, st = step(grads, st,
                       jax.random.fold_in(jax.random.PRNGKey(7), s),
                       sched.work(s))
        outs.append(upd)
    return outs, st


def _assert_close(a, b, taps, atol):
    for n in taps:
        x, y = np.asarray(a[n]["w"]), np.asarray(b[n]["w"])
        assert np.isfinite(x).all() and np.isfinite(y).all()
        np.testing.assert_allclose(x, y, atol=atol, rtol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["bkfac", "kfac", "bkfacc"])
def test_sharded_matches_replicated(variant):
    """Sharded ≡ replicated Kfac.update on the mixed model.  bkfac
    exercises the Brand light path, kfac the dense-EVD heavy path, and
    bkfacc the randomized correction — per-slot keys are preserved by
    the shard permutation, so even randomized modes match exactly."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    taps = _mixed_taps()
    a, _ = _run(taps, variant, sharded=True)
    b, _ = _run(taps, variant, sharded=False)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["kfac", "bkfacc"])
def test_sharded_staggered_matches_replicated_staggered(variant):
    """The sharding transformation commutes with the staggered work
    masks (scheduler aligned to the curvature mesh)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    taps = _mixed_taps()
    a, sta = _run(taps, variant, sharded=True, stagger=True)
    b, stb = _run(taps, variant, sharded=False, stagger=True)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)
    # factor-state parity up to the eigenbasis: compare M and the
    # represented matrix U diag(D) Uᵀ — raw U columns of a *degenerate*
    # eigenpair may rotate under fp-level input perturbations (the
    # preconditioner is invariant to exactly that rotation)
    for name in taps:
        for fa, fb in ((sta.factors[name].A, stb.factors[name].A),
                       (sta.factors[name].G, stb.factors[name].G)):
            np.testing.assert_allclose(np.asarray(fa.M), np.asarray(fb.M),
                                       atol=1e-5, rtol=1e-4)
            ra = np.asarray(fa.U * fa.D[..., None, :]) @ \
                np.swapaxes(np.asarray(fa.U), -1, -2)
            rb = np.asarray(fb.U * fb.D[..., None, :]) @ \
                np.swapaxes(np.asarray(fb.U), -1, -2)
            np.testing.assert_allclose(ra, rb, atol=1e-5)


# ---------------------------------------------------------------------------
# async launch/land pipeline, sharded ≡ replicated
# ---------------------------------------------------------------------------

def _run_async(taps, variant, *, sharded, lag, steps=5):
    """Like _run but under the async pipeline with *step-varying* stats
    operands — a drifting M is what makes staleness (and any sharding
    bug in the launch/land plumbing) observable."""
    pol = policy.PolicyConfig(variant=variant, r=8, max_dense_dim=8192)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              T_updt=1, T_brand=1, T_inv=3, T_rsvd=3,
                              T_corct=3, stagger=True, stagger_splits=2,
                              async_heavy=True, heavy_lag=lag)
    opt = kfac_lib.Kfac(cfg, taps)
    if sharded:
        mesh = mesh_lib.make_mesh((8,), ("curv",))
        curv.CurvatureEngine.for_kfac(opt, mesh, "curv")
    sched = opt.scheduler(align=8)
    params = _data(taps)[0]
    st = opt.init(params)

    def step(grads, st, acts, pgs, rng, work):
        return opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                          n_tokens=N_STAT, rng=rng, work=work)
    step = jax.jit(step, static_argnames=("work",))
    outs = []
    for s in range(steps):
        _, grads, acts, pgs = tap_data(taps,
                                       jax.random.PRNGKey(200 + s))
        upd, st = step(grads, st, acts, pgs,
                       jax.random.fold_in(jax.random.PRNGKey(7), s),
                       sched.work(s))
        outs.append(upd)
    return outs, st



@pytest.mark.parametrize("variant", _marked_variants())
def test_async_lag0_sharded_matches_sync_replicated(variant):
    """The exactness contract in its strongest form: lag=0 async on the
    8-device sharded engine ≡ synchronous replicated, across all 5
    policy variants (per-slot keys survive both the shard permutation
    and the snapshot/land round-trip)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    taps = _mixed_taps()
    a, _ = _run_async(taps, variant, sharded=True, lag=0)
    b, _ = _run_async(taps, variant, sharded=False, lag=0)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["kfac", "bkfacc"])
def test_async_lag_sharded_matches_replicated(variant):
    """lag>0: the in-flight snapshot, panel ring, and landing swap all
    shard — per-device pipeline ≡ replicated pipeline (dense-EVD and
    randomized-correction-with-replay paths)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    taps = _mixed_taps()
    a, sta = _run_async(taps, variant, sharded=True, lag=2, steps=6)
    b, stb = _run_async(taps, variant, sharded=False, lag=2, steps=6)
    for ua, ub in zip(a, b):
        _assert_close(ua, ub, taps, atol=1e-5)
    # in-flight buffers themselves round-trip the shard permutation
    for bi in sta.inflight:
        np.testing.assert_allclose(np.asarray(sta.inflight[bi].M),
                                   np.asarray(stb.inflight[bi].M),
                                   atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(sta.inflight[bi].panels),
                                   np.asarray(stb.inflight[bi].panels),
                                   atol=1e-5, rtol=1e-4)


def test_sharded_under_mesh_context_with_shardings():
    """The engine's shard_map composes with an outer jit whose inputs
    carry NamedShardings (the production trainer path)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    taps = _mixed_taps()
    a, _ = _run(taps, "bkfac", sharded=True, steps=2)
    assert all(np.isfinite(np.asarray(u["fc"]["w"])).all() for u in a)

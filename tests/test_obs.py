"""Curvature telemetry subsystem (repro/obs/): event-log schema
round-trip, in-graph Meter semantics, and the load-bearing acceptance
claim — telemetry is numerically inert: metrics-on training must equal
metrics-off training bit-for-bit, replicated and 8-device sharded.
"""
import json
import os

import numpy as np
import pytest

# must precede backend init in THIS process; harmless if jax was already
# initialized with one device (the mesh tests then skip)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib, policy
from repro.launch import mesh as mesh_lib
from repro.models import layers
from repro.obs import events as ev_lib
from repro.obs import metrics as m_lib
from repro.obs import summary as sum_lib
from repro.optim import base as optbase
from repro.train import loop
from repro import specs

D_IN, D_H, D_OUT, N_BS, N_STAT = 12, 32, 4, 16, 16

#: fast-tier variant subset for the train-twice parity tests; the
#: telemetry-smoke / distributed CI jobs run this file unfiltered.
_FAST_VARIANTS = {"bkfac"}


def _marked_variants():
    return [v if v in _FAST_VARIANTS
            else pytest.param(v, marks=pytest.mark.slow)
            for v in policy.VARIANTS]


# ---------------------------------------------------------------------------
# event-log schema
# ---------------------------------------------------------------------------

_SAMPLE_EVENTS = {
    "run_start": dict(config={"arch": "t", "steps": 2}),
    "run_end": dict(steps=2, loss_first=1.0, loss_last=0.5,
                    s_per_step=0.01),
    "log": dict(msg="hello"),
    "step": dict(step=0, loss=1.25, dt_s=0.01, phase="heavy"),
    "metrics": dict(step=10, window_steps=10,
                    values={"work/stats_fired": 5.0},
                    kinds={"work/stats_fired": "counter"}),
    "sched": dict(detail="T_inv=5 buckets=2"),
    "async_launch": dict(step=3, bucket=0, lo=0, hi=8),
    "async_land": dict(step=5, bucket=0, lo=0, hi=8, overlapped=True),
    "async_miss": dict(step=5, bucket=1, lo=0, hi=8),
    "ckpt_save": dict(step=10, path="/tmp/x"),
    "ckpt_restore": dict(step=10, path="/tmp/x"),
    "repartition": dict(detail="8 -> 6 devices"),
    "remediation": dict(step=4, stage=1, action="escalate",
                        detail="damping scale 1 -> 8"),
    "serve_request": dict(uid=1, wait_s=0.0, total_s=0.2, n_new=32,
                          tenant=0, kind="infer"),
    "tenant_update": dict(tenant=0, step=3, loss=1.5, phase="light"),
}


def test_every_event_type_round_trips(tmp_path):
    """One of each type through the writer, read back validated — and the
    sample dict must cover the registry exactly, so adding a type without
    a test shows up here."""
    assert set(_SAMPLE_EVENTS) == set(ev_lib.EVENT_TYPES)
    path = tmp_path / "events.jsonl"
    with ev_lib.TelemetryWriter(str(path), console=False) as w:
        for etype, fields in _SAMPLE_EVENTS.items():
            w.emit(etype, **fields)
    evs = list(ev_lib.read_events(str(path)))
    assert [e["type"] for e in evs] == list(_SAMPLE_EVENTS)
    for e in evs:
        assert e["schema"] == ev_lib.SCHEMA_VERSION
        assert isinstance(e["t"], float)


def test_writer_rejects_malformed_events(tmp_path):
    w = ev_lib.TelemetryWriter(str(tmp_path / "e.jsonl"), console=False)
    with pytest.raises(ev_lib.EventSchemaError):
        w.emit("no_such_type", x=1)
    with pytest.raises(ev_lib.EventSchemaError):
        w.emit("step", step=0, loss=1.0)       # missing dt_s, phase
    w.close()
    # nothing reached the log
    assert list(ev_lib.read_events(str(tmp_path / "e.jsonl"))) == []


def test_reader_flags_corrupt_lines(tmp_path):
    path = tmp_path / "e.jsonl"
    path.write_text('{"schema": 1, "t": 0.0, "type": "log", "msg": "ok"}\n'
                    "not json\n")
    with pytest.raises(ev_lib.EventSchemaError, match="e.jsonl:2"):
        list(ev_lib.read_events(str(path)))
    # unknown type with validation off passes through
    path.write_text(json.dumps({"schema": 1, "t": 0.0, "type": "xx"}) +
                    "\n")
    assert len(list(ev_lib.read_events(str(path), validate=False))) == 1


def test_console_renders_familiar_lines():
    lines = []
    w = ev_lib.TelemetryWriter(console=True, console_fn=lines.append)
    w.log("resuming")
    w.emit("step", step=7, loss=2.5, dt_s=0.012, phase="light")
    w.emit("metrics", step=7, window_steps=5, values={}, kinds={})
    w.close()
    assert lines[0] == "[train] resuming"
    assert lines[1].startswith("[train] step     7")
    assert "light" in lines[1]
    assert len(lines) == 2            # metrics stay off the console


# ---------------------------------------------------------------------------
# Meter: in-graph accumulation, cadence, counter/gauge semantics
# ---------------------------------------------------------------------------

def _toy_meter(sink, every):
    catalog = (m_lib.MetricSpec("c", m_lib.COUNTER),
               m_lib.MetricSpec("g", m_lib.GAUGE))
    return m_lib.Meter(catalog, sink, every=every)


def test_meter_counter_gauge_flush_cadence():
    got = []
    meter = _toy_meter(lambda s, w, v: got.append((s, w, v)), every=3)

    def step(mbuf, k):
        with meter.collecting() as col:
            m_lib.record("c", 2.0)
            m_lib.record("c", 1.0)          # counters add within a step
            m_lib.record("g", jnp.float32(k))
        return meter.maybe_flush(meter.merge(mbuf, col), k)

    mbuf = meter.init()
    for k in range(7):
        mbuf = jax.block_until_ready(step(mbuf, jnp.int32(k)))
    # windows closed at steps 2 and 5 (3 merges each)
    assert [(s, w) for s, w, _ in got] == [(2, 3), (5, 3)]
    assert got[0][2]["c"] == 9.0            # 3 steps x (2+1)
    assert got[1][2]["c"] == 9.0            # counter reset between windows
    assert got[1][2]["g"] == 5.0            # gauge: last value wins
    meter.drain(mbuf, 6)                    # 1-step partial window
    assert got[-1][0] == 6 and got[-1][1] == 1 and got[-1][2]["c"] == 3.0


def test_record_is_noop_without_collector():
    calls = []
    m_lib.record("anything", lambda: calls.append(1) or 1.0)
    assert not calls                        # thunk never evaluated
    assert not m_lib.active()


def test_record_under_jit_with_collector():
    meter = _toy_meter(lambda *a: None, every=10)

    @jax.jit
    def f(x, mbuf):
        with meter.collecting() as col:
            m_lib.record("g", x * 2.0)
            m_lib.record("not_in_catalog", x)    # silently ignored
        return meter.merge(mbuf, col)

    out = f(jnp.float32(3.0), meter.init())
    assert float(out["g"]) == 6.0
    assert int(out["_steps"]) == 1


def test_catalog_for_all_variants_unique_and_typed():
    taps = {"fc": kfac_lib.TapInfo("fc/w", 24, 16, n_stat=N_STAT)}
    for variant in policy.VARIANTS:
        opt = kfac_lib.Kfac(_cfg(variant), taps)
        catalog = m_lib.catalog_for(opt)
        names = [s.name for s in catalog]
        assert len(names) == len(set(names)), variant
        assert all(s.kind in (m_lib.COUNTER, m_lib.GAUGE)
                   for s in catalog), variant
        if variant == "nskfac":
            assert any(n.endswith("/ns_res") for n in names)
        if variant in ("kfac", "rkfac", "brkfac"):
            assert any(n.endswith("/trunc_mass") for n in names)


# ---------------------------------------------------------------------------
# the acceptance claim: telemetry is numerically inert
# ---------------------------------------------------------------------------

def _make_mlp():
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    params = {
        "fc0": {"w": layers.dense_init(ks[0], D_IN, D_H)},
        "fc1": {"w": layers.dense_init(ks[1], D_H, D_OUT)},
    }
    taps = {
        "fc0": kfac_lib.TapInfo("fc0/w", D_IN, D_H, n_stat=N_STAT),
        "fc1": kfac_lib.TapInfo("fc1/w", D_H, D_OUT, n_stat=N_STAT),
    }
    return params, taps


def _mlp_loss(params, probes, batch):
    x, y = batch
    acts = {}
    h, acts["fc0"] = layers.tapped_matmul(params["fc0"]["w"], x,
                                          probes.get("fc0"), N_STAT)
    h = jax.nn.relu(h)
    h, acts["fc1"] = layers.tapped_matmul(params["fc1"]["w"], h,
                                          probes.get("fc1"), N_STAT)
    return jnp.mean(jnp.square(h - y)), acts


def _batches(n):
    key = jax.random.PRNGKey(3)
    W = jax.random.normal(key, (D_IN, D_OUT)) / np.sqrt(D_IN)
    out = []
    for i in range(n):
        x = jax.random.normal(jax.random.fold_in(key, i + 1),
                              (N_BS, D_IN))
        out.append((x, jnp.tanh(x @ W)))
    return out


def _cfg(variant, **kw):
    pol = policy.PolicyConfig(variant=variant, r=8, max_dense_dim=512)
    kwargs = dict(policy=pol, lr=optbase.constant(0.05),
                  damping_phi=optbase.constant(0.1), weight_decay=1e-4,
                  clip=10.0, T_updt=1, T_inv=4, T_brand=1, T_rsvd=4,
                  T_corct=4, fallback_lr=optbase.constant(1e-2))
    kwargs.update(kw)
    return kfac_lib.KfacConfig(**kwargs)


def _train(variant, telemetry_path=None, steps=9, mesh=None,
           curvature_axis=None, **cfg_kw):
    params, taps = _make_mlp()
    opt = kfac_lib.Kfac(_cfg(variant, **cfg_kw), taps)
    writer = (ev_lib.TelemetryWriter(telemetry_path, console=False)
              if telemetry_path else None)
    state, losses = loop.run_kfac_training(
        _mlp_loss, opt, params, _batches(steps), n_tokens=N_BS, seed=0,
        dist=specs.DistSpec(mesh=mesh, curvature_axis=curvature_axis),
        obs=specs.ObsSpec(writer=writer,
                          metrics_every=3 if writer else 0))
    if writer is not None:
        writer.close()
    return state, losses


def _assert_identical(sa, la, sb, lb):
    """Metrics-on must be *bit-identical* to metrics-off: telemetry only
    reads hot-path values, so the optimizer's graph outputs are the same
    program."""
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        jax.device_get(sa.params), jax.device_get(sb.params))


@pytest.mark.parametrize("variant", _marked_variants())
def test_metrics_on_equals_metrics_off(variant, tmp_path):
    path = str(tmp_path / "events.jsonl")
    s_off, l_off = _train(variant)
    s_on, l_on = _train(variant, telemetry_path=path)
    _assert_identical(s_off, l_off, s_on, l_on)
    evs = list(ev_lib.read_events(path))          # validates schema
    metrics = [e for e in evs if e["type"] == "metrics"]
    assert metrics, "meter never flushed"
    # counters summed over the run cover every step
    total_stats = sum(e["values"]["work/stats_fired"] for e in metrics)
    assert total_stats > 0
    assert len([e for e in evs if e["type"] == "step"]) == len(l_on)


@pytest.mark.parametrize("variant", ["bkfac",
                                     pytest.param(
                                         "nskfac",
                                         marks=pytest.mark.slow)])
def test_async_metrics_on_equals_off(variant, tmp_path):
    """Same claim through the async launch/land pipeline (in-graph
    landings; the snapshot/land machinery records launch/land slots)."""
    path = str(tmp_path / "events.jsonl")
    kw = dict(async_heavy=True, heavy_lag=2, stagger=True,
              stagger_splits=2)
    s_off, l_off = _train(variant, steps=10, **kw)
    s_on, l_on = _train(variant, telemetry_path=path, steps=10, **kw)
    _assert_identical(s_off, l_off, s_on, l_on)
    assert [e for e in ev_lib.read_events(path) if e["type"] == "metrics"]


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["bkfac", "nskfac"])
def test_sharded_metrics_on_equals_off(variant, tmp_path):
    """The claim on an 8-device host mesh: aux diagnostics ride the
    engine's all-gather, metrics are recorded at the outer trace level,
    and the io_callback flush emits schema-valid windows under
    shard_map-based training."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = mesh_lib.make_mesh((8,), ("curv",))
    path = str(tmp_path / "events.jsonl")
    s_off, l_off = _train(variant, mesh=mesh, curvature_axis="curv")
    s_on, l_on = _train(variant, telemetry_path=path, mesh=mesh,
                        curvature_axis="curv")
    _assert_identical(s_off, l_off, s_on, l_on)
    metrics = [e for e in ev_lib.read_events(path)
               if e["type"] == "metrics"]
    assert metrics, "no flush under shard_map"
    for e in metrics:
        assert set(e["values"]) == set(e["kinds"])
        assert all(np.isfinite(v) for v in e["values"].values())


# ---------------------------------------------------------------------------
# summary CLI on a real run's log
# ---------------------------------------------------------------------------

def test_summary_reports_a_real_run(tmp_path, capsys):
    path = str(tmp_path / "events.jsonl")
    _train("bkfac", telemetry_path=path)
    report = sum_lib.summarize(path)
    assert report["steps"]["count"] == 9
    assert set(report["steps"]["phases"])       # phase-keyed timings
    assert report["metrics"]["windows"] >= 2
    assert "work/stats_fired" in report["metrics"]["values"]
    text = sum_lib.render(report)
    assert "telemetry summary" in text and "work/stats_fired" in text
    # the CLI entry: report and validate modes both succeed
    assert sum_lib.main([path]) == 0
    assert sum_lib.main([path, "--validate"]) == 0
    capsys.readouterr()


def test_summary_validate_fails_on_bad_log(tmp_path, capsys):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"schema": 1, "t": 0.0, "type": "mystery"}\n')
    assert sum_lib.main([str(path), "--validate"]) == 1
    capsys.readouterr()

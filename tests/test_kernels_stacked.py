"""Interpret-mode parity of the stack-batched kernels and the fused
preconditioner against the ``ref.py`` oracles.

Sweeps aligned shapes (direct kernel path), misaligned shapes (pad-to-tile
path), one- and two-level stacks, and fp32/bf16.  The dispatch tests pin
``REPRO_PALLAS=interpret`` and poison the oracle so a silent fallback fails
loudly instead of vacuously passing.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import precond
from repro.kernels import ref, ops
from repro.kernels.precond_fused import precond_fused_pallas


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-3, rtol=2e-3)


def _close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.fixture
def interpret_mode(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")


def _no_fallback(monkeypatch, *names):
    """Poison oracle entry points used by ops dispatch so a fallback to ref
    inside ops.* raises instead of silently passing the parity check.
    Call AFTER computing the expected value (ref is shared)."""
    def boom(*a, **k):
        raise AssertionError("ops dispatch fell back to the ref oracle")
    for name in names:
        monkeypatch.setattr(ops.ref, name, boom)


def _orth(key, shape):
    q, _ = jnp.linalg.qr(jax.random.normal(key, shape))
    return q


# ---------------------------------------------------------------------------
# stacked kernels, aligned + pad path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stack,d,n,dtype", [
    ((2, 2), 128, 128, jnp.float32),  # aligned, 2-level stack
    ((2,), 136, 72, jnp.bfloat16),    # pad path
])
def test_ea_syrk_stacked(interpret_mode, monkeypatch, stack, d, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + n))
    M = jax.random.normal(k1, stack + (d, d), dtype=jnp.float32)
    M = ((M + jnp.swapaxes(M, -1, -2)) / 2).astype(dtype)
    X = jax.random.normal(k2, stack + (d, n), dtype=dtype)
    want = ref.ea_syrk(M, X, 0.95, False)
    _no_fallback(monkeypatch, "ea_syrk")
    got = ops.ea_syrk(M, X, 0.95, False)
    assert got.shape == want.shape == stack + (d, d)
    _close(got, want, dtype)


@pytest.mark.parametrize("stack,d,r,n,dtype", [
    ((2, 2), 128, 8, 128, jnp.float32),   # aligned, 2-level stack
    ((2,), 136, 12, 72, jnp.bfloat16),    # pad path
])
def test_brand_panel_stacked(interpret_mode, monkeypatch, stack, d, r, n,
                             dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + r + n))
    U = _orth(k1, stack + (d, r)).astype(dtype)
    A = jax.random.normal(k2, stack + (d, n), dtype=dtype)
    C_want, P_want = ref.brand_panel(U, A)
    _no_fallback(monkeypatch, "brand_panel")
    C_got, P_got = ops.brand_panel(U, A)
    assert C_got.shape == stack + (r, n) and P_got.shape == stack + (d, n)
    _close(C_got, C_want, dtype)
    _close(P_got, P_want, dtype)


@pytest.mark.parametrize("stack,p,d,w,dtype", [
    ((2, 2), 128, 128, 8, jnp.float32),   # aligned, 2-level stack
    ((2,), 120, 136, 12, jnp.bfloat16),   # pad path
])
def test_lowrank_apply_stacked(interpret_mode, monkeypatch, stack, p, d, w,
                               dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(p + d + w), 4)
    X = jax.random.normal(k1, stack + (p, d), dtype=dtype)
    U = _orth(k2, stack + (d, w)).astype(dtype)
    s = -jax.random.uniform(k3, stack + (w,), minval=0.1,
                            maxval=1.0).astype(dtype)
    lam = jax.random.uniform(k4, stack, minval=0.3, maxval=2.0)  # per-element
    want = ref.lowrank_apply(X, U, s, lam)
    _no_fallback(monkeypatch, "lowrank_apply")
    got = ops.lowrank_apply(X, U, s, lam)
    _close(got, want, dtype)


# ---------------------------------------------------------------------------
# fused preconditioner
# ---------------------------------------------------------------------------

def _fused_operands(stack, p, d, w_g, w_a, dtype):
    ks = jax.random.split(jax.random.PRNGKey(p + d + w_g + w_a), 7)
    J = jax.random.normal(ks[0], stack + (p, d), dtype=dtype)
    U_g = _orth(ks[1], stack + (p, w_g)).astype(dtype)
    U_a = _orth(ks[2], stack + (d, w_a)).astype(dtype)
    s_g = -jax.random.uniform(ks[3], stack + (w_g,), minval=0.1,
                              maxval=1.0).astype(dtype)
    s_a = -jax.random.uniform(ks[4], stack + (w_a,), minval=0.1,
                              maxval=1.0).astype(dtype)
    lam_g = jax.random.uniform(ks[5], stack, minval=0.3, maxval=2.0)
    lam_a = jax.random.uniform(ks[6], stack, minval=0.3, maxval=2.0)
    return J, U_g, s_g, lam_g, U_a, s_a, lam_a


@pytest.mark.parametrize("stack,p,d,w_g,w_a,dtype", [
    ((2,), 128, 256, 16, 24, jnp.float32),   # aligned, stacked
    ((2,), 128, 256, 16, 24, jnp.bfloat16),
    pytest.param((), 256, 128, 8, 8, jnp.float32,
                 marks=pytest.mark.slow),    # unstacked
    pytest.param((2,), 120, 136, 13, 10, jnp.float32,
                 marks=pytest.mark.slow),    # pad path (bf16 twin stays fast)
    ((2,), 120, 136, 13, 10, jnp.bfloat16),
    pytest.param((2, 2), 128, 128, 8, 16, jnp.float32,
                 marks=pytest.mark.slow),    # 2-level stack
])
def test_precond_fused_vs_ref(interpret_mode, monkeypatch, stack, p, d,
                              w_g, w_a, dtype):
    args = _fused_operands(stack, p, d, w_g, w_a, dtype)
    want = ref.precond_fused(*args)
    _no_fallback(monkeypatch, "precond_fused")
    got = ops.precond_fused(*args)
    assert got.shape == stack + (p, d)
    _close(got, want, dtype)


def test_precond_fused_kernel_direct():
    """Raw batched kernel (no dispatch) against the oracle."""
    args = _fused_operands((2,), 128, 128, 16, 8, jnp.float32)
    J, U_g, s_g, lam_g, U_a, s_a, lam_a = args
    got = precond_fused_pallas(J, U_g, s_g, 1.0 / lam_g, U_a, s_a,
                               1.0 / lam_a, interpret=True)
    want = ref.precond_fused(*args)
    _close(got, want, jnp.float32)


def test_precond_fused_matches_two_sided_composition(interpret_mode):
    """Fused path ≡ apply_inv_right then apply_inv_left (Alg 1)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    p, d, w = 128, 256, 16
    J = jax.random.normal(ks[0], (p, d))
    U_a = _orth(ks[1], (d, w))
    U_g = _orth(ks[2], (p, w))
    D_a = jnp.sort(jax.random.uniform(ks[3], (w,), minval=0.05,
                                      maxval=3.0))[::-1]
    D_g = jnp.sort(jax.random.uniform(ks[4], (w,), minval=0.05,
                                      maxval=3.0))[::-1]
    lam_a, lam_g = jnp.asarray(0.4), jnp.asarray(0.7)
    got = precond.kfac_precondition(J, U_g, D_g, lam_g, U_a, D_a, lam_a,
                                    use_kernel=True)
    want = precond.kfac_precondition(J, U_g, D_g, lam_g, U_a, D_a, lam_a,
                                     use_kernel=False)
    _close(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------

def test_shared_operand_broadcasts_across_stack(interpret_mode, monkeypatch):
    """One U/s shared by every stacked element (matmul-style broadcasting)
    must batch correctly, not mis-index a size-1 axis."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    X = jax.random.normal(k1, (3, 128, 128))
    U = _orth(k2, (128, 16))                 # unstacked, shared
    s = -jax.random.uniform(k3, (16,), minval=0.1, maxval=1.0)
    want = ref.lowrank_apply(X, U, s, 0.5)
    _no_fallback(monkeypatch, "lowrank_apply")
    got = ops.lowrank_apply(X, U, s, 0.5)
    assert got.shape == (3, 128, 128)
    _close(got, want, jnp.float32)


def test_fused_vmem_guard_falls_back_unfused(interpret_mode, monkeypatch):
    """A d too large for the J-resident stripes must dispatch to the
    unfused kernel path (two lowrank_apply round-trips), not the oracle."""
    monkeypatch.setattr(ops, "_FUSED_VMEM_BUDGET", 16 * 1024)  # force it
    args = _fused_operands((2,), 128, 256, 16, 8, jnp.float32)
    want = ref.precond_fused(*args)
    _no_fallback(monkeypatch, "precond_fused")
    got = ops.precond_fused(*args)
    _close(got, want, jnp.float32)


def test_tiny_shapes_fall_back_to_ref(interpret_mode):
    """Dims whose padding would exceed the growth cap use the oracle."""
    M = jnp.eye(100)
    X = jnp.ones((100, 7))          # n: 7 → 128 is way past _PAD_MAX
    got = ops.ea_syrk(M, X, 0.9, False)
    want = ref.ea_syrk(M, X, 0.9, False)
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.slow
def test_stacked_optimizer_update_kernels_match_jnp(interpret_mode):
    """End to end: a stacked tap steps identically with use_kernels on/off."""
    from repro.core import kfac as kfac_lib
    from repro.core import policy
    from repro.optim import base as optbase

    L, D, N = 2, 128, 32
    taps = {"blk": kfac_lib.TapInfo("blk/w", D, D, stack=(L,), n_stat=N)}
    pol = policy.PolicyConfig(variant="bkfac", r=16, max_dense_dim=512)
    key = jax.random.PRNGKey(0)
    params = {"blk": {"w": jax.random.normal(key, (L, D, D)) * 0.05}}
    grads = {"blk": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                            (L, D, D))}}
    acts = {"blk": jax.random.normal(jax.random.fold_in(key, 2), (L, N, D))}
    pgs = {"blk": jax.random.normal(jax.random.fold_in(key, 3),
                                    (L, N, D)) * 1e-3}

    def run(use_k):
        cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                                  T_updt=1, T_brand=1, use_kernels=use_k)
        opt = kfac_lib.Kfac(cfg, taps)
        st = opt.init(params)
        for step in range(1):
            upd, st = opt.update(grads, st, params, acts=acts,
                                 probe_grads=pgs, n_tokens=N,
                                 rng=jax.random.fold_in(key, 10 + step),
                                 work=opt.uniform_work(True, True, False))
        return upd["blk"]["w"]

    a, b = run(False), run(True)
    assert np.isfinite(np.asarray(a)).all()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)

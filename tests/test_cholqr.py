"""CholeskyQR2-style tall-skinny QR: algebraic properties of the oracle
and interpret-mode parity of the Pallas kernel pair (SYRK + root-apply)
against it, plus the Brand-update wiring
(`sym_brand_update(use_kernel=True)`).

Property tolerances are driven by the algorithm: two passes of the
clamped spectral root give ‖QᵀQ − I‖ ≈ machine-eps on full-rank panels,
QᵀQ is a rank-k projector to machine precision for *any* fp32 panel
(sub-noise-floor directions become an exactly-null subspace, never
unit-norm garbage), and Q R reconstructs the retained spectral content.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import brand
from repro.kernels import ref, ops
from repro.kernels.cholqr import cholqr2_batched_pallas


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-3, rtol=2e-3)


def _close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.fixture
def interpret_mode(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")


def _no_fallback(monkeypatch, *names):
    def boom(*a, **k):
        raise AssertionError("ops dispatch fell back to the ref oracle")
    for name in names:
        monkeypatch.setattr(ops.ref, name, boom)


# ---------------------------------------------------------------------------
# oracle properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stack,d,n,dtype", [
    ((), 256, 128, jnp.float32),
    pytest.param((), 300, 72, jnp.float32,
                 marks=pytest.mark.slow),   # misaligned dims
    pytest.param((2,), 256, 128, jnp.float32,
                 marks=pytest.mark.slow),   # stacked
    ((), 256, 128, jnp.bfloat16),
])
def test_cholqr2_orthonormal_and_reconstructs(stack, d, n, dtype):
    A = jax.random.normal(jax.random.PRNGKey(d + n), stack + (d, n),
                          dtype=dtype)
    Q, R = ref.cholqr2(A)
    assert Q.shape == stack + (d, n) and R.shape == stack + (n, n)
    assert Q.dtype == A.dtype and R.dtype == jnp.float32
    eye = jnp.eye(n)
    QtQ = jnp.swapaxes(Q, -1, -2).astype(jnp.float32) @ Q.astype(jnp.float32)
    orth_tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(QtQ),
                               np.broadcast_to(eye, QtQ.shape),
                               atol=orth_tol)
    _close(Q.astype(jnp.float32) @ R, A, dtype)
    # R symmetric psd (the clamped spectral root, not a triangular factor)
    np.testing.assert_allclose(np.asarray(R),
                               np.asarray(jnp.swapaxes(R, -1, -2)),
                               atol=1e-5)


def test_cholqr2_rank_deficient_panel_is_finite():
    """Zero columns (A already in span of the held basis) must not NaN the
    factorization — the clamp keeps Q finite and Q R exact."""
    A = jax.random.normal(jax.random.PRNGKey(0), (192, 64))
    A = A.at[:, 32:].set(0.0)
    Q, R = ref.cholqr2(A)
    assert bool(jnp.isfinite(Q).all()) and bool(jnp.isfinite(R).all())
    np.testing.assert_allclose(np.asarray(Q @ R), np.asarray(A), atol=1e-4)


@pytest.mark.parametrize("cond", [
    1e2, pytest.param(1e4, marks=pytest.mark.slow),
    pytest.param(1e6, marks=pytest.mark.slow), 1e8])
def test_cholqr2_ill_conditioned_panel_stays_projector(cond):
    """For any fp32 conditioning, QᵀQ must be a rank-k projector to
    machine precision (sub-noise-floor directions become an exactly-null
    subspace — a raw/shifted Cholesky renormalizes them into unit-norm
    garbage instead) and Q R must reconstruct the retained content."""
    d, n = 512, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(int(np.log10(cond))))
    Qo, _ = jnp.linalg.qr(jax.random.normal(k1, (d, n)))
    V, _ = jnp.linalg.qr(jax.random.normal(k2, (n, n)))
    s = jnp.logspace(0, -float(np.log10(cond)), n)
    A = (Qo * s) @ V.T
    Q, R = ref.cholqr2(A)
    assert bool(jnp.isfinite(Q).all()) and bool(jnp.isfinite(R).all())
    P = Q.T @ Q
    np.testing.assert_allclose(np.asarray(P @ P), np.asarray(P), atol=1e-4)
    # retained content reconstructed: error bounded by the clamp floor
    rel = float(jnp.abs(Q @ R - A).max() / jnp.abs(A).max())
    assert rel < 3e-2, rel


def test_cholqr2_matches_householder_reconstruction():
    """Same factorization as jnp.linalg.qr up to column signs — compare
    via the sign-invariant products Q Qᵀ (span projector) and Q R."""
    A = jax.random.normal(jax.random.PRNGKey(1), (200, 48))
    Q, R = ref.cholqr2(A)
    Qh, Rh = jnp.linalg.qr(A)
    np.testing.assert_allclose(np.asarray(Q @ Q.T), np.asarray(Qh @ Qh.T),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Q @ R), np.asarray(Qh @ Rh),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# kernel parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stack,d,n,dtype", [
    ((), 256, 128, jnp.float32),      # aligned
    ((2,), 256, 128, jnp.float32),    # stacked
    ((2,), 200, 72, jnp.float32),     # pad path (d and n)
    ((), 256, 128, jnp.bfloat16),
])
def test_ops_cholqr2_matches_oracle(interpret_mode, monkeypatch, stack, d,
                                    n, dtype):
    A = jax.random.normal(jax.random.PRNGKey(d + n), stack + (d, n),
                          dtype=dtype)
    Q_want, R_want = ref.cholqr2(A)
    _no_fallback(monkeypatch, "cholqr2")
    Q_got, R_got = ops.cholqr2(A)
    assert Q_got.shape == stack + (d, n)
    assert R_got.shape == stack + (n, n)
    _close(Q_got, Q_want, dtype)
    _close(R_got, R_want, dtype)


def test_cholqr2_kernel_direct():
    """Raw batched kernel pair (no dispatch) against the oracle."""
    A = jax.random.normal(jax.random.PRNGKey(3), (2, 256, 128))
    Q, R = cholqr2_batched_pallas(A, bk=128, interpret=True)
    Q_want, R_want = ref.cholqr2(A)
    _close(Q, Q_want, jnp.float32)
    _close(R, R_want, jnp.float32)


def test_ops_orthonormalize(interpret_mode, monkeypatch):
    Y = jax.random.normal(jax.random.PRNGKey(4), (256, 128))
    _no_fallback(monkeypatch, "cholqr2")
    Q = ops.orthonormalize(Y)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(128), atol=1e-4)


def test_tiny_panel_falls_back_to_oracle(interpret_mode):
    """n = 8 → 128 is way past the pad growth cap: oracle semantics, same
    CholeskyQR2 numerics (the PowerSGD rank-8 compressor hits this)."""
    Y = jax.random.normal(jax.random.PRNGKey(5), (300, 8))
    Q = ops.orthonormalize(Y)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(8), atol=1e-4)


# ---------------------------------------------------------------------------
# Brand-update wiring
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("stack", [(), (3,)])  # CI kernel-parity runs both
def test_sym_brand_update_kernel_path_matches_jnp(interpret_mode, stack):
    """use_kernel=True (Pallas panel + CholeskyQR2) and the default
    Householder path represent the same matrix and spectrum."""
    d, r, n = 256, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    U = jnp.linalg.qr(jax.random.normal(ks[0], stack + (d, r)))[0]
    D = jnp.sort(jax.random.uniform(ks[1], stack + (r,), minval=0.1,
                                    maxval=2.0), axis=-1)[..., ::-1]
    A = jax.random.normal(ks[2], stack + (d, n))
    U1, D1 = brand.sym_brand_update(U, D, A, use_kernel=False)
    U2, D2 = brand.sym_brand_update(U, D, A, use_kernel=True)
    np.testing.assert_allclose(np.asarray(D1), np.asarray(D2),
                               rtol=1e-3, atol=1e-3)
    rec1 = (U1 * D1[..., None, :]) @ jnp.swapaxes(U1, -1, -2)
    rec2 = (U2 * D2[..., None, :]) @ jnp.swapaxes(U2, -1, -2)
    np.testing.assert_allclose(np.asarray(rec1), np.asarray(rec2),
                               rtol=2e-3, atol=2e-3)

"""Hypothesis property-based tests on the system's core invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st

from repro.core import brand, precond, rsvd
from repro.kernels import ref

_dims = st.integers(min_value=8, max_value=48)
_ranks = st.integers(min_value=2, max_value=8)
_seeds = st.integers(min_value=0, max_value=2**16)
_rhos = st.floats(min_value=0.5, max_value=0.99)

SET = settings(max_examples=25, deadline=None)


def _state(seed, d, r):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    Q, _ = jnp.linalg.qr(jax.random.normal(k1, (d, r)))
    D = jnp.sort(jax.random.uniform(k2, (r,), minval=0.05, maxval=3.0))[::-1]
    return Q, D


@SET
@given(d=_dims, r=_ranks, n=_ranks, seed=_seeds)
def test_sym_brand_exactness(d, r, n, seed):
    """∀ state, update: Brand's update reconstructs UDUᵀ + AAᵀ exactly."""
    if r + n >= d:
        return
    U, D = _state(seed, d, r)
    A = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, n))
    U2, D2 = brand.sym_brand_update(U, D, A)
    np.testing.assert_allclose(np.asarray((U2 * D2) @ U2.T),
                               np.asarray((U * D) @ U.T + A @ A.T),
                               atol=5e-4)
    # psd + descending invariants
    assert np.all(np.asarray(D2) >= -1e-5)
    assert np.all(np.diff(np.asarray(D2)) <= 1e-5)


@SET
@given(d=_dims, n=_ranks, seed=_seeds, rho=_rhos)
def test_ea_psd_invariant(d, n, seed, rho):
    """The EA K-factor stays symmetric psd under any update stream."""
    M = jnp.zeros((d, d))
    for i in range(4):
        X = jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(seed), i), (d, n))
        M = ref.ea_syrk(M, X, rho, i == 0)
    Mn = np.asarray(M)
    np.testing.assert_allclose(Mn, Mn.T, atol=1e-5)
    w = np.linalg.eigvalsh((Mn + Mn.T) / 2)
    assert w.min() >= -1e-4 * max(1.0, abs(w).max())


@SET
@given(d=_dims, r=_ranks, seed=_seeds)
def test_truncation_error_optimality(d, r, seed):
    """EVD rank-r truncation error ≤ error of any Brand-state truncation
    of the same matrix (Prop 3.1 generalization)."""
    X = jax.random.normal(jax.random.PRNGKey(seed), (d, 2 * r))
    M = X @ X.T
    U, D = rsvd.exact_evd(M, r=r)
    opt = np.linalg.norm(np.asarray((U * D) @ U.T - M))
    Ub, Db = _state(seed + 1, d, r)
    other = np.linalg.norm(np.asarray((Ub * Db) @ Ub.T - M))
    assert opt <= other + 1e-4


@SET
@given(d=_dims, seed=_seeds,
       lam=st.floats(min_value=0.05, max_value=2.0))
def test_inverse_application_identity(d, seed, lam):
    """apply_inv_right with the FULL spectrum == dense inverse application,
    for any psd factor and damping."""
    X = jax.random.normal(jax.random.PRNGKey(seed), (d, d))
    M = X @ X.T / d
    U, D = rsvd.exact_evd(M)
    J = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, d))
    got = precond.apply_inv_right(J, U, D, jnp.asarray(lam))
    want = J @ np.linalg.inv(np.asarray(M) + lam * np.eye(d))
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-2, atol=5e-3)


@SET
@given(d=_dims, r=_ranks, seed=_seeds,
       phi=st.floats(min_value=0.01, max_value=0.5))
def test_spectrum_continuation_invariants(d, r, seed, phi):
    """Continuation preserves D+λ total on retained modes, keeps D ≥ 0,
    and never decreases λ (more conservative steps — paper §3.5)."""
    _, D = _state(seed, d, r)
    D = jnp.concatenate([D, jnp.zeros((3,))])     # padded state
    lam = precond.damping_from_spectrum(D, jnp.asarray(phi))
    D2, lam2 = precond.spectrum_continuation(D, lam)
    assert float(lam2) >= float(lam) - 1e-7
    assert np.all(np.asarray(D2) >= -1e-7)
    # retained modes keep D+λ exactly
    np.testing.assert_allclose(np.asarray(D2[:r] + lam2),
                               np.asarray(D[:r] + lam), rtol=1e-5)


@SET
@given(seed=_seeds, m=_dims, n=_dims, k=_ranks)
def test_ea_syrk_kernel_property(seed, m, n, k):
    """ops.ea_syrk == ref.ea_syrk for arbitrary shapes (dispatch safety)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(seed)
    M = jax.random.normal(key, (m, m))
    X = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    got = ops.ea_syrk(M, X, 0.9, False)
    want = ref.ea_syrk(M, X, 0.9, False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

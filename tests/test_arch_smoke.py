"""Per-architecture smoke tests: reduced config of the same family, one
forward + loss/grad step and one decode step on CPU; asserts shapes + no
NaNs.  (Full configs are exercised only via the dry-run.)"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # multi-minute e2e tier

from repro.configs.base import ARCH_NAMES, get_arch
from repro.models import layers
from repro.models.lm import LM

B, T = 2, 32


def _batch(arch, key):
    k1, k2, k3 = jax.random.split(key, 3)
    n_tok = T - (arch.n_prefix if arch.frontend == "vision" else 0)
    batch = {
        "tokens": jax.random.randint(k1, (B, n_tok), 0, arch.vocab),
        "targets": jax.random.randint(k2, (B, n_tok), 0, arch.vocab),
    }
    if arch.is_encdec:
        batch["frames"] = jax.random.normal(k3, (B, T, arch.d_model)) * 0.1
        batch["tokens"] = batch["tokens"][:, : T // arch.dec_ratio]
        batch["targets"] = batch["targets"][:, : T // arch.dec_ratio]
    if arch.frontend == "vision":
        batch["embeds"] = jax.random.normal(k3, (B, arch.n_prefix,
                                                 arch.d_model)) * 0.1
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCH_NAMES:
        arch = get_arch(name).reduced()
        lm = LM(arch, remat=False)
        params = lm.init(jax.random.PRNGKey(0))
        out[name] = (arch, lm, params)
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_grad(name, built):
    arch, lm, params = built[name]
    batch = _batch(arch, jax.random.PRNGKey(1))
    probes = layers.make_probes(lm.taps)

    def loss(p, pr):
        return lm.loss_fn(p, pr, batch)

    (l, acts), (gp, gprobe) = jax.value_and_grad(
        loss, argnums=(0, 1), has_aux=True)(params, probes)
    assert np.isfinite(float(l)), f"{name}: loss={l}"
    # activations recorded for every tap
    for tname, tap in lm.taps.items():
        assert tname in acts, f"{name}: missing act {tname}"
        a = acts[tname]
        assert a.shape == tap.stack + (tap.n_stat, tap.d_in), \
            f"{name}/{tname}: {a.shape}"
        g = gprobe[tname]
        assert g.shape == tap.stack + (tap.n_stat, tap.d_out)
        assert np.isfinite(np.asarray(g)).all(), f"{name}/{tname} probe grad"
    # param grads finite
    for leaf in jax.tree_util.tree_leaves(gp):
        assert np.isfinite(np.asarray(leaf)).all(), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(name, built):
    arch, lm, params = built[name]
    S = 16
    cross_len = T if arch.is_encdec else 0
    cache = lm.init_cache(B, S, cross_len=cross_len)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, cache = lm.decode_step(params, cache, token, jnp.asarray(0))
    assert logits.shape == (B, 1, arch.vocab)
    assert np.isfinite(np.asarray(logits)).all(), name
    logits, cache = lm.decode_step(params, cache, token, jnp.asarray(1))
    assert np.isfinite(np.asarray(logits)).all(), name


@pytest.mark.parametrize("name", ["gemma3_4b", "mamba2_2p7b",
                                  "recurrentgemma_2b"])
def test_decode_matches_forward(name, built):
    """Greedy decode logits == full-forward logits position by position."""
    arch, lm, params = built[name]
    key = jax.random.PRNGKey(3)
    n_tok = 8
    tokens = jax.random.randint(key, (B, n_tok), 0, arch.vocab)
    batch = {"tokens": tokens, "targets": tokens}
    logits_full, _, _, _ = lm.forward(params, batch, train=False)
    cache = lm.init_cache(B, n_tok)
    outs = []
    for t in range(n_tok):
        lg, cache = lm.decode_step(params, cache, tokens[:, t: t + 1],
                                   jnp.asarray(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_full_configs():
    """Full (non-reduced) configs must hit the advertised parameter scale."""
    expected = {
        "qwen2_72b": (60e9, 90e9),
        "deepseek_v3_671b": (550e9, 750e9),
        "gemma2_27b": (20e9, 34e9),
        "mamba2_2p7b": (2.0e9, 3.5e9),
        "llama4_scout_17b_a16e": (80e9, 130e9),  # total (17B active)
        "recurrentgemma_2b": (2.0e9, 4.5e9),
        "gemma3_4b": (3.0e9, 6e9),
        "h2o_danube_3_4b": (3.0e9, 5e9),
        "whisper_medium": (0.25e9, 1.2e9),
        "internvl2_76b": (60e9, 90e9),
    }
    from repro.launch.param_count import count_params
    for name, (lo, hi) in expected.items():
        n = count_params(get_arch(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B params not in " \
                              f"[{lo/1e9:.0f}B, {hi/1e9:.0f}B]"

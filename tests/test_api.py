"""Public API surface (repro.api) + the one-cycle deprecation shims.

The consolidation contract: every legacy loose kwarg / three-bool call
still runs, warns exactly once, and produces BIT-IDENTICAL results to
its spec-based replacement — the shim converts arguments, it never forks
the code path.
"""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api, specs
from repro.core import kfac as kfac_lib
from repro.models import layers
from repro.optim import base as optbase
from repro.train import loop


# ---------------------------------------------------------------------------
# repro.api surface
# ---------------------------------------------------------------------------

def test_api_all_importable():
    for name in api.__all__:
        assert hasattr(api, name), f"api.__all__ lists missing {name!r}"
        assert getattr(api, name) is not None


def test_api_covers_headline_symbols():
    for name in ("Kfac", "KfacConfig", "TenantBank", "TenantService",
                 "DistSpec", "ObsSpec", "CkptSpec", "ResilienceSpec",
                 "run_kfac_training", "Engine", "TelemetryWriter"):
        assert name in api.__all__


# ---------------------------------------------------------------------------
# tiny shared fixture: 2-layer tapped MLP
# ---------------------------------------------------------------------------

D_IN, D_H, D_OUT, N_BS, N_STAT = 12, 16, 8, 8, 4


def _make():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    params = {"fc0": {"w": layers.dense_init(ks[0], D_IN, D_H)},
              "fc1": {"w": layers.dense_init(ks[1], D_H, D_OUT)}}
    taps = {"fc0": kfac_lib.TapInfo("fc0/w", D_IN, D_H, n_stat=N_STAT),
            "fc1": kfac_lib.TapInfo("fc1/w", D_H, D_OUT, n_stat=N_STAT)}
    return params, taps


def _loss(params, probes, batch):
    x, y = batch
    acts = {}
    h, acts["fc0"] = layers.tapped_matmul(params["fc0"]["w"], x,
                                          probes.get("fc0"), N_STAT)
    h = jax.nn.relu(h)
    h, acts["fc1"] = layers.tapped_matmul(params["fc1"]["w"], h,
                                          probes.get("fc1"), N_STAT)
    return jnp.mean(jnp.square(h - y)), acts


def _batches(n):
    key = jax.random.PRNGKey(3)
    out = []
    for i in range(n):
        x = jax.random.normal(jax.random.fold_in(key, i + 1),
                              (N_BS, D_IN))
        out.append((x, jnp.tanh(x[:, :D_OUT])))
    return out


def _opt():
    pol = api.PolicyConfig(variant="bkfac", r=4, max_dense_dim=512)
    cfg = api.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                         damping_phi=optbase.constant(0.1),
                         T_updt=1, T_inv=2, T_brand=1, T_rsvd=2,
                         T_corct=2)
    _, taps = _make()
    return api.Kfac(cfg, taps)


def _tree_eq(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# legacy kwargs -> spec objects (run_kfac_training)
# ---------------------------------------------------------------------------

def test_legacy_kwargs_equal_specs_and_warn(tmp_path):
    params, _ = _make()
    batches = _batches(4)

    specs._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s_old, l_old = loop.run_kfac_training(
            _loss, _opt(), params, batches, n_tokens=N_BS, seed=0,
            ckpt_dir=str(tmp_path / "old"), ckpt_every=2, ckpt_keep=2)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert dep, "legacy kwargs must raise DeprecationWarning"
    assert "CkptSpec" in str(dep[0].message)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s_new, l_new = loop.run_kfac_training(
            _loss, _opt(), params, batches, n_tokens=N_BS, seed=0,
            ckpt=api.CkptSpec(dir=str(tmp_path / "new"), every=2, keep=2))
    assert not [x for x in w if issubclass(x.category,
                                           DeprecationWarning)]

    np.testing.assert_array_equal(np.asarray(l_old), np.asarray(l_new))
    _tree_eq(s_old.params, s_new.params)
    _tree_eq(s_old.opt.factors, s_new.opt.factors)


def test_legacy_kwarg_warns_once_per_process():
    specs._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(3):
            specs.warn_once("k", "msg")
    assert len(w) == 1


def test_spec_plus_legacy_conflict_raises():
    params, _ = _make()
    with pytest.raises(ValueError, match="conflicts"):
        loop.run_kfac_training(
            _loss, _opt(), params, _batches(1), n_tokens=N_BS,
            ckpt=api.CkptSpec(dir="x"), ckpt_dir="y")


def test_unknown_kwarg_raises():
    params, _ = _make()
    with pytest.raises(TypeError, match="unexpected keyword"):
        loop.run_kfac_training(_loss, _opt(), params, _batches(1),
                               n_tokens=N_BS, no_such_option=1)


# ---------------------------------------------------------------------------
# three-bool shims (Kfac.update / KfacConfig.flags / make_kfac_step)
# ---------------------------------------------------------------------------

def test_update_bool_shim_matches_work_mask():
    params, _ = _make()
    opt = _opt()
    state = opt.init(params)
    probes = layers.make_probes(opt.taps, jnp.float32)
    loss, acts, gp, gprobe = loop.kfac_grads(_loss, params, probes,
                                             _batches(1)[0])
    rng = jax.random.PRNGKey(7)
    specs._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        u_old, s_old = opt.update(gp, state, params, acts=acts,
                                  probe_grads=gprobe, n_tokens=N_BS,
                                  rng=rng, do_stats=True, do_light=True,
                                  do_heavy=True)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    u_new, s_new = opt.update(gp, state, params, acts=acts,
                              probe_grads=gprobe, n_tokens=N_BS, rng=rng,
                              work=opt.uniform_work(True, True, True))
    _tree_eq(u_old, u_new)
    _tree_eq(s_old.factors, s_new.factors)


def test_flags_shim_warns_and_delegates():
    opt = _opt()
    specs._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        flags = opt.cfg.flags(0)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert flags == {"do_stats": True, "do_light": True,
                     "do_heavy": False}


def test_make_kfac_step_shim_matches_scheduled():
    params, _ = _make()
    batch = _batches(1)[0]
    opt = _opt()
    specs._WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = loop.make_kfac_step(_loss, opt, n_tokens=N_BS)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    scheduled = loop.make_scheduled_kfac_step(_loss, opt, n_tokens=N_BS)
    st0 = loop.TrainState(params=params, opt=opt.init(params),
                          rng=jax.random.PRNGKey(0))
    s_old, loss_old = legacy(st0, batch, True, True, False)
    s_new, loss_new = scheduled(st0, batch,
                                opt.uniform_work(True, True, False))
    np.testing.assert_array_equal(np.asarray(loss_old),
                                  np.asarray(loss_new))
    _tree_eq(s_old.params, s_new.params)


def test_build_train_step_rejects_mixed_dist_and_loose():
    from repro.configs.base import get_arch
    from repro.launch import steps as steps_lib
    with pytest.raises(ValueError, match="not both"):
        steps_lib.build_train_step(
            get_arch("gemma3_4b").reduced(),
            dist=api.DistSpec(curvature_axis="curv"),
            curvature_axis="curv")

"""Edge-case tests for ``policy.select_mode`` — the boundaries decide
bucket membership (equal specs ⇔ shared bucket) and which scheduler units
exist, so off-by-ones here silently change the whole hot path."""
import pytest

from repro.core import policy
from repro.core.kfactor import Mode


def _pol(variant="bkfac", r=32, r_o=10, max_dense_dim=1024):
    return policy.PolicyConfig(variant=variant, r=r, r_o=r_o,
                               max_dense_dim=max_dense_dim)


class TestBThreshold:
    """Paper applicability condition d > r + n_stat is STRICT."""

    def test_exactly_equal_is_narrow(self):
        pol = _pol(r=32)
        n_stat = 64
        d = 32 + 64                       # d == r + n_stat
        assert policy.select_mode(pol, d, n_stat) == Mode.RSVD

    def test_one_above_is_wide(self):
        pol = _pol(r=32)
        assert policy.select_mode(pol, 32 + 64 + 1, 64) == Mode.BRAND

    def test_r_clamped_to_d(self):
        # r = min(cfg.r, d): with cfg.r ≥ d the factor is never "wide"
        # (d > d + n_stat is false) and the tiny override (d ≤ r + r_o)
        # always holds — exact EVD, the cheapest correct choice
        pol = _pol(r=10_000, max_dense_dim=100_000)
        assert policy.select_mode(pol, 2048, 64) == Mode.EVD


class TestMemoryGate:
    def test_exactly_at_gate_keeps_dense(self):
        pol = _pol(variant="rkfac", r=32, max_dense_dim=1024)
        assert policy.select_mode(pol, 1024, 64) == Mode.RSVD

    def test_one_above_gate_degrades_to_brand(self):
        pol = _pol(variant="rkfac", r=32, max_dense_dim=1024)
        assert policy.select_mode(pol, 1025, 64) == Mode.BRAND

    def test_gate_applies_to_all_m_holding_modes(self):
        # nskfac included: NS holds M *and* a dense inverse, so it must
        # degrade to pure Brand at the same gate
        n_stat = 64
        for variant in ("kfac", "rkfac", "brkfac", "bkfacc", "nskfac"):
            pol = _pol(variant=variant, r=32, max_dense_dim=1024)
            assert policy.select_mode(pol, 4096, n_stat) == Mode.BRAND, \
                variant

    def test_pure_brand_unaffected(self):
        pol = _pol(variant="bkfac", r=32, max_dense_dim=1024)
        assert policy.select_mode(pol, 4096, 64) == Mode.BRAND


class TestTinyEvdOverride:
    def test_exactly_r_plus_ro_is_evd(self):
        pol = _pol(r=32, r_o=10)
        assert policy.select_mode(pol, 42, 64) == Mode.EVD

    def test_one_above_is_not(self):
        pol = _pol(r=32, r_o=10)
        assert policy.select_mode(pol, 43, 64) == Mode.RSVD

    def test_override_applies_last(self):
        # even a factor past the memory gate goes EVD when tiny (its M is
        # tiny by construction; the gate's 275 GB argument can't apply)
        pol = _pol(r=32, r_o=10, max_dense_dim=16)
        assert policy.select_mode(pol, 20, 64) == Mode.EVD

    def test_r_clamp_makes_small_d_always_evd(self):
        # r = min(cfg.r, d) ⇒ d ≤ r + r_o whenever d ≤ cfg.r
        pol = _pol(r=256, r_o=10)
        for d in (8, 64, 256):
            assert policy.select_mode(pol, d, 32) == Mode.EVD

    def test_ns_exempt_from_tiny_override(self):
        # NS's contract is a factorization-free heavy path; the EVD
        # override would smuggle an eigh back in at tiny d
        pol = _pol(variant="nskfac", r=32, r_o=10)
        assert policy.select_mode(pol, 42, 64) == Mode.NS


def test_unknown_variant_raises():
    pol = policy.PolicyConfig(variant="sgd")
    with pytest.raises(ValueError):
        policy.select_mode(pol, 128, 32)


def test_spec_width_consistency_at_boundaries():
    """make_factor_spec must stay self-consistent at the boundaries the
    bucketer keys on (width drives every gathered operand shape)."""
    pol = _pol(r=32, r_o=10, max_dense_dim=1024)
    spec_narrow = policy.make_factor_spec(pol, 96, 64)    # d == r+n_stat
    assert spec_narrow.mode == Mode.RSVD
    assert spec_narrow.width == 32
    spec_wide = policy.make_factor_spec(pol, 97, 64)
    assert spec_wide.mode == Mode.BRAND
    assert spec_wide.width == 32 + 64
    spec_tiny = policy.make_factor_spec(pol, 42, 64)
    assert spec_tiny.mode == Mode.EVD
    assert spec_tiny.width == 32

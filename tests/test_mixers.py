"""Correctness tests for attention / SSD / RG-LRU mixers, including
train-vs-decode consistency (the serve path must match the train path)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import attention as attn
from repro.models import ssm


def _naive_attention(q, k, v, causal=True, window=0, softcap=0.0):
    B, Tq, H, hd = q.shape
    Tk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Tq, Hk, G, hd)
    s = jnp.einsum("bqkgh,bskh->bqkgs", qg, k) / np.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp, kp = jnp.arange(Tq), jnp.arange(Tk)
    valid = jnp.ones((Tq, Tk), bool)
    if causal:
        valid &= qp[:, None] >= kp[None, :]
    if window > 0:
        valid &= qp[:, None] - kp[None, :] < window
    s = jnp.where(valid[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgs,bskh->bqkgh", p, v).reshape(B, Tq, H, hd)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (16, 0.0), (0, 30.0)])
def test_blockwise_matches_naive(window, softcap):
    B, T, H, Hk, hd = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, Hk, hd))
    v = jax.random.normal(ks[2], (B, T, Hk, hd))
    got = attn.blockwise_attention(q, k, v, causal=True, window=window,
                                   softcap=softcap, q_block=16, kv_block=16)
    want = _naive_attention(q, k, v, True, window, softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_decode_matches_train():
    """Last-token output of train attention == decode over the cache."""
    B, T, H, Hk, hd = 2, 32, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, Hk, hd))
    v = jax.random.normal(ks[2], (B, T, Hk, hd))
    full = attn.blockwise_attention(q, k, v, q_block=8, kv_block=8)
    dec = attn.decode_attention(q[:, -1:], k, v, t=jnp.asarray(T - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-4)


def test_decode_window():
    B, T, H, Hk, hd = 1, 32, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, Hk, hd))
    v = jax.random.normal(ks[2], (B, T, Hk, hd))
    full = _naive_attention(q, k, v, True, window=8)
    dec = attn.decode_attention(q[:, -1:], k, v, window=8,
                                t=jnp.asarray(T - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-5, rtol=2e-4)


class TestSSD:
    def _inputs(self, B=2, T=32, H=4, P=8, G=2, N=6, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        xh = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.1
        A = -jax.nn.softplus(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (B, T, G, N))
        Cm = jax.random.normal(ks[4], (B, T, G, N))
        return xh, dt, A, Bm, Cm

    @pytest.mark.slow
    def test_chunked_matches_reference(self):
        xh, dt, A, Bm, Cm = self._inputs()
        got = ssm.ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)
        want = ssm.ssd_reference(xh, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-3)

    def test_decode_matches_chunked(self):
        xh, dt, A, Bm, Cm = self._inputs(seed=1)
        B, T, H, P = xh.shape
        N = Bm.shape[-1]
        full = ssm.ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)
        state = jnp.zeros((B, H, N, P))
        for t in range(T):
            y, state = ssm.ssd_decode_step(xh[:, t], dt[:, t], A,
                                           Bm[:, t], Cm[:, t], state)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, -1]),
                                   atol=1e-4, rtol=1e-3)


class TestRGLRU:
    @pytest.mark.slow
    def test_scan_matches_step_loop(self):
        B, T, D = 2, 16, 12
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        x = jax.random.normal(ks[0], (B, T, D))
        gx = jax.random.normal(ks[1], (B, T, D))
        ga = jax.random.normal(ks[2], (B, T, D))
        lam = jax.random.normal(ks[3], (D,))
        full = ssm.rglru(x, gx, ga, lam)
        h = jnp.zeros((B, D))
        outs = []
        for t in range(T):
            y, h = ssm.rglru_step(x[:, t], gx[:, t], ga[:, t], lam, h)
            outs.append(y)
        step = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                                   atol=1e-5, rtol=1e-4)

    @pytest.mark.slow
    def test_stability(self):
        """|a| < 1 ⇒ bounded states on long sequences."""
        B, T, D = 1, 512, 8
        x = jnp.ones((B, T, D)) * 5.0
        out = ssm.rglru(x, jnp.ones((B, T, D)) * 3, jnp.ones((B, T, D)) * 3,
                        jnp.zeros((D,)))
        assert np.isfinite(np.asarray(out)).all()
        assert np.abs(np.asarray(out)).max() < 100.0


def test_causal_conv1d_step_consistency():
    B, T, C, K = 2, 10, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (B, T, C))
    w = jax.random.normal(ks[1], (K, C))
    full = ssm.causal_conv1d(x, w)
    buf = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(T):
        y, buf = ssm.causal_conv1d_step(x[:, t], buf, w)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5, rtol=1e-4)

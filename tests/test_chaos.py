"""Chaos tier: the resilience layer (repro/train/health.py + chaos.py).

Covers the two load-bearing acceptance claims:

  1. **Inertness** — a healthy run with health guards on is bit-for-bit
     identical to one with them off, across every policy variant, the
     async overlapped pipeline, and the 8-device sharded engine.
  2. **Recovery** — every injected fault class ends in a documented
     remediation: NaN grads → skip/escalate/refresh ladder; corrupted
     in-flight buffers → guarded in-graph fallback; hung / dead / dropped
     async workers → bounded-deadline miss + pool respawn; host loss
     mid-stagger-cycle → repartition on the shrunk mesh with the heavy
     cadence resumed from ``KfacState.phase`` (no warmup spike);
     truncated checkpoints → checksum detection + ring rollback.
"""
import json
import os

import numpy as np
import pytest

# must precede backend init in THIS process; harmless if jax was already
# initialized with one device (the mesh tests then skip)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import kfac as kfac_lib
from repro.launch import mesh as mesh_lib
from repro.obs import events as ev_lib
from repro.obs import summary as sum_lib
from repro.train import chaos as chaos_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import elastic, loop, straggler
from repro.train.chaos import ChaosMonkey, Fault
from repro.train.health import (HealthConfig, RemediationPolicy,
                                STAGE_ELASTIC)
from repro import specs

from test_obs import (N_BS, _batches, _cfg, _make_mlp, _marked_variants,
                      _mlp_loss, _assert_identical)


def _train(variant, steps=9, health=None, policy_obj=None, overlap=False,
           mesh=None, curvature_axis=None, row_axis=None,
           curvature_compress=None, writer=None, metrics_every=0,
           chaos=None, ckpt_dir=None, ckpt_every=5, state=None,
           batches=None, **cfg_kw):
    params, taps = _make_mlp()
    opt = kfac_lib.Kfac(_cfg(variant, **cfg_kw), taps)
    out = loop.run_kfac_training(
        _mlp_loss, opt, None if state is not None else params,
        batches if batches is not None else _batches(steps),
        n_tokens=N_BS, seed=0, state=state, overlap=overlap,
        dist=specs.DistSpec(mesh=mesh, curvature_axis=curvature_axis,
                            row_axis=row_axis,
                            curvature_compress=curvature_compress),
        obs=specs.ObsSpec(writer=writer, metrics_every=metrics_every),
        resilience=specs.ResilienceSpec(health=health, policy=policy_obj,
                                        chaos=chaos),
        ckpt=specs.CkptSpec(dir=ckpt_dir, every=ckpt_every))
    return out


def _all_finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating))


# ---------------------------------------------------------------------------
# fault plans are deterministic and typed
# ---------------------------------------------------------------------------

class TestChaosMonkey:
    def test_seeded_plan_is_deterministic_and_in_range(self):
        a = ChaosMonkey.from_seed(7, 20, kinds=chaos_lib.KINDS, n_faults=5)
        b = ChaosMonkey.from_seed(7, 20, kinds=chaos_lib.KINDS, n_faults=5)
        assert a.faults == b.faults
        assert len(a.faults) == 5
        assert all(1 <= f.step < 20 and f.kind in chaos_lib.KINDS
                   for f in a.faults)
        c = ChaosMonkey.from_seed(8, 20, kinds=chaos_lib.KINDS, n_faults=5)
        assert a.faults != c.faults        # seed actually drives the plan

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault(3, "meteor_strike")

    def test_empty_plan_is_inert(self):
        m = ChaosMonkey(())
        batch = (jnp.ones((2, 3)), jnp.zeros((2,)))
        out = m.corrupt_batch(5, batch)
        assert out is batch
        m.check(5)                          # no raise
        m.harass_runner(5, None)
        assert m.injected == [] and m.summary() == {}

    def test_corrupt_batch_nans_float_leaves_only(self):
        m = ChaosMonkey((Fault(2, "nan_grad"),))
        x, idx = jnp.ones((4,)), jnp.arange(4)
        bx, bidx = m.corrupt_batch(2, (x, idx))
        assert bool(jnp.all(jnp.isnan(bx)))
        np.testing.assert_array_equal(np.asarray(bidx), np.asarray(idx))
        assert m.summary() == {"nan_grad": 1}

    def test_host_loss_raises_like_failure_injector(self):
        m = ChaosMonkey((Fault(4, "host_loss"),))
        m.check(3)
        with pytest.raises(RuntimeError, match="injected node failure"):
            m.check(4)


# ---------------------------------------------------------------------------
# the remediation policy state machine (pure host side)
# ---------------------------------------------------------------------------

def _report(ok=1.0, **extra):
    rep = {"ok": ok, "grad_nonfinite": 0.0 if ok else 8.0,
           "grad_abs_max": 1.0, "update_nonfinite": 0.0,
           "update_abs_max": 1.0, "bucket0/factor_nonfinite": 0.0}
    rep.update(extra)
    return rep


class TestRemediationPolicy:
    def test_ladder_escalates_in_stage_order(self):
        pol = RemediationPolicy(HealthConfig())
        for k in range(6):                   # 6-step faulty streak
            assert pol.observe(k, float("nan"), _report(ok=0.0))
        # streak 1, 2 → skip + damping escalation; streak 3 → forced
        # refresh; 4, 5 → skip only (escalations maxed); 6 → rollback
        assert pol.count("skip") == 6
        assert pol.count("escalate") == 2
        assert pol.count("refresh") == 1
        assert pol.count("rollback") == 1
        assert pol.damping_scale == 64.0     # 8.0 ** 2
        assert pol.take_refresh() and not pol.take_refresh()
        assert pol.take_rollback() and not pol.take_rollback()

    def test_deescalates_after_recovery_window(self):
        cfg = HealthConfig(recovery_steps=3)
        pol = RemediationPolicy(cfg)
        pol.observe(0, float("nan"), _report(ok=0.0))
        assert pol.damping_scale == cfg.escalation
        for k in range(1, 4):
            assert not pol.observe(k, 1.0, _report())
        assert pol.damping_scale == 1.0
        assert pol.count("deescalate") == 1

    def test_loss_divergence_faults_without_guard_trip(self):
        pol = RemediationPolicy(HealthConfig())
        for k in range(3):
            assert not pol.observe(k, 1.0, _report())
        assert pol.observe(3, 1e6, _report())       # ok report, huge loss
        assert pol.count("skip") == 0               # guard never tripped
        assert pol.count("escalate") == 1

    def test_ns_residual_blowup_is_a_fault(self):
        pol = RemediationPolicy(HealthConfig())
        rep = _report(**{"bucket0/ns_res": 0.9})
        assert pol.observe(0, 1.0, rep)

    def test_actions_reach_the_event_log(self, tmp_path):
        path = str(tmp_path / "e.jsonl")
        with ev_lib.TelemetryWriter(path, console=False) as w:
            pol = RemediationPolicy(HealthConfig(), writer=w)
            pol.observe(0, float("nan"), _report(ok=0.0))
        evs = [e for e in ev_lib.read_events(path)
               if e["type"] == "remediation"]
        assert [e["action"] for e in evs] == ["skip", "escalate"]
        assert all(e["step"] == 0 for e in evs)


# ---------------------------------------------------------------------------
# checkpoint integrity: checksums, torn writes, the healthy-ring walk
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)), "step": jnp.asarray(seed)}


class TestCheckpointIntegrity:
    def test_manifest_records_a_checksum_per_array(self, tmp_path):
        path = ckpt_lib.save(str(tmp_path), 0, _tree())
        with open(os.path.join(path, "manifest.json")) as f:
            man = json.load(f)
        assert man["schema"] == ckpt_lib.SCHEMA_VERSION >= 5
        assert len(man["checksums"]) == man["n_arrays"] > 0
        assert all(len(d) == 8 for d in man["checksums"].values())

    def test_truncated_archive_raises_corruption_error(self, tmp_path):
        ckpt_lib.save(str(tmp_path), 3, _tree())
        assert chaos_lib.truncate_latest(str(tmp_path))
        with pytest.raises(ckpt_lib.CheckpointCorruptionError,
                           match="truncated or unreadable"):
            ckpt_lib.restore(str(tmp_path), _tree())

    def test_silent_bitflip_caught_by_checksum(self, tmp_path):
        path = ckpt_lib.save(str(tmp_path), 0, _tree())
        npz = os.path.join(path, "arrays.npz")
        with np.load(npz) as z:
            arrays = {k: np.array(z[k]) for k in z.files}
        key = next(k for k, v in arrays.items() if v.size > 1)
        arrays[key].flat[0] += 1.0           # valid zip, flipped payload
        np.savez(npz, **arrays)
        with pytest.raises(ckpt_lib.CheckpointCorruptionError,
                           match="failed integrity check"):
            ckpt_lib.restore(str(tmp_path), _tree())

    def test_pre_checksum_checkpoint_restores_unverified(self, tmp_path):
        """v4 snapshots (no ``checksums``) predate verification and must
        keep restoring — schema explains, it does not reject."""
        path = ckpt_lib.save(str(tmp_path), 0, _tree())
        man_path = os.path.join(path, "manifest.json")
        with open(man_path) as f:
            man = json.load(f)
        del man["checksums"]
        man["schema"] = 4
        with open(man_path, "w") as f:
            json.dump(man, f)
        got, _ = ckpt_lib.restore(str(tmp_path), _tree())
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(_tree()["w"]))

    def test_restore_latest_healthy_walks_past_corruption(self, tmp_path):
        for s in (1, 2, 3):
            ckpt_lib.save(str(tmp_path), s, _tree(s))
        assert chaos_lib.truncate_latest(str(tmp_path))   # step 3 torn
        got, man = ckpt_lib.restore_latest_healthy(str(tmp_path), _tree())
        assert man["step"] == 2
        assert int(got["step"]) == 2
        assert [s["step"] for s in man["skipped_corrupt"]] == [3]
        assert "CheckpointCorruptionError" in man["skipped_corrupt"][0][
            "error"]

    def test_restore_latest_healthy_exhausted_is_actionable(self, tmp_path):
        ckpt_lib.save(str(tmp_path), 1, _tree())
        chaos_lib.truncate_latest(str(tmp_path))
        with pytest.raises(FileNotFoundError, match="no healthy"):
            ckpt_lib.restore_latest_healthy(str(tmp_path), _tree())


# ---------------------------------------------------------------------------
# acceptance claim 1: guards are provably inert on healthy runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", _marked_variants())
def test_health_on_equals_health_off(variant):
    s_off, l_off = _train(variant)
    pol = RemediationPolicy(HealthConfig())
    s_on, l_on = _train(variant, policy_obj=pol)
    _assert_identical(s_off, l_off, s_on, l_on)
    assert pol.actions == []                 # nothing remediated
    assert pol.damping_scale == 1.0


@pytest.mark.parametrize("variant", ["bkfac",
                                     pytest.param(
                                         "rkfac",
                                         marks=pytest.mark.slow)])
def test_health_inert_through_async_pipeline(variant):
    """Same claim with the overlapped launch/land pipeline active (rkfac
    exercises real worker-thread landings; bkfac the no-heavy-op path)."""
    kw = dict(steps=10, async_heavy=True, heavy_lag=2, stagger=True,
              stagger_splits=2, overlap=True)
    s_off, l_off = _train(variant, **kw)
    s_on, l_on = _train(variant, health=True, **kw)
    _assert_identical(s_off, l_off, s_on, l_on)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["bkfac", "nskfac"])
def test_health_inert_sharded(variant):
    """The claim on an 8-device host mesh: the factor checks read the
    post-all-gather states at the outer trace level, so the guarded
    sharded step is the same program as the unguarded one."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = mesh_lib.make_mesh((8,), ("curv",))
    s_off, l_off = _train(variant, mesh=mesh, curvature_axis="curv")
    s_on, l_on = _train(variant, health=True, mesh=mesh,
                        curvature_axis="curv")
    _assert_identical(s_off, l_off, s_on, l_on)


def test_healthy_run_health_metrics_all_zero(tmp_path):
    """With telemetry attached, the guard's metric channels flush as
    exact zeros on a healthy run — the observable form of inertness."""
    path = str(tmp_path / "events.jsonl")
    with ev_lib.TelemetryWriter(path, console=False) as w:
        _train("bkfac", health=True, writer=w, metrics_every=3)
    metrics = [e for e in ev_lib.read_events(path)
               if e["type"] == "metrics"]
    assert metrics
    for e in metrics:
        assert e["values"]["health/guard_trips"] == 0.0
        assert e["values"]["health/grad_nonfinite"] == 0.0


# ---------------------------------------------------------------------------
# acceptance claim 2: every fault class ends in documented remediation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", _marked_variants())
def test_nan_grad_recovery_ladder(variant):
    """Three consecutive poisoned batches: the guard skips each (losses
    at the fault steps are NaN, params never move), damping escalates
    twice, the streak forces an out-of-cadence refresh, and four healthy
    steps later the damping de-escalates back to exactly 1.0."""
    chaos = ChaosMonkey(tuple(Fault(k, "nan_grad") for k in (3, 4, 5)))
    pol = RemediationPolicy(HealthConfig())
    state, losses = _train(variant, steps=12, policy_obj=pol, chaos=chaos)
    assert chaos.summary() == {"nan_grad": 3}
    assert pol.count("skip") == 3
    assert pol.count("escalate") == 2
    assert pol.count("refresh") == 1
    assert pol.count("deescalate") == 1
    assert pol.damping_scale == 1.0
    for k, loss in enumerate(losses):
        assert np.isfinite(loss) == (k not in (3, 4, 5)), (k, loss)
    assert _all_finite(state.params)
    assert _all_finite(state.opt.factors)


@pytest.mark.slow
def test_corrupt_inflight_lands_guarded(tmp_path):
    """A fully poisoned in-flight snapshot whose landing is forced onto
    the in-graph fallback (futures dropped): the guard catches the NaN
    factor swap, a follow-up faulty streak forces the stage-2 refresh,
    and the final factor states are finite — poison never sticks."""
    faults = (Fault(5, "corrupt_inflight"), Fault(5, "drop_landing"),
              Fault(6, "drop_landing"), Fault(7, "drop_landing"),
              Fault(7, "nan_grad"), Fault(8, "nan_grad"))
    chaos = ChaosMonkey(faults)
    path = str(tmp_path / "events.jsonl")
    with ev_lib.TelemetryWriter(path, console=False) as w:
        pol = RemediationPolicy(HealthConfig(), writer=w)
        state, losses = _train(
            "rkfac", steps=14, policy_obj=pol, chaos=chaos, overlap=True,
            writer=w, async_heavy=True, heavy_lag=2, stagger=True,
            stagger_splits=1)
    assert chaos.summary()["corrupt_inflight"] == 1
    assert chaos.summary()["drop_landing"] >= 1
    assert pol.count("skip") >= 3            # poisoned land + NaN batches
    assert pol.count("refresh") >= 1         # streak forced the stage-2
    assert _all_finite(state.opt.factors)
    assert _all_finite(state.params)
    misses = [e for e in ev_lib.read_events(path)
              if e["type"] == "async_miss"]
    # every miss is a benign in-graph fallback: "dropped" (tombstoned by
    # the chaos drop / the refresh) or "resume" (a landing whose pending
    # launch the stage-2 refresh wiped before it tombstoned)
    assert misses
    assert {e["reason"] for e in misses} <= {"dropped", "resume"}
    assert any(e["reason"] == "dropped" for e in misses)


class TestRunnerDeadline:
    def _runner(self, tmp_path, **kw):
        params, taps = _make_mlp()
        opt = kfac_lib.Kfac(_cfg("rkfac", async_heavy=True, heavy_lag=2,
                                 stagger=True), taps)
        sched = opt.scheduler()
        work = next(sched.work(k) for k in range(1, 32)
                    if any(sched.work(k).land))
        writer = ev_lib.TelemetryWriter(str(tmp_path / "e.jsonl"),
                                        console=False)
        return (loop.AsyncInverseRunner(opt, writer=writer, **kw),
                work, writer)

    def _keys(self, work):
        return [(bi, lo, hi) for bi, rs in enumerate(work.land)
                for lo, hi in rs]

    def test_deadline_tracks_median_heavy_time(self, tmp_path):
        r, _, w = self._runner(tmp_path, deadline_factor=4.0,
                               min_deadline_s=0.001)
        assert r._deadline() == 60.0         # no observation yet: fixed cap
        r._durations = [1.0, 2.0, 3.0]
        assert r._deadline() == 8.0          # 4 × median
        r.deadline_s = 0.25
        assert r._deadline() == 0.25         # explicit override wins
        r.close(); w.close()

    def test_miss_reasons_cover_all_causes(self, tmp_path):
        """timeout (hung worker), crash (dead worker), dropped
        (remediation/elastic discard), resume (restored mid-lag) — each
        miss lands in-graph (None result), is counted by reason, emits
        an event, and hung/dead pools are respawned."""
        r, work, w = self._runner(tmp_path, deadline_s=0.2)
        keys = self._keys(work)
        assert keys, "test premise: the mask has land ranges"

        for key in keys:                                   # hung worker
            r._pending[key] = chaos_lib._hung_future()
        out = r.landing(work, step=6)
        assert all(res is None for rs in out.values() for res in rs)
        assert r.health["miss_reasons"]["timeout"] == len(keys)
        assert r.health["respawns"] == len(keys)

        for key in keys:                                   # dead worker
            r._pending[key] = chaos_lib._DeadFuture()
        r.landing(work, step=10)
        assert r.health["miss_reasons"]["crash"] == len(keys)

        for key in keys:                                   # dropped
            r._pending[key] = chaos_lib._hung_future()
        r.drop_pending()
        assert not r._pending
        r.landing(work, step=14)
        assert r.health["miss_reasons"]["dropped"] == len(keys)

        r.landing(work, step=18)                           # fresh resume
        assert r.health["miss_reasons"]["resume"] == len(keys)
        assert r.health["missed"] == 4 * len(keys)
        r.close(); w.close()
        evs = [e for e in ev_lib.read_events(str(tmp_path / "e.jsonl"))
               if e["type"] == "async_miss"]
        assert {e["reason"] for e in evs} == {"timeout", "crash",
                                              "dropped", "resume"}


@pytest.mark.slow
def test_hung_and_dead_workers_do_not_change_numbers(monkeypatch):
    """Integration: hang one landing's workers and kill another's
    mid-run.  Both miss within the (shortened) deadline, the pool
    respawns, every miss lands in-graph — and because the fallback is
    pure, the harassed overlapped run matches the plain in-graph run."""
    kw = dict(async_heavy=True, heavy_lag=2, stagger=True,
              stagger_splits=1)
    _, ref_losses = _train("rkfac", steps=14, **kw)

    orig = loop.AsyncInverseRunner.for_opt.__func__
    seen = {}

    def patched(cls, opt, writer=None):
        r = orig(cls, opt, writer=writer)
        if r is not None:
            r.deadline_s = 0.3
            seen["runner"] = r
        return r

    monkeypatch.setattr(loop.AsyncInverseRunner, "for_opt",
                        classmethod(patched))
    chaos = ChaosMonkey((Fault(6, "hang_landing"),
                         Fault(10, "worker_death")))
    _, losses = _train("rkfac", steps=14, overlap=True, chaos=chaos,
                       **kw)
    assert chaos.summary() == {"hang_landing": 1, "worker_death": 1}
    health = seen["runner"].health
    assert health["miss_reasons"].get("timeout", 0) >= 1
    assert health["miss_reasons"].get("crash", 0) >= 1
    assert health["respawns"] >= 2
    np.testing.assert_allclose(losses, ref_losses, rtol=1e-5, atol=1e-7)


@pytest.mark.slow
def test_truncated_checkpoint_rollback(tmp_path):
    """A 7-step NaN streak exhausts the ladder into a rollback while the
    newest snapshot was torn on disk: the restore walks the ring past it
    to the older healthy snapshot, re-anchors the schedule, and training
    finishes healthy.  The whole story must validate as telemetry."""
    ckpt_dir = str(tmp_path / "ckpt")
    path = str(tmp_path / "events.jsonl")
    faults = tuple(Fault(k, "nan_grad") for k in range(3, 10)) \
        + (Fault(2, "truncate_ckpt"),)
    chaos = ChaosMonkey(faults)
    with ev_lib.TelemetryWriter(path, console=False) as w:
        pol = RemediationPolicy(HealthConfig(), writer=w)
        state, losses = _train("bkfac", steps=14, policy_obj=pol,
                               chaos=chaos, writer=w, ckpt_dir=ckpt_dir,
                               ckpt_every=2)
    assert chaos.summary()["truncate_ckpt"] == 1
    assert pol.count("rollback") == 1
    assert pol.count("restored") == 1
    restored = next(a for a in pol.actions if a["action"] == "restored")
    assert "healthy step 0" in restored["detail"]    # walked past step 2
    assert np.isfinite(losses[-1])
    assert _all_finite(state.params)
    # the event log tells the same story, and validates
    evs = list(ev_lib.read_events(path))
    assert [e["type"] for e in evs].count("ckpt_restore") == 1
    assert sum_lib.main([path, "--validate"]) == 0
    report = sum_lib.summarize(path)
    res = report["resilience"]
    assert res["remediations"] == len(pol.actions)
    assert res["actions"]["rollback"] == 1


# ---------------------------------------------------------------------------
# elastic recovery: real-topology ladder, repartition events, host loss
# ---------------------------------------------------------------------------

class TestElasticLadder:
    def test_device_ladder_halves_to_one(self):
        ladder = elastic.device_ladder(8)
        assert ladder == (((8,), ("data",)), ((4,), ("data",)),
                          ((2,), ("data",)), ((1,), ("data",)))

    def test_device_ladder_trailing_axes_stay_one(self):
        ladder = elastic.device_ladder(4, axes=("data", "model"))
        assert ladder[0] == ((4, 1), ("data", "model"))
        assert ladder[-1] == ((1, 1), ("data", "model"))

    def test_device_ladder_defaults_to_real_devices(self):
        ladder = elastic.device_ladder()
        assert ladder[0][0][0] == len(jax.devices())

    def test_runner_emits_repartition_and_remediation(self, tmp_path):
        path = str(tmp_path / "e.jsonl")

        def make_state(mesh):
            return {"x": jnp.zeros(())}

        def make_step(mesh):
            return lambda state, k: {"x": state["x"] + 1}

        with ev_lib.TelemetryWriter(path, console=False) as w:
            inj = elastic.FailureInjector(fail_at=[3])
            runner = elastic.ElasticRunner(
                ckpt_dir=str(tmp_path / "ckpt"), make_state=make_state,
                make_step=make_step, ckpt_every=1,
                meshes=(((1,), ("data",)), ((1,), ("data",))),
                injector=inj, writer=w)
            _, info = runner.run(6)
        assert info["restarts"] == 1
        evs = list(ev_lib.read_events(path))
        reparts = [e for e in evs if e["type"] == "repartition"]
        assert len(reparts) == 2             # initial mesh + post-failure
        remeds = [e for e in evs if e["type"] == "remediation"]
        assert len(remeds) == 1
        assert remeds[0]["stage"] == STAGE_ELASTIC
        assert remeds[0]["action"] == "repartition"


def test_straggler_mitigations_join_remediation_stream(tmp_path):
    path = str(tmp_path / "e.jsonl")
    with ev_lib.TelemetryWriter(path, console=False) as w:
        det = straggler.StragglerDetector(patience=3, rebalance_after=6,
                                          writer=w)
        for k in range(12):
            times = {f"h{i}": 1.0 for i in range(4)}
            if k >= 4:
                times["h2"] = 3.0
            det.observe_step(k, times)
    evs = [e for e in ev_lib.read_events(path)
           if e["type"] == "remediation"]
    assert evs and all(e["stage"] == STAGE_ELASTIC for e in evs)
    actions = {e["action"] for e in evs}
    assert "drop_stats" in actions and "rebalance" in actions
    assert all("straggler h2" in e["detail"] for e in evs)


@pytest.mark.slow
def test_host_loss_mid_cycle_resumes_phase_on_shrunk_mesh(tmp_path):
    """Kill the host mid-stagger-cycle on an 8-device mesh; resume on a
    4-device mesh from the last checkpoint.  The schedule must pick up
    from ``KfacState.phase``: the resumed run's work cadence (step-event
    phase labels) continues the uninterrupted run's exactly — in
    particular the first resumed step is NOT the warmup heavy spike —
    and the suffix losses track the replicated reference."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    steps, fail_at, ckpt_dir = 12, 7, str(tmp_path / "ckpt")
    kw = dict(stagger=True, stagger_splits=1)
    ref_path = str(tmp_path / "ref.jsonl")
    with ev_lib.TelemetryWriter(ref_path, console=False) as w:
        _, ref_losses = _train("rkfac", steps=steps, writer=w, **kw)
    ref_labels = [e["phase"] for e in ev_lib.read_events(ref_path)
                  if e["type"] == "step"]

    mesh8 = mesh_lib.make_mesh((8,), ("curv",))
    chaos = ChaosMonkey((Fault(fail_at, "host_loss"),))
    with pytest.raises(RuntimeError, match="injected node failure"):
        _train("rkfac", steps=steps, mesh=mesh8, curvature_axis="curv",
               chaos=chaos, ckpt_dir=ckpt_dir, ckpt_every=2, **kw)
    assert ckpt_lib.latest_step(ckpt_dir) == 6

    # survivors: half the devices; fresh optimizer, restored state
    mesh4 = mesh_lib.make_mesh((4,), ("curv",))
    params, taps = _make_mlp()
    opt = kfac_lib.Kfac(_cfg("rkfac", **kw), taps)
    template = loop.TrainState(params=params, opt=opt.init(params),
                               rng=jax.random.PRNGKey(0))
    restored, man = ckpt_lib.restore_latest_healthy(ckpt_dir, template)
    assert man["step"] == 6 and man["skipped_corrupt"] == []
    res_path = str(tmp_path / "resumed.jsonl")
    with ev_lib.TelemetryWriter(res_path, console=False) as w:
        state, tail = loop.run_kfac_training(
            _mlp_loss, opt, None, _batches(steps)[man["step"] + 1:],
            n_tokens=N_BS, state=restored,
            dist=specs.DistSpec(mesh=mesh4, curvature_axis="curv"),
            obs=specs.ObsSpec(writer=w))
    res_labels = [e["phase"] for e in ev_lib.read_events(res_path)
                  if e["type"] == "step"]
    # cadence resumes mid-cycle: label-for-label the uninterrupted tail,
    # and NOT a from-scratch restart (whose first step is the warmup
    # heavy spike)
    assert res_labels == ref_labels[man["step"] + 1:]
    warm_label = opt.scheduler().work(0).label
    assert res_labels[0] != warm_label
    assert _all_finite(state.params)
    np.testing.assert_allclose(tail, ref_losses[man["step"] + 1:],
                               rtol=5e-3, atol=1e-5)

@pytest.mark.slow
def test_host_loss_mid_cycle_2d_mesh_compressed_collectives(tmp_path):
    """The 2D-mesh variant of the host-loss drill, with the curvature
    engine's (U, λ) gathers riding rank-q PowerSGD factors: kill the
    host mid-stagger-cycle on a 4×2 data × curv mesh, resume on the 2×2
    rung (a dropped data row).  Compression is per-slot with a
    deterministic seeded basis, so it is mesh-shape-invariant: the
    resumed compressed run must track the uninterrupted compressed 4×2
    reference, cadence resuming from ``KfacState.phase`` (no warmup
    spike), losses and params finite."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    steps, fail_at, ckpt_dir = 12, 7, str(tmp_path / "ckpt")
    kw = dict(stagger=True, stagger_splits=1)
    mesh42 = mesh_lib.make_mesh((4, 2), ("data", "curv"))
    ref_path = str(tmp_path / "ref.jsonl")
    with ev_lib.TelemetryWriter(ref_path, console=False) as w:
        _, ref_losses = _train("rkfac", steps=steps, mesh=mesh42,
                               curvature_axis="curv", row_axis="data",
                               curvature_compress=6, writer=w, **kw)
    ref_labels = [e["phase"] for e in ev_lib.read_events(ref_path)
                  if e["type"] == "step"]

    chaos = ChaosMonkey((Fault(fail_at, "host_loss"),))
    with pytest.raises(RuntimeError, match="injected node failure"):
        _train("rkfac", steps=steps, mesh=mesh42, curvature_axis="curv",
               row_axis="data", curvature_compress=6, chaos=chaos,
               ckpt_dir=ckpt_dir, ckpt_every=2, **kw)
    assert ckpt_lib.latest_step(ckpt_dir) == 6

    # survivors: the 2×2 ladder rung — the data axis shrank
    ladder = elastic.device_ladder(8, axes=("data", "curv"),
                                   shape=(4, 2))
    assert ladder[1][0] == (2, 2)
    assert elastic.shrunk_axes(ladder[0][0], ladder[1][0],
                               ("data", "curv")) == ("data",)
    mesh22 = mesh_lib.make_mesh((2, 2), ("data", "curv"))
    params, taps = _make_mlp()
    opt = kfac_lib.Kfac(_cfg("rkfac", **kw), taps)
    template = loop.TrainState(params=params, opt=opt.init(params),
                               rng=jax.random.PRNGKey(0))
    restored, man = ckpt_lib.restore_latest_healthy(ckpt_dir, template)
    assert man["step"] == 6 and man["skipped_corrupt"] == []
    res_path = str(tmp_path / "resumed.jsonl")
    with ev_lib.TelemetryWriter(res_path, console=False) as w:
        state, tail = loop.run_kfac_training(
            _mlp_loss, opt, None, _batches(steps)[man["step"] + 1:],
            n_tokens=N_BS, state=restored,
            dist=specs.DistSpec(mesh=mesh22, curvature_axis="curv",
                                row_axis="data", curvature_compress=6),
            obs=specs.ObsSpec(writer=w))
    res_labels = [e["phase"] for e in ev_lib.read_events(res_path)
                  if e["type"] == "step"]
    assert res_labels == ref_labels[man["step"] + 1:]
    warm_label = opt.scheduler().work(0).label
    assert res_labels[0] != warm_label
    assert _all_finite(state.params)
    np.testing.assert_allclose(tail, ref_losses[man["step"] + 1:],
                               rtol=5e-3, atol=1e-5)

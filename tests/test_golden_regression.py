"""Golden-path regression: the paper's accuracy ordering of the K-factor
modes.

50 EA steps of each ``kfactor.Mode`` on a synthetic power-law spectrum with
fixed seeds, then the inverse application of each mode's low-rank state is
compared against the dense solve (``precond.dense_inv_apply`` semantics,
single factor).  The paper's ordering must hold:

    EVD ≤ RSVD ≤ BRAND_CORR ≤ BRAND

Setup notes (what makes the comparison apples-to-apples):
  * all approximate modes hold the same apply width w = r + n_stat
    (RSVD gets r=w; Brand modes hold r truncated + n_stat fresh);
  * EVD runs at full rank — the K-FAC baseline's inverse is exact, so its
    error is ~0 by construction;
  * every mode does its heavy op on the last step, so nobody is compared
    on a stale inverse representation;
  * BRAND_CORR corrects over the full retained basis (n_crc = r) — the
    strongest correction the schedule allows.  On a stationary spectrum
    the correction's gain over pure BRAND is small, so that link in the
    chain is asserted with a 1% slack while the others are strict.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kfactor, precond
from repro.core.kfactor import KFactorSpec, Mode

D, R, N_STAT, RHO, STEPS, T_HEAVY = 96, 12, 12, 0.85, 50, 10
DECAY, PHI, SEED = 0.8, 0.3, 0


def _stats_factors():
    """50 stats factors X_k = M½ Z_k drawn from a fixed power-law spectrum."""
    key = jax.random.PRNGKey(SEED)
    lam_true = jnp.power(jnp.arange(1, D + 1, dtype=jnp.float32), -DECAY)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (D, D)))
    L = Q * jnp.sqrt(lam_true)
    Z = jax.random.normal(jax.random.fold_in(key, 100),
                          (STEPS, D, N_STAT)) / np.sqrt(N_STAT)
    return L @ Z, key


@functools.partial(jax.jit, static_argnames=("spec",))
def _run_mode(spec: KFactorSpec, Xs, key):
    def step(st, inp):
        k, X = inp
        first = k == 0
        st = kfactor.stats_step(spec, st, X, first)
        heavy = jnp.logical_or(k % T_HEAVY == 0, k == STEPS - 1)
        st = kfactor.inverse_rep_step(spec, st, X, jax.random.fold_in(key, k),
                                      first, heavy)
        return st, ()

    st, _ = jax.lax.scan(step, spec.init(),
                         (jnp.arange(Xs.shape[0]), Xs))
    return st


def test_mode_accuracy_ordering():
    Xs, key = _stats_factors()
    M_exact = kfactor.exact_ea(list(Xs), RHO)
    lam = PHI * float(jnp.max(jnp.linalg.eigvalsh(M_exact)))
    J = jnp.eye(D)
    # single-factor dense reference: Γ side trivial (zero factor, λ_g = 1)
    want = precond.dense_inv_apply(J, jnp.zeros((D, D)), 1.0, M_exact, lam)

    w = R + N_STAT
    errs = {}
    for mode in (Mode.EVD, Mode.RSVD, Mode.BRAND_CORR, Mode.BRAND):
        r = {Mode.EVD: D, Mode.RSVD: w}.get(mode, R)
        spec = KFactorSpec(d=D, r=r, n_stat=N_STAT, mode=mode, rho=RHO,
                           n_crc=(R if mode is Mode.BRAND_CORR else 0))
        st = _run_mode(spec, Xs, key)
        got = precond.apply_inv_right(J, st.U, st.D, jnp.asarray(lam))
        errs[mode] = float(jnp.linalg.norm(got - want) /
                           jnp.linalg.norm(want))

    # NS holds the dense damped inverse in U — its application is a plain
    # GEMM (J @ U), compared against the same dense solve.  NS's own λ̂
    # (ns_phi·λ_max via power iteration) matches the eigh-derived lam above
    # to ~1e-6, so NS sits at EVD-level accuracy: assert it beats every
    # truncated mode, but NOT that EVD ≤ NS (both are exact-level and may
    # swap within float noise).
    spec_ns = KFactorSpec(d=D, r=R, n_stat=N_STAT, mode=Mode.NS, rho=RHO,
                          ns_phi=PHI)
    st_ns = _run_mode(spec_ns, Xs, key)
    errs[Mode.NS] = float(jnp.linalg.norm(J @ st_ns.U - want) /
                          jnp.linalg.norm(want))
    # converged, no fallback
    assert float(st_ns.aux[kfactor.AUX_RES]) < kfactor._NS_RES_MAX

    assert all(np.isfinite(list(errs.values())))
    # K-FAC's exact inverse is essentially error-free...
    assert errs[Mode.EVD] < 1e-4, errs
    # ...and so is a converged Newton–Schulz refinement of it
    assert errs[Mode.NS] < 1e-4, errs
    assert errs[Mode.NS] <= errs[Mode.RSVD], errs
    # ...RSVD pays the rank truncation...
    assert errs[Mode.EVD] <= errs[Mode.RSVD], errs
    # ...Brand modes additionally pay the compounded online truncation...
    assert errs[Mode.RSVD] <= errs[Mode.BRAND_CORR], errs
    # ...and the correction must not lose to pure Brand (1% slack: on a
    # stationary spectrum the two nearly coincide).
    assert errs[Mode.BRAND_CORR] <= errs[Mode.BRAND] * 1.01, errs
    # the chain is also materially separated where the paper says it is
    assert errs[Mode.RSVD] < 0.95 * errs[Mode.BRAND], errs

"""Cross-layer bucketing (core/buckets.py + the bucketed Kfac hot path):
shape-class grouping rules, gather/scatter round-trips, and bucketed
vs per-tap parity of full optimizer steps on a mixed-shape model
(FC + scanned stack + two-level MoE stack + linear-apply tap).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import buckets, kfac as kfac_lib, kfactor, policy
from repro.optim import base as optbase


def _mixed_taps(N=16):
    """FC + unrolled twin + scanned stack + MoE stack: the 48-wide
    specs share a class; the 32-wide G sides share another."""
    return {
        "fc":   kfac_lib.TapInfo("fc/w", 48, 32, n_stat=N),
        "fc2":  kfac_lib.TapInfo("fc2/w", 48, 32, n_stat=N),
        "scan": kfac_lib.TapInfo("scan/w", 48, 48, stack=(3,), n_stat=N),
        "moe":  kfac_lib.TapInfo("moe/w", 48, 32, stack=(2, 2), n_stat=N),
    }


def _data(taps, key=None):
    if key is None:
        key = jax.random.PRNGKey(0)
    params, grads, acts, pgs = {}, {}, {}, {}
    for i, (n, t) in enumerate(taps.items()):
        shp = t.stack + (t.d_in, t.d_out)
        params[n] = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                            shp) * 0.05}
        grads[n] = {"w": jax.random.normal(jax.random.fold_in(key, 10 + i),
                                           shp)}
        acts[n] = jax.random.normal(jax.random.fold_in(key, 20 + i),
                                    t.stack + (t.n_stat, t.d_in))
        pgs[n] = jax.random.normal(jax.random.fold_in(key, 30 + i),
                                   t.stack + (t.n_stat, t.d_out)) * 1e-3
    return params, grads, acts, pgs


def _run(taps, variant, bucketed, steps=2, heavy_every=2, r=8,
         max_dense_dim=8192, use_kernels=False, momentum=0.9):
    pol = policy.PolicyConfig(variant=variant, r=r,
                              max_dense_dim=max_dense_dim)
    cfg = kfac_lib.KfacConfig(policy=pol, lr=optbase.constant(0.05),
                              momentum=momentum, T_updt=1, T_brand=1,
                              bucketed=bucketed, use_kernels=use_kernels)
    opt = kfac_lib.Kfac(cfg, taps)
    params, grads, acts, pgs = _data(taps)
    st = opt.init(params)
    key = jax.random.PRNGKey(7)
    outs = []
    for s in range(steps):
        upd, st = opt.update(grads, st, params, acts=acts, probe_grads=pgs,
                             n_tokens=list(taps.values())[0].n_stat,
                             rng=jax.random.fold_in(key, s),
                             work=opt.uniform_work(
                                 True, True, s % heavy_every == 0))
        outs.append(upd)
    return opt, outs


# ---------------------------------------------------------------------------
# bucket construction rules
# ---------------------------------------------------------------------------

def test_factor_buckets_group_by_spec():
    taps = _mixed_taps()
    pol = policy.PolicyConfig(variant="bkfac", r=8, max_dense_dim=8192)
    opt = kfac_lib.Kfac(kfac_lib.KfacConfig(policy=pol), taps)
    fb = opt.factor_buckets
    # d=48 A-sides of fc/fc2/moe + both sides of scan share one spec;
    # d=32 G-sides of fc/fc2/moe share another.
    assert len(fb) == 2
    by_d = {b.spec.d: b for b in fb}
    assert by_d[32].total == 1 + 1 + 4            # fc, fc2, moe G-sides
    assert by_d[48].total == 1 + 1 + 3 + 3 + 4    # A-sides + scan both sides
    # deterministic entry layout: offsets tile the batch exactly
    for b in fb:
        assert b.entries[0].offset == 0
        for e0, e1 in zip(b.entries, b.entries[1:]):
            assert e1.offset == e0.offset + e0.count
        assert b.entries[-1].offset + b.entries[-1].count == b.total


def test_precond_buckets_group_by_spec_pair_and_apply_mode():
    taps = _mixed_taps()
    taps = dict(taps, lin=kfac_lib.TapInfo("lin/w", 48, 32, n_stat=16,
                                           linear_apply=True))
    pol = policy.PolicyConfig(variant="bkfac", r=8, max_dense_dim=8192)
    opt = kfac_lib.Kfac(kfac_lib.KfacConfig(policy=pol), taps)
    pb = opt.precond_buckets
    # (48→32) quadratic {fc, fc2, moe}, (48→48) {scan}, (48→32) linear {lin}
    assert len(pb) == 3
    sizes = sorted((b.total, b.linear_apply) for b in pb)
    assert sizes == [(1, True), (3, False), (6, False)]


def test_odd_shape_falls_out_into_singleton_bucket():
    taps = _mixed_taps()
    taps = dict(taps, odd=kfac_lib.TapInfo("odd/w", 80, 48, n_stat=16))
    pol = policy.PolicyConfig(variant="bkfac", r=8, max_dense_dim=8192)
    opt = kfac_lib.Kfac(kfac_lib.KfacConfig(policy=pol), taps)
    d80 = [b for b in opt.factor_buckets if b.spec.d == 80]
    assert len(d80) == 1 and d80[0].total == 1


# ---------------------------------------------------------------------------
# gather / scatter round-trips
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_gather_scatter_roundtrip():
    entries = (buckets.Entry("a", "A", (), 0, 1),
               buckets.Entry("b", "A", (2, 3), 1, 6),
               buckets.Entry("c", "G", (4,), 7, 4))
    key = jax.random.PRNGKey(1)
    leaves = {("a", "A"): jax.random.normal(key, (5, 7)),
              ("b", "A"): jax.random.normal(key, (2, 3, 5, 7)),
              ("c", "G"): jax.random.normal(key, (4, 5, 7))}
    batched = buckets.gather(entries, leaves)
    assert batched.shape == (11, 5, 7)
    back = buckets.scatter(entries, batched)
    for k, v in leaves.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(v))


def test_gather_scatter_states_roundtrip():
    entries = (buckets.Entry("a", "A", (), 0, 1),
               buckets.Entry("b", "G", (2,), 1, 2))
    spec = kfactor.KFactorSpec(d=16, r=4, n_stat=4, mode=kfactor.Mode.BRAND)
    sts = {("a", "A"): spec.init(),
           ("b", "G"): jax.tree_util.tree_map(
               lambda x: jnp.broadcast_to(x, (2,) + x.shape) + 1.0,
               spec.init())}
    big = buckets.gather_states(entries, sts)
    assert big.U.shape == (3,) + sts[("a", "A")].U.shape
    back = buckets.scatter_states(entries, big)
    for k in sts:
        for a, b in zip(jax.tree_util.tree_leaves(back[k]),
                        jax.tree_util.tree_leaves(sts[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bucketed vs per-tap optimizer parity
# ---------------------------------------------------------------------------

def _assert_updates_close(a, b, taps, atol):
    for n in taps:
        x, y = np.asarray(a[n]["w"]), np.asarray(b[n]["w"])
        assert np.isfinite(x).all() and np.isfinite(y).all()
        np.testing.assert_allclose(x, y, atol=atol, rtol=1e-4)


@pytest.mark.slow
def test_bucketed_matches_per_tap_brand_modes():
    """Pure-Brand (deterministic) path: bucketed ≡ per-tap bitwise-ish.

    (slow tier locally; CI's bucketed-parity job runs this file in full —
    the fast tier keeps `test_bucketed_kernel_path_matches_jnp` as the
    end-to-end gate.)"""
    taps = _mixed_taps()
    _, a = _run(taps, "bkfac", bucketed=True)
    _, b = _run(taps, "bkfac", bucketed=False)
    for ua, ub in zip(a, b):
        _assert_updates_close(ua, ub, taps, atol=1e-6)


@pytest.mark.slow
def test_bucketed_matches_per_tap_evd_mode():
    """K-FAC baseline (EVD heavy, deterministic): parity incl. heavy."""
    taps = _mixed_taps()
    _, a = _run(taps, "kfac", bucketed=True)
    _, b = _run(taps, "kfac", bucketed=False)
    for ua, ub in zip(a, b):
        _assert_updates_close(ua, ub, taps, atol=1e-4)


@pytest.mark.slow
def test_bucketed_linear_apply_matches_per_tap():
    taps = {"lin": kfac_lib.TapInfo("lin/w", 48, 32, n_stat=16,
                                    linear_apply=True),
            "lin2": kfac_lib.TapInfo("lin2/w", 48, 32, n_stat=16,
                                     linear_apply=True),
            "fc": kfac_lib.TapInfo("fc/w", 48, 32, n_stat=16)}
    _, a = _run(taps, "bkfac", bucketed=True)
    _, b = _run(taps, "bkfac", bucketed=False)
    for ua, ub in zip(a, b):
        _assert_updates_close(ua, ub, taps, atol=1e-5)


@pytest.mark.slow
def test_bucketed_randomized_heavy_modes_run():
    """brkfac heavy overwrites draw different keys in the two paths, so
    only statistical agreement holds — assert finiteness + magnitudes."""
    taps = _mixed_taps()
    _, a = _run(taps, "brkfac", bucketed=True, r=8)
    _, b = _run(taps, "brkfac", bucketed=False, r=8)
    for ua, ub in zip(a, b):
        for n in taps:
            x, y = np.asarray(ua[n]["w"]), np.asarray(ub[n]["w"])
            assert np.isfinite(x).all() and np.isfinite(y).all()
            assert abs(np.linalg.norm(x) - np.linalg.norm(y)) \
                <= 0.5 * (np.linalg.norm(x) + np.linalg.norm(y))


@pytest.mark.slow
def test_bucketed_kernel_path_matches_jnp(monkeypatch):
    """Bucketed + use_kernels (interpret) ≡ bucketed jnp oracles, end to
    end on the mixed model — the acceptance gate of the PR."""
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    taps = _mixed_taps()
    _, a = _run(taps, "bkfac", bucketed=True, use_kernels=True, steps=2)
    _, b = _run(taps, "bkfac", bucketed=True, use_kernels=False, steps=2)
    for ua, ub in zip(a, b):
        _assert_updates_close(ua, ub, taps, atol=2e-3)
